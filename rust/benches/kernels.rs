//! Kernel microbenchmarks — the perf-pass instrument (EXPERIMENTS.md
//! §Perf). Measures the quantized dot-product hot loop per format, the
//! activation quantizer, and the dense matmul backends.

use elib::quant::act::quantize_activations;
use elib::quant::dot::vec_dot;
use elib::quant::{QTensor, QuantType};
use elib::tensor::Tensor2;
use elib::util::bench::{black_box, Bench};
use elib::util::rng::Rng;

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(1);

    // vec_dot over a 4096-wide row (128 blocks) per format.
    let n = 4096;
    let w: Vec<f32> = rng.normal_vec(n, 0.05);
    let x: Vec<f32> = rng.normal_vec(n, 1.0);
    let act = quantize_activations(&x);
    println!("== quantized vec_dot ({n} elems) ==");
    for q in [
        QuantType::Q4_0,
        QuantType::Q4_1,
        QuantType::Q5_0,
        QuantType::Q5_1,
        QuantType::Q8_0,
        QuantType::F16,
        QuantType::F32,
    ] {
        let t = QTensor::quantize(q, &w, 1, n);
        b.run_with_work(
            &format!("vec_dot/{}", q.name()),
            Some(2.0 * n as f64),
            "FLOP",
            || {
                black_box(vec_dot(q, &t.data, &act));
            },
        );
    }

    println!("\n== activation quantization ==");
    b.run_with_work("quantize_activations/4096", Some(n as f64), "elem", || {
        black_box(quantize_activations(&x));
    });

    println!("\n== dense matmul backends (256x256x256) ==");
    let m = 256;
    let a = Tensor2::from_vec(rng.normal_vec(m * m, 1.0), m, m);
    let c = Tensor2::from_vec(rng.normal_vec(m * m, 1.0), m, m);
    let flops = Tensor2::matmul_flops(m, m, m);
    b.run_with_work("matmul/naive", Some(flops), "FLOP", || {
        black_box(a.matmul_naive(&c));
    });
    for t in [1usize, 2, 4, 8] {
        b.run_with_work(&format!("matmul/blocked_t{t}"), Some(flops), "FLOP", || {
            black_box(a.matmul_blocked(&c, t));
        });
    }

    println!("\n== qmatvec through the kernel layer (352x128, all formats) ==");
    use elib::kernel::{BackendKind, Dispatcher};
    let rows = 352;
    let cols = 128;
    let wsrc = rng.normal_vec(rows * cols, 0.05);
    let xv = rng.normal_vec(cols, 1.0);
    let mut out = vec![0f32; rows];
    for q in [QuantType::Q4_0, QuantType::Q8_0] {
        let wt = QTensor::quantize(q, &wsrc, rows, cols);
        for kind in [BackendKind::Naive, BackendKind::Parallel(4)] {
            let d = Dispatcher::new(kind);
            b.run_with_work(
                &format!("qmatvec/{}/{}", q.name(), kind.label()),
                Some(2.0 * (rows * cols) as f64),
                "FLOP",
                || {
                    d.qmatvec(&wt, &xv, &mut out);
                    black_box(out[0]);
                },
            );
        }
    }
}
