//! Figure 3 reproduction: (a) FLOPS accelerated vs non-accelerated,
//! (b) 4 threads vs 8 threads — both as *real host measurements* of the
//! dense matmul benchmark (the paper's own FLOPS workload) and as the
//! simulated per-device series.
//!
//!     cargo bench --bench fig3_flops

use elib::device::{Accel, DeviceSpec};
use elib::tensor::Tensor2;
use elib::util::bench::{black_box, Bench};
use elib::util::rng::Rng;
use elib::util::table::{f2, Table};

fn main() {
    // --- real host measurement (mat-mat multiply, as §5.2.1) ----------
    let mut b = Bench::new();
    let mut rng = Rng::new(3);
    let m = 192;
    let a = Tensor2::from_vec(rng.normal_vec(m * m, 1.0), m, m);
    let c = Tensor2::from_vec(rng.normal_vec(m * m, 1.0), m, m);
    let flops = Tensor2::matmul_flops(m, m, m);
    println!("== host FLOPS (this machine, {m}^3 matmul) ==");
    let naive = b
        .run_with_work("host/naive(t1)", Some(flops), "FLOP", || {
            black_box(a.matmul_naive(&c));
        })
        .throughput()
        .unwrap();
    let mut by_threads = Vec::new();
    for t in [1usize, 4, 8] {
        let r = b
            .run_with_work(&format!("host/blocked(t{t})"), Some(flops), "FLOP", || {
                black_box(a.matmul_blocked(&c, t));
            })
            .throughput()
            .unwrap();
        by_threads.push((t, r));
    }
    let t4 = by_threads.iter().find(|(t, _)| *t == 4).unwrap().1;
    println!(
        "\nhost: blocked(t4) is {:.2}x naive — the Fig-3a acceleration effect\n",
        t4 / naive
    );

    // --- simulated devices (Fig 3a + 3b series) ------------------------
    let mut ta = Table::new(&["Device", "CPU none t4", "CPU accel t4", "GPU"])
        .left_cols(1)
        .title("Figure 3a (simulated devices), GFLOPS");
    let mut tb = Table::new(&["Device", "Accel", "t4", "t8", "t4/t8"])
        .left_cols(2)
        .title("Figure 3b (simulated devices), GFLOPS");
    for d in DeviceSpec::paper_devices() {
        ta.row(vec![
            d.name.into(),
            f2(d.matmul_gflops(Accel::CpuNone, 4)),
            f2(d.matmul_gflops(Accel::CpuBlas, 4)),
            f2(d.matmul_gflops(Accel::Gpu, 4)),
        ]);
        for (accel, label) in [(Accel::CpuNone, "None"), (Accel::CpuBlas, "BLAS")] {
            let f4 = d.matmul_gflops(accel, 4);
            let f8 = d.matmul_gflops(accel, 8);
            tb.row(vec![
                d.name.into(),
                label.into(),
                f2(f4),
                f2(f8),
                f2(f4 / f8),
            ]);
        }
    }
    println!("{}", ta.render());
    println!("{}", tb.render());
    std::fs::create_dir_all("target/bench-out").unwrap();
    std::fs::write("target/bench-out/fig3a.csv", ta.to_csv()).unwrap();
    std::fs::write("target/bench-out/fig3b.csv", tb.to_csv()).unwrap();

    // Shape checks: accel > none everywhere; t4 >= t8 on BLAS rows.
    for d in DeviceSpec::paper_devices() {
        assert!(d.matmul_gflops(Accel::CpuBlas, 4) > d.matmul_gflops(Accel::CpuNone, 4));
        assert!(d.matmul_gflops(Accel::Gpu, 4) > d.matmul_gflops(Accel::CpuBlas, 4));
        assert!(d.matmul_gflops(Accel::CpuBlas, 4) >= d.matmul_gflops(Accel::CpuBlas, 8));
    }
    println!("fig3 shape checks OK");
}
