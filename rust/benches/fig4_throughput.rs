//! Figure 4 reproduction: inference throughput (tok/s) per device ×
//! accelerator × quantization. Host side measures the *real* native
//! engine on every format/backend; device side prices the 7B workload.
//!
//!     make artifacts && cargo bench --bench fig4_throughput

use elib::coordinator::flow;
use elib::device::{Accel, DeviceSpec, Workload};
use elib::graph::{generate, Engine, Sampler};
use elib::kernel::BackendKind;
use elib::model::{LlamaConfig, ModelWeights};
use elib::quant::QuantType;
use elib::util::table::{f2, Table};

fn main() {
    // --- real host throughput per quant × backend ----------------------
    let (cfg, dense) = flow::load_original(std::path::Path::new(
        "artifacts/tiny_llama_f32.eguf",
    ))
    .expect("run `make artifacts` first");
    let mut th = Table::new(&[
        "quant", "bytes/token", "naive tok/s", "parallel(t4) tok/s", "speedup",
    ])
    .left_cols(1)
    .title("host: real decode throughput (trained tiny model, 32 tokens)");
    let mut bytes_q4 = 0u64;
    let mut bytes_q8 = 0u64;
    for q in QuantType::PAPER_SET {
        let mf = elib::model::testutil::build_model_file(&cfg, q, &dense);
        let bpt = ModelWeights::load(&mf).unwrap().bytes_per_token();
        let mut rates = Vec::new();
        for backend in [BackendKind::Naive, BackendKind::Parallel(4)] {
            let mut e = Engine::new(ModelWeights::load(&mf).unwrap(), backend);
            let stats = generate(&mut e, &[116, 104, 101, 32], 32, &mut Sampler::Greedy).unwrap();
            rates.push(stats.decode_throughput());
        }
        th.row(vec![
            q.name().into(),
            bpt.to_string(),
            f2(rates[0]),
            f2(rates[1]),
            f2(rates[1] / rates[0]),
        ]);
        if q == QuantType::Q4_0 {
            bytes_q4 = bpt;
        }
        if q == QuantType::Q8_0 {
            bytes_q8 = bpt;
        }
    }
    println!("{}", th.render());
    println!(
        "host bytes/token q8_0/q4_0 = {:.2}x — the quantization lever the paper's\n\
         throughput gains come from. NOTE: on this x86 host the 3.4 MB tiny model\n\
         is cache-resident, so decode is NOT memory-bound and host throughput is\n\
         format-insensitive; the memory-bound regime (model >> LLC) is what the\n\
         device simulator prices below (see EXPERIMENTS.md).\n",
        bytes_q8 as f64 / bytes_q4 as f64,
    );

    // --- simulated Fig 4 ------------------------------------------------
    let seven_b = LlamaConfig::llama_7b();
    let mut t = Table::new(&["Quant", "Device", "CPU none", "CPU accel", "GPU"])
        .left_cols(2)
        .title("Figure 4 (simulated devices): throughput, tok/s");
    for q in QuantType::PAPER_SET {
        for d in DeviceSpec::paper_devices() {
            let w = Workload::decode(&seven_b, q, 1, 128);
            let row: Vec<f64> = Accel::ALL
                .iter()
                .map(|a| 1.0 / d.tpot(&w, *a, 4))
                .collect();
            t.row(vec![
                q.name().into(),
                d.name.into(),
                f2(row[0]),
                f2(row[1]),
                f2(row[2]),
            ]);
        }
    }
    println!("{}", t.render());
    std::fs::create_dir_all("target/bench-out").unwrap();
    std::fs::write("target/bench-out/fig4.csv", t.to_csv()).unwrap();

    // Shape checks: q4_0 streams fewer bytes than q8_0 (the mechanism)
    // and beats it on every simulated device/accelerator (the effect in
    // the memory-bound regime).
    assert!(bytes_q4 < bytes_q8, "{bytes_q4} !< {bytes_q8}");
    for d in DeviceSpec::paper_devices() {
        for a in Accel::ALL {
            let w4 = Workload::decode(&seven_b, QuantType::Q4_0, 1, 128);
            let w8 = Workload::decode(&seven_b, QuantType::Q8_0, 1, 128);
            // <= : the compute-bound Xiaomi naive-CPU cell is format-
            // independent (the paper's own Xiaomi anomaly, §5.2.2).
            assert!(d.tpot(&w4, a, 4) <= d.tpot(&w8, a, 4), "{} {a:?}", d.name);
        }
    }
    println!("fig4 shape checks OK");
}
