//! Figure 6 reproduction: inference accuracy (perplexity) per format and
//! backend. The CPU rows are *real*: held-out perplexity of the trained
//! tiny model under each quantization, and the real degraded-GPU backend
//! shows the precision pathology direction; the device rows apply the
//! per-device precision model (OpenCL ≈ 10×, Metal clean).
//!
//!     make artifacts && cargo bench --bench fig6_accuracy

use elib::coordinator::flow;
use elib::device::{Accel, DeviceSpec};
use elib::graph::Engine;
use elib::kernel::{BackendKind, Precision};
use elib::metrics;
use elib::model::ModelWeights;
use elib::quant::QuantType;
use elib::util::table::{f2, Table};

fn main() {
    let (cfg, dense) = flow::load_original(std::path::Path::new(
        "artifacts/tiny_llama_f32.eguf",
    ))
    .expect("run `make artifacts` first");
    let eval = std::fs::read_to_string("artifacts/corpus_eval.txt").unwrap();
    let toks: Vec<u32> = eval.bytes().take(512).map(|b| b as u32).collect();

    let mut th = Table::new(&["quant", "ppl cpu", "ppl gpu-degraded", "degradation"])
        .left_cols(1)
        .title("host: real held-out perplexity (trained tiny model, 512 tokens)");
    let mut cpu_ppls = Vec::new();
    for q in [
        QuantType::F32,
        QuantType::Q8_0,
        QuantType::Q5_1,
        QuantType::Q5_0,
        QuantType::Q4_1,
        QuantType::Q4_0,
    ] {
        let mf = elib::model::testutil::build_model_file(&cfg, q, &dense);
        let mut ppl_by_backend = Vec::new();
        for backend in [
            BackendKind::Naive,
            BackendKind::Gpu(Precision::DegradedF16),
        ] {
            let mut e = Engine::new(ModelWeights::load(&mf).unwrap(), backend);
            let (nll, n) = e.sequence_nll(&toks).unwrap();
            ppl_by_backend.push(metrics::perplexity(nll, n));
        }
        th.row(vec![
            q.name().into(),
            format!("{:.4}", ppl_by_backend[0]),
            format!("{:.4}", ppl_by_backend[1]),
            format!("{:+.2}%", (ppl_by_backend[1] / ppl_by_backend[0] - 1.0) * 100.0),
        ]);
        cpu_ppls.push((q, ppl_by_backend[0], ppl_by_backend[1]));
    }
    println!("{}", th.render());

    // Real quantization effects at this ppl scale (the model is well
    // trained on a simple grammar, so per-format deltas are small):
    // q4_0 must be the worst of the paper set, and q8_0 must be
    // "almost indistinguishable" from f32 (paper Table 4's claims).
    let f32_ppl = cpu_ppls[0].1;
    let q4_0 = cpu_ppls.iter().find(|(q, ..)| *q == QuantType::Q4_0).unwrap().1;
    let q8_0 = cpu_ppls.iter().find(|(q, ..)| *q == QuantType::Q8_0).unwrap().1;
    let worst = cpu_ppls[1..].iter().map(|(_, p, _)| *p).fold(0.0, f64::max);
    assert!(q4_0 >= worst * 0.9999, "q4_0 {q4_0} must be worst (worst {worst})");
    assert!(q4_0 >= q8_0, "q4_0 {q4_0} must be no better than q8_0 {q8_0}");
    assert!(
        (q8_0 / f32_ppl - 1.0).abs() < 0.01,
        "q8_0 {q8_0} must be ~f32 {f32_ppl}"
    );

    // --- simulated Fig 6 (device precision model applied) ---------------
    let mut t = Table::new(&["Quant", "Device", "CPU", "GPU", "GPU/CPU"])
        .left_cols(2)
        .title("Figure 6 (simulated devices): perplexity");
    for (q, cpu_ppl, _) in cpu_ppls.iter().skip(1) {
        for d in DeviceSpec::paper_devices() {
            let gpu = d.simulated_ppl(*cpu_ppl, Accel::Gpu, *q);
            t.row(vec![
                q.name().into(),
                d.name.into(),
                f2(*cpu_ppl),
                f2(gpu),
                f2(gpu / cpu_ppl),
            ]);
        }
    }
    println!("{}", t.render());
    std::fs::create_dir_all("target/bench-out").unwrap();
    std::fs::write("target/bench-out/fig6.csv", t.to_csv()).unwrap();

    // Shape: OpenCL devices blow up ~10x, Metal stays clean (paper Fig 6).
    let nano = DeviceSpec::nanopi();
    let mac = DeviceSpec::macbook();
    assert!(nano.simulated_ppl(6.5, Accel::Gpu, QuantType::Q4_0) > 40.0);
    assert!((mac.simulated_ppl(6.5, Accel::Gpu, QuantType::Q4_0) - 6.5).abs() < 1e-9);
    println!("fig6 shape checks OK");
}
