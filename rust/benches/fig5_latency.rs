//! Figure 5 reproduction: (a) TTLM — time to load model, (b) TTFT —
//! time to first token. Host side measures real EGUF load + real prefill
//! on the tiny model; device side prices the 7B-scale load.
//!
//!     make artifacts && cargo bench --bench fig5_latency

use std::time::Instant;

use elib::coordinator::flow;
use elib::device::{Accel, DeviceSpec, Workload};
use elib::gguf::ModelFile;
use elib::graph::{generate, Engine, Sampler};
use elib::kernel::BackendKind;
use elib::model::{LlamaConfig, ModelWeights};
use elib::quant::QuantType;
use elib::util::table::{f2, f3, Table};

fn main() {
    // --- real host TTLM + TTFT -----------------------------------------
    let (cfg, dense) = flow::load_original(std::path::Path::new(
        "artifacts/tiny_llama_f32.eguf",
    ))
    .expect("run `make artifacts` first");
    let out = std::path::Path::new("target/bench-out/fig5");
    std::fs::create_dir_all(out).unwrap();
    let mut th = Table::new(&["quant", "file bytes", "TTLM host (ms)", "TTFT host (ms)"])
        .left_cols(1)
        .title("host: real model-load + prefill latency (tiny model)");
    for q in QuantType::PAPER_SET {
        let mf = elib::model::testutil::build_model_file(&cfg, q, &dense);
        let path = out.join(format!("m_{}.eguf", q.name()));
        mf.save(&path).unwrap();
        let t0 = Instant::now();
        let loaded = ModelFile::load(&path).unwrap();
        let weights = ModelWeights::load(&loaded).unwrap();
        let ttlm = t0.elapsed().as_secs_f64();
        let mut e = Engine::new(weights, BackendKind::Parallel(4));
        let prompt: Vec<u32> = (0..32u32).map(|i| 97 + i % 24).collect();
        let stats = generate(&mut e, &prompt, 1, &mut Sampler::Greedy).unwrap();
        th.row(vec![
            q.name().into(),
            loaded.tensor_bytes().to_string(),
            f3(ttlm * 1e3),
            f3((stats.prefill_secs + stats.decode_secs[0]) * 1e3),
        ]);
    }
    println!("{}", th.render());

    // --- simulated Fig 5a/5b --------------------------------------------
    let seven_b = LlamaConfig::llama_7b();
    let mut ta = Table::new(&["Quant", "NanoPI", "Xiaomi", "Macbook"])
        .left_cols(1)
        .title("Figure 5a (simulated): TTLM seconds (7B model)");
    let mut tb = Table::new(&["Quant", "Device", "CPU none", "CPU accel", "GPU"])
        .left_cols(2)
        .title("Figure 5b (simulated): TTFT seconds (prompt 32)");
    for q in QuantType::PAPER_SET {
        let w = Workload::decode(&seven_b, q, 1, 128);
        let devs = DeviceSpec::paper_devices();
        ta.row(vec![
            q.name().into(),
            f2(devs[0].ttlm(w.model_bytes)),
            f2(devs[1].ttlm(w.model_bytes)),
            f2(devs[2].ttlm(w.model_bytes)),
        ]);
        for d in &devs {
            let row: Vec<f64> = Accel::ALL
                .iter()
                .map(|a| d.ttft(&w, 32, *a, 4))
                .collect();
            tb.row(vec![
                q.name().into(),
                d.name.into(),
                f2(row[0]),
                f2(row[1]),
                f2(row[2]),
            ]);
        }
    }
    println!("{}", ta.render());
    println!("{}", tb.render());
    std::fs::write("target/bench-out/fig5a.csv", ta.to_csv()).unwrap();
    std::fs::write("target/bench-out/fig5b.csv", tb.to_csv()).unwrap();

    // Shape checks (Fig 5a): TTLM grows with model size on every device;
    // MacBook is ~an order of magnitude faster than NanoPI/Xiaomi.
    let devs = DeviceSpec::paper_devices();
    for d in &devs {
        let w4 = Workload::decode(&seven_b, QuantType::Q4_0, 1, 128);
        let w8 = Workload::decode(&seven_b, QuantType::Q8_0, 1, 128);
        assert!(d.ttlm(w4.model_bytes) < d.ttlm(w8.model_bytes));
    }
    let w = Workload::decode(&seven_b, QuantType::Q4_0, 1, 128);
    assert!(devs[2].ttlm(w.model_bytes) * 5.0 < devs[0].ttlm(w.model_bytes));
    println!("fig5 shape checks OK");
}
