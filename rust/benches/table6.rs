//! Regenerates the paper's Table 6: the full device × accelerator ×
//! quantization grid with FLOPS (t4/t8), throughput, TTLM, TTFT, MBU and
//! perplexity. Writes CSV next to the textual table.
//!
//!     make artifacts && cargo bench --bench table6

use elib::coordinator::{Elib, ElibConfig};
use elib::report;

fn main() {
    let mut cfg = ElibConfig::default();
    cfg.out_dir = "target/bench-out/table6".into();
    cfg.bench.gen_tokens = 16;
    cfg.bench.ppl_tokens = 256;
    let elib = Elib::new(cfg).quiet();
    let (rep, _) = elib.run().expect("run `make artifacts` first");

    let t = report::table6(&rep.records);
    println!("{}", t.render());
    std::fs::write("target/bench-out/table6/table6.csv", t.to_csv()).unwrap();

    // Shape assertions vs the paper (who wins, roughly by how much).
    let recs = &rep.records;
    let get = |d: &str, acc: &str, fw_none: bool, q: &str| {
        recs.iter()
            .find(|r| {
                r.device == d
                    && r.accelerator == acc
                    && (r.framework == "None") == fw_none
                    && r.qtype.name() == q
            })
            .unwrap_or_else(|| panic!("missing row {d}/{acc}/{q}"))
    };
    // 45 rows: 5 quants x 3 devices x 3 accels.
    assert_eq!(recs.len(), 45, "grid must be complete");
    // MacBook dominates throughput on every format.
    for q in ["q4_0", "q8_0"] {
        let mac = get("Macbook", "GPU", false, q).throughput_tok_s;
        let nano = get("NanoPI", "GPU", false, q).throughput_tok_s;
        assert!(mac > 2.0 * nano, "{q}: mac {mac} vs nano {nano}");
    }
    // MBU band 0.25..0.95 on memory-bound cells. The Xiaomi naive-CPU
    // rows are compute-bound (0.23 tok/s), so their *self-consistent*
    // MBU is tiny — note: the paper's own Table 6 lists MBU 0.54 there,
    // which does not verify against its eq. 2 (1.05 tok/s × 3.9 GB ≈
    // 0.16·peak); our grid keeps eq. 2 exact instead.
    for r in recs {
        if r.device == "Xiaomi" && r.framework == "None" {
            assert!(r.mbu > 0.0 && r.mbu < 0.25, "compute-bound cell: {r:?}");
            continue;
        }
        assert!((0.25..0.95).contains(&r.mbu), "MBU out of band: {r:?}");
    }
    // OpenCL ppl pathology present on NanoPI/Xiaomi GPU, absent on Mac.
    let ppl_cpu = get("NanoPI", "CPU", true, "q4_0").ppl;
    let ppl_gpu = get("NanoPI", "GPU", false, "q4_0").ppl;
    assert!(ppl_gpu > 5.0 * ppl_cpu, "OpenCL pathology missing");
    let mac_cpu = get("Macbook", "CPU", true, "q4_0").ppl;
    let mac_gpu = get("Macbook", "GPU", false, "q4_0").ppl;
    assert!((mac_gpu / mac_cpu - 1.0).abs() < 0.05, "Metal must be clean");
    println!("table6 shape checks OK ({} rows)", recs.len());
}
