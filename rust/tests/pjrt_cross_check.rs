//! PJRT ↔ native cross-validation (DESIGN.md §7): the AOT-lowered
//! JAX/Pallas graph executed through the xla crate must agree with the
//! from-scratch rust engine on the same weights.
//!
//! Requires `make artifacts`.

use std::path::Path;

use elib::graph::Engine;
use elib::kernel::BackendKind;
use elib::model::{testutil, ModelWeights};
use elib::quant::QuantType;
use elib::runtime::{Artifacts, PjrtEngine, PjrtVariant};
use elib::util::stats::max_abs_diff;

/// `None` when `make artifacts` hasn't run: these tests skip instead of
/// failing so the tier-1 gate runs with or without the trained model.
fn artifacts() -> Option<Artifacts> {
    if !Path::new("artifacts").join("model_meta.json").exists() {
        eprintln!("skipping: artifacts missing — run `make artifacts` for full coverage");
        return None;
    }
    Some(Artifacts::load(Path::new("artifacts")).expect("artifacts present but unloadable"))
}

fn native_engine(arts: &Artifacts, q: QuantType) -> Engine {
    let mf = arts.weights_f32().unwrap();
    let mut dense = testutil::DenseWeights::new();
    for (name, t) in &mf.tensors {
        dense.insert(name.clone(), (t.dequantize(), t.rows, t.cols));
    }
    let nmf = testutil::build_model_file(&arts.config, q, &dense);
    Engine::new(ModelWeights::load(&nmf).unwrap(), BackendKind::Naive)
}

#[test]
fn meta_config_matches_rust_tiny() {
    let Some(arts) = artifacts() else { return };
    assert_eq!(arts.config, elib::model::LlamaConfig::tiny(),
        "python TINY_CONFIG and rust LlamaConfig::tiny() diverged");
    assert_eq!(arts.param_order.len(), 3 + 9 * arts.config.n_layers);
}

#[test]
fn pjrt_f32_matches_native_f32() {
    let Some(arts) = artifacts() else { return };
    let mut pjrt = PjrtEngine::load(&arts, PjrtVariant::F32).unwrap();
    let mut native = native_engine(&arts, QuantType::F32);
    let toks: Vec<u32> = "the cache ".bytes().map(|b| b as u32).collect();
    for (i, t) in toks.iter().enumerate() {
        let lp = pjrt.decode(*t).unwrap();
        let ln = native.forward(*t, i).unwrap().to_vec();
        let d = max_abs_diff(&lp, &ln);
        assert!(d < 2e-3, "pos {i}: |pjrt - native| = {d}");
    }
}

#[test]
fn pjrt_q8_matches_native_q8() {
    // Both sides consume the SAME q8_0 bytes (rust packs them; the Pallas
    // kernel unpacks in-graph) — agreement proves the bit-level format
    // contract across the language boundary. The two engines differ by
    // design in the *activation* side: ggml-style native uses int8
    // activations (w8·a8 integer dot), the PJRT graph dequantizes weights
    // against f32 activations — so logits agree only within the
    // activation-quantization envelope, and the predicted token must
    // match.
    let Some(arts) = artifacts() else { return };
    let mut pjrt = PjrtEngine::load(&arts, PjrtVariant::Q8_0).unwrap();
    let mut native = native_engine(&arts, QuantType::Q8_0);
    let toks: Vec<u32> = "memory ".bytes().map(|b| b as u32).collect();
    for (i, t) in toks.iter().enumerate() {
        let lp = pjrt.decode(*t).unwrap();
        let ln = native.forward(*t, i).unwrap().to_vec();
        let d = max_abs_diff(&lp, &ln);
        assert!(d < 0.25, "pos {i}: |pjrt_q8 - native_q8| = {d}");
        assert!(d > 0.0, "paths are distinct by construction");
        assert_eq!(
            elib::graph::sampler::argmax(&lp),
            elib::graph::sampler::argmax(&ln),
            "pos {i}: prediction must agree"
        );
    }
}

#[test]
fn pjrt_reset_replays_identically() {
    let Some(arts) = artifacts() else { return };
    let mut pjrt = PjrtEngine::load(&arts, PjrtVariant::F32).unwrap();
    let toks = [104u32, 101, 108];
    let mut first = Vec::new();
    for t in toks {
        first = pjrt.decode(t).unwrap();
    }
    pjrt.reset().unwrap();
    let mut second = Vec::new();
    for t in toks {
        second = pjrt.decode(t).unwrap();
    }
    assert_eq!(first, second);
}

#[test]
fn pjrt_context_overflow_is_error() {
    let Some(arts) = artifacts() else { return };
    let mut pjrt = PjrtEngine::load(&arts, PjrtVariant::F32).unwrap();
    // Drive pos to the limit cheaply by decoding max_seq_len tokens.
    for _ in 0..arts.config.max_seq_len {
        pjrt.decode(97).unwrap();
    }
    assert!(pjrt.decode(97).is_err());
}
