//! End-to-end integration: artifacts → quantization flow → Algorithm-1
//! run → report, plus failure-injection on the coordinator.
//!
//! Requires `make artifacts` (the Makefile orders this before `cargo
//! test`).

use std::path::Path;

use elib::coordinator::{flow, runner, Elib, ElibConfig};
use elib::graph::{generate, Engine, Sampler};
use elib::kernel::{BackendKind, Precision};
use elib::metrics;
use elib::model::ModelWeights;
use elib::quant::QuantType;
use elib::report;

/// `None` when `make artifacts` hasn't run (e.g. the CI property-smoke
/// job): artifact-dependent tests skip instead of failing, so the tier-1
/// gate is meaningful with or without the trained model.
fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("tiny_llama_f32.eguf").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts missing — run `make artifacts` for full coverage");
        None
    }
}

fn small_config(out: &str) -> Option<ElibConfig> {
    let mut cfg = ElibConfig::default();
    cfg.artifacts_dir = artifacts_dir()?.to_path_buf();
    cfg.out_dir = format!("target/test-out/{out}").into();
    cfg.bench.gen_tokens = 8;
    cfg.bench.ppl_tokens = 96;
    Some(cfg)
}

#[test]
fn trained_model_beats_uniform_by_a_lot() {
    let Some(arts) = artifacts_dir() else { return };
    let (cfg, dense) =
        flow::load_original(&arts.join("tiny_llama_f32.eguf")).unwrap();
    let mf = elib::model::testutil::build_model_file(&cfg, QuantType::F32, &dense);
    let mut e = Engine::new(ModelWeights::load(&mf).unwrap(), BackendKind::Naive);
    let eval = std::fs::read_to_string(arts.join("corpus_eval.txt")).unwrap();
    let toks: Vec<u32> = eval.bytes().take(256).map(|b| b as u32).collect();
    let (nll, n) = e.sequence_nll(&toks).unwrap();
    let ppl = metrics::perplexity(nll, n);
    assert!(
        ppl < 4.0,
        "trained model held-out ppl {ppl} (uniform is 256) — training failed?"
    );
}

#[test]
fn quantization_orders_real_perplexity() {
    // The Fig-6 CPU-row result on the *real* trained model: accuracy
    // ordering q4_0 worst … q8_0 ≈ f32.
    let Some(arts) = artifacts_dir() else { return };
    let (cfg, dense) =
        flow::load_original(&arts.join("tiny_llama_f32.eguf")).unwrap();
    let eval = std::fs::read_to_string(arts.join("corpus_eval.txt")).unwrap();
    let toks: Vec<u32> = eval.bytes().take(384).map(|b| b as u32).collect();
    let mut ppl = std::collections::BTreeMap::new();
    for q in [QuantType::F32, QuantType::Q4_0, QuantType::Q8_0] {
        let mf = elib::model::testutil::build_model_file(&cfg, q, &dense);
        let mut e = Engine::new(ModelWeights::load(&mf).unwrap(), BackendKind::Naive);
        let (nll, n) = e.sequence_nll(&toks).unwrap();
        ppl.insert(q.name(), metrics::perplexity(nll, n));
    }
    assert!(ppl["q4_0"] > ppl["q8_0"] * 0.999, "{ppl:?}");
    assert!(ppl["q8_0"] < ppl["f32"] * 1.05, "q8_0 ~ f32: {ppl:?}");
}

#[test]
fn degraded_gpu_backend_perturbs_but_stays_bounded() {
    // The real f16-accumulation backend produces measurable logit drift
    // (the *direction* of the OpenCL pathology); the order-of-magnitude
    // ppl blow-up the paper observed comes from genuinely broken driver
    // stacks and is modeled at the device layer (device::simulated_ppl).
    let Some(arts) = artifacts_dir() else { return };
    let (cfg, dense) =
        flow::load_original(&arts.join("tiny_llama_f32.eguf")).unwrap();
    let eval = std::fs::read_to_string(arts.join("corpus_eval.txt")).unwrap();
    let toks: Vec<u32> = eval.bytes().take(256).map(|b| b as u32).collect();
    let mf = elib::model::testutil::build_model_file(&cfg, QuantType::Q4_0, &dense);
    let mut clean = Engine::new(ModelWeights::load(&mf).unwrap(), BackendKind::Naive);
    let mut degr = Engine::new(
        ModelWeights::load(&mf).unwrap(),
        BackendKind::Gpu(Precision::DegradedF16),
    );
    // Logits must actually drift…
    let lc = clean.forward(toks[0], 0).unwrap().to_vec();
    let ld = degr.forward(toks[0], 0).unwrap().to_vec();
    let drift = elib::util::stats::max_abs_diff(&lc, &ld);
    assert!(drift > 0.0, "degraded backend produced identical logits");
    // …but perplexity stays bounded (it's a precision model, not noise).
    clean.reset();
    degr.reset();
    let (n1, c1) = clean.sequence_nll(&toks).unwrap();
    let (n2, c2) = degr.sequence_nll(&toks).unwrap();
    let (p1, p2) = (metrics::perplexity(n1, c1), metrics::perplexity(n2, c2));
    assert!(
        (p2 / p1 - 1.0).abs() < 0.05,
        "degraded ppl {p2} wildly off clean {p1}"
    );
}

#[test]
fn full_algorithm1_run_produces_complete_grid() {
    let Some(cfg) = small_config("full_run") else { return };
    let (rep, json_path) = Elib::new(cfg).quiet().run().unwrap();
    assert_eq!(rep.records.len(), 45, "5 quants × 3 devices × 3 accels");
    assert!(json_path.exists());
    assert_eq!(rep.host.len(), 15, "5 quants × 3 host backends");
    // Report renders without panicking and mentions every device.
    let text = report::full_report(&rep);
    for d in ["NanoPI", "Xiaomi", "Macbook"] {
        assert!(text.contains(d), "report missing {d}");
    }
    // Paper ratio directions.
    for r in report::summary_ratios(&rep.records) {
        assert!(r.q4_vs_q8_cpu > 1.0 && r.q4_vs_q8_gpu > 1.0);
        assert!(r.gpu_vs_cpu_mean > 1.0);
    }
}

#[test]
fn batch_sweep_amortizes_weight_traffic_end_to_end() {
    // The acceptance criterion: a benchmark run with --batch-sizes 1,4
    // reports strictly lower measured bytes-per-token (and higher MBU) at
    // batch 4 than batch 1 on the same quant/backend.
    let Some(mut cfg) = small_config("batch_sweep") else { return };
    cfg.quant_schemes = vec![QuantType::Q4_0, QuantType::Q8_0];
    cfg.bench.batch_sizes = vec![1, 4];
    let (rep, _) = Elib::new(cfg).quiet().run().unwrap();
    assert_eq!(rep.host.len(), 2 * 3 * 2, "2 quants × 3 backends × 2 batches");
    for q in [QuantType::Q4_0, QuantType::Q8_0] {
        for backend in ["cpu/none", "cpu/blas(t4)", "gpu/opencl"] {
            let pick = |b: usize| {
                rep.host
                    .iter()
                    .find(|h| h.qtype == q && h.backend == backend && h.batch == b)
                    .unwrap()
            };
            let (h1, h4) = (pick(1), pick(4));
            assert!(
                h4.bytes_per_token < h1.bytes_per_token,
                "{}/{backend}: bytes/token {} !< {}",
                q.name(),
                h4.bytes_per_token,
                h1.bytes_per_token
            );
            assert!(
                h4.host_mbu > h1.host_mbu,
                "{}/{backend}: MBU {} !> {}",
                q.name(),
                h4.host_mbu,
                h1.host_mbu
            );
        }
    }
    // The rendered report carries the sweep section.
    let text = report::full_report(&rep);
    assert!(text.contains("Batch sweep"));
}

#[test]
fn run_report_json_round_trips() {
    let Some(cfg) = small_config("json_rt") else { return };
    let (rep, path) = Elib::new(cfg).quiet().run().unwrap();
    let text = std::fs::read_to_string(path).unwrap();
    let parsed = elib::util::json::parse(&text).unwrap();
    let records = parsed.get("records").unwrap().as_arr().unwrap();
    assert_eq!(records.len(), rep.records.len());
    assert!(records[0].get("mbu").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn memory_overflow_guard_skips_oversized_deployments() {
    // A 65B deployment cannot fit any 16 GB paper device: the RQ2
    // constraint-1 guard must skip, not crash.
    use elib::device::DeviceSpec;
    use elib::model::{scale, LlamaConfig};
    let need = scale::max_ram_bytes(&LlamaConfig::llama_65b(), QuantType::Q4_0, 1);
    for d in DeviceSpec::paper_devices() {
        assert!(!d.fits_ram(need), "{} should not fit 65B", d.name);
    }
}

#[test]
fn timeout_guard_reports_skip_not_hang() {
    let mf = elib::model::testutil::random_model_file(QuantType::Q4_0, 1);
    let out = runner::run_inference_guarded(
        mf,
        BackendKind::Naive,
        vec![1, 2, 3],
        500,
        (0..64).collect(),
        1,
        std::time::Duration::from_millis(1),
    );
    assert!(matches!(out, Err(runner::SkipReason::Timeout { .. })));
}

#[test]
fn generation_is_reproducible_across_backends() {
    let Some(arts) = artifacts_dir() else { return };
    let (cfg, dense) =
        flow::load_original(&arts.join("tiny_llama_f32.eguf")).unwrap();
    let mf = elib::model::testutil::build_model_file(&cfg, QuantType::Q5_0, &dense);
    let prompt: Vec<u32> = "the scheduler ".bytes().map(|b| b as u32).collect();
    let mut outs = Vec::new();
    for backend in [BackendKind::Naive, BackendKind::Parallel(4)] {
        let mut e = Engine::new(ModelWeights::load(&mf).unwrap(), backend);
        let stats = generate(&mut e, &prompt, 24, &mut Sampler::Greedy).unwrap();
        outs.push(stats.tokens);
    }
    assert_eq!(
        outs[0], outs[1],
        "greedy generation must be identical across exact backends"
    );
}

#[test]
fn trained_model_generates_corpus_like_text() {
    // The end-to-end "it actually works" check: greedy output from the
    // trained model must contain corpus vocabulary, not noise.
    let Some(arts) = artifacts_dir() else { return };
    let (cfg, dense) =
        flow::load_original(&arts.join("tiny_llama_f32.eguf")).unwrap();
    let mf = elib::model::testutil::build_model_file(&cfg, QuantType::Q8_0, &dense);
    let mut e = Engine::new(ModelWeights::load(&mf).unwrap(), BackendKind::Parallel(4));
    let tok = elib::model::ByteTokenizer;
    let prompt = tok.encode("the inference engine ");
    let stats = generate(&mut e, &prompt, 64, &mut Sampler::Greedy).unwrap();
    let text = tok.decode(&stats.tokens);
    let ascii = text.bytes().filter(|b| b.is_ascii_graphic() || *b == b' ' || *b == b'\n').count();
    assert!(
        ascii as f64 / text.len() as f64 > 0.95,
        "output not text-like: {text:?}"
    );
    let has_word = ["the", "cache", "token", "memory", "bandwidth", "device", "model"]
        .iter()
        .any(|w| text.contains(w));
    assert!(has_word, "no corpus vocabulary in: {text:?}");
}
