//! Bad: host clock reads in a deterministic zone. Priced time must come
//! from the virtual clock; wall time differs on every machine.

use std::time::Instant;

pub fn measure() -> f64 {
    let t0 = Instant::now();
    busy_work();
    t0.elapsed().as_secs_f64()
}

fn busy_work() {}
