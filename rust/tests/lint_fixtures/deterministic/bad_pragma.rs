//! Bad: broken escape pragmas. An unknown rule name or a missing
//! reason must be a finding — a typo must never silently allow.

pub fn quiet() -> u32 {
    let a = 1; // elib-lint: allow(no-such-rule, reason = "typo in the rule name")
    let b = 2; // elib-lint: allow(wall-clock)
    a + b
}
