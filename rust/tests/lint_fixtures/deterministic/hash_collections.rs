//! Bad: hash collections in a deterministic zone. Iteration order
//! depends on the hasher's per-build layout, so anything derived from
//! it is not bit-for-bit stable.

use std::collections::HashMap;

pub fn tally(names: &[String]) -> usize {
    let mut counts: HashMap<String, usize> = HashMap::new();
    for n in names {
        *counts.entry(n.clone()).or_insert(0) += 1;
    }
    counts.len()
}
