//! Bad: raw thread spawn in a deterministic zone. Completion order
//! leaks into result order; fan out through `util::threadpool` instead.

pub fn fan_out(jobs: Vec<u64>) -> Vec<u64> {
    let mut handles = Vec::new();
    for j in jobs {
        handles.push(std::thread::spawn(move || j * 2));
    }
    handles.into_iter().filter_map(|h| h.join().ok()).collect()
}
