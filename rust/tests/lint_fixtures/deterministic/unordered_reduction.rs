//! Bad: float reduction over a hash container's iteration order. The
//! sum's rounding depends on bucket layout — a different allocator or
//! std version changes the artifact bytes.

pub fn total(hash_weights: &std::collections::BTreeMap<String, f64>) -> f64 {
    let hash_order_sum: f64 = hash_weights.values().sum();
    hash_order_sum
}
