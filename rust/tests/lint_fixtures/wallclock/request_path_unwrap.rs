//! Bad: panicking on a daemon request path. A poisoned lock or a
//! missing id must become a structured 4xx/5xx, not a dead worker.

pub fn handle(req: Result<String, String>, hub: &std::sync::Mutex<Vec<u64>>) -> String {
    let body = req.unwrap();
    let guard = hub.lock().expect("hub lock");
    format!("{} ({} entries)", body, guard.len())
}
