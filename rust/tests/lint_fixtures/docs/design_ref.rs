//! Bad: a doc comment citing a DESIGN.md section that does not exist.
//! See DESIGN.md §99 for the algorithm this module pretends to follow.

pub fn documented() -> u32 {
    7
}
