//! Bad: a ServeParams serializer emitting an identity key that
//! ScenarioSpec serialization cannot derive — `compare_bench` identity
//! would silently lose a knob.

impl ServeParams {
    pub(crate) fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("kv_pool_blocks", Json::Num(4.0)),
            ("brand_new_knob", Json::Num(1.0)),
        ])
    }
}
