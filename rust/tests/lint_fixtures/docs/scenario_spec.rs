//! The scenario-side serializer the bad ServeParams fixture is compared
//! against: it knows `seed` and the `pool_blocks` alias, but not
//! `brand_new_knob`.

impl ScenarioSpec {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::Num(self.seed as f64)),
            ("pool_blocks", Json::Num(4.0)),
        ])
    }
}
