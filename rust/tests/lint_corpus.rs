//! `elib lint` end-to-end: the real tree must be clean, and the
//! deliberately-bad fixture corpus under `rust/tests/lint_fixtures/`
//! must demonstrate every rule firing (DESIGN.md §11). This is the
//! same pair of checks the CI `lint` job runs via the CLI; here they
//! gate `cargo test` without needing a built binary.

use std::path::Path;

use elib::analysis::{find_root, run_fixture_lint, run_lint, rules::RULES};

fn repo_root() -> &'static Path {
    // rust/tests/ → the crate dir is rust/, the repo root its parent.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("crate dir has a parent")
}

#[test]
fn find_root_locates_the_repo_from_inside_it() {
    let root = repo_root();
    let from_src = root.join("rust").join("src").join("analysis");
    assert_eq!(find_root(&from_src).as_deref(), Some(root));
    assert_eq!(find_root(root).as_deref(), Some(root));
}

#[test]
fn real_tree_lints_clean() {
    let rep = run_lint(repo_root()).expect("lint run");
    let rendered: Vec<String> = rep
        .findings
        .iter()
        .map(|f| format!("{}:{} {} {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(
        rep.findings.is_empty(),
        "the tree must lint clean at merge; findings:\n{}",
        rendered.join("\n")
    );
    assert_eq!(rep.exit_code(), 0);
    // The tree's pragma escapes are deliberate and enumerable: four
    // wall-clock allows in graph/ (host-side timing is the measured
    // product there) and one raw-thread-spawn for the coordinator's
    // timeout watchdog. A new escape should be a conscious decision —
    // update this count alongside it.
    assert_eq!(
        rep.allows.len(),
        5,
        "unexpected pragma escapes: {:?}",
        rep.allows
            .iter()
            .map(|a| format!("{}:{} {}", a.file, a.line, a.rule))
            .collect::<Vec<_>>()
    );
}

#[test]
fn fixture_corpus_fires_every_rule() {
    let rep = run_fixture_lint(repo_root()).expect("fixture lint run");
    assert!(!rep.findings.is_empty(), "the bad corpus must produce findings");
    assert_ne!(rep.exit_code(), 0);
    let fired = rep.rules_fired();
    let missing: Vec<&str> =
        RULES.iter().copied().filter(|r| !fired.contains(r)).collect();
    assert!(
        missing.is_empty(),
        "fixture corpus must demonstrate every rule; missing: {missing:?}\nfired: {fired:?}"
    );
}
