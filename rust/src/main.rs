//! `elib` — the ELIB command-line launcher.
//!
//! Subcommands:
//!   quantize    run the automatic quantization flow
//!   bench       full Algorithm-1 benchmark grid (Table 6 + figures)
//!   serve       continuous-batching serving simulator (bench.json)
//!   daemon      wall-clock HTTP serving daemon over the sim (daemon.json)
//!   fleet       device-aware serving sweep: device × accel × quant (fleet.json)
//!   cluster     deterministic router over a heterogeneous replica fleet (cluster.json)
//!   bench-check compare a serve bench.json against a committed baseline
//!   generate    run the native engine on a prompt and print metrics
//!   report      print the static tables (devices / storage / quant)
//!   pjrt-check  load the AOT artifacts and cross-check PJRT vs native
//!   lint        repo static analysis: determinism zones + doc contracts

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use elib::coordinator::{
    compare_bench, run_fleet, run_serve, ArrivalMode, Elib, ElibConfig, SchedulerPolicy,
    ServeParams,
};
use elib::device::{Accel, DeviceSpec};
use elib::graph::{generate, Engine, Sampler};
use elib::kernel::{BackendKind, Precision};
use elib::metrics;
use elib::model::{ByteTokenizer, ModelWeights};
use elib::quant::QuantType;
use elib::report;
use elib::runtime::{Artifacts, PjrtEngine, PjrtVariant};
use elib::util::cli::Command;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let sub = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    match sub {
        "quantize" => cmd_quantize(rest),
        "bench" => cmd_bench(rest),
        "serve" => cmd_serve(rest),
        "daemon" => cmd_daemon(rest),
        "fleet" => cmd_fleet(rest),
        "cluster" => cmd_cluster(rest),
        "bench-check" => cmd_bench_check(rest),
        "generate" => cmd_generate(rest),
        "report" => cmd_report(rest),
        "pjrt-check" => cmd_pjrt_check(rest),
        "lint" => cmd_lint(rest),
        "help" | "--help" | "-h" => {
            println!(
                "elib — edge LLM inference benchmarking (ELIB reproduction)\n\n\
                 subcommands:\n  \
                 quantize    run the automatic quantization flow\n  \
                 bench       full benchmark grid (Table 6 + all figures)\n  \
                 serve       continuous-batching serving simulator\n  \
                 daemon      wall-clock HTTP serving daemon over the sim\n  \
                 fleet       device-aware serving sweep (device × accel × quant)\n  \
                 cluster     routed serving over a heterogeneous replica fleet\n  \
                 bench-check compare a serve bench.json against a baseline\n  \
                 generate    generate text with the native engine\n  \
                 report      print the static tables\n  \
                 pjrt-check  cross-check the PJRT path against native\n  \
                 lint        repo static analysis (determinism zones + doc contracts)\n\n\
                 `elib <cmd> --help` for options"
            );
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand `{other}` (try `elib help`)")),
    }
}

fn base_config(a: &elib::util::cli::Args) -> Result<ElibConfig> {
    let mut cfg = match a.get("config") {
        Some(p) => ElibConfig::from_file(Path::new(p))?,
        None => ElibConfig::default(),
    };
    if let Some(d) = a.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(d);
    }
    if let Some(d) = a.get("out") {
        cfg.out_dir = PathBuf::from(d);
    }
    if let Some(s) = a.get("schemes") {
        cfg.quant_schemes = s
            .split(',')
            .map(|x| QuantType::parse(x.trim()).ok_or_else(|| anyhow!("bad scheme `{x}`")))
            .collect::<Result<_>>()?;
    }
    cfg.bench.iterations = a.parse_usize("iterations", cfg.bench.iterations)?;
    cfg.bench.gen_tokens = a.parse_usize("gen-tokens", cfg.bench.gen_tokens)?;
    cfg.bench.ppl_tokens = a.parse_usize("ppl-tokens", cfg.bench.ppl_tokens)?;
    cfg.bench.batch_size = a.parse_usize("batch", cfg.bench.batch_size)?;
    if let Some(s) = a.get("batch-sizes") {
        cfg.bench.batch_sizes = s
            .split(',')
            .map(|x| match x.trim().parse::<usize>() {
                Ok(b) if b >= 1 => Ok(b),
                _ => Err(anyhow!("bad batch size `{x}` in --batch-sizes")),
            })
            .collect::<Result<_>>()?;
    }
    cfg.bench.scheduler_threads = a.parse_usize("threads", cfg.bench.scheduler_threads)?;
    Ok(cfg)
}

fn shared_opts(c: Command) -> Command {
    c.opt("config", None, "JSON config file")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .opt("out", Some("target/elib-out"), "output directory")
        .opt("schemes", None, "comma-separated quant schemes")
        .opt("iterations", None, "benchmark iterations")
        .opt("gen-tokens", None, "tokens generated per run")
        .opt("ppl-tokens", None, "eval tokens for perplexity")
        .opt("batch", None, "simulated batch size")
        .opt("batch-sizes", None, "host batch sweep, comma-separated (e.g. 1,2,4,8)")
        .opt("threads", None, "benchmark scheduler worker threads")
}

fn cmd_quantize(argv: &[String]) -> Result<()> {
    let a = shared_opts(Command::new("quantize", "run the automatic quantization flow"))
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let cfg = base_config(&a)?;
    std::fs::create_dir_all(&cfg.out_dir)?;
    let models = Elib::new(cfg).quantization_flow()?;
    println!("{} quantized models written", models.len());
    Ok(())
}

fn cmd_bench(argv: &[String]) -> Result<()> {
    let a = shared_opts(Command::new("bench", "full Algorithm-1 benchmark grid"))
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let cfg = base_config(&a)?;
    let (rep, path) = Elib::new(cfg).run()?;
    println!("\n{}", report::full_report(&rep));
    println!("machine-readable report: {}", path.display());
    Ok(())
}

/// Parse `lo,hi` (or a single `n`, meaning `n,n`) into an inclusive range.
fn parse_len_range(s: &str) -> Result<(usize, usize)> {
    let parts: Vec<&str> = s.split(',').map(str::trim).collect();
    let parse = |x: &str| -> Result<usize> {
        x.parse::<usize>()
            .map_err(|_| anyhow!("bad length `{x}` in range `{s}`"))
    };
    match parts.as_slice() {
        [one] => {
            let n = parse(one)?;
            Ok((n, n))
        }
        [lo, hi] => Ok((parse(lo)?, parse(hi)?)),
        _ => Err(anyhow!("length range must be `lo,hi`, got `{s}`")),
    }
}

/// Fixed weight-init seed of the synthetic serve model, independent of
/// the trace seed so `--seed` varies the traffic, not the model.
const SYNTHETIC_MODEL_SEED: u64 = 0x5EED;

/// Dense original weights for the serving scenarios: the trained
/// artifacts when present, else the seeded synthetic tiny model.
fn serve_originals(
    cfg: &ElibConfig,
    force_synthetic: bool,
    label: &str,
) -> Result<(elib::model::LlamaConfig, elib::model::testutil::DenseWeights)> {
    let original = cfg.artifacts_dir.join("tiny_llama_f32.eguf");
    if force_synthetic || !original.exists() {
        if !force_synthetic {
            println!(
                "[{label}] no artifacts at {}; using the seeded synthetic model",
                original.display()
            );
        }
        let mcfg = elib::model::LlamaConfig::tiny();
        let dense = elib::model::testutil::random_weights(&mcfg, SYNTHETIC_MODEL_SEED);
        Ok((mcfg, dense))
    } else {
        elib::coordinator::flow::load_original(&original)
    }
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let a = shared_opts(Command::new("serve", "continuous-batching serving simulator"))
        .opt("arrival-rate", None, "mean request arrivals per virtual second (default 4)")
        .opt("num-requests", None, "requests in the seeded trace (default 64)")
        .opt("seed", None, "trace seed: shapes, prompts, arrivals (default 7)")
        .opt("slots", None, "engine slots = max concurrent requests (default 4)")
        .opt(
            "workload",
            None,
            "workload: poisson | closed | chat | diurnal | flash-crowd | heavy-tail (default poisson)",
        )
        .opt("mode", None, "alias of --workload (the PR-2 flag name)")
        .opt("clients", None, "closed-loop client count (default 4)")
        .opt("turns", None, "chat turns per session lo,hi (with --workload chat; default 2,3)")
        .opt(
            "scheduler",
            None,
            "admission policy: fcfs | priority | chunked | slo-aware (default fcfs)",
        )
        .opt("slo-ttft", None, "interactive-tier TTFT deadline, virtual seconds (enables SLOs)")
        .opt("slo-tpot", None, "interactive-tier TPOT deadline, virtual seconds (enables SLOs)")
        .opt("thermal-tau", None, "thermal time constant, busy virtual seconds (enables throttling)")
        .opt("thermal-floor", None, "steady-state thermal derate in (0,1] (default 0.5)")
        .opt("chunk-tokens", None, "prefill chunk size (with --scheduler chunked; default 32)")
        .opt("kv-pool-blocks", None, "paged-KV pool budget in blocks (default: unbounded)")
        .flag("kv-prefix-share", "copy-on-write KV prefix sharing across admitted prompts")
        .opt(
            "system-prompt",
            None,
            "seeded system-prompt tokens prepended to first turns (with --kv-prefix-share)",
        )
        .opt("prompt-len", None, "prompt length range lo,hi (default 8,24)")
        .opt("output-len", None, "output length range lo,hi (default 4,24)")
        .opt("quant", Some("q4_0"), "weight format")
        .flag(
            "compare-schedulers",
            "serve the same trace under fcfs, priority and chunked (plus slo-aware when \
             SLOs are set), print the comparison and, with SLOs, the hostile-traffic grid",
        )
        .opt("device", None, "price the clock on a simulated device (NanoPI | Xiaomi | Macbook)")
        .opt("accel", None, "device accelerator: none | blas | gpu (with --device; default blas)")
        .opt("device-threads", None, "device CPU threads for the clock (with --device; default 4)")
        .opt("bench-json", None, "machine-readable output path (default <out>/bench.json)")
        .flag("synthetic", "force the seeded synthetic tiny model (no artifacts needed)")
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let cfg = base_config(&a)?;
    let mut sp = cfg.serve.clone();
    sp.arrival_rate = a.parse_f64("arrival-rate", sp.arrival_rate)?;
    sp.num_requests = a.parse_usize("num-requests", sp.num_requests)?;
    sp.seed = a.parse_u64("seed", sp.seed)?;
    sp.slots = a.parse_usize("slots", sp.slots)?;
    if let Some(v) = a.get("prompt-len") {
        sp.prompt_len = parse_len_range(v)?;
    }
    if let Some(v) = a.get("output-len") {
        sp.output_len = parse_len_range(v)?;
    }
    let cfg_clients = match sp.mode {
        ArrivalMode::ClosedLoop { clients } => clients,
        _ => 4,
    };
    let clients = a.parse_usize("clients", cfg_clients)?;
    let cfg_turns = match sp.mode {
        ArrivalMode::Chat { turns } => turns,
        _ => (2, 3),
    };
    let turns = match a.get("turns") {
        Some(v) => parse_len_range(v)?,
        None => cfg_turns,
    };
    // `--workload` is the canonical name; `--mode` stays as the PR-2 alias.
    let wl_key = match (a.get("workload"), a.get("mode")) {
        (Some(w), Some(m)) if w != m => {
            return Err(anyhow!("--workload `{w}` and --mode `{m}` disagree (pick one)"))
        }
        (Some(w), _) => w.to_string(),
        (None, Some(m)) => m.to_string(),
        (None, None) => sp.mode.label().to_string(),
    };
    match wl_key.as_str() {
        "poisson" => {
            anyhow::ensure!(
                a.get("clients").is_none(),
                "--clients only applies to --workload closed (the poisson open loop has no clients)"
            );
            anyhow::ensure!(
                a.get("turns").is_none(),
                "--turns only applies to --workload chat"
            );
            sp.mode = ArrivalMode::Poisson;
        }
        "closed" => {
            anyhow::ensure!(
                a.get("turns").is_none(),
                "--turns only applies to --workload chat"
            );
            sp.mode = ArrivalMode::ClosedLoop { clients };
        }
        "chat" => {
            anyhow::ensure!(
                a.get("clients").is_none(),
                "--clients only applies to --workload closed (chat sessions pace themselves)"
            );
            sp.mode = ArrivalMode::Chat { turns };
        }
        "diurnal" | "flash-crowd" | "heavy-tail" => {
            anyhow::ensure!(
                a.get("clients").is_none() && a.get("turns").is_none(),
                "--clients/--turns do not apply to the open-loop hostile workloads"
            );
            sp.mode = match wl_key.as_str() {
                "diurnal" => ArrivalMode::Diurnal,
                "flash-crowd" => ArrivalMode::FlashCrowd,
                _ => ArrivalMode::HeavyTail,
            };
        }
        other => {
            return Err(anyhow!(
                "bad --workload `{other}` \
                 (poisson | closed | chat | diurnal | flash-crowd | heavy-tail)"
            ))
        }
    }
    // Scheduler policy: the config's choice unless overridden on the CLI.
    // The chunk default follows the config's chunked policy (if any), so
    // `--scheduler chunked` on top of a configured chunk size keeps it.
    let cfg_chunk = match sp.scheduler {
        SchedulerPolicy::Chunked { chunk_tokens } => chunk_tokens,
        _ => 32,
    };
    let chunk_tokens = a.parse_usize("chunk-tokens", cfg_chunk)?;
    if let Some(s) = a.get("scheduler") {
        sp.scheduler = SchedulerPolicy::parse(s, chunk_tokens)
            .ok_or_else(|| anyhow!("bad --scheduler `{s}` (fcfs | priority | chunked | slo-aware)"))?;
    } else if a.get("chunk-tokens").is_some()
        && matches!(sp.scheduler, SchedulerPolicy::Chunked { .. })
    {
        // Config picked chunked; the CLI may still retune the chunk.
        sp.scheduler = SchedulerPolicy::Chunked { chunk_tokens };
    }
    // --chunk-tokens also feeds the chunked leg of --compare-schedulers.
    anyhow::ensure!(
        a.get("chunk-tokens").is_none()
            || a.flag("compare-schedulers")
            || matches!(sp.scheduler, SchedulerPolicy::Chunked { .. }),
        "--chunk-tokens only applies to --scheduler chunked (or --compare-schedulers)"
    );
    // Paged-KV knobs (the engine always runs the paged layout; these
    // bound the pool and turn on copy-on-write prefix sharing).
    if let Some(v) = a.get("kv-pool-blocks") {
        let blocks = v
            .parse::<usize>()
            .map_err(|_| anyhow!("bad --kv-pool-blocks `{v}`"))?;
        anyhow::ensure!(blocks >= 1, "--kv-pool-blocks must be at least 1");
        sp.pool_blocks = Some(blocks);
    }
    if a.flag("kv-prefix-share") {
        sp.prefix_share = true;
    }
    sp.system_prompt = a.parse_usize("system-prompt", sp.system_prompt)?;
    anyhow::ensure!(
        sp.system_prompt == 0 || sp.prefix_share,
        "--system-prompt only pays off with --kv-prefix-share \
         (a shared prefix nobody shares just burns prefill)"
    );
    // SLOs: either deadline flag enables them (the other defaults to ∞);
    // the tier spread and validation live in ServeParams.
    if a.get("slo-ttft").is_some() || a.get("slo-tpot").is_some() {
        sp.slo = Some(elib::coordinator::SloSpec {
            ttft: a.parse_f64("slo-ttft", f64::INFINITY)?,
            tpot: a.parse_f64("slo-tpot", f64::INFINITY)?,
        });
    }
    if a.get("thermal-tau").is_some() {
        sp.thermal = Some(elib::device::Thermal {
            tau: a.parse_f64("thermal-tau", 1.0)?,
            floor: a.parse_f64("thermal-floor", 0.5)?,
        });
    } else {
        anyhow::ensure!(
            a.get("thermal-floor").is_none(),
            "--thermal-floor only applies with --thermal-tau"
        );
    }
    // Default engine backend: `--threads` picks the kernel thread count;
    // the clock is virtual, so any value reproduces the exact same
    // bench.json (property-tested). With `--device`, the backend follows
    // the accelerator instead (`runner::backend_for`) — the same mapping
    // fleet cells use, so a solo device run reproduces its fleet cell's
    // numerics (including the degraded-precision OpenCL GPU path).
    let mut backend = BackendKind::Parallel(cfg.bench.scheduler_threads.max(1));
    match a.get("device") {
        Some(name) => {
            let spec = DeviceSpec::by_name(name)
                .ok_or_else(|| anyhow!("unknown --device `{name}` (NanoPI | Xiaomi | Macbook)"))?;
            let accel = Accel::parse(a.get_or("accel", "blas"))
                .ok_or_else(|| anyhow!("bad --accel (none | blas | gpu)"))?;
            backend = elib::coordinator::runner::backend_for(accel, &spec);
            sp.device = Some(elib::coordinator::DeviceTarget {
                device: spec.name.to_string(),
                accel,
                threads: a.parse_usize("device-threads", 4)?,
            });
        }
        None => anyhow::ensure!(
            a.get("accel").is_none() && a.get("device-threads").is_none(),
            "--accel/--device-threads only apply with --device"
        ),
    }
    let q = QuantType::parse(a.get_or("quant", "q4_0")).ok_or_else(|| anyhow!("bad --quant"))?;
    let (mcfg, dense) = serve_originals(&cfg, a.flag("synthetic"), "serve")?;
    let mf = elib::model::testutil::build_model_file(&mcfg, q, &dense);

    if a.flag("compare-schedulers") {
        anyhow::ensure!(
            a.get("bench-json").is_none(),
            "--compare-schedulers prints a table and writes no bench.json; \
             run a single-scheduler serve to emit one"
        );
        // One seeded trace, one admission policy per row: the token
        // streams are identical (scheduler changes timing, never
        // numerics), so the latency/throughput deltas are pure policy
        // effects. The lineup is the scheduler registry itself — a new
        // registered policy joins the comparison with no CLI change —
        // minus the SLO-needing rows when no SLOs are set.
        let mut policies = Vec::new();
        for entry in elib::coordinator::registry::SCHEDULERS {
            if entry.needs_slo && sp.slo.is_none() {
                continue;
            }
            policies.push(
                SchedulerPolicy::parse(entry.name, chunk_tokens)
                    .expect("registry scheduler names parse"),
            );
        }
        let mut reports = Vec::new();
        for policy in &policies {
            let run = ServeParams {
                scheduler: *policy,
                ..sp.clone()
            };
            reports.push(run_serve(&mf, backend, &run)?);
        }
        println!("{}", report::scheduler_comparison(&reports));
        if sp.slo.is_some() {
            // Hostile-traffic grid: every policy over stationary,
            // diurnal and flash-crowd arrivals, goodput winner named
            // per workload (report::slo_section).
            let mut grid = Vec::new();
            for mode in [ArrivalMode::Poisson, ArrivalMode::Diurnal, ArrivalMode::FlashCrowd] {
                for policy in &policies {
                    let run = ServeParams {
                        mode,
                        scheduler: *policy,
                        ..sp.clone()
                    };
                    grid.push(run_serve(&mf, backend, &run)?);
                }
            }
            println!("{}", report::slo_section(&grid));
        }
        return Ok(());
    }

    let rep = run_serve(&mf, backend, &sp)?;
    println!("{}", report::serve_section(&rep));
    let path = a
        .get("bench-json")
        .map(PathBuf::from)
        .unwrap_or_else(|| cfg.out_dir.join("bench.json"));
    if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&path, elib::util::json::to_string_pretty(&rep.to_json()))
        .map_err(|e| anyhow!("write {}: {e}", path.display()))?;
    println!(
        "bench.json: {} (token-stream fnv {:016x})",
        path.display(),
        rep.tokens_fnv()
    );
    Ok(())
}

/// Minimal SIGINT hook for `elib daemon` — no signal crate; the handler
/// just flips an atomic the foreground loop polls, so Ctrl-C triggers
/// the same graceful drain as `POST /admin/shutdown`.
#[cfg(unix)]
mod sig {
    use std::sync::atomic::{AtomicBool, Ordering};

    static STOP: AtomicBool = AtomicBool::new(false);

    extern "C" fn on_sigint(_signum: i32) {
        STOP.store(true, Ordering::SeqCst);
    }

    pub fn install() {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        // Only atomics are touched in the handler, so the libc default
        // restrictions on async-signal-safety are respected.
        unsafe {
            let _ = signal(SIGINT, on_sigint as extern "C" fn(i32) as usize);
        }
    }

    pub fn stopped() -> bool {
        STOP.load(Ordering::SeqCst)
    }
}

#[cfg(not(unix))]
mod sig {
    pub fn install() {}

    pub fn stopped() -> bool {
        false
    }
}

fn cmd_daemon(argv: &[String]) -> Result<()> {
    let a = shared_opts(Command::new("daemon", "wall-clock HTTP serving daemon over the sim"))
        .opt("host", None, "bind address (default 127.0.0.1; 0.0.0.0 exposes)")
        .opt("port", None, "TCP port, 0 = ephemeral (default 8080)")
        .opt("workers", None, "connection worker threads (default 4)")
        .opt("queue-depth", None, "requests allowed to wait before 429 + Retry-After (default 8)")
        .opt("max-requests", None, "lifetime request budget = pre-allocated sim ids (default 4096)")
        .opt("pace", None, "virtual seconds per wall second (default 1.0; >1 runs faster than real time)")
        .opt("slots", None, "engine slots = max concurrent decodes (default 4)")
        .opt("seed", None, "scheduler seed (default 7)")
        .opt("scheduler", None, "admission policy: fcfs | priority | chunked (default fcfs)")
        .opt("chunk-tokens", None, "prefill chunk size (with --scheduler chunked; default 32)")
        .opt("kv-pool-blocks", None, "paged-KV pool budget in blocks (default: unbounded)")
        .flag("kv-prefix-share", "copy-on-write KV prefix sharing across admitted prompts")
        .opt("thermal-tau", None, "thermal time constant, busy virtual seconds (enables throttling)")
        .opt("thermal-floor", None, "steady-state thermal derate in (0,1] (default 0.5)")
        .opt("device", None, "price the clock on a simulated device (NanoPI | Xiaomi | Macbook)")
        .opt("accel", None, "device accelerator: none | blas | gpu (with --device; default blas)")
        .opt("device-threads", None, "device CPU threads for the clock (with --device; default 4)")
        .opt("quant", Some("q4_0"), "weight format")
        .opt("daemon-json", None, "final report path (default <out>/daemon.json)")
        .flag("synthetic", "force the seeded synthetic tiny model (no artifacts needed)")
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let cfg = base_config(&a)?;
    let mut sp = cfg.serve.clone();
    sp.seed = a.parse_u64("seed", sp.seed)?;
    sp.slots = a.parse_usize("slots", sp.slots)?;
    let cfg_chunk = match sp.scheduler {
        SchedulerPolicy::Chunked { chunk_tokens } => chunk_tokens,
        _ => 32,
    };
    let chunk_tokens = a.parse_usize("chunk-tokens", cfg_chunk)?;
    if let Some(s) = a.get("scheduler") {
        sp.scheduler = SchedulerPolicy::parse(s, chunk_tokens)
            .ok_or_else(|| anyhow!("bad --scheduler `{s}` (fcfs | priority | chunked)"))?;
    } else if a.get("chunk-tokens").is_some()
        && matches!(sp.scheduler, SchedulerPolicy::Chunked { .. })
    {
        sp.scheduler = SchedulerPolicy::Chunked { chunk_tokens };
    }
    anyhow::ensure!(
        a.get("chunk-tokens").is_none() || matches!(sp.scheduler, SchedulerPolicy::Chunked { .. }),
        "--chunk-tokens only applies to --scheduler chunked"
    );
    // Live HTTP traffic carries no SLO tier tags, so the slo-aware policy
    // would read `None` everywhere — reject it rather than silently
    // degrade to fcfs-with-extra-steps.
    anyhow::ensure!(
        !matches!(sp.scheduler, SchedulerPolicy::SloAware),
        "the daemon serves untagged live traffic; --scheduler slo-aware needs the seeded \
         workloads of `elib serve`"
    );
    sp.slo = None;
    if let Some(v) = a.get("kv-pool-blocks") {
        let blocks = v
            .parse::<usize>()
            .map_err(|_| anyhow!("bad --kv-pool-blocks `{v}`"))?;
        anyhow::ensure!(blocks >= 1, "--kv-pool-blocks must be at least 1");
        sp.pool_blocks = Some(blocks);
    }
    if a.flag("kv-prefix-share") {
        sp.prefix_share = true;
    }
    if a.get("thermal-tau").is_some() {
        sp.thermal = Some(elib::device::Thermal {
            tau: a.parse_f64("thermal-tau", 1.0)?,
            floor: a.parse_f64("thermal-floor", 0.5)?,
        });
    } else {
        anyhow::ensure!(
            a.get("thermal-floor").is_none(),
            "--thermal-floor only applies with --thermal-tau"
        );
    }
    let mut backend = BackendKind::Parallel(cfg.bench.scheduler_threads.max(1));
    match a.get("device") {
        Some(name) => {
            let spec = DeviceSpec::by_name(name)
                .ok_or_else(|| anyhow!("unknown --device `{name}` (NanoPI | Xiaomi | Macbook)"))?;
            let accel = Accel::parse(a.get_or("accel", "blas"))
                .ok_or_else(|| anyhow!("bad --accel (none | blas | gpu)"))?;
            backend = elib::coordinator::runner::backend_for(accel, &spec);
            sp.device = Some(elib::coordinator::DeviceTarget {
                device: spec.name.to_string(),
                accel,
                threads: a.parse_usize("device-threads", 4)?,
            });
        }
        None => anyhow::ensure!(
            a.get("accel").is_none() && a.get("device-threads").is_none(),
            "--accel/--device-threads only apply with --device"
        ),
    }
    let q = QuantType::parse(a.get_or("quant", "q4_0")).ok_or_else(|| anyhow!("bad --quant"))?;
    let (mcfg, dense) = serve_originals(&cfg, a.flag("synthetic"), "daemon")?;
    let mf = elib::model::testutil::build_model_file(&mcfg, q, &dense);

    let path = a
        .get("daemon-json")
        .map(PathBuf::from)
        .unwrap_or_else(|| cfg.out_dir.join("daemon.json"));
    if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)?;
    }
    let dc = &cfg.daemon;
    let port = a.parse_usize("port", dc.port as usize)?;
    anyhow::ensure!(port <= u16::MAX as usize, "--port {port} out of range");
    let dp = elib::daemon::DaemonParams {
        host: a.get_or("host", &dc.host).to_string(),
        port: port as u16,
        workers: a.parse_usize("workers", dc.workers)?,
        queue_depth: a.parse_usize("queue-depth", dc.queue_depth)?,
        max_requests: a.parse_usize("max-requests", dc.max_requests)?,
        pace: a.parse_f64("pace", dc.pace)?,
        // The dashboard's report panels fetch whitelisted *.json from
        // here, so point it where daemon.json will land.
        report_dir: path
            .parent()
            .filter(|p| !p.as_os_str().is_empty())
            .map(Path::to_path_buf)
            .unwrap_or_else(|| PathBuf::from(".")),
        serve: sp,
    };
    let pace = dp.pace;
    let handle = elib::daemon::spawn(&mf, backend, dp)?;
    println!(
        "[daemon] listening on http://{} (pace {pace}x, quant {})",
        handle.addr(),
        q.name()
    );
    println!(
        "[daemon] POST /v1/completions | GET /metrics | GET / (dashboard) | POST /admin/shutdown"
    );
    sig::install();
    let mut announced = false;
    while !handle.finished() {
        if sig::stopped() && !announced {
            println!("[daemon] SIGINT — draining in-flight decodes, shedding the queue");
            handle.shutdown();
            announced = true;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    let stats = handle.stats();
    let rep = handle.join()?;
    println!("{}", report::daemon_section(&rep, &stats));
    std::fs::write(&path, elib::util::json::to_string_pretty(&rep.to_json()))
        .map_err(|e| anyhow!("write {}: {e}", path.display()))?;
    println!(
        "daemon.json: {} (token-stream fnv {:016x})",
        path.display(),
        rep.tokens_fnv()
    );
    Ok(())
}

fn cmd_fleet(argv: &[String]) -> Result<()> {
    let a = shared_opts(Command::new(
        "fleet",
        "device-aware serving sweep: one seeded trace per device × accel × quant",
    ))
    .opt("devices", None, "comma-separated device names (default: all three)")
    .opt("accels", None, "comma-separated accels: none,blas,gpu (default blas,gpu)")
    .opt("quants", None, "comma-separated quant formats (default q4_0,q8_0)")
    .opt("slots", None, "engine slots per cell = capacity-gate concurrency (default 8)")
    .opt("device-threads", None, "device CPU threads for the clock (default 4)")
    .opt("arrival-rate", None, "mean request arrivals per virtual second (default 2)")
    .opt("num-requests", None, "requests in the shared seeded trace (default 48)")
    .opt("seed", None, "trace seed: shapes, prompts, arrivals (default 7)")
    .opt("prompt-len", None, "prompt length range lo,hi (default 8,24)")
    .opt("output-len", None, "output length range lo,hi (default 4,24)")
    .opt("fleet-json", None, "machine-readable output path (default <out>/fleet.json)")
    .flag("synthetic", "force the seeded synthetic tiny model (no artifacts needed)")
    .parse(argv)
    .map_err(|e| anyhow!("{e}"))?;
    let cfg = base_config(&a)?;
    let mut fp = cfg.fleet.clone();
    if let Some(s) = a.get("devices") {
        fp.devices = s
            .split(',')
            .map(|x| {
                DeviceSpec::by_name(x.trim())
                    .ok_or_else(|| anyhow!("unknown device `{x}` in --devices"))
            })
            .collect::<Result<_>>()?;
    }
    if let Some(s) = a.get("accels") {
        fp.accels = s
            .split(',')
            .map(|x| Accel::parse(x).ok_or_else(|| anyhow!("bad accel `{x}` (none | blas | gpu)")))
            .collect::<Result<_>>()?;
    }
    if let Some(s) = a.get("quants") {
        fp.quants = s
            .split(',')
            .map(|x| QuantType::parse(x.trim()).ok_or_else(|| anyhow!("bad quant `{x}`")))
            .collect::<Result<_>>()?;
    }
    fp.slots = a.parse_usize("slots", fp.slots)?;
    fp.device_threads = a.parse_usize("device-threads", fp.device_threads)?;
    // `--threads` fans fleet cells over the scheduler pool; fleet.json is
    // bitwise identical for any value (CI cmp-checks a rerun).
    fp.scheduler_threads = cfg.bench.scheduler_threads.max(1);
    fp.trace.arrival_rate = a.parse_f64("arrival-rate", fp.trace.arrival_rate)?;
    fp.trace.num_requests = a.parse_usize("num-requests", fp.trace.num_requests)?;
    fp.trace.seed = a.parse_u64("seed", fp.trace.seed)?;
    if let Some(v) = a.get("prompt-len") {
        fp.trace.prompt_len = parse_len_range(v)?;
    }
    if let Some(v) = a.get("output-len") {
        fp.trace.output_len = parse_len_range(v)?;
    }
    let (mcfg, dense) = serve_originals(&cfg, a.flag("synthetic"), "fleet")?;
    let rep = run_fleet(&mcfg, &dense, &fp)?;
    println!("{}", report::fleet_section(&rep));
    let path = a
        .get("fleet-json")
        .map(PathBuf::from)
        .unwrap_or_else(|| cfg.out_dir.join("fleet.json"));
    if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&path, elib::util::json::to_string_pretty(&rep.to_json()))
        .map_err(|e| anyhow!("write {}: {e}", path.display()))?;
    println!(
        "fleet.json: {} ({} cells, {} infeasible)",
        path.display(),
        rep.cells.len(),
        rep.infeasible_count()
    );
    Ok(())
}

/// Parse one `--edge`/`--cloud` fleet list: `dev[:accel[:quant]]`
/// comma-separated (accel defaults to blas, quant to q4_0). Names are
/// synthesized as `<tier><i>:<device>` so a device may appear twice.
fn parse_replicas(
    s: &str,
    tier: elib::coordinator::Tier,
    slots: usize,
    threads: usize,
) -> Result<Vec<elib::coordinator::ReplicaSpec>> {
    let mut out = Vec::new();
    for (i, item) in s.split(',').map(str::trim).filter(|x| !x.is_empty()).enumerate() {
        let mut parts = item.split(':');
        let dev = parts.next().unwrap_or("");
        let spec = DeviceSpec::by_name(dev).ok_or_else(|| {
            anyhow!("unknown device `{dev}` in --{} (NanoPI | Xiaomi | Macbook)", tier.key())
        })?;
        let accel = match parts.next() {
            Some(x) => Accel::parse(x)
                .ok_or_else(|| anyhow!("bad accel `{x}` in `{item}` (none | blas | gpu)"))?,
            None => Accel::CpuBlas,
        };
        let quant = match parts.next() {
            Some(x) => QuantType::parse(x).ok_or_else(|| anyhow!("bad quant `{x}` in `{item}`"))?,
            None => QuantType::Q4_0,
        };
        anyhow::ensure!(
            parts.next().is_none(),
            "bad replica `{item}` in --{} (dev[:accel[:quant]])",
            tier.key()
        );
        out.push(elib::coordinator::ReplicaSpec::on_device(
            &format!("{}{}:{}", tier.key(), i, spec.name),
            tier,
            spec.name,
            accel,
            quant,
            slots,
            threads,
        ));
    }
    Ok(out)
}

fn cmd_cluster(argv: &[String]) -> Result<()> {
    use elib::coordinator::{run_cluster, ClusterParams, RoutePolicy, ScenarioSpec, Tier};
    let a = shared_opts(Command::new(
        "cluster",
        "deterministic routed serving: one seeded trace over a heterogeneous replica fleet",
    ))
    .opt("arrival-rate", None, "mean request arrivals per virtual second (default 4)")
    .opt("num-requests", None, "requests in the seeded trace (default 64)")
    .opt("seed", None, "trace seed: shapes, prompts, arrivals (default 7)")
    .opt("slots", None, "engine slots per replica (default 4)")
    .opt(
        "workload",
        None,
        "workload: poisson | closed | chat | diurnal | flash-crowd | heavy-tail (default poisson)",
    )
    .opt("clients", None, "closed-loop client count (with --workload closed)")
    .opt("turns", None, "chat turns per session lo,hi (with --workload chat)")
    .opt(
        "scheduler",
        None,
        "per-replica admission policy: fcfs | priority | chunked | slo-aware (default fcfs)",
    )
    .opt("chunk-tokens", None, "prefill chunk size (with --scheduler chunked)")
    .opt("slo-ttft", None, "interactive-tier TTFT deadline, virtual seconds (enables SLOs)")
    .opt("slo-tpot", None, "interactive-tier TPOT deadline, virtual seconds (enables SLOs)")
    .opt("kv-pool-blocks", None, "paged-KV pool budget in blocks, per replica")
    .flag("kv-prefix-share", "copy-on-write KV prefix sharing on every replica")
    .opt(
        "system-prompt",
        None,
        "seeded system-prompt tokens prepended to first turns (with --kv-prefix-share)",
    )
    .opt("prompt-len", None, "prompt length range lo,hi (default 8,24)")
    .opt("output-len", None, "output length range lo,hi (default 4,24)")
    .opt(
        "edge",
        Some("NanoPI:blas:q4_0,Xiaomi:blas:q4_0"),
        "edge replicas, dev[:accel[:quant]] comma-separated",
    )
    .opt(
        "cloud",
        Some("Macbook:gpu:q4_0"),
        "cloud replicas, dev[:accel[:quant]] comma-separated (empty = edge-only)",
    )
    .opt(
        "policies",
        None,
        "routing policies, comma-separated (default: all four)",
    )
    .opt("device-threads", None, "device CPU threads for each replica clock (default 4)")
    .opt("cluster-json", None, "machine-readable output path (default <out>/cluster.json)")
    .flag("synthetic", "force the seeded synthetic tiny model (no artifacts needed)")
    .parse(argv)
    .map_err(|e| anyhow!("{e}"))?;
    let cfg = base_config(&a)?;

    // The traffic side is one ScenarioSpec — the same unified grammar
    // `serve` resolves into its ServeParams — seeded from the config's
    // serve section, with the per-replica knobs (device) held back for
    // the ReplicaSpecs.
    let mut spec = ScenarioSpec::from_params(&cfg.serve);
    spec.device = None;
    spec.arrival_rate = a.parse_f64("arrival-rate", spec.arrival_rate)?;
    spec.num_requests = a.parse_usize("num-requests", spec.num_requests)?;
    spec.seed = a.parse_u64("seed", spec.seed)?;
    spec.slots = a.parse_usize("slots", spec.slots)?;
    if let Some(v) = a.get("prompt-len") {
        spec.prompt_len = parse_len_range(v)?;
    }
    if let Some(v) = a.get("output-len") {
        spec.output_len = parse_len_range(v)?;
    }
    if let Some(w) = a.get("workload") {
        spec.workload = w.to_string();
    }
    if a.get("clients").is_some() {
        spec.clients = Some(a.parse_usize("clients", 4)?);
    }
    if let Some(v) = a.get("turns") {
        spec.turns = Some(parse_len_range(v)?);
    }
    if let Some(s) = a.get("scheduler") {
        spec.scheduler = s.to_string();
    }
    if a.get("chunk-tokens").is_some() {
        spec.chunk_tokens = Some(a.parse_usize("chunk-tokens", 32)?);
    }
    if a.get("slo-ttft").is_some() || a.get("slo-tpot").is_some() {
        spec.slo = Some(elib::coordinator::SloSpec {
            ttft: a.parse_f64("slo-ttft", f64::INFINITY)?,
            tpot: a.parse_f64("slo-tpot", f64::INFINITY)?,
        });
    }
    if let Some(v) = a.get("kv-pool-blocks") {
        let blocks = v
            .parse::<usize>()
            .map_err(|_| anyhow!("bad --kv-pool-blocks `{v}`"))?;
        anyhow::ensure!(blocks >= 1, "--kv-pool-blocks must be at least 1");
        spec.pool_blocks = Some(blocks);
    }
    if a.flag("kv-prefix-share") {
        spec.prefix_share = true;
    }
    spec.system_prompt = a.parse_usize("system-prompt", spec.system_prompt)?;
    anyhow::ensure!(
        spec.system_prompt == 0 || spec.prefix_share,
        "--system-prompt only pays off with --kv-prefix-share \
         (a shared prefix nobody shares just burns prefill)"
    );
    // Surface spec errors (bad workload/scheduler names, knob misuse)
    // before any model loading.
    spec.resolve().map(|_| ())?;

    let dev_threads = a.parse_usize("device-threads", 4)?;
    let mut replicas = parse_replicas(a.get_or("edge", ""), Tier::Edge, spec.slots, dev_threads)?;
    replicas.extend(parse_replicas(a.get_or("cloud", ""), Tier::Cloud, spec.slots, dev_threads)?);
    anyhow::ensure!(!replicas.is_empty(), "--edge/--cloud produced an empty fleet");
    let policies = match a.get("policies") {
        Some(s) => s
            .split(',')
            .map(str::trim)
            .filter(|x| !x.is_empty())
            .map(|x| {
                RoutePolicy::parse(x)
                    .ok_or_else(|| anyhow!("bad policy `{x}` ({})", RoutePolicy::names()))
            })
            .collect::<Result<Vec<_>>>()?,
        None => RoutePolicy::ALL.to_vec(),
    };
    let cp = ClusterParams {
        scenario: spec,
        replicas,
        policies,
        // `--threads` fans the policies over the scheduler pool;
        // cluster.json is bitwise identical for any value (CI cmp-checks
        // a rerun).
        threads: cfg.bench.scheduler_threads.max(1),
    };
    let (mcfg, dense) = serve_originals(&cfg, a.flag("synthetic"), "cluster")?;
    let rep = run_cluster(&mcfg, &dense, &cp)?;
    println!("{}", report::cluster_section(&rep));
    let path = a
        .get("cluster-json")
        .map(PathBuf::from)
        .unwrap_or_else(|| cfg.out_dir.join("cluster.json"));
    if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&path, elib::util::json::to_string_pretty(&rep.to_json()))
        .map_err(|e| anyhow!("write {}: {e}", path.display()))?;
    println!(
        "cluster.json: {} ({} policies, {} replicas)",
        path.display(),
        rep.policies.len(),
        rep.params.replicas.len()
    );
    Ok(())
}

fn cmd_bench_check(argv: &[String]) -> Result<()> {
    let a = Command::new("bench-check", "compare a serve bench.json against a baseline")
        .opt("bench", Some("bench.json"), "current bench.json")
        .opt("baseline", Some("ci/bench_baseline.json"), "committed baseline")
        .opt("tol-pct", None, "relative tolerance band, percent (default 5)")
        .flag(
            "write-baseline",
            "promote the current bench.json: write it (plus tolerance_pct) to --baseline",
        )
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let read = |key: &str| -> Result<elib::util::json::Json> {
        let path = a.get(key).expect("opt has a default");
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read {key} `{path}`: {e}"))?;
        elib::util::json::parse(&text).map_err(|e| anyhow!("parse {key} `{path}`: {e}"))
    };
    let current = read("bench")?;
    if a.flag("write-baseline") {
        // Promotion: the current run becomes the committed reference.
        // Tolerance precedence: an explicit --tol-pct wins, else the old
        // baseline's band carries over, else the 5% default.
        let tol = match a.get("tol-pct") {
            Some(_) => a.parse_f64("tol-pct", 5.0)?,
            None => read("baseline")
                .ok()
                .and_then(|b| b.get("tolerance_pct").and_then(elib::util::json::Json::as_f64))
                .unwrap_or(5.0),
        };
        let mut doc = current;
        if let elib::util::json::Json::Obj(m) = &mut doc {
            m.insert("tolerance_pct".into(), elib::util::json::Json::Num(tol));
        } else {
            return Err(anyhow!("bench.json must be an object to promote"));
        }
        let path = a.get("baseline").expect("opt has a default");
        std::fs::write(path, elib::util::json::to_string_pretty(&doc))
            .map_err(|e| anyhow!("write baseline `{path}`: {e}"))?;
        println!(
            "baseline promoted: {path} (tolerance {tol}%) — commit it to arm the gate"
        );
        return Ok(());
    }
    let baseline = read("baseline")?;
    let cmp = compare_bench(&current, &baseline, a.parse_f64("tol-pct", 5.0)?);
    for n in &cmp.notes {
        println!("note: {n}");
    }
    if cmp.is_pass() {
        println!("bench-check OK (no regressions beyond the tolerance band)");
        Ok(())
    } else {
        for v in &cmp.violations {
            eprintln!("REGRESSION: {v}");
        }
        Err(anyhow!("bench-check FAILED: {} regression(s)", cmp.violations.len()))
    }
}

fn cmd_generate(argv: &[String]) -> Result<()> {
    let a = shared_opts(Command::new("generate", "generate text with the native engine"))
        .opt("quant", Some("q4_0"), "weight format")
        .opt("backend", Some("parallel"), "naive | parallel | gpu | gpu-degraded")
        .opt("prompt", Some("the benchmark measures "), "prompt text")
        .opt("tokens", Some("64"), "tokens to generate")
        .opt("top-k", Some("1"), "sampler top-k (1 = greedy)")
        .opt("seed", Some("42"), "sampler seed")
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let cfg = base_config(&a)?;
    let q = QuantType::parse(a.get_or("quant", "q4_0"))
        .ok_or_else(|| anyhow!("bad --quant"))?;
    let backend = match a.get_or("backend", "parallel") {
        "naive" => BackendKind::Naive,
        "parallel" => BackendKind::Parallel(4),
        "gpu" => BackendKind::Gpu(Precision::Full),
        "gpu-degraded" => BackendKind::Gpu(Precision::DegradedF16),
        other => return Err(anyhow!("bad --backend `{other}`")),
    };
    // Quantize on the fly from the original artifacts.
    std::fs::create_dir_all(&cfg.out_dir)?;
    let (mcfg, dense) = elib::coordinator::flow::load_original(
        &cfg.artifacts_dir.join("tiny_llama_f32.eguf"),
    )?;
    let mf = elib::model::testutil::build_model_file(&mcfg, q, &dense);
    let weights = ModelWeights::load(&mf)?;
    let param_bytes = weights.bytes_per_token();
    let mut engine = Engine::new(weights, backend);
    let tok = ByteTokenizer;
    let prompt = tok.encode(a.get_or("prompt", "the benchmark measures "));
    let n = a.parse_usize("tokens", 64)?;
    let k = a.parse_usize("top-k", 1)?;
    let mut sampler = if k <= 1 {
        Sampler::Greedy
    } else {
        Sampler::top_k(k, 0.8, a.parse_u64("seed", 42)?)
    };
    let stats = generate(&mut engine, &prompt, n, &mut sampler)?;
    println!("{}", tok.decode(&stats.tokens));
    println!("---");
    println!(
        "quant={} backend={} prefill={:.1}ms decode={:.2} tok/s tpot={:.2}ms",
        q.name(),
        backend.label(),
        stats.prefill_secs * 1e3,
        stats.decode_throughput(),
        stats.tpot_secs() * 1e3,
    );
    let mbu = metrics::mbu(param_bytes, 0, stats.tpot_secs(), cfg.bench.host_peak_bw);
    println!(
        "weight stream: {}/token, host MBU {:.3} (vs assumed {:.0} GB/s peak)",
        elib::util::table::human_bytes(param_bytes),
        mbu,
        cfg.bench.host_peak_bw / 1e9
    );
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<()> {
    let a = Command::new("report", "print static tables")
        .flag("devices", "Table 1")
        .flag("storage", "Table 3")
        .flag("quant", "Table 5")
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let all = !a.flag("devices") && !a.flag("storage") && !a.flag("quant");
    if all || a.flag("devices") {
        println!("{}", report::table1().render());
    }
    if all || a.flag("storage") {
        println!("{}", report::table3().render());
    }
    if all || a.flag("quant") {
        println!("{}", report::table5().render());
    }
    Ok(())
}

fn cmd_lint(argv: &[String]) -> Result<()> {
    let a = Command::new("lint", "repo static analysis: determinism zones + doc contracts")
        .opt("root", None, "repo root (default: walk up from the current directory)")
        .opt("lint-json", None, "machine-readable findings path (written in addition to stdout)")
        .flag("fixtures", "lint the deliberately-bad corpus under rust/tests/lint_fixtures")
        .flag(
            "expect-all-rules",
            "with --fixtures: exit 0 iff every rule fired at least once (CI self-test)",
        )
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    anyhow::ensure!(
        !a.flag("expect-all-rules") || a.flag("fixtures"),
        "--expect-all-rules only applies with --fixtures"
    );
    let root = match a.get("root") {
        Some(r) => PathBuf::from(r),
        None => {
            let cwd = std::env::current_dir()?;
            elib::analysis::find_root(&cwd).ok_or_else(|| {
                anyhow!(
                    "no repo root at or above {} (looking for rust/src + DESIGN.md); \
                     pass --root",
                    cwd.display()
                )
            })?
        }
    };
    let rep = if a.flag("fixtures") {
        elib::analysis::run_fixture_lint(&root)?
    } else {
        elib::analysis::run_lint(&root)?
    };
    print!("{}", elib::analysis::reportfmt::render_text(&rep.findings, &rep.allows));
    if let Some(path) = a.get("lint-json") {
        let doc = elib::analysis::reportfmt::to_json(&rep.findings, &rep.allows);
        std::fs::write(path, elib::util::json::to_string_pretty(&doc))
            .map_err(|e| anyhow!("write {path}: {e}"))?;
        println!("lint.json: {path}");
    }
    if a.flag("expect-all-rules") {
        // Self-test mode: the corpus is *supposed* to be dirty — success
        // means every rule in the book produced at least one finding.
        let fired = rep.rules_fired();
        let missing: Vec<&str> = elib::analysis::rules::RULES
            .iter()
            .copied()
            .filter(|r| !fired.contains(r))
            .collect();
        anyhow::ensure!(
            missing.is_empty(),
            "fixture corpus never fired: {}",
            missing.join(", ")
        );
        println!(
            "fixture corpus demonstrates all {} rules",
            elib::analysis::rules::RULES.len()
        );
        return Ok(());
    }
    anyhow::ensure!(
        rep.findings.is_empty(),
        "lint found {} finding(s)",
        rep.findings.len()
    );
    Ok(())
}

fn cmd_pjrt_check(argv: &[String]) -> Result<()> {
    let a = Command::new("pjrt-check", "cross-check PJRT vs native logits")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .opt("variant", Some("f32"), "f32 | q8_0")
        .opt("tokens", Some("8"), "tokens to compare")
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let arts = Artifacts::load(Path::new(a.get_or("artifacts", "artifacts")))?;
    let variant = match a.get_or("variant", "f32") {
        "f32" => PjrtVariant::F32,
        "q8_0" => PjrtVariant::Q8_0,
        other => return Err(anyhow!("bad --variant `{other}`")),
    };
    let mut pjrt = PjrtEngine::load(&arts, variant)?;
    // Native engine over the same weights/format.
    let mf = arts.weights_f32()?;
    let mut dense = elib::model::testutil::DenseWeights::new();
    for (name, t) in &mf.tensors {
        dense.insert(name.clone(), (t.dequantize(), t.rows, t.cols));
    }
    let native_q = match variant {
        PjrtVariant::F32 => QuantType::F32,
        PjrtVariant::Q8_0 => QuantType::Q8_0,
    };
    let nmf = elib::model::testutil::build_model_file(&arts.config, native_q, &dense);
    let mut native = Engine::new(ModelWeights::load(&nmf)?, BackendKind::Naive);
    let n = a.parse_usize("tokens", 8)?;
    let all = ByteTokenizer.encode("the cache streams the weights ");
    let toks: Vec<u32> = all[..n.min(all.len())].to_vec();
    let mut worst = 0f32;
    for (i, t) in toks.iter().enumerate() {
        let lp = pjrt.decode(*t)?;
        let ln = native.forward(*t, i)?;
        let d = elib::util::stats::max_abs_diff(&lp, ln);
        worst = worst.max(d);
        println!("pos {i}: max |pjrt - native| = {d:.6}");
    }
    anyhow::ensure!(worst < 2e-3, "cross-check FAILED: {worst} >= 2e-3");
    println!("pjrt-check OK ({} tokens, worst {:.2e})", toks.len(), worst);
    Ok(())
}
