//! `elib` — the ELIB command-line launcher.
//!
//! Subcommands:
//!   quantize    run the automatic quantization flow
//!   bench       full Algorithm-1 benchmark grid (Table 6 + figures)
//!   generate    run the native engine on a prompt and print metrics
//!   report      print the static tables (devices / storage / quant)
//!   pjrt-check  load the AOT artifacts and cross-check PJRT vs native

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Result};

use elib::coordinator::{Elib, ElibConfig};
use elib::graph::{generate, Engine, Sampler};
use elib::kernel::{BackendKind, Precision};
use elib::metrics;
use elib::model::{ByteTokenizer, ModelWeights};
use elib::quant::QuantType;
use elib::report;
use elib::runtime::{Artifacts, PjrtEngine, PjrtVariant};
use elib::util::cli::Command;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &[String]) -> Result<()> {
    let sub = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1.min(args.len())..];
    match sub {
        "quantize" => cmd_quantize(rest),
        "bench" => cmd_bench(rest),
        "generate" => cmd_generate(rest),
        "report" => cmd_report(rest),
        "pjrt-check" => cmd_pjrt_check(rest),
        "help" | "--help" | "-h" => {
            println!(
                "elib — edge LLM inference benchmarking (ELIB reproduction)\n\n\
                 subcommands:\n  \
                 quantize    run the automatic quantization flow\n  \
                 bench       full benchmark grid (Table 6 + all figures)\n  \
                 generate    generate text with the native engine\n  \
                 report      print the static tables\n  \
                 pjrt-check  cross-check the PJRT path against native\n\n\
                 `elib <cmd> --help` for options"
            );
            Ok(())
        }
        other => Err(anyhow!("unknown subcommand `{other}` (try `elib help`)")),
    }
}

fn base_config(a: &elib::util::cli::Args) -> Result<ElibConfig> {
    let mut cfg = match a.get("config") {
        Some(p) => ElibConfig::from_file(Path::new(p))?,
        None => ElibConfig::default(),
    };
    if let Some(d) = a.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(d);
    }
    if let Some(d) = a.get("out") {
        cfg.out_dir = PathBuf::from(d);
    }
    if let Some(s) = a.get("schemes") {
        cfg.quant_schemes = s
            .split(',')
            .map(|x| QuantType::parse(x.trim()).ok_or_else(|| anyhow!("bad scheme `{x}`")))
            .collect::<Result<_>>()?;
    }
    cfg.bench.iterations = a.parse_usize("iterations", cfg.bench.iterations)?;
    cfg.bench.gen_tokens = a.parse_usize("gen-tokens", cfg.bench.gen_tokens)?;
    cfg.bench.ppl_tokens = a.parse_usize("ppl-tokens", cfg.bench.ppl_tokens)?;
    cfg.bench.batch_size = a.parse_usize("batch", cfg.bench.batch_size)?;
    if let Some(s) = a.get("batch-sizes") {
        cfg.bench.batch_sizes = s
            .split(',')
            .map(|x| match x.trim().parse::<usize>() {
                Ok(b) if b >= 1 => Ok(b),
                _ => Err(anyhow!("bad batch size `{x}` in --batch-sizes")),
            })
            .collect::<Result<_>>()?;
    }
    cfg.bench.scheduler_threads = a.parse_usize("threads", cfg.bench.scheduler_threads)?;
    Ok(cfg)
}

fn shared_opts(c: Command) -> Command {
    c.opt("config", None, "JSON config file")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .opt("out", Some("target/elib-out"), "output directory")
        .opt("schemes", None, "comma-separated quant schemes")
        .opt("iterations", None, "benchmark iterations")
        .opt("gen-tokens", None, "tokens generated per run")
        .opt("ppl-tokens", None, "eval tokens for perplexity")
        .opt("batch", None, "simulated batch size")
        .opt("batch-sizes", None, "host batch sweep, comma-separated (e.g. 1,2,4,8)")
        .opt("threads", None, "benchmark scheduler worker threads")
}

fn cmd_quantize(argv: &[String]) -> Result<()> {
    let a = shared_opts(Command::new("quantize", "run the automatic quantization flow"))
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let cfg = base_config(&a)?;
    std::fs::create_dir_all(&cfg.out_dir)?;
    let models = Elib::new(cfg).quantization_flow()?;
    println!("{} quantized models written", models.len());
    Ok(())
}

fn cmd_bench(argv: &[String]) -> Result<()> {
    let a = shared_opts(Command::new("bench", "full Algorithm-1 benchmark grid"))
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let cfg = base_config(&a)?;
    let (rep, path) = Elib::new(cfg).run()?;
    println!("\n{}", report::full_report(&rep));
    println!("machine-readable report: {}", path.display());
    Ok(())
}

fn cmd_generate(argv: &[String]) -> Result<()> {
    let a = shared_opts(Command::new("generate", "generate text with the native engine"))
        .opt("quant", Some("q4_0"), "weight format")
        .opt("backend", Some("parallel"), "naive | parallel | gpu | gpu-degraded")
        .opt("prompt", Some("the benchmark measures "), "prompt text")
        .opt("tokens", Some("64"), "tokens to generate")
        .opt("top-k", Some("1"), "sampler top-k (1 = greedy)")
        .opt("seed", Some("42"), "sampler seed")
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let cfg = base_config(&a)?;
    let q = QuantType::parse(a.get_or("quant", "q4_0"))
        .ok_or_else(|| anyhow!("bad --quant"))?;
    let backend = match a.get_or("backend", "parallel") {
        "naive" => BackendKind::Naive,
        "parallel" => BackendKind::Parallel(4),
        "gpu" => BackendKind::Gpu(Precision::Full),
        "gpu-degraded" => BackendKind::Gpu(Precision::DegradedF16),
        other => return Err(anyhow!("bad --backend `{other}`")),
    };
    // Quantize on the fly from the original artifacts.
    std::fs::create_dir_all(&cfg.out_dir)?;
    let (mcfg, dense) = elib::coordinator::flow::load_original(
        &cfg.artifacts_dir.join("tiny_llama_f32.eguf"),
    )?;
    let mf = elib::model::testutil::build_model_file(&mcfg, q, &dense);
    let weights = ModelWeights::load(&mf)?;
    let param_bytes = weights.bytes_per_token();
    let mut engine = Engine::new(weights, backend);
    let tok = ByteTokenizer;
    let prompt = tok.encode(a.get_or("prompt", "the benchmark measures "));
    let n = a.parse_usize("tokens", 64)?;
    let k = a.parse_usize("top-k", 1)?;
    let mut sampler = if k <= 1 {
        Sampler::Greedy
    } else {
        Sampler::top_k(k, 0.8, a.parse_u64("seed", 42)?)
    };
    let stats = generate(&mut engine, &prompt, n, &mut sampler)?;
    println!("{}", tok.decode(&stats.tokens));
    println!("---");
    println!(
        "quant={} backend={} prefill={:.1}ms decode={:.2} tok/s tpot={:.2}ms",
        q.name(),
        backend.label(),
        stats.prefill_secs * 1e3,
        stats.decode_throughput(),
        stats.tpot_secs() * 1e3,
    );
    let mbu = metrics::mbu(param_bytes, 0, stats.tpot_secs(), cfg.bench.host_peak_bw);
    println!(
        "weight stream: {}/token, host MBU {:.3} (vs assumed {:.0} GB/s peak)",
        elib::util::table::human_bytes(param_bytes),
        mbu,
        cfg.bench.host_peak_bw / 1e9
    );
    Ok(())
}

fn cmd_report(argv: &[String]) -> Result<()> {
    let a = Command::new("report", "print static tables")
        .flag("devices", "Table 1")
        .flag("storage", "Table 3")
        .flag("quant", "Table 5")
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let all = !a.flag("devices") && !a.flag("storage") && !a.flag("quant");
    if all || a.flag("devices") {
        println!("{}", report::table1().render());
    }
    if all || a.flag("storage") {
        println!("{}", report::table3().render());
    }
    if all || a.flag("quant") {
        println!("{}", report::table5().render());
    }
    Ok(())
}

fn cmd_pjrt_check(argv: &[String]) -> Result<()> {
    let a = Command::new("pjrt-check", "cross-check PJRT vs native logits")
        .opt("artifacts", Some("artifacts"), "artifacts directory")
        .opt("variant", Some("f32"), "f32 | q8_0")
        .opt("tokens", Some("8"), "tokens to compare")
        .parse(argv)
        .map_err(|e| anyhow!("{e}"))?;
    let arts = Artifacts::load(Path::new(a.get_or("artifacts", "artifacts")))?;
    let variant = match a.get_or("variant", "f32") {
        "f32" => PjrtVariant::F32,
        "q8_0" => PjrtVariant::Q8_0,
        other => return Err(anyhow!("bad --variant `{other}`")),
    };
    let mut pjrt = PjrtEngine::load(&arts, variant)?;
    // Native engine over the same weights/format.
    let mf = arts.weights_f32()?;
    let mut dense = elib::model::testutil::DenseWeights::new();
    for (name, t) in &mf.tensors {
        dense.insert(name.clone(), (t.dequantize(), t.rows, t.cols));
    }
    let native_q = match variant {
        PjrtVariant::F32 => QuantType::F32,
        PjrtVariant::Q8_0 => QuantType::Q8_0,
    };
    let nmf = elib::model::testutil::build_model_file(&arts.config, native_q, &dense);
    let mut native = Engine::new(ModelWeights::load(&nmf)?, BackendKind::Naive);
    let n = a.parse_usize("tokens", 8)?;
    let all = ByteTokenizer.encode("the cache streams the weights ");
    let toks: Vec<u32> = all[..n.min(all.len())].to_vec();
    let mut worst = 0f32;
    for (i, t) in toks.iter().enumerate() {
        let lp = pjrt.decode(*t)?;
        let ln = native.forward(*t, i)?;
        let d = elib::util::stats::max_abs_diff(&lp, ln);
        worst = worst.max(d);
        println!("pos {i}: max |pjrt - native| = {d:.6}");
    }
    anyhow::ensure!(worst < 2e-3, "cross-check FAILED: {worst} >= 2e-3");
    println!("pjrt-check OK ({} tokens, worst {:.2e})", toks.len(), worst);
    Ok(())
}
