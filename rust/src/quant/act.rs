//! Activation quantization for the quantized dot-product path.
//!
//! Like ggml, the hot matmul quantizes the *activation* vector once per row
//! of output to 8-bit blocks, then performs integer dot products against
//! the packed weights. `ActBlock` keeps the f32 scale and the sum of the
//! quants (`sum_q`), which the affine formats (q4_1/q5_1) need to fold the
//! weight zero-point `m` into the dot product:
//!
//!   Σ w·a = Σ (q_w·d_w + m)·(q_a·d_a) = d_w·d_a·Σ q_w q_a + m·d_a·Σ q_a

use super::QK;

/// One quantized activation block: 32 int8 quants + f32 scale.
#[derive(Clone, Copy, Debug)]
pub struct ActBlock {
    pub d: f32,
    pub qs: [i8; QK],
    /// Σ qs — cached for affine weight formats.
    pub sum_q: i32,
}

impl ActBlock {
    pub fn quantize(chunk: &[f32]) -> ActBlock {
        debug_assert_eq!(chunk.len(), QK);
        let amax = chunk.iter().fold(0f32, |a, x| a.max(x.abs()));
        let d = amax / 127.0;
        let id = if d != 0.0 { 1.0 / d } else { 0.0 };
        let mut qs = [0i8; QK];
        let mut sum_q = 0i32;
        for (j, &x) in chunk.iter().enumerate() {
            let q = (x * id).round().clamp(-127.0, 127.0) as i32;
            qs[j] = q as i8;
            sum_q += q;
        }
        ActBlock { d, qs, sum_q }
    }

    pub fn dequantize(&self) -> [f32; QK] {
        let mut out = [0f32; QK];
        for (o, q) in out.iter_mut().zip(self.qs.iter()) {
            *o = *q as f32 * self.d;
        }
        out
    }
}

/// Quantize a full activation vector (length multiple of 32).
pub fn quantize_activations(x: &[f32]) -> Vec<ActBlock> {
    assert_eq!(x.len() % QK, 0, "activation length {} % {QK} != 0", x.len());
    x.chunks_exact(QK).map(ActBlock::quantize).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_small() {
        let mut rng = Rng::new(2);
        let x = rng.normal_vec(QK * 4, 1.0);
        let blocks = quantize_activations(&x);
        let amax = x.iter().fold(0f32, |a, v| a.max(v.abs()));
        for (bi, b) in blocks.iter().enumerate() {
            let back = b.dequantize();
            for j in 0..QK {
                assert!((back[j] - x[bi * QK + j]).abs() <= amax / 127.0 * 0.51);
            }
        }
    }

    #[test]
    fn sum_q_matches() {
        let mut rng = Rng::new(3);
        let x = rng.normal_vec(QK, 1.0);
        let b = ActBlock::quantize(&x);
        assert_eq!(b.sum_q, b.qs.iter().map(|q| *q as i32).sum::<i32>());
    }

    #[test]
    fn zero_vector() {
        let b = ActBlock::quantize(&[0.0; QK]);
        assert_eq!(b.d, 0.0);
        assert!(b.qs.iter().all(|q| *q == 0));
    }
}
