//! Per-format block codecs (quantize_row / dequantize_row).
//!
//! Quantization math follows ggml's reference implementations:
//! symmetric (`_0`) formats derive the scale from the signed value of
//! largest magnitude (`d = amax_signed / -2^(bits-1)`), asymmetric (`_1`)
//! formats use min/max affine mapping. Scales are stored as f16.

use crate::util::half::{f16_to_f32, f32_to_f16, round_f16};

use super::{QuantType, QK};

#[inline]
fn put_f16(dst: &mut [u8], off: usize, x: f32) {
    let h = f32_to_f16(x);
    dst[off] = (h & 0xff) as u8;
    dst[off + 1] = (h >> 8) as u8;
}

#[inline]
pub(crate) fn get_f16(src: &[u8], off: usize) -> f32 {
    f16_to_f32(u16::from_le_bytes([src[off], src[off + 1]]))
}

#[inline]
fn put_u32(dst: &mut [u8], off: usize, x: u32) {
    dst[off..off + 4].copy_from_slice(&x.to_le_bytes());
}

#[inline]
pub(crate) fn get_u32(src: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([src[off], src[off + 1], src[off + 2], src[off + 3]])
}

/// Dispatch: quantize one row (length multiple of the block size).
pub fn quantize_row(qtype: QuantType, src: &[f32], dst: &mut [u8]) {
    assert_eq!(dst.len(), qtype.row_bytes(src.len()));
    match qtype {
        QuantType::F32 => {
            for (i, x) in src.iter().enumerate() {
                dst[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
            }
        }
        QuantType::F16 => {
            for (i, x) in src.iter().enumerate() {
                let h = f32_to_f16(*x);
                dst[i * 2] = (h & 0xff) as u8;
                dst[i * 2 + 1] = (h >> 8) as u8;
            }
        }
        QuantType::Q4_0 => row_q4_0(src, dst),
        QuantType::Q4_1 => row_q4_1(src, dst),
        QuantType::Q5_0 => row_q5_0(src, dst),
        QuantType::Q5_1 => row_q5_1(src, dst),
        QuantType::Q8_0 => row_q8_0(src, dst),
    }
}

/// Dispatch: dequantize one row.
pub fn dequantize_row(qtype: QuantType, src: &[u8], dst: &mut [f32]) {
    assert_eq!(src.len(), qtype.row_bytes(dst.len()));
    match qtype {
        QuantType::F32 => {
            for (i, d) in dst.iter_mut().enumerate() {
                *d = f32::from_le_bytes([src[i * 4], src[i * 4 + 1], src[i * 4 + 2], src[i * 4 + 3]]);
            }
        }
        QuantType::F16 => {
            for (i, d) in dst.iter_mut().enumerate() {
                *d = f16_to_f32(u16::from_le_bytes([src[i * 2], src[i * 2 + 1]]));
            }
        }
        QuantType::Q4_0 => derow_q4_0(src, dst),
        QuantType::Q4_1 => derow_q4_1(src, dst),
        QuantType::Q5_0 => derow_q5_0(src, dst),
        QuantType::Q5_1 => derow_q5_1(src, dst),
        QuantType::Q8_0 => derow_q8_0(src, dst),
    }
}

// --- q4_0: w = (q - 8) * d, d = signed_amax / -8 ------------------------

fn row_q4_0(src: &[f32], dst: &mut [u8]) {
    let bb = QuantType::Q4_0.block_bytes();
    for (bi, chunk) in src.chunks_exact(QK).enumerate() {
        let out = &mut dst[bi * bb..(bi + 1) * bb];
        // Value of largest magnitude, sign preserved (ggml convention: the
        // extreme value maps exactly to quant level 0 or 15).
        let mut amax = 0.0f32;
        let mut vmax = 0.0f32;
        for &x in chunk {
            if x.abs() > amax {
                amax = x.abs();
                vmax = x;
            }
        }
        let d = vmax / -8.0;
        let d = round_f16(d);
        let id = if d != 0.0 { 1.0 / d } else { 0.0 };
        put_f16(out, 0, d);
        for j in 0..QK / 2 {
            let q0 = quant_nibble(chunk[j] * id, 8.0, 15);
            let q1 = quant_nibble(chunk[j + QK / 2] * id, 8.0, 15);
            out[2 + j] = q0 | (q1 << 4);
        }
    }
}

fn derow_q4_0(src: &[u8], dst: &mut [f32]) {
    let bb = QuantType::Q4_0.block_bytes();
    for (bi, chunk) in dst.chunks_exact_mut(QK).enumerate() {
        let inp = &src[bi * bb..(bi + 1) * bb];
        let d = get_f16(inp, 0);
        for j in 0..QK / 2 {
            let b = inp[2 + j];
            chunk[j] = ((b & 0x0f) as i32 - 8) as f32 * d;
            chunk[j + QK / 2] = ((b >> 4) as i32 - 8) as f32 * d;
        }
    }
}

// --- q4_1: w = q * d + m, affine over [min, max] ------------------------

fn row_q4_1(src: &[f32], dst: &mut [u8]) {
    let bb = QuantType::Q4_1.block_bytes();
    for (bi, chunk) in src.chunks_exact(QK).enumerate() {
        let out = &mut dst[bi * bb..(bi + 1) * bb];
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in chunk {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let d = round_f16((hi - lo) / 15.0);
        let m = round_f16(lo);
        let id = if d != 0.0 { 1.0 / d } else { 0.0 };
        put_f16(out, 0, d);
        put_f16(out, 2, m);
        for j in 0..QK / 2 {
            let q0 = quant_nibble((chunk[j] - m) * id, 0.0, 15);
            let q1 = quant_nibble((chunk[j + QK / 2] - m) * id, 0.0, 15);
            out[4 + j] = q0 | (q1 << 4);
        }
    }
}

fn derow_q4_1(src: &[u8], dst: &mut [f32]) {
    let bb = QuantType::Q4_1.block_bytes();
    for (bi, chunk) in dst.chunks_exact_mut(QK).enumerate() {
        let inp = &src[bi * bb..(bi + 1) * bb];
        let d = get_f16(inp, 0);
        let m = get_f16(inp, 2);
        for j in 0..QK / 2 {
            let b = inp[4 + j];
            chunk[j] = (b & 0x0f) as f32 * d + m;
            chunk[j + QK / 2] = (b >> 4) as f32 * d + m;
        }
    }
}

// --- q5_0: w = (q - 16) * d, 5th bits in qh ------------------------------

fn row_q5_0(src: &[f32], dst: &mut [u8]) {
    let bb = QuantType::Q5_0.block_bytes();
    for (bi, chunk) in src.chunks_exact(QK).enumerate() {
        let out = &mut dst[bi * bb..(bi + 1) * bb];
        let mut amax = 0.0f32;
        let mut vmax = 0.0f32;
        for &x in chunk {
            if x.abs() > amax {
                amax = x.abs();
                vmax = x;
            }
        }
        let d = round_f16(vmax / -16.0);
        let id = if d != 0.0 { 1.0 / d } else { 0.0 };
        put_f16(out, 0, d);
        let mut qh = 0u32;
        let mut qs = [0u8; QK / 2];
        for j in 0..QK / 2 {
            let q0 = quant_5bit(chunk[j] * id);
            let q1 = quant_5bit(chunk[j + QK / 2] * id);
            qs[j] = (q0 & 0x0f) | ((q1 & 0x0f) << 4);
            qh |= ((q0 as u32 >> 4) & 1) << j;
            qh |= ((q1 as u32 >> 4) & 1) << (j + QK / 2);
        }
        put_u32(out, 2, qh);
        out[6..6 + QK / 2].copy_from_slice(&qs);
    }
}

fn derow_q5_0(src: &[u8], dst: &mut [f32]) {
    let bb = QuantType::Q5_0.block_bytes();
    for (bi, chunk) in dst.chunks_exact_mut(QK).enumerate() {
        let inp = &src[bi * bb..(bi + 1) * bb];
        let d = get_f16(inp, 0);
        let qh = get_u32(inp, 2);
        for j in 0..QK / 2 {
            let b = inp[6 + j];
            let q0 = (b & 0x0f) as u32 | (((qh >> j) & 1) << 4);
            let q1 = (b >> 4) as u32 | (((qh >> (j + QK / 2)) & 1) << 4);
            chunk[j] = (q0 as i32 - 16) as f32 * d;
            chunk[j + QK / 2] = (q1 as i32 - 16) as f32 * d;
        }
    }
}

// --- q5_1: w = q * d + m, 5th bits in qh ---------------------------------

fn row_q5_1(src: &[f32], dst: &mut [u8]) {
    let bb = QuantType::Q5_1.block_bytes();
    for (bi, chunk) in src.chunks_exact(QK).enumerate() {
        let out = &mut dst[bi * bb..(bi + 1) * bb];
        let mut lo = f32::INFINITY;
        let mut hi = f32::NEG_INFINITY;
        for &x in chunk {
            lo = lo.min(x);
            hi = hi.max(x);
        }
        let d = round_f16((hi - lo) / 31.0);
        let m = round_f16(lo);
        let id = if d != 0.0 { 1.0 / d } else { 0.0 };
        put_f16(out, 0, d);
        put_f16(out, 2, m);
        let mut qh = 0u32;
        let mut qs = [0u8; QK / 2];
        for j in 0..QK / 2 {
            let q0 = quant_5bit_affine((chunk[j] - m) * id);
            let q1 = quant_5bit_affine((chunk[j + QK / 2] - m) * id);
            qs[j] = (q0 & 0x0f) | ((q1 & 0x0f) << 4);
            qh |= ((q0 as u32 >> 4) & 1) << j;
            qh |= ((q1 as u32 >> 4) & 1) << (j + QK / 2);
        }
        put_u32(out, 4, qh);
        out[8..8 + QK / 2].copy_from_slice(&qs);
    }
}

fn derow_q5_1(src: &[u8], dst: &mut [f32]) {
    let bb = QuantType::Q5_1.block_bytes();
    for (bi, chunk) in dst.chunks_exact_mut(QK).enumerate() {
        let inp = &src[bi * bb..(bi + 1) * bb];
        let d = get_f16(inp, 0);
        let m = get_f16(inp, 2);
        let qh = get_u32(inp, 4);
        for j in 0..QK / 2 {
            let b = inp[8 + j];
            let q0 = (b & 0x0f) as u32 | (((qh >> j) & 1) << 4);
            let q1 = (b >> 4) as u32 | (((qh >> (j + QK / 2)) & 1) << 4);
            chunk[j] = q0 as f32 * d + m;
            chunk[j + QK / 2] = q1 as f32 * d + m;
        }
    }
}

// --- q8_0: w = q * d -----------------------------------------------------

fn row_q8_0(src: &[f32], dst: &mut [u8]) {
    let bb = QuantType::Q8_0.block_bytes();
    for (bi, chunk) in src.chunks_exact(QK).enumerate() {
        let out = &mut dst[bi * bb..(bi + 1) * bb];
        let amax = chunk.iter().fold(0f32, |a, x| a.max(x.abs()));
        let d = round_f16(amax / 127.0);
        let id = if d != 0.0 { 1.0 / d } else { 0.0 };
        put_f16(out, 0, d);
        for (j, &x) in chunk.iter().enumerate() {
            let q = (x * id).round().clamp(-127.0, 127.0) as i8;
            out[2 + j] = q as u8;
        }
    }
}

fn derow_q8_0(src: &[u8], dst: &mut [f32]) {
    let bb = QuantType::Q8_0.block_bytes();
    for (bi, chunk) in dst.chunks_exact_mut(QK).enumerate() {
        let inp = &src[bi * bb..(bi + 1) * bb];
        let d = get_f16(inp, 0);
        for (j, slot) in chunk.iter_mut().enumerate() {
            *slot = (inp[2 + j] as i8) as f32 * d;
        }
    }
}

#[inline]
fn quant_nibble(scaled: f32, bias: f32, max: i32) -> u8 {
    ((scaled + bias + 0.5).floor() as i32).clamp(0, max) as u8
}

#[inline]
fn quant_5bit(scaled: f32) -> u8 {
    ((scaled + 16.5).floor() as i32).clamp(0, 31) as u8
}

#[inline]
fn quant_5bit_affine(scaled: f32) -> u8 {
    ((scaled + 0.5).floor() as i32).clamp(0, 31) as u8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QTensor;
    use crate::testkit::{check, gen};
    use crate::util::rng::Rng;

    fn roundtrip(q: QuantType, src: &[f32]) -> Vec<f32> {
        QTensor::quantize(q, src, 1, src.len()).dequantize()
    }

    #[test]
    fn f32_f16_storage_roundtrip() {
        let src = vec![1.5f32, -2.25, 0.0, 1000.0];
        assert_eq!(roundtrip(QuantType::F32, &src), src);
        let back = roundtrip(QuantType::F16, &src);
        for (a, b) in src.iter().zip(&back) {
            assert!((a - b).abs() <= a.abs() / 1024.0);
        }
    }

    #[test]
    fn q4_0_extreme_value_is_exact() {
        // The max-magnitude value maps to an exact quant level, so it
        // reconstructs to within f16 rounding of itself.
        let mut src = vec![0.01f32; 32];
        src[7] = -1.0;
        let back = roundtrip(QuantType::Q4_0, &src);
        assert!((back[7] - -1.0).abs() < 1e-3, "{}", back[7]);
    }

    #[test]
    fn q4_1_endpoints_exact() {
        let mut rng = Rng::new(5);
        let mut src: Vec<f32> = (0..32).map(|_| rng.range_f32(0.2, 0.8)).collect();
        src[0] = 0.1; // min
        src[31] = 0.9; // max
        let back = roundtrip(QuantType::Q4_1, &src);
        assert!((back[0] - 0.1).abs() < 2e-3, "min {}", back[0]);
        assert!((back[31] - 0.9).abs() < 2e-3, "max {}", back[31]);
    }

    #[test]
    fn q5_uses_fifth_bit() {
        // 32 distinct levels need the high bit: a ramp over a block must
        // reconstruct >16 distinct values for q5 but <=16 for q4.
        let src: Vec<f32> = (0..32).map(|i| i as f32 / 31.0).collect();
        let count_distinct = |xs: &[f32]| {
            let mut v: Vec<u32> = xs.iter().map(|x| x.to_bits()).collect();
            v.sort_unstable();
            v.dedup();
            v.len()
        };
        let b5 = roundtrip(QuantType::Q5_1, &src);
        let b4 = roundtrip(QuantType::Q4_1, &src);
        assert!(count_distinct(&b5) > 16, "q5_1 distinct {}", count_distinct(&b5));
        assert!(count_distinct(&b4) <= 16, "q4_1 distinct {}", count_distinct(&b4));
    }

    #[test]
    fn q8_0_tight_roundtrip() {
        let mut rng = Rng::new(1);
        let src = rng.normal_vec(256, 1.0);
        let back = roundtrip(QuantType::Q8_0, &src);
        let amax = src.iter().fold(0f32, |a, x| a.max(x.abs()));
        for (a, b) in src.iter().zip(&back) {
            // Error bounded by half a quant step + f16 scale rounding.
            assert!((a - b).abs() <= amax / 127.0 * 0.51 + amax / 1024.0);
        }
    }

    #[test]
    fn all_zero_block_is_stable() {
        let src = vec![0.0f32; 64];
        for q in QuantType::PAPER_SET {
            let back = roundtrip(q, &src);
            assert!(back.iter().all(|x| *x == 0.0), "{} broke on zeros", q.name());
        }
    }

    #[test]
    fn constant_block() {
        let src = vec![0.7f32; 32];
        for q in QuantType::PAPER_SET {
            let back = roundtrip(q, &src);
            for b in &back {
                assert!((b - 0.7).abs() < 0.1, "{}: {b}", q.name());
            }
        }
    }

    /// Worst-case reconstruction error a format may show on one block,
    /// derived from that block's own statistics. Quantization error is at
    /// most one quant step (the asymmetric clamp at the far end of a
    /// symmetric range costs a full step, not half), plus the f16
    /// rounding of the stored scale/offset — so the bound is
    /// `1.6 × step + f16 terms`, where `step` is the block scale.
    fn max_block_error(q: QuantType, block: &[f32]) -> f32 {
        let amax = block.iter().fold(0f32, |a, x| a.max(x.abs()));
        let lo = block.iter().fold(f32::INFINITY, |a, x| a.min(*x));
        let hi = block.iter().fold(f32::NEG_INFINITY, |a, x| a.max(*x));
        let f16_eps = amax / 256.0 + 1e-6;
        match q {
            QuantType::F32 => 0.0,
            QuantType::F16 => amax / 1024.0 + 1e-7,
            QuantType::Q4_0 => amax / 8.0 * 1.6 + f16_eps,
            QuantType::Q4_1 => (hi - lo) / 15.0 * 1.6 + f16_eps,
            QuantType::Q5_0 => amax / 16.0 * 1.6 + f16_eps,
            QuantType::Q5_1 => (hi - lo) / 31.0 * 1.6 + f16_eps,
            QuantType::Q8_0 => amax / 127.0 * 1.6 + f16_eps,
        }
    }

    /// Round-trip property over *all* formats: on the adversarial
    /// distribution (magnitudes spanning ~7 decades plus exact zeros),
    /// quantize→dequantize error stays within the per-block scale bound.
    #[test]
    fn prop_roundtrip_error_bounded_by_block_scale() {
        const ALL: [QuantType; 7] = [
            QuantType::F32,
            QuantType::F16,
            QuantType::Q4_0,
            QuantType::Q4_1,
            QuantType::Q5_0,
            QuantType::Q5_1,
            QuantType::Q8_0,
        ];
        check("roundtrip error vs block scale", |rng, _| {
            let n = gen::multiple_of(rng, crate::quant::QK, 256);
            let src = gen::f32_vec(rng, n);
            for q in ALL {
                let back = roundtrip(q, &src);
                for (bi, block) in src.chunks(crate::quant::QK).enumerate() {
                    let bound = max_block_error(q, block);
                    for (j, (x, y)) in block
                        .iter()
                        .zip(&back[bi * crate::quant::QK..])
                        .enumerate()
                    {
                        let err = (x - y).abs();
                        if err > bound {
                            return Err(format!(
                                "{}: block {bi} elem {j}: |{x} - {y}| = {err} > bound {bound}",
                                q.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    /// Zero is always a fixed point of the round trip, for every format —
    /// the adversarial generator injects exact zeros to probe this.
    #[test]
    fn prop_zeros_survive_roundtrip_exactly() {
        check("zeros are fixed points", |rng, _| {
            let n = gen::multiple_of(rng, crate::quant::QK, 128);
            let src = gen::f32_vec(rng, n);
            for q in [QuantType::Q4_0, QuantType::Q5_0, QuantType::Q8_0] {
                let back = roundtrip(q, &src);
                for (i, (x, y)) in src.iter().zip(&back).enumerate() {
                    // Symmetric formats map 0 to the exact zero level.
                    if *x == 0.0 && *y != 0.0 {
                        return Err(format!("{}: zero at {i} became {y}", q.name()));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn multi_block_rows_independent() {
        // Changing block 2 must not change block 1's bytes.
        let mut rng = Rng::new(9);
        let mut src = rng.normal_vec(64, 1.0);
        let t1 = QTensor::quantize(QuantType::Q4_0, &src, 1, 64);
        for x in &mut src[32..] {
            *x *= 3.0;
        }
        let t2 = QTensor::quantize(QuantType::Q4_0, &src, 1, 64);
        assert_eq!(&t1.data[..18], &t2.data[..18]);
        assert_ne!(&t1.data[18..], &t2.data[18..]);
    }
}
