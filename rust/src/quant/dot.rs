//! Quantized dot-product kernels — the decode hot path.
//!
//! `vec_dot(qtype, weight_row_bytes, act_blocks)` computes the inner
//! product of one packed weight row with a q8-quantized activation vector
//! without materializing dequantized weights, exactly as ggml's
//! `ggml_vec_dot_q*` family does. Integer block sums are accumulated in
//! i32 and scaled once per block:
//!
//!   q4_0 : d_w·d_a·(Σ q_w q_a − 8·Σ q_a)
//!   q4_1 : d_w·d_a·Σ q_w q_a + m·d_a·Σ q_a
//!   q5_0 : d_w·d_a·(Σ q_w q_a − 16·Σ q_a)
//!   q5_1 : d_w·d_a·Σ q_w q_a + m·d_a·Σ q_a
//!   q8_0 : d_w·d_a·Σ q_w q_a

use super::act::ActBlock;
use super::blocks::{get_f16, get_u32};
use super::{QuantType, QK};

/// Dot product of one packed weight row against quantized activations.
/// `row` must contain exactly `act.len()` blocks of `qtype`.
pub fn vec_dot(qtype: QuantType, row: &[u8], act: &[ActBlock]) -> f32 {
    debug_assert_eq!(row.len(), act.len() * qtype.block_bytes());
    match qtype {
        QuantType::Q4_0 => dot_q4_0(row, act),
        QuantType::Q4_1 => dot_q4_1(row, act),
        QuantType::Q5_0 => dot_q5_0(row, act),
        QuantType::Q5_1 => dot_q5_1(row, act),
        QuantType::Q8_0 => dot_q8_0(row, act),
        QuantType::F16 => dot_f16(row, act),
        QuantType::F32 => dot_f32(row, act),
    }
}

/// Reference implementation: dequantize the row, then f32 dot against the
/// dequantized activations. Used by tests to bound `vec_dot` error.
pub fn vec_dot_reference(qtype: QuantType, row: &[u8], act: &[ActBlock]) -> f32 {
    let n = act.len() * QK;
    let mut w = vec![0f32; n];
    super::blocks::dequantize_row(qtype, row, &mut w);
    let mut acc = 0f64;
    for (bi, b) in act.iter().enumerate() {
        let a = b.dequantize();
        for j in 0..QK {
            acc += (w[bi * QK + j] * a[j]) as f64;
        }
    }
    acc as f32
}

fn dot_q4_0(row: &[u8], act: &[ActBlock]) -> f32 {
    let bb = QuantType::Q4_0.block_bytes();
    let mut acc = 0f32;
    for (bi, a) in act.iter().enumerate() {
        let blk = &row[bi * bb..(bi + 1) * bb];
        let d = get_f16(blk, 0);
        let qs = &blk[2..2 + QK / 2];
        let mut isum = 0i32;
        for j in 0..QK / 2 {
            let b = qs[j];
            isum += (b & 0x0f) as i32 * a.qs[j] as i32;
            isum += (b >> 4) as i32 * a.qs[j + QK / 2] as i32;
        }
        acc += d * a.d * (isum - 8 * a.sum_q) as f32;
    }
    acc
}

fn dot_q4_1(row: &[u8], act: &[ActBlock]) -> f32 {
    let bb = QuantType::Q4_1.block_bytes();
    let mut acc = 0f32;
    for (bi, a) in act.iter().enumerate() {
        let blk = &row[bi * bb..(bi + 1) * bb];
        let d = get_f16(blk, 0);
        let m = get_f16(blk, 2);
        let qs = &blk[4..4 + QK / 2];
        let mut isum = 0i32;
        for j in 0..QK / 2 {
            let b = qs[j];
            isum += (b & 0x0f) as i32 * a.qs[j] as i32;
            isum += (b >> 4) as i32 * a.qs[j + QK / 2] as i32;
        }
        acc += d * a.d * isum as f32 + m * a.d * a.sum_q as f32;
    }
    acc
}

fn dot_q5_0(row: &[u8], act: &[ActBlock]) -> f32 {
    // Perf (EXPERIMENTS.md §Perf L3-2): the naive form extracts the 5th
    // bit per element, defeating vectorization. Split instead into a
    // vectorizable 4-bit dot plus a sparse high-bit pass driven by
    // trailing_zeros over qh: isum = Σ q4·a + 16·Σ_{b∈qh} a_b.
    let bb = QuantType::Q5_0.block_bytes();
    let mut acc = 0f32;
    for (bi, a) in act.iter().enumerate() {
        let blk = &row[bi * bb..(bi + 1) * bb];
        let d = get_f16(blk, 0);
        let qh = get_u32(blk, 2);
        let qs = &blk[6..6 + QK / 2];
        let mut isum = 0i32;
        for j in 0..QK / 2 {
            let b = qs[j];
            isum += (b & 0x0f) as i32 * a.qs[j] as i32;
            isum += (b >> 4) as i32 * a.qs[j + QK / 2] as i32;
        }
        isum += 16 * hi_bit_sum(qh, &a.qs);
        acc += d * a.d * (isum - 16 * a.sum_q) as f32;
    }
    acc
}

/// Σ of activation quants at positions where the 5th-bit mask is set.
/// Branchless (mask-multiply) so LLVM can vectorize; the data-dependent
/// `trailing_zeros` walk measured 1.8× slower on random masks
/// (EXPERIMENTS.md §Perf L3-2 iteration log).
#[inline]
fn hi_bit_sum(qh: u32, aq: &[i8; QK]) -> i32 {
    let mut s = 0i32;
    for (j, &a) in aq.iter().enumerate() {
        s += (((qh >> j) & 1) as i32) * a as i32;
    }
    s
}

fn dot_q5_1(row: &[u8], act: &[ActBlock]) -> f32 {
    // Same high-bit split as dot_q5_0 (§Perf L3-2).
    let bb = QuantType::Q5_1.block_bytes();
    let mut acc = 0f32;
    for (bi, a) in act.iter().enumerate() {
        let blk = &row[bi * bb..(bi + 1) * bb];
        let d = get_f16(blk, 0);
        let m = get_f16(blk, 2);
        let qh = get_u32(blk, 4);
        let qs = &blk[8..8 + QK / 2];
        let mut isum = 0i32;
        for j in 0..QK / 2 {
            let b = qs[j];
            isum += (b & 0x0f) as i32 * a.qs[j] as i32;
            isum += (b >> 4) as i32 * a.qs[j + QK / 2] as i32;
        }
        isum += 16 * hi_bit_sum(qh, &a.qs);
        acc += d * a.d * isum as f32 + m * a.d * a.sum_q as f32;
    }
    acc
}

fn dot_q8_0(row: &[u8], act: &[ActBlock]) -> f32 {
    let bb = QuantType::Q8_0.block_bytes();
    let mut acc = 0f32;
    for (bi, a) in act.iter().enumerate() {
        let blk = &row[bi * bb..(bi + 1) * bb];
        let d = get_f16(blk, 0);
        let qs = &blk[2..2 + QK];
        let mut isum = 0i32;
        for j in 0..QK {
            isum += (qs[j] as i8) as i32 * a.qs[j] as i32;
        }
        acc += d * a.d * isum as f32;
    }
    acc
}

fn dot_f16(row: &[u8], act: &[ActBlock]) -> f32 {
    let mut acc = 0f32;
    for (bi, a) in act.iter().enumerate() {
        let ad = a.dequantize();
        for j in 0..QK {
            let off = (bi * QK + j) * 2;
            acc += get_f16(row, off) * ad[j];
        }
    }
    acc
}

fn dot_f32(row: &[u8], act: &[ActBlock]) -> f32 {
    let mut acc = 0f32;
    for (bi, a) in act.iter().enumerate() {
        let ad = a.dequantize();
        for j in 0..QK {
            let off = (bi * QK + j) * 4;
            let w = f32::from_le_bytes([row[off], row[off + 1], row[off + 2], row[off + 3]]);
            acc += w * ad[j];
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::act::quantize_activations;
    use crate::quant::QTensor;
    use crate::testkit::{check, gen};

    #[test]
    fn prop_vec_dot_matches_reference() {
        check("vec_dot == dequant-dot", |rng, _| {
            let n = gen::multiple_of(rng, QK, 256);
            let w = gen::activations(rng, n);
            let x = gen::activations(rng, n);
            let act = quantize_activations(&x);
            for q in [
                QuantType::Q4_0,
                QuantType::Q4_1,
                QuantType::Q5_0,
                QuantType::Q5_1,
                QuantType::Q8_0,
                QuantType::F16,
                QuantType::F32,
            ] {
                let t = QTensor::quantize(q, &w, 1, n);
                let fast = vec_dot(q, &t.data, &act);
                let slow = vec_dot_reference(q, &t.data, &act);
                let tol = 1e-3 * (n as f32).sqrt() + slow.abs() * 1e-4;
                if (fast - slow).abs() > tol {
                    return Err(format!(
                        "{}: fast {fast} vs ref {slow} (n={n})",
                        q.name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_dot_close_to_f32_truth() {
        // The end-to-end quantized dot must approximate the full-precision
        // dot within the format's error envelope.
        check("dot approximates f32", |rng, _| {
            let n = gen::multiple_of(rng, QK, 256);
            let w = gen::activations(rng, n);
            let x = gen::activations(rng, n);
            let act = quantize_activations(&x);
            let truth: f32 = w.iter().zip(&x).map(|(a, b)| a * b).sum();
            let scale = (n as f32).sqrt(); // expected |dot| scale for unit gaussians
            for (q, tol) in [
                (QuantType::Q4_0, 0.30),
                // both sides are 8-bit; per-element err ~ 3σ/127 each side
                (QuantType::Q8_0, 0.06),
            ] {
                let t = QTensor::quantize(q, &w, 1, n);
                let d = vec_dot(q, &t.data, &act);
                if (d - truth).abs() > tol * scale {
                    return Err(format!(
                        "{}: dot {d} vs truth {truth}, tol {}",
                        q.name(),
                        tol * scale
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn empty_row_is_zero() {
        for q in QuantType::PAPER_SET {
            assert_eq!(vec_dot(q, &[], &[]), 0.0);
        }
    }
}
