//! GGML-style block quantization (paper §3.3, Table 4/5).
//!
//! Reimplements the five GGML weight formats the paper benchmarks —
//! `q4_0, q4_1, q5_0, q5_1, q8_0` — plus `f16`/`f32` storage, with the
//! same 32-value block structure, f16 scales, nibble packing and
//! activation-quantized (q8) dot products as ggml. Format semantics:
//!
//! | type | block bytes | layout                               | reconstruction    |
//! |------|-------------|--------------------------------------|-------------------|
//! | q4_0 | 18          | d:f16, 16B nibbles                   | w = (q − 8)·d     |
//! | q4_1 | 20          | d:f16, m:f16, 16B nibbles            | w = q·d + m       |
//! | q5_0 | 22          | d:f16, qh:u32, 16B nibbles           | w = (q − 16)·d    |
//! | q5_1 | 24          | d:f16, m:f16, qh:u32, 16B nibbles    | w = q·d + m       |
//! | q8_0 | 34          | d:f16, 32×i8                         | w = q·d           |
//!
//! Nibble packing follows ggml: byte `j` of a block holds weight `j` in its
//! low nibble and weight `j+16` in its high nibble; `qh` bit `j` is the 5th
//! bit of weight `j`. The python compile path (python/compile/kernels/
//! quant.py) mirrors this layout bit-for-bit so PJRT artifacts and the
//! native engine agree.

pub mod act;
pub mod blocks;
pub mod dot;

use crate::util::stats;

/// Weights-per-block for all quantized formats (GGML's QK).
pub const QK: usize = 32;

/// Storage/quantization type of a tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum QuantType {
    F32,
    F16,
    Q4_0,
    Q4_1,
    Q5_0,
    Q5_1,
    Q8_0,
}

impl QuantType {
    /// All quantized formats the paper benchmarks, in Table-5 order.
    pub const PAPER_SET: [QuantType; 5] = [
        QuantType::Q4_0,
        QuantType::Q4_1,
        QuantType::Q5_0,
        QuantType::Q5_1,
        QuantType::Q8_0,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            QuantType::F32 => "f32",
            QuantType::F16 => "f16",
            QuantType::Q4_0 => "q4_0",
            QuantType::Q4_1 => "q4_1",
            QuantType::Q5_0 => "q5_0",
            QuantType::Q5_1 => "q5_1",
            QuantType::Q8_0 => "q8_0",
        }
    }

    pub fn parse(s: &str) -> Option<QuantType> {
        Some(match s.to_ascii_lowercase().as_str() {
            "f32" => QuantType::F32,
            "f16" => QuantType::F16,
            "q4_0" => QuantType::Q4_0,
            "q4_1" => QuantType::Q4_1,
            "q5_0" => QuantType::Q5_0,
            "q5_1" => QuantType::Q5_1,
            "q8_0" => QuantType::Q8_0,
            _ => return None,
        })
    }

    /// Weights per block.
    pub fn block_size(&self) -> usize {
        match self {
            QuantType::F32 | QuantType::F16 => 1,
            _ => QK,
        }
    }

    /// Bytes per block.
    pub fn block_bytes(&self) -> usize {
        match self {
            QuantType::F32 => 4,
            QuantType::F16 => 2,
            QuantType::Q4_0 => 2 + QK / 2,          // 18
            QuantType::Q4_1 => 4 + QK / 2,          // 20
            QuantType::Q5_0 => 2 + 4 + QK / 2,      // 22
            QuantType::Q5_1 => 4 + 4 + QK / 2,      // 24
            QuantType::Q8_0 => 2 + QK,              // 34
        }
    }

    /// Actual storage cost including scales/zero-points.
    pub fn bits_per_weight(&self) -> f64 {
        self.block_bytes() as f64 * 8.0 / self.block_size() as f64
    }

    /// The *nominal* bits-per-weight the paper's Table 5 lists (weight bits
    /// only, scales excluded) — kept so reports can print both.
    pub fn nominal_bits_per_weight(&self) -> f64 {
        match self {
            QuantType::F32 => 32.0,
            QuantType::F16 => 16.0,
            QuantType::Q4_0 => 4.0,
            QuantType::Q4_1 => 4.5,
            QuantType::Q5_0 => 5.0,
            QuantType::Q5_1 => 5.5,
            QuantType::Q8_0 => 8.0,
        }
    }

    /// Bytes needed to store `n` weights (n must be block-aligned for
    /// quantized types).
    pub fn row_bytes(&self, n: usize) -> usize {
        assert_eq!(
            n % self.block_size(),
            0,
            "{} length {n} not a multiple of block size {}",
            self.name(),
            self.block_size()
        );
        n / self.block_size() * self.block_bytes()
    }

    pub fn is_quantized(&self) -> bool {
        !matches!(self, QuantType::F32 | QuantType::F16)
    }
}

/// A 2-D quantized tensor, row-major: each of `rows` rows holds
/// `cols / block_size` consecutive blocks. Matmul weight matrices are
/// stored so a row is one output neuron's weight vector (dot-friendly).
#[derive(Clone, Debug)]
pub struct QTensor {
    pub qtype: QuantType,
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<u8>,
}

impl QTensor {
    /// Quantize a row-major f32 matrix.
    pub fn quantize(qtype: QuantType, src: &[f32], rows: usize, cols: usize) -> QTensor {
        assert_eq!(src.len(), rows * cols, "shape mismatch");
        let rb = qtype.row_bytes(cols);
        let mut data = vec![0u8; rb * rows];
        for r in 0..rows {
            blocks::quantize_row(qtype, &src[r * cols..(r + 1) * cols], &mut data[r * rb..(r + 1) * rb]);
        }
        QTensor {
            qtype,
            rows,
            cols,
            data,
        }
    }

    pub fn row_bytes(&self) -> usize {
        self.qtype.row_bytes(self.cols)
    }

    pub fn row(&self, r: usize) -> &[u8] {
        let rb = self.row_bytes();
        &self.data[r * rb..(r + 1) * rb]
    }

    /// Dequantize the whole tensor back to f32.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            blocks::dequantize_row(self.qtype, self.row(r), &mut out[r * self.cols..(r + 1) * self.cols]);
        }
        out
    }

    pub fn n_bytes(&self) -> usize {
        self.data.len()
    }

    pub fn n_elements(&self) -> usize {
        self.rows * self.cols
    }
}

/// Quantization error report for one tensor (drives the accuracy analysis
/// and the Fig-6 discussion).
#[derive(Clone, Debug)]
pub struct QuantError {
    pub qtype: QuantType,
    pub rmse: f64,
    pub max_abs: f32,
    /// RMSE normalized by the RMS of the source (scale-free).
    pub relative_rmse: f64,
}

/// Quantize-dequantize `src` and measure reconstruction error.
pub fn measure_error(qtype: QuantType, src: &[f32]) -> QuantError {
    let cols = src.len();
    let t = QTensor::quantize(qtype, src, 1, cols);
    let back = t.dequantize();
    let rmse = stats::mse(src, &back).sqrt();
    let src_rms = stats::rms(src).max(1e-30);
    QuantError {
        qtype,
        rmse,
        max_abs: stats::max_abs_diff(src, &back),
        relative_rmse: rmse / src_rms,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, gen};
    use crate::util::rng::Rng;

    #[test]
    fn block_bytes_match_ggml() {
        assert_eq!(QuantType::Q4_0.block_bytes(), 18);
        assert_eq!(QuantType::Q4_1.block_bytes(), 20);
        assert_eq!(QuantType::Q5_0.block_bytes(), 22);
        assert_eq!(QuantType::Q5_1.block_bytes(), 24);
        assert_eq!(QuantType::Q8_0.block_bytes(), 34);
    }

    #[test]
    fn bits_per_weight_actual_and_nominal() {
        assert!((QuantType::Q4_0.bits_per_weight() - 4.5).abs() < 1e-12);
        assert!((QuantType::Q8_0.bits_per_weight() - 8.5).abs() < 1e-12);
        assert_eq!(QuantType::Q4_0.nominal_bits_per_weight(), 4.0);
        assert_eq!(QuantType::Q5_1.nominal_bits_per_weight(), 5.5);
    }

    #[test]
    fn parse_round_trips() {
        for q in [
            QuantType::F32,
            QuantType::F16,
            QuantType::Q4_0,
            QuantType::Q4_1,
            QuantType::Q5_0,
            QuantType::Q5_1,
            QuantType::Q8_0,
        ] {
            assert_eq!(QuantType::parse(q.name()), Some(q));
        }
        assert_eq!(QuantType::parse("q3_k"), None);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn unaligned_rows_rejected() {
        QuantType::Q4_0.row_bytes(33);
    }

    #[test]
    fn error_ordering_matches_paper_table4() {
        // Table 4: accuracy order q4_0 < q4_1 < q5_0 < q5_1 < q8_0. On
        // gaussian weights the reconstruction error must follow that order.
        let mut rng = Rng::new(42);
        let src = rng.normal_vec(32 * 256, 0.05);
        let errs: Vec<f64> = QuantType::PAPER_SET
            .iter()
            .map(|q| measure_error(*q, &src).relative_rmse)
            .collect();
        // q4_0 > q4_1 > q5_0 > q5_1 > q8_0 (error decreasing)
        for w in errs.windows(2) {
            assert!(
                w[0] > w[1],
                "error not strictly decreasing across formats: {errs:?}"
            );
        }
        // q8_0 "almost indistinguishable from f16": rel error < 1%.
        assert!(errs[4] < 0.01, "q8_0 rel rmse {}", errs[4]);
    }

    #[test]
    fn prop_quantize_never_increases_magnitude_wildly() {
        check("bounded reconstruction", |rng, _| {
            let n = gen::multiple_of(rng, QK, 512);
            let src = gen::f32_vec(rng, n);
            for q in QuantType::PAPER_SET {
                let t = QTensor::quantize(q, &src, 1, n);
                let back = t.dequantize();
                let src_max = src.iter().fold(0f32, |a, x| a.max(x.abs()));
                for (i, b) in back.iter().enumerate() {
                    if b.abs() > src_max * 1.51 + 1e-6 {
                        return Err(format!(
                            "{}: reconstructed |{}| at {i} exceeds 1.5*max |{src_max}|",
                            q.name(),
                            b
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_relative_error_bounds() {
        // Per-format error envelopes on unit-scale gaussian data.
        check("error envelopes", |rng, _| {
            let n = gen::multiple_of(rng, QK, 256);
            let src: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let bounds = [
                (QuantType::Q4_0, 0.15),
                (QuantType::Q4_1, 0.12),
                (QuantType::Q5_0, 0.08),
                (QuantType::Q5_1, 0.06),
                (QuantType::Q8_0, 0.01),
            ];
            for (q, bound) in bounds {
                let e = measure_error(q, &src);
                if e.relative_rmse > bound {
                    return Err(format!(
                        "{} rel rmse {} > {bound}",
                        q.name(),
                        e.relative_rmse
                    ));
                }
            }
            Ok(())
        });
    }
}
