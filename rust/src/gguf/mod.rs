//! EGUF — the model container format of the benchmarking runtime.
//!
//! A GGUF-like single-file container holding (a) a JSON metadata blob
//! (architecture hyper-parameters, tokenizer kind, provenance) and (b) a
//! sequence of named, possibly-quantized tensors. The ELIB quantization
//! flow (paper Algorithm 1, Ln. 2) writes one EGUF file per quantization
//! scheme; the model layer loads them, and TTLM is measured over this load
//! path.
//!
//! Layout (all little-endian):
//! ```text
//!   magic   "EGUF"            4 bytes
//!   version u32               currently 1
//!   meta_len u64, meta JSON   UTF-8
//!   n_tensors u64
//!   per tensor:
//!     name_len u64, name UTF-8
//!     qtype    u32            (QuantType discriminant, stable codes)
//!     rows u64, cols u64
//!     data_len u64, data bytes
//! ```

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant::{QTensor, QuantType};
use crate::util::json::{self, Json};

pub const MAGIC: &[u8; 4] = b"EGUF";
pub const VERSION: u32 = 1;

/// Stable on-disk codes for tensor types.
fn qtype_code(q: QuantType) -> u32 {
    match q {
        QuantType::F32 => 0,
        QuantType::F16 => 1,
        QuantType::Q4_0 => 2,
        QuantType::Q4_1 => 3,
        QuantType::Q5_0 => 6,
        QuantType::Q5_1 => 7,
        QuantType::Q8_0 => 8,
    }
}

fn qtype_from_code(c: u32) -> Option<QuantType> {
    Some(match c {
        0 => QuantType::F32,
        1 => QuantType::F16,
        2 => QuantType::Q4_0,
        3 => QuantType::Q4_1,
        6 => QuantType::Q5_0,
        7 => QuantType::Q5_1,
        8 => QuantType::Q8_0,
        _ => return None,
    })
}

/// An in-memory EGUF model file.
#[derive(Clone, Debug)]
pub struct ModelFile {
    pub meta: Json,
    pub tensors: Vec<(String, QTensor)>,
}

impl ModelFile {
    pub fn new(meta: Json) -> Self {
        Self {
            meta,
            tensors: Vec::new(),
        }
    }

    pub fn add(&mut self, name: &str, t: QTensor) {
        self.tensors.push((name.to_string(), t));
    }

    pub fn get(&self, name: &str) -> Option<&QTensor> {
        self.tensors
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, t)| t)
    }

    /// Total tensor payload bytes (the "model size" column of Table 5).
    pub fn tensor_bytes(&self) -> u64 {
        self.tensors.iter().map(|(_, t)| t.n_bytes() as u64).sum()
    }

    pub fn n_parameters(&self) -> u64 {
        self.tensors.iter().map(|(_, t)| t.n_elements() as u64).sum()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        let meta = json::to_string(&self.meta);
        w.write_all(&(meta.len() as u64).to_le_bytes())?;
        w.write_all(meta.as_bytes())?;
        w.write_all(&(self.tensors.len() as u64).to_le_bytes())?;
        for (name, t) in &self.tensors {
            w.write_all(&(name.len() as u64).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&qtype_code(t.qtype).to_le_bytes())?;
            w.write_all(&(t.rows as u64).to_le_bytes())?;
            w.write_all(&(t.cols as u64).to_le_bytes())?;
            w.write_all(&(t.data.len() as u64).to_le_bytes())?;
            w.write_all(&t.data)?;
        }
        w.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<ModelFile> {
        let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{}: not an EGUF file (bad magic)", path.display());
        }
        let version = read_u32(&mut r)?;
        if version != VERSION {
            bail!("{}: unsupported EGUF version {version}", path.display());
        }
        let meta_len = read_u64(&mut r)? as usize;
        if meta_len > 64 << 20 {
            bail!("metadata blob implausibly large ({meta_len} bytes)");
        }
        let mut meta_buf = vec![0u8; meta_len];
        r.read_exact(&mut meta_buf)?;
        let meta = json::parse(std::str::from_utf8(&meta_buf).context("meta not utf-8")?)
            .map_err(|e| anyhow::anyhow!("bad metadata json: {e}"))?;
        let n_tensors = read_u64(&mut r)? as usize;
        let mut tensors = Vec::with_capacity(n_tensors);
        for i in 0..n_tensors {
            let name_len = read_u64(&mut r)? as usize;
            if name_len > 4096 {
                bail!("tensor {i}: name too long");
            }
            let mut name = vec![0u8; name_len];
            r.read_exact(&mut name)?;
            let name = String::from_utf8(name).context("tensor name not utf-8")?;
            let qtype = qtype_from_code(read_u32(&mut r)?)
                .with_context(|| format!("tensor {name}: unknown qtype"))?;
            let rows = read_u64(&mut r)? as usize;
            let cols = read_u64(&mut r)? as usize;
            let data_len = read_u64(&mut r)? as usize;
            let expect = qtype.row_bytes(cols) * rows;
            if data_len != expect {
                bail!("tensor {name}: payload {data_len} != expected {expect}");
            }
            let mut data = vec![0u8; data_len];
            r.read_exact(&mut data)?;
            tensors.push((
                name,
                QTensor {
                    qtype,
                    rows,
                    cols,
                    data,
                },
            ));
        }
        Ok(ModelFile { meta, tensors })
    }
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("elib-gguf-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(8);
        let meta = Json::obj(vec![
            ("arch", Json::Str("tiny-llama".into())),
            ("d_model", Json::Num(128.0)),
        ]);
        let mut mf = ModelFile::new(meta.clone());
        for (i, q) in QuantType::PAPER_SET.iter().enumerate() {
            let src = rng.normal_vec(64 * 32, 0.1);
            mf.add(&format!("w{i}"), QTensor::quantize(*q, &src, 64, 32));
        }
        let p = tmp("roundtrip.eguf");
        mf.save(&p).unwrap();
        let back = ModelFile::load(&p).unwrap();
        assert_eq!(back.meta, meta);
        assert_eq!(back.tensors.len(), 5);
        for ((n1, t1), (n2, t2)) in mf.tensors.iter().zip(&back.tensors) {
            assert_eq!(n1, n2);
            assert_eq!(t1.qtype, t2.qtype);
            assert_eq!(t1.data, t2.data);
        }
        assert_eq!(back.tensor_bytes(), mf.tensor_bytes());
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("bad_magic.eguf");
        std::fs::write(&p, b"NOPExxxxxxxxxxxxxxxx").unwrap();
        assert!(ModelFile::load(&p).is_err());
    }

    #[test]
    fn rejects_truncated_file() {
        let mut mf = ModelFile::new(Json::obj(vec![]));
        mf.add(
            "w",
            QTensor::quantize(QuantType::Q8_0, &vec![0.5; 32], 1, 32),
        );
        let p = tmp("trunc.eguf");
        mf.save(&p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 7]).unwrap();
        assert!(ModelFile::load(&p).is_err());
    }

    #[test]
    fn rejects_size_mismatch() {
        // Corrupt the declared cols so payload check fires.
        let mut mf = ModelFile::new(Json::obj(vec![]));
        mf.add(
            "w",
            QTensor::quantize(QuantType::Q8_0, &vec![0.5; 64], 2, 32),
        );
        let p = tmp("mismatch.eguf");
        mf.save(&p).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // rows field sits right after name+qtype; bump it.
        // header: 4 magic + 4 ver + 8 meta_len + meta("{}")=2 + 8 n + 8 name_len + 1 name + 4 qtype
        let rows_off = 4 + 4 + 8 + 2 + 8 + 8 + 1 + 4;
        bytes[rows_off] = 5;
        std::fs::write(&p, &bytes).unwrap();
        assert!(ModelFile::load(&p).is_err());
    }

    #[test]
    fn parameter_and_byte_accounting() {
        let mut mf = ModelFile::new(Json::obj(vec![]));
        mf.add(
            "a",
            QTensor::quantize(QuantType::Q4_0, &vec![0.1; 128], 4, 32),
        );
        assert_eq!(mf.n_parameters(), 128);
        assert_eq!(mf.tensor_bytes(), 4 * 18);
    }
}
