//! Weight storage for the tiny-LLaMA evaluation model: loading from an
//! EGUF container and re-quantizing between formats (the per-tensor half
//! of the automatic quantization flow).

use anyhow::{anyhow, Context, Result};

use crate::gguf::ModelFile;
use crate::quant::{QTensor, QuantType};

use super::LlamaConfig;

/// One transformer block's weights. Projection matrices are stored
/// row-major with `rows = out_features` so a row is one output neuron
/// (dot-product friendly for qmatvec).
#[derive(Clone, Debug)]
pub struct LayerWeights {
    pub wq: QTensor,
    pub wk: QTensor,
    pub wv: QTensor,
    pub wo: QTensor,
    /// SwiGLU: gate (w1), down (w2), up (w3).
    pub w1: QTensor,
    pub w2: QTensor,
    pub w3: QTensor,
    pub attn_norm: Vec<f32>,
    pub ffn_norm: Vec<f32>,
}

/// Full model weights.
#[derive(Clone, Debug)]
pub struct ModelWeights {
    pub config: LlamaConfig,
    /// The dominant storage format (weights of projection matrices).
    pub qtype: QuantType,
    pub tok_emb: QTensor,
    pub layers: Vec<LayerWeights>,
    pub out_norm: Vec<f32>,
    pub lm_head: QTensor,
}

fn f32_vec(t: &QTensor) -> Vec<f32> {
    t.dequantize()
}

impl ModelWeights {
    /// Load from an EGUF container written by the quantization flow (or by
    /// the python export via `elib quantize`).
    pub fn load(mf: &ModelFile) -> Result<Self> {
        let cfg_json = mf
            .meta
            .get("config")
            .ok_or_else(|| anyhow!("EGUF meta missing `config`"))?;
        let config = LlamaConfig::from_json(cfg_json)?;
        let get = |name: &str| -> Result<QTensor> {
            mf.get(name)
                .cloned()
                .ok_or_else(|| anyhow!("missing tensor `{name}`"))
        };
        let mut layers = Vec::with_capacity(config.n_layers);
        for l in 0..config.n_layers {
            let p = |s: &str| format!("layers.{l}.{s}");
            layers.push(LayerWeights {
                wq: get(&p("wq"))?,
                wk: get(&p("wk"))?,
                wv: get(&p("wv"))?,
                wo: get(&p("wo"))?,
                w1: get(&p("w1"))?,
                w2: get(&p("w2"))?,
                w3: get(&p("w3"))?,
                attn_norm: f32_vec(&get(&p("attn_norm"))?),
                ffn_norm: f32_vec(&get(&p("ffn_norm"))?),
            });
        }
        let weights = Self {
            qtype: layers
                .first()
                .map(|l| l.wq.qtype)
                .unwrap_or(QuantType::F32),
            config,
            tok_emb: get("tok_emb")?,
            layers,
            out_norm: f32_vec(&get("out_norm")?),
            lm_head: get("lm_head")?,
        };
        weights.validate().context("EGUF weight shapes")?;
        Ok(weights)
    }

    /// Shape sanity against the config.
    pub fn validate(&self) -> Result<()> {
        let c = &self.config;
        let kv_dim = c.n_kv_heads * c.head_dim();
        anyhow::ensure!(
            self.tok_emb.rows == c.vocab_size && self.tok_emb.cols == c.d_model,
            "tok_emb shape {}x{}",
            self.tok_emb.rows,
            self.tok_emb.cols
        );
        anyhow::ensure!(self.layers.len() == c.n_layers, "layer count");
        for (i, l) in self.layers.iter().enumerate() {
            let chk = |name: &str, t: &QTensor, r: usize, cc: usize| {
                anyhow::ensure!(
                    t.rows == r && t.cols == cc,
                    "layer {i} {name}: {}x{} != {r}x{cc}",
                    t.rows,
                    t.cols
                );
                Ok(())
            };
            chk("wq", &l.wq, c.d_model, c.d_model)?;
            chk("wk", &l.wk, kv_dim, c.d_model)?;
            chk("wv", &l.wv, kv_dim, c.d_model)?;
            chk("wo", &l.wo, c.d_model, c.d_model)?;
            chk("w1", &l.w1, c.d_ff, c.d_model)?;
            chk("w2", &l.w2, c.d_model, c.d_ff)?;
            chk("w3", &l.w3, c.d_ff, c.d_model)?;
            anyhow::ensure!(l.attn_norm.len() == c.d_model, "attn_norm len");
            anyhow::ensure!(l.ffn_norm.len() == c.d_model, "ffn_norm len");
        }
        anyhow::ensure!(
            self.lm_head.rows == c.vocab_size && self.lm_head.cols == c.d_model,
            "lm_head shape"
        );
        Ok(())
    }

    /// Bytes of weight data streamed per generated token: every projection
    /// matrix + embedding row + lm_head — the numerator term of
    /// "Total Model Parameter Size" in the paper's MBU eq. 2, measured on
    /// the actual packed representation.
    pub fn bytes_per_token(&self) -> u64 {
        let mut b = 0u64;
        for l in &self.layers {
            for t in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w1, &l.w2, &l.w3] {
                b += t.n_bytes() as u64;
            }
            b += (l.attn_norm.len() + l.ffn_norm.len()) as u64 * 4;
        }
        b += self.lm_head.n_bytes() as u64;
        b += self.tok_emb.row_bytes() as u64; // one embedding row per token
        b += self.out_norm.len() as u64 * 4;
        b
    }

    /// Total packed weight bytes (model size on disk, Table 5 column).
    pub fn total_bytes(&self) -> u64 {
        let mut b = self.tok_emb.n_bytes() as u64 + self.lm_head.n_bytes() as u64;
        b += self.out_norm.len() as u64 * 4;
        for l in &self.layers {
            for t in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w1, &l.w2, &l.w3] {
                b += t.n_bytes() as u64;
            }
            b += (l.attn_norm.len() + l.ffn_norm.len()) as u64 * 4;
        }
        b
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::random_model_file;

    #[test]
    fn load_validates_and_roundtrips() {
        let mf = random_model_file(QuantType::Q4_0, 7);
        let w = ModelWeights::load(&mf).unwrap();
        assert_eq!(w.qtype, QuantType::Q4_0);
        assert_eq!(w.layers.len(), w.config.n_layers);
        assert!(w.total_bytes() > 0);
        assert!(w.bytes_per_token() <= w.total_bytes());
    }

    #[test]
    fn missing_tensor_is_an_error() {
        let mut mf = random_model_file(QuantType::Q8_0, 7);
        mf.tensors.retain(|(n, _)| n != "layers.0.wq");
        assert!(ModelWeights::load(&mf).is_err());
    }

    #[test]
    fn bytes_scale_with_format() {
        let b4 = ModelWeights::load(&random_model_file(QuantType::Q4_0, 1))
            .unwrap()
            .total_bytes();
        let b8 = ModelWeights::load(&random_model_file(QuantType::Q8_0, 1))
            .unwrap()
            .total_bytes();
        // q8_0 is 34/18 the size of q4_0 on the projection matrices.
        assert!(b8 > b4, "{b8} !> {b4}");
    }
}
