//! Byte-level tokenizer for the tiny evaluation model.
//!
//! The paper's LLaMA uses SentencePiece; our trained evaluation model is
//! byte-level (vocab 256) so the tokenizer is exact, dependency-free and
//! identical between the rust engine and the python training path. Two
//! reserved conventions: token == byte value, and `\n` (0x0A) doubles as
//! the document separator the corpus generator emits.

/// Byte-level tokenizer (vocab = 256).
#[derive(Clone, Copy, Debug, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub const VOCAB_SIZE: usize = 256;

    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.bytes().map(|b| b as u32).collect()
    }

    pub fn decode(&self, tokens: &[u32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|t| (*t & 0xff) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn vocab_size(&self) -> usize {
        Self::VOCAB_SIZE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_roundtrip() {
        let t = ByteTokenizer;
        let s = "the quick brown fox\n";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn utf8_roundtrip() {
        let t = ByteTokenizer;
        let s = "héllo 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
        assert_eq!(t.encode(s).len(), s.len()); // bytes, not chars
    }

    #[test]
    fn tokens_bounded_by_vocab() {
        let t = ByteTokenizer;
        for tok in t.encode("any text at all …") {
            assert!((tok as usize) < ByteTokenizer::VOCAB_SIZE);
        }
    }

    #[test]
    fn invalid_bytes_decode_lossy() {
        let t = ByteTokenizer;
        let s = t.decode(&[0xff, 0xfe]);
        assert!(!s.is_empty()); // replacement chars, no panic
    }
}
