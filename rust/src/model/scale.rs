//! Storage / RAM math behind the paper's Table 3 ("Storage of LLaMA
//! models based on their parameters") and Table 5 ("Quantized model for
//! benchmarking"): model file size per quantization format and the max
//! RAM required to run it.

use crate::quant::QuantType;

use super::LlamaConfig;

/// Table-3/5 row for one (model, format) pair.
#[derive(Clone, Debug)]
pub struct StorageRow {
    pub model: &'static str,
    pub n_params: u64,
    pub qtype: QuantType,
    pub file_bytes: u64,
    pub max_ram_bytes: u64,
}

/// File size of `config` stored in `qtype`: projection/embedding tensors
/// in the packed format, norm vectors kept f32 (as ggml does).
pub fn model_file_bytes(config: &LlamaConfig, qtype: QuantType) -> u64 {
    let d = config.d_model as u64;
    let norm_params = config.n_layers as u64 * 2 * d + d;
    let matrix_params = config.n_params() - norm_params;
    let bpw = qtype.bits_per_weight();
    (matrix_params as f64 * bpw / 8.0) as u64 + norm_params * 4
}

/// Max RAM: weights + full-context KV cache (f16, as llama.cpp allocates)
/// + activation scratch (~2·d_model·d_ff f32) + a fixed runtime floor.
/// This is what Algorithm 1's memory-overflow guard compares against the
/// device's RAM.
pub fn max_ram_bytes(config: &LlamaConfig, qtype: QuantType, batch: usize) -> u64 {
    ram_bytes_for_context(config, qtype, batch, config.max_seq_len)
}

/// RAM for a deployment whose per-slot KV is bounded by `context_tokens`
/// instead of the full model context — the token-granular admission math
/// behind the paged KV allocator (DESIGN.md §5): a paged pool only holds
/// blocks for positions actually cached, so a serve trace that never
/// exceeds `context_tokens` per slot needs exactly this much RAM.
/// `max_ram_bytes` is the `context_tokens == max_seq_len` special case.
pub fn ram_bytes_for_context(
    config: &LlamaConfig,
    qtype: QuantType,
    batch: usize,
    context_tokens: usize,
) -> u64 {
    let kv = kv_cache_bytes(config, batch, context_tokens.min(config.max_seq_len), 2);
    let scratch = 2 * config.d_model as u64 * config.d_ff as u64 * 4;
    const RUNTIME_FLOOR: u64 = 512 << 20; // OS + runtime resident floor
    model_file_bytes(config, qtype) + kv + scratch * batch as u64 + RUNTIME_FLOOR
}

/// KV cache size, paper eq. 3:
/// batch × seq × (d_model/n_heads) × n_layers × n_kv_heads × data_byte × 2.
pub fn kv_cache_bytes(config: &LlamaConfig, batch: usize, seq: usize, data_byte: u64) -> u64 {
    batch as u64
        * seq as u64
        * (config.d_model / config.n_heads) as u64
        * config.n_layers as u64
        * config.n_kv_heads as u64
        * data_byte
        * 2
}

/// Regenerate Table 3: original (f16) vs INT4 (q4_0) storage for the
/// LLaMA family.
pub fn table3() -> Vec<StorageRow> {
    let fams: [(&'static str, LlamaConfig); 4] = [
        ("7B", LlamaConfig::llama_7b()),
        ("13B", LlamaConfig::llama_13b()),
        ("30B", LlamaConfig::llama_30b()),
        ("65B", LlamaConfig::llama_65b()),
    ];
    let mut rows = Vec::new();
    for (name, cfg) in fams {
        for q in [QuantType::F16, QuantType::Q4_0] {
            rows.push(StorageRow {
                model: name,
                n_params: cfg.n_params(),
                qtype: q,
                file_bytes: model_file_bytes(&cfg, q),
                max_ram_bytes: max_ram_bytes(&cfg, q, 1),
            });
        }
    }
    rows
}

/// Regenerate Table 5: the five benchmark formats (plus the original) on
/// LLaMA-7B.
pub fn table5() -> Vec<StorageRow> {
    let cfg = LlamaConfig::llama_7b();
    let mut rows: Vec<StorageRow> = QuantType::PAPER_SET
        .iter()
        .map(|q| StorageRow {
            model: "7B",
            n_params: cfg.n_params(),
            qtype: *q,
            file_bytes: model_file_bytes(&cfg, *q),
            max_ram_bytes: max_ram_bytes(&cfg, *q, 1),
        })
        .collect();
    rows.push(StorageRow {
        model: "7B",
        n_params: cfg.n_params(),
        qtype: QuantType::F16,
        file_bytes: model_file_bytes(&cfg, QuantType::F16),
        max_ram_bytes: max_ram_bytes(&cfg, QuantType::F16, 1),
    });
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    const GB: f64 = 1024.0 * 1024.0 * 1024.0;

    #[test]
    fn table3_7b_sizes_match_paper_shape() {
        let rows = table3();
        let orig = rows
            .iter()
            .find(|r| r.model == "7B" && r.qtype == QuantType::F16)
            .unwrap();
        let q4 = rows
            .iter()
            .find(|r| r.model == "7B" && r.qtype == QuantType::Q4_0)
            .unwrap();
        // Paper Table 3: 7B original 13 GB, INT4 3.9 GB. Ours: f16 ≈ 12.6,
        // q4_0 ≈ 3.6–4.0 — within 15% of the paper.
        let og = orig.file_bytes as f64 / GB;
        let qg = q4.file_bytes as f64 / GB;
        assert!((11.0..14.0).contains(&og), "orig {og} GB");
        assert!((3.2..4.3).contains(&qg), "q4_0 {qg} GB");
    }

    #[test]
    fn table5_order_and_ram_fit() {
        let rows = table5();
        // File sizes strictly increase across q4_0..q8_0 (paper Table 5).
        for w in rows[..5].windows(2) {
            assert!(w[0].file_bytes < w[1].file_bytes);
        }
        // All five quantized 7B models must fit a 16 GB device; the f16
        // original must not leave qualitative headroom (paper: 14.7G RAM).
        for r in &rows[..5] {
            assert!(
                (r.max_ram_bytes as f64) < 16.0 * GB,
                "{} needs {} GB",
                r.qtype.name(),
                r.max_ram_bytes as f64 / GB
            );
        }
        let f16 = rows.last().unwrap();
        assert!(f16.max_ram_bytes as f64 > 12.0 * GB);
    }

    #[test]
    fn kv_cache_eq3_example() {
        // 7B, batch 1, seq 2048, f16: 2048·128·32·32·2·2 = 1 GiB.
        let c = LlamaConfig::llama_7b();
        let kv = kv_cache_bytes(&c, 1, 2048, 2);
        assert_eq!(kv, 2048 * 128 * 32 * 32 * 2 * 2);
    }

    #[test]
    fn context_bounded_ram_interpolates_to_max() {
        let c = LlamaConfig::llama_7b();
        let q = QuantType::Q8_0;
        let full = max_ram_bytes(&c, q, 8);
        let tight = ram_bytes_for_context(&c, q, 8, 48);
        assert!(tight < full, "bounded context must need less RAM");
        assert_eq!(ram_bytes_for_context(&c, q, 8, c.max_seq_len), full);
        // Clamped at the model context window.
        assert_eq!(ram_bytes_for_context(&c, q, 8, 2 * c.max_seq_len), full);
        // Each extra context token costs exactly one eq.-3 row per slot.
        assert_eq!(
            ram_bytes_for_context(&c, q, 8, 49) - tight,
            kv_cache_bytes(&c, 8, 1, 2)
        );
        // The paged frontier flip this PR exists for: q8_0 @ 8 slots on a
        // 16 GiB device is infeasible at full context but feasible at the
        // default fleet trace's bounded context.
        const GIB: u64 = 1 << 30;
        assert!(full > 16 * GIB);
        assert!(tight < 16 * GIB);
    }

    #[test]
    fn kv_cache_scales_linearly_in_batch_and_seq() {
        let c = LlamaConfig::llama_7b();
        assert_eq!(
            kv_cache_bytes(&c, 4, 512, 2),
            4 * kv_cache_bytes(&c, 1, 512, 2)
        );
        assert_eq!(
            kv_cache_bytes(&c, 1, 1024, 2),
            2 * kv_cache_bytes(&c, 1, 512, 2)
        );
    }
}
