//! Model layer of the Model–Graph–Kernel runtime (paper Fig 2): "store
//! the input LLM parameters, tokenizer, historic tokens".
//!
//! Holds the architecture config, the (possibly quantized) weights loaded
//! from an EGUF container, the byte-level tokenizer of the evaluation
//! model, and the parameter-count / storage math behind the paper's
//! Tables 3 and 5 (`scale`).

pub mod scale;
pub mod testutil;
pub mod tokenizer;
pub mod weights;

pub use tokenizer::ByteTokenizer;
pub use weights::{LayerWeights, ModelWeights};

use anyhow::Result;

use crate::util::json::Json;

/// LLaMA-family architecture hyper-parameters.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LlamaConfig {
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// GQA: number of key/value heads (== n_heads for MHA; the paper's
    /// MBU eq. 3 carries this as `n_kv_heads`).
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq_len: usize,
    pub rope_theta: f32,
    pub norm_eps: f32,
}

impl LlamaConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// The tiny evaluation model this repo trains (see DESIGN.md §2).
    pub fn tiny() -> Self {
        Self {
            vocab_size: 256,
            d_model: 128,
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 4,
            d_ff: 352, // ~8/3 · d, multiple of 32
            max_seq_len: 256,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    /// LLaMA-7B — the paper's evaluation model; used by `scale` to produce
    /// Table-3/5-scale numbers and by the device simulator's workload
    /// description.
    pub fn llama_7b() -> Self {
        Self {
            vocab_size: 32000,
            d_model: 4096,
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 32,
            d_ff: 11008,
            max_seq_len: 2048,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    pub fn llama_13b() -> Self {
        Self {
            vocab_size: 32000,
            d_model: 5120,
            n_layers: 40,
            n_heads: 40,
            n_kv_heads: 40,
            d_ff: 13824,
            max_seq_len: 2048,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    pub fn llama_30b() -> Self {
        Self {
            vocab_size: 32000,
            d_model: 6656,
            n_layers: 60,
            n_heads: 52,
            n_kv_heads: 52,
            d_ff: 17920,
            max_seq_len: 2048,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    pub fn llama_65b() -> Self {
        Self {
            vocab_size: 32000,
            d_model: 8192,
            n_layers: 80,
            n_heads: 64,
            n_kv_heads: 64,
            d_ff: 22016,
            max_seq_len: 2048,
            rope_theta: 10000.0,
            norm_eps: 1e-5,
        }
    }

    /// Exact parameter count of the architecture (tied embeddings NOT
    /// assumed; lm_head counted separately, as in LLaMA).
    pub fn n_params(&self) -> u64 {
        let d = self.d_model as u64;
        let v = self.vocab_size as u64;
        let ff = self.d_ff as u64;
        let kv = (self.n_kv_heads * self.head_dim()) as u64;
        let per_layer =
            d * d            // wq
            + d * kv         // wk
            + d * kv         // wv
            + d * d          // wo
            + 3 * d * ff     // w1 gate, w2 down, w3 up
            + 2 * d; // two rmsnorm vectors
        v * d            // tok_embeddings
            + self.n_layers as u64 * per_layer
            + d              // final norm
            + v * d // lm_head
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("vocab_size", Json::Num(self.vocab_size as f64)),
            ("d_model", Json::Num(self.d_model as f64)),
            ("n_layers", Json::Num(self.n_layers as f64)),
            ("n_heads", Json::Num(self.n_heads as f64)),
            ("n_kv_heads", Json::Num(self.n_kv_heads as f64)),
            ("d_ff", Json::Num(self.d_ff as f64)),
            ("max_seq_len", Json::Num(self.max_seq_len as f64)),
            ("rope_theta", Json::Num(self.rope_theta as f64)),
            ("norm_eps", Json::Num(self.norm_eps as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let get = |k: &str| -> Result<f64> {
            j.req_f64(k)
                .map_err(|e| anyhow::anyhow!("model config: {e}"))
        };
        Ok(Self {
            vocab_size: get("vocab_size")? as usize,
            d_model: get("d_model")? as usize,
            n_layers: get("n_layers")? as usize,
            n_heads: get("n_heads")? as usize,
            n_kv_heads: get("n_kv_heads")? as usize,
            d_ff: get("d_ff")? as usize,
            max_seq_len: get("max_seq_len")? as usize,
            rope_theta: get("rope_theta")? as f32,
            norm_eps: get("norm_eps")? as f32,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_7b_close_to_6_7b() {
        // LLaMA-7B is 6.74B parameters.
        let n = LlamaConfig::llama_7b().n_params();
        assert!(
            (6.5e9..7.0e9).contains(&(n as f64)),
            "7B param count {n}"
        );
    }

    #[test]
    fn params_scale_across_family() {
        let p7 = LlamaConfig::llama_7b().n_params();
        let p13 = LlamaConfig::llama_13b().n_params();
        let p30 = LlamaConfig::llama_30b().n_params();
        let p65 = LlamaConfig::llama_65b().n_params();
        assert!(p7 < p13 && p13 < p30 && p30 < p65);
        assert!((p13 as f64 / p7 as f64) > 1.8);
    }

    #[test]
    fn json_roundtrip() {
        let c = LlamaConfig::tiny();
        let back = LlamaConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(c, back);
    }

    #[test]
    fn head_dim_divides() {
        let c = LlamaConfig::tiny();
        assert_eq!(c.head_dim() * c.n_heads, c.d_model);
    }
}
