//! Shared helpers for building synthetic models: random weight
//! generation (tests/benches) and assembling an EGUF `ModelFile` from
//! dense f32 tensors (the per-tensor half of the quantization flow).

use std::collections::BTreeMap;

use crate::gguf::ModelFile;
use crate::quant::{QTensor, QuantType};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::LlamaConfig;

/// Dense f32 weights keyed by tensor name (the python trainer exports
/// exactly this set; `random_weights` fabricates it for tests).
pub type DenseWeights = BTreeMap<String, (Vec<f32>, usize, usize)>;

/// Names+shapes of every tensor a `config` model carries.
pub fn tensor_specs(config: &LlamaConfig) -> Vec<(String, usize, usize)> {
    let d = config.d_model;
    let kv = config.n_kv_heads * config.head_dim();
    let mut v = vec![
        ("tok_emb".to_string(), config.vocab_size, d),
        ("out_norm".to_string(), 1, d),
        ("lm_head".to_string(), config.vocab_size, d),
    ];
    for l in 0..config.n_layers {
        let p = |s: &str| format!("layers.{l}.{s}");
        v.push((p("wq"), d, d));
        v.push((p("wk"), kv, d));
        v.push((p("wv"), kv, d));
        v.push((p("wo"), d, d));
        v.push((p("w1"), config.d_ff, d));
        v.push((p("w2"), d, config.d_ff));
        v.push((p("w3"), config.d_ff, d));
        v.push((p("attn_norm"), 1, d));
        v.push((p("ffn_norm"), 1, d));
    }
    v
}

/// Random dense weights with transformer-ish init (norms at 1.0,
/// projections at σ = 1/sqrt(d)).
pub fn random_weights(config: &LlamaConfig, seed: u64) -> DenseWeights {
    let mut rng = Rng::new(seed);
    let mut out = DenseWeights::new();
    for (name, rows, cols) in tensor_specs(config) {
        let data = if name.contains("norm") {
            vec![1.0f32; rows * cols]
        } else {
            let scale = 1.0 / (config.d_model as f32).sqrt();
            rng.normal_vec(rows * cols, scale)
        };
        out.insert(name, (data, rows, cols));
    }
    out
}

/// Quantize dense weights into an EGUF ModelFile. Norm vectors stay f32
/// (matching ggml); everything else is packed as `qtype`.
pub fn build_model_file(
    config: &LlamaConfig,
    qtype: QuantType,
    dense: &DenseWeights,
) -> ModelFile {
    let meta = Json::obj(vec![
        ("arch", Json::Str("tiny-llama".into())),
        ("config", config.to_json()),
        ("qtype", Json::Str(qtype.name().into())),
    ]);
    let mut mf = ModelFile::new(meta);
    for (name, (data, rows, cols)) in dense {
        let t = if name.contains("norm") {
            QTensor::quantize(QuantType::F32, data, *rows, *cols)
        } else {
            QTensor::quantize(qtype, data, *rows, *cols)
        };
        mf.add(name, t);
    }
    mf
}

/// A complete random tiny model in one call (tests/benches).
pub fn random_model_file(qtype: QuantType, seed: u64) -> ModelFile {
    let config = LlamaConfig::tiny();
    build_model_file(&config, qtype, &random_weights(&config, seed))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_count_matches_param_count() {
        let c = LlamaConfig::tiny();
        let total: u64 = tensor_specs(&c)
            .iter()
            .map(|(_, r, cl)| (*r * *cl) as u64)
            .sum();
        assert_eq!(total, c.n_params());
    }

    #[test]
    fn builder_emits_all_tensors() {
        let mf = random_model_file(QuantType::Q5_0, 3);
        assert_eq!(
            mf.tensors.len(),
            tensor_specs(&LlamaConfig::tiny()).len()
        );
        // Norms stay f32.
        assert_eq!(mf.get("out_norm").unwrap().qtype, QuantType::F32);
        assert_eq!(mf.get("layers.0.wq").unwrap().qtype, QuantType::Q5_0);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = random_model_file(QuantType::Q4_0, 11);
        let b = random_model_file(QuantType::Q4_0, 11);
        assert_eq!(a.get("layers.1.wo").unwrap().data, b.get("layers.1.wo").unwrap().data);
    }
}
