//! The device clock: one roofline pricing rule for *both* benchmark
//! paths (DESIGN.md §5, "Device clock").
//!
//! The solo grid (`coordinator::runner`) and the serving simulator
//! (`coordinator::serve`) used to carry separate pricing code — the grid
//! priced `Workload`s on [`DeviceSpec`] calibration while serve priced
//! its measured ledger on a flat `peak_bw`/`peak_flops` pair. A
//! [`DeviceClock`] is the single derivation both now share:
//!
//! ```text
//!   eff_flops = F_eff(accel, threads)        // contention past saturation
//!   eff_bw    = mem_bw · frac(accel, qtype)  // achievable-bandwidth MBU ceiling
//!   t_step    = max(bytes / eff_bw, flops / eff_flops)
//! ```
//!
//! `peak_bw` (the raw bus) rides along as the MBU denominator: pricing
//! happens at *achievable* bandwidth, utilization is reported against
//! *peak* — which is exactly how the paper's Table-6 MBU column is
//! defined.
//!
//! [`scaled`](DeviceClock::scaled) maps the clock onto the tiny measured
//! engine: multiplying all three rates by `tiny_bytes / 7B_bytes` makes a
//! tiny-model decode step take the virtual time the 7B deployment would
//! on the real device, so `elib fleet` latencies read in edge-realistic
//! seconds while every token is still really computed.

use crate::quant::QuantType;

use super::{Accel, DeviceSpec};

/// A resolved roofline: what one engine step costs on a device, for a
/// given accelerator, quant format and thread count. Pure f64 arithmetic
/// from [`DeviceSpec`] calibration — deterministic on every machine.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceClock {
    /// Device name the clock was derived from (empty for [`flat`]).
    ///
    /// [`flat`]: DeviceClock::flat
    pub device: String,
    pub accel: Accel,
    /// CPU threads the contention model was evaluated at.
    pub threads: usize,
    /// Achievable decode bandwidth, bytes/s (accel- and quant-scaled).
    pub eff_bw: f64,
    /// Effective compute under thread contention, FLOP/s.
    pub eff_flops: f64,
    /// Raw bus bandwidth, bytes/s — the MBU denominator.
    pub peak_bw: f64,
}

impl DeviceClock {
    /// Derive the clock from a device's calibration (DESIGN.md §2/§5).
    pub fn new(spec: &DeviceSpec, accel: Accel, qtype: QuantType, threads: usize) -> Self {
        Self {
            device: spec.name.to_string(),
            accel,
            threads,
            eff_bw: spec.decode_bw(accel, qtype),
            eff_flops: spec.matmul_gflops(accel, threads) * 1e9,
            peak_bw: spec.mem_bw,
        }
    }

    /// A device-less clock that prices and reports against the same flat
    /// pair — the PR-2 serving roofline, kept so `elib serve` without
    /// `--device` reproduces its pre-fleet `bench.json` bit for bit.
    pub fn flat(peak_bw: f64, peak_flops: f64) -> Self {
        Self {
            device: String::new(),
            accel: Accel::CpuNone,
            threads: 0,
            eff_bw: peak_bw,
            eff_flops: peak_flops,
            peak_bw,
        }
    }

    /// Rescale every rate by `scale` — used to serve a model `1/scale`×
    /// smaller than the deployment the calibration describes. Ratios
    /// (and hence MBU) are invariant; absolute step times shrink with
    /// the model, so tiny-engine steps price at 7B-realistic seconds
    /// when `scale = tiny_model_bytes / 7B_model_bytes`.
    pub fn scaled(mut self, scale: f64) -> Self {
        self.eff_bw *= scale;
        self.eff_flops *= scale;
        self.peak_bw *= scale;
        self
    }

    /// Seconds one step of `bytes` traffic and `flops` work takes:
    /// the roofline max of the memory and compute sides.
    pub fn step_secs(&self, bytes: u64, flops: f64) -> f64 {
        (bytes as f64 / self.eff_bw).max(flops / self.eff_flops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_matches_spec_derivation() {
        let spec = DeviceSpec::nanopi();
        let c = DeviceClock::new(&spec, Accel::CpuBlas, QuantType::Q4_0, 4);
        assert_eq!(c.eff_bw, spec.decode_bw(Accel::CpuBlas, QuantType::Q4_0));
        assert_eq!(c.eff_flops, spec.matmul_gflops(Accel::CpuBlas, 4) * 1e9);
        assert_eq!(c.peak_bw, spec.mem_bw);
        assert_eq!(c.device, "NanoPI");
    }

    #[test]
    fn contention_slows_the_clock_past_saturation() {
        // Fig 3b through the clock: 8 threads price a compute-bound step
        // slower than 4 on a contention-heavy device.
        let spec = DeviceSpec::xiaomi();
        let t4 = DeviceClock::new(&spec, Accel::CpuBlas, QuantType::Q8_0, 4);
        let t8 = DeviceClock::new(&spec, Accel::CpuBlas, QuantType::Q8_0, 8);
        let flops = 1e12;
        assert!(t8.step_secs(0, flops) > t4.step_secs(0, flops));
    }

    #[test]
    fn quant_bits_scale_achievable_bandwidth() {
        let spec = DeviceSpec::macbook();
        let q4 = DeviceClock::new(&spec, Accel::Gpu, QuantType::Q4_0, 4);
        let q8 = DeviceClock::new(&spec, Accel::Gpu, QuantType::Q8_0, 4);
        assert!(
            q4.eff_bw < q8.eff_bw,
            "lower-bit formats pay more unpack overhead per byte"
        );
        // Pricing happens below peak: the MBU ceiling is a fraction.
        assert!(q8.eff_bw < q8.peak_bw);
    }

    #[test]
    fn step_secs_takes_the_roofline_max() {
        let c = DeviceClock::flat(100.0, 1000.0);
        // Memory-bound: 200 bytes / 100 B/s = 2 s > 100 flops / 1000.
        assert_eq!(c.step_secs(200, 100.0), 2.0);
        // Compute-bound: 5000 flops / 1000 = 5 s > 1 s of bytes.
        assert_eq!(c.step_secs(100, 5000.0), 5.0);
    }

    #[test]
    fn scaling_preserves_ratios() {
        let spec = DeviceSpec::nanopi();
        let c = DeviceClock::new(&spec, Accel::CpuBlas, QuantType::Q4_0, 4);
        let s = c.clone().scaled(1e-3);
        assert_eq!(s.eff_bw, c.eff_bw * 1e-3);
        assert_eq!(s.peak_bw, c.peak_bw * 1e-3);
        assert!((s.eff_bw / s.peak_bw - c.eff_bw / c.peak_bw).abs() < 1e-15);
        // A 1000x smaller step takes the same time on the scaled clock.
        let t_full = c.step_secs(1_000_000, 1e9);
        let t_tiny = s.step_secs(1_000, 1e6);
        assert!((t_full - t_tiny).abs() / t_full < 1e-12);
    }
}
