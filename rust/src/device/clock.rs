//! The device clock: one roofline pricing rule for *both* benchmark
//! paths (DESIGN.md §5, "Device clock").
//!
//! The solo grid (`coordinator::runner`) and the serving simulator
//! (`coordinator::serve`) used to carry separate pricing code — the grid
//! priced `Workload`s on [`DeviceSpec`] calibration while serve priced
//! its measured ledger on a flat `peak_bw`/`peak_flops` pair. A
//! [`DeviceClock`] is the single derivation both now share:
//!
//! ```text
//!   eff_flops = F_eff(accel, threads)        // contention past saturation
//!   eff_bw    = mem_bw · frac(accel, qtype)  // achievable-bandwidth MBU ceiling
//!   t_step    = max(bytes / eff_bw, flops / eff_flops)
//! ```
//!
//! `peak_bw` (the raw bus) rides along as the MBU denominator: pricing
//! happens at *achievable* bandwidth, utilization is reported against
//! *peak* — which is exactly how the paper's Table-6 MBU column is
//! defined.
//!
//! [`scaled`](DeviceClock::scaled) maps the clock onto the tiny measured
//! engine: multiplying all three rates by `tiny_bytes / 7B_bytes` makes a
//! tiny-model decode step take the virtual time the 7B deployment would
//! on the real device, so `elib fleet` latencies read in edge-realistic
//! seconds while every token is still really computed.

use crate::quant::QuantType;

use super::{Accel, DeviceSpec};

/// Thermal-throttling model: sustained load exponentially degrades the
/// compute side of the roofline toward a floor (DESIGN.md §5). With
/// `busy` virtual seconds of accumulated engine work, the effective
/// compute is
///
/// ```text
///   eff_flops(busy) = eff_flops · (floor + (1 − floor) · e^(−busy/tau))
/// ```
///
/// — full speed cold (`busy = 0` ⇒ derate 1), monotonically falling,
/// asymptoting at `floor · eff_flops`. Pure f64 arithmetic of virtual
/// time, so throttled runs stay bit-reproducible across machines and
/// `--threads`. Bandwidth is left alone: edge thermal envelopes clamp
/// the compute clocks long before the memory bus (the sustained-load
/// degradation "Sometimes Painful but Certainly Promising" measures).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Thermal {
    /// Exponential time constant, virtual seconds of *busy* engine time.
    pub tau: f64,
    /// Asymptotic fraction of cold-state `eff_flops`, in (0, 1].
    pub floor: f64,
}

/// A resolved roofline: what one engine step costs on a device, for a
/// given accelerator, quant format and thread count. Pure f64 arithmetic
/// from [`DeviceSpec`] calibration — deterministic on every machine.
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceClock {
    /// Device name the clock was derived from (empty for [`flat`]).
    ///
    /// [`flat`]: DeviceClock::flat
    pub device: String,
    pub accel: Accel,
    /// CPU threads the contention model was evaluated at.
    pub threads: usize,
    /// Achievable decode bandwidth, bytes/s (accel- and quant-scaled).
    pub eff_bw: f64,
    /// Effective compute under thread contention, FLOP/s (cold state —
    /// see [`Thermal`] for the sustained-load derate).
    pub eff_flops: f64,
    /// Raw bus bandwidth, bytes/s — the MBU denominator.
    pub peak_bw: f64,
    /// Optional sustained-load throttling; `None` (the default) prices
    /// every step at the cold rate — the pre-thermal clock bit for bit.
    pub thermal: Option<Thermal>,
}

impl DeviceClock {
    /// Derive the clock from a device's calibration (DESIGN.md §2/§5).
    pub fn new(spec: &DeviceSpec, accel: Accel, qtype: QuantType, threads: usize) -> Self {
        Self {
            device: spec.name.to_string(),
            accel,
            threads,
            eff_bw: spec.decode_bw(accel, qtype),
            eff_flops: spec.matmul_gflops(accel, threads) * 1e9,
            peak_bw: spec.mem_bw,
            thermal: None,
        }
    }

    /// A device-less clock that prices and reports against the same flat
    /// pair — the PR-2 serving roofline, kept so `elib serve` without
    /// `--device` reproduces its pre-fleet `bench.json` bit for bit.
    pub fn flat(peak_bw: f64, peak_flops: f64) -> Self {
        Self {
            device: String::new(),
            accel: Accel::CpuNone,
            threads: 0,
            eff_bw: peak_bw,
            eff_flops: peak_flops,
            peak_bw,
            thermal: None,
        }
    }

    /// Attach a sustained-load thermal derate (see [`Thermal`]).
    pub fn with_thermal(mut self, tau: f64, floor: f64) -> Self {
        self.thermal = Some(Thermal { tau, floor });
        self
    }

    /// Rescale every rate by `scale` — used to serve a model `1/scale`×
    /// smaller than the deployment the calibration describes. Ratios
    /// (and hence MBU) are invariant; absolute step times shrink with
    /// the model, so tiny-engine steps price at 7B-realistic seconds
    /// when `scale = tiny_model_bytes / 7B_model_bytes`.
    pub fn scaled(mut self, scale: f64) -> Self {
        self.eff_bw *= scale;
        self.eff_flops *= scale;
        self.peak_bw *= scale;
        self
    }

    /// Seconds one step of `bytes` traffic and `flops` work takes:
    /// the roofline max of the memory and compute sides (cold state —
    /// any thermal derate is ignored; this is the pre-thermal pricing
    /// rule, kept verbatim so un-throttled runs never move a bit).
    pub fn step_secs(&self, bytes: u64, flops: f64) -> f64 {
        (bytes as f64 / self.eff_bw).max(flops / self.eff_flops)
    }

    /// The thermal derate factor after `busy_secs` of accumulated engine
    /// work: 1.0 with no thermal model (or cold), monotonically
    /// non-increasing in `busy_secs`, asymptoting at `floor`.
    pub fn thermal_derate(&self, busy_secs: f64) -> f64 {
        match self.thermal {
            None => 1.0,
            Some(t) => t.floor + (1.0 - t.floor) * (-busy_secs / t.tau).exp(),
        }
    }

    /// [`step_secs`](DeviceClock::step_secs) under sustained load: the
    /// compute side of the roofline is derated by
    /// [`thermal_derate`](DeviceClock::thermal_derate) at `busy_secs` of
    /// accumulated virtual engine time. Without a thermal model this is
    /// exactly `step_secs` for every `busy_secs`.
    pub fn step_secs_at(&self, bytes: u64, flops: f64, busy_secs: f64) -> f64 {
        (bytes as f64 / self.eff_bw).max(flops / (self.eff_flops * self.thermal_derate(busy_secs)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_matches_spec_derivation() {
        let spec = DeviceSpec::nanopi();
        let c = DeviceClock::new(&spec, Accel::CpuBlas, QuantType::Q4_0, 4);
        assert_eq!(c.eff_bw, spec.decode_bw(Accel::CpuBlas, QuantType::Q4_0));
        assert_eq!(c.eff_flops, spec.matmul_gflops(Accel::CpuBlas, 4) * 1e9);
        assert_eq!(c.peak_bw, spec.mem_bw);
        assert_eq!(c.device, "NanoPI");
    }

    #[test]
    fn contention_slows_the_clock_past_saturation() {
        // Fig 3b through the clock: 8 threads price a compute-bound step
        // slower than 4 on a contention-heavy device.
        let spec = DeviceSpec::xiaomi();
        let t4 = DeviceClock::new(&spec, Accel::CpuBlas, QuantType::Q8_0, 4);
        let t8 = DeviceClock::new(&spec, Accel::CpuBlas, QuantType::Q8_0, 8);
        let flops = 1e12;
        assert!(t8.step_secs(0, flops) > t4.step_secs(0, flops));
    }

    #[test]
    fn quant_bits_scale_achievable_bandwidth() {
        let spec = DeviceSpec::macbook();
        let q4 = DeviceClock::new(&spec, Accel::Gpu, QuantType::Q4_0, 4);
        let q8 = DeviceClock::new(&spec, Accel::Gpu, QuantType::Q8_0, 4);
        assert!(
            q4.eff_bw < q8.eff_bw,
            "lower-bit formats pay more unpack overhead per byte"
        );
        // Pricing happens below peak: the MBU ceiling is a fraction.
        assert!(q8.eff_bw < q8.peak_bw);
    }

    #[test]
    fn step_secs_takes_the_roofline_max() {
        let c = DeviceClock::flat(100.0, 1000.0);
        // Memory-bound: 200 bytes / 100 B/s = 2 s > 100 flops / 1000.
        assert_eq!(c.step_secs(200, 100.0), 2.0);
        // Compute-bound: 5000 flops / 1000 = 5 s > 1 s of bytes.
        assert_eq!(c.step_secs(100, 5000.0), 5.0);
    }

    /// The satellite property: under sustained load the effective
    /// compute never *increases* — the derate is monotonically
    /// non-increasing in busy time, starts at exactly 1.0 cold, and
    /// never falls below the floor.
    #[test]
    fn thermal_derate_is_monotone_and_floored() {
        let c = DeviceClock::flat(100e6, 2e9).with_thermal(5.0, 0.4);
        assert_eq!(c.thermal_derate(0.0), 1.0, "cold start runs at full speed");
        let mut prev = 1.0;
        for i in 1..=200 {
            let d = c.thermal_derate(i as f64 * 0.25);
            assert!(d <= prev, "derate rose at busy={}: {d} > {prev}", i as f64 * 0.25);
            assert!(d >= 0.4, "derate fell through the floor: {d}");
            prev = d;
        }
        assert!((c.thermal_derate(1e6) - 0.4).abs() < 1e-9, "asymptote is the floor");
        // Compute-bound steps slow down accordingly; memory-bound steps
        // are untouched (the bus does not throttle).
        let cold = c.step_secs_at(0, 1e9, 0.0);
        let hot = c.step_secs_at(0, 1e9, 1e6);
        assert_eq!(cold, c.step_secs(0, 1e9));
        assert!((hot - cold / 0.4).abs() / hot < 1e-9);
        assert_eq!(c.step_secs_at(200_000_000, 0.0, 1e6), c.step_secs(200_000_000, 0.0));
        // No thermal model: step_secs_at is step_secs at any busy time.
        let plain = DeviceClock::flat(100e6, 2e9);
        for busy in [0.0, 1.0, 50.0] {
            assert_eq!(plain.step_secs_at(64, 1e7, busy), plain.step_secs(64, 1e7));
        }
    }

    #[test]
    fn scaling_preserves_ratios() {
        let spec = DeviceSpec::nanopi();
        let c = DeviceClock::new(&spec, Accel::CpuBlas, QuantType::Q4_0, 4);
        let s = c.clone().scaled(1e-3);
        assert_eq!(s.eff_bw, c.eff_bw * 1e-3);
        assert_eq!(s.peak_bw, c.peak_bw * 1e-3);
        assert!((s.eff_bw / s.peak_bw - c.eff_bw / c.peak_bw).abs() < 1e-15);
        // A 1000x smaller step takes the same time on the scaled clock.
        let t_full = c.step_secs(1_000_000, 1e9);
        let t_tiny = s.step_secs(1_000, 1e6);
        assert!((t_full - t_tiny).abs() / t_full < 1e-12);
    }
}
