//! Edge-device simulator (DESIGN.md §2 substitution for the paper's
//! physical NanoPI / Xiaomi Redmi Note12 Turbo / MacBook Air M2 testbed).
//!
//! Each [`DeviceSpec`] carries the Table-1 hardware description plus a
//! small set of calibration parameters; timing is a roofline model:
//!
//!   t_step = max( flops / F_eff(accel, threads),
//!                 bytes / BW_eff(accel, qtype) )
//!
//! with three mechanisms the paper's analysis hinges on:
//!
//! * **thread contention** (Fig 3b): past `bw_saturation_threads`, extra
//!   threads fight for LPDDR bandwidth and *reduce* effective FLOPS;
//! * **achievable-bandwidth fraction** (`mbu_base` per accelerator,
//!   scaled by bits-per-weight): smaller-bit formats pay more per-block
//!   overhead, so their achieved bandwidth — and hence MBU — is lower,
//!   exactly the gradient Table 6 shows;
//! * **precision pathology** (Fig 6): the OpenCL GPU path on Mali/Adreno
//!   multiplies perplexity by ~an order of magnitude, while Metal is
//!   numerically clean.

pub mod clock;
pub mod workload;

pub use clock::{DeviceClock, Thermal};
pub use workload::Workload;

use crate::model::{scale, LlamaConfig};
use crate::quant::QuantType;

/// Accelerator axis of Table 6.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Accel {
    /// CPU without acceleration framework ("None").
    CpuNone,
    /// CPU + BLAS library (OpenBLAS / Apple Accelerate).
    CpuBlas,
    /// GPU hybrid computing (CLBlast&OpenCL / Metal).
    Gpu,
}

impl Accel {
    pub const ALL: [Accel; 3] = [Accel::CpuNone, Accel::CpuBlas, Accel::Gpu];

    /// Stable machine-readable key (CLI `--accels`, `fleet.json`).
    pub fn key(&self) -> &'static str {
        match self {
            Accel::CpuNone => "none",
            Accel::CpuBlas => "blas",
            Accel::Gpu => "gpu",
        }
    }

    pub fn parse(s: &str) -> Option<Accel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "none" | "cpu" | "cpu-none" => Some(Accel::CpuNone),
            "blas" | "cpu-blas" => Some(Accel::CpuBlas),
            "gpu" => Some(Accel::Gpu),
            _ => None,
        }
    }
}

/// Outcome of the RAM-capacity admission gate: what a 7B-scale serving
/// deployment needs against what the device has. Oversubscribed fleet
/// cells carry this as a structured `infeasible` result instead of
/// panicking (the deploy-feasibility constraint of RQ2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Capacity {
    /// Param bytes + per-slot full-context KV + scratch + runtime floor.
    pub need_bytes: u64,
    /// The device's RAM.
    pub have_bytes: u64,
}

impl Capacity {
    pub fn fits(&self) -> bool {
        self.need_bytes <= self.have_bytes
    }
}

/// A simulated edge device (Table 1 + calibration).
#[derive(Clone, Debug)]
pub struct DeviceSpec {
    pub name: &'static str,
    pub platform: &'static str,
    pub os: &'static str,
    pub ram_bytes: u64,
    /// Peak memory bandwidth, bytes/s (Table 1: 34/26/50 GB/s).
    pub mem_bw: f64,
    /// Sustained model-load bandwidth from storage, bytes/s (drives TTLM).
    pub storage_bw: f64,
    pub big_cores: usize,
    pub little_cores: usize,
    /// Single big-core GFLOPS running *naive* scalar code.
    pub naive_gflops_core: f64,
    /// Single big-core GFLOPS running BLAS-tuned code.
    pub blas_gflops_core: f64,
    /// Little-core contribution relative to a big core.
    pub little_core_ratio: f64,
    /// GPU matmul GFLOPS (achievable, not marketing peak).
    pub gpu_gflops: f64,
    /// Threads that saturate memory bandwidth; beyond this, contention.
    pub bw_saturation_threads: usize,
    /// Contention exponent: effective FLOPS scale by (sat/t)^beta past
    /// saturation.
    pub contention_beta: f64,
    /// Fraction of peak memory bandwidth the decode loop can achieve per
    /// accelerator (MBU ceiling), at the q8_0 reference point.
    pub mbu_base_cpu_none: f64,
    pub mbu_base_cpu_blas: f64,
    pub mbu_base_gpu: f64,
    /// Perplexity multiplier of the GPU path (OpenCL precision bug ⇒ ≫1;
    /// Metal ⇒ 1.0).
    pub gpu_ppl_factor: f64,
    /// Framework label per accelerator (Table 6 "Framework" column).
    pub framework_cpu_blas: &'static str,
    pub framework_gpu: &'static str,
}

impl DeviceSpec {
    /// The paper's three devices, calibrated to Table 1 specs.
    pub fn nanopi() -> Self {
        DeviceSpec {
            name: "NanoPI",
            platform: "IoT",
            os: "Ubuntu",
            ram_bytes: 16 << 30,
            mem_bw: 34e9,
            storage_bw: 65e6, // eMMC-class: 3.5 GB model in ~54 s
            big_cores: 4,     // Cortex-A76 @2.4GHz
            little_cores: 4,  // Cortex-A55
            naive_gflops_core: 9.6,
            blas_gflops_core: 13.5,
            little_core_ratio: 0.35,
            gpu_gflops: 140.0, // Mali-G610 achievable
            bw_saturation_threads: 4,
            contention_beta: 1.0,
            mbu_base_cpu_none: 0.48,
            mbu_base_cpu_blas: 0.52,
            mbu_base_gpu: 0.58,
            gpu_ppl_factor: 8.5,
            framework_cpu_blas: "OpenBLAS",
            framework_gpu: "CLBlast&OpenCL",
        }
    }

    pub fn xiaomi() -> Self {
        DeviceSpec {
            name: "Xiaomi",
            platform: "Mobile",
            os: "Android",
            ram_bytes: 16 << 30,
            mem_bw: 26e9,
            storage_bw: 47e6, // UFS throttled by Android runtime: ~74 s
            big_cores: 4,     // 1×X2 + 3×A710 (averaged)
            little_cores: 4,  // A510
            // Android NDK scalar builds are notoriously poor (paper
            // measures 2.6 GFLOPS!): naive path barely vectorizes.
            naive_gflops_core: 0.75,
            blas_gflops_core: 17.0,
            little_core_ratio: 0.3,
            gpu_gflops: 145.0, // Adreno 725 achievable under CLBlast
            bw_saturation_threads: 4,
            contention_beta: 1.4, // aggressive thermal+bw throttling
            mbu_base_cpu_none: 0.55,
            mbu_base_cpu_blas: 0.62,
            mbu_base_gpu: 0.66,
            gpu_ppl_factor: 9.5,
            framework_cpu_blas: "OpenBLAS",
            framework_gpu: "CLBlast&OpenCL",
        }
    }

    pub fn macbook() -> Self {
        DeviceSpec {
            name: "Macbook",
            platform: "PC",
            os: "MacOS",
            ram_bytes: 16 << 30,
            mem_bw: 50e9,
            storage_bw: 520e6, // NVMe SSD: 3.5 GB in ~7 s
            big_cores: 4,      // Avalanche
            little_cores: 4,   // Blizzard
            naive_gflops_core: 105.0, // NEON-vectorized by clang even "naive"
            blas_gflops_core: 170.0,  // AMX via Accelerate
            little_core_ratio: 0.4,
            gpu_gflops: 1250.0, // 10-core M2 GPU under Metal
            bw_saturation_threads: 4,
            contention_beta: 0.55, // unified memory degrades gracefully
            mbu_base_cpu_none: 0.68,
            mbu_base_cpu_blas: 0.76,
            mbu_base_gpu: 0.87,
            gpu_ppl_factor: 1.0, // Metal is numerically clean (Fig 6)
            framework_cpu_blas: "Accelerate",
            framework_gpu: "Metal",
        }
    }

    /// All three benchmark devices, Table-6 order.
    pub fn paper_devices() -> Vec<DeviceSpec> {
        vec![Self::nanopi(), Self::xiaomi(), Self::macbook()]
    }

    pub fn by_name(name: &str) -> Option<DeviceSpec> {
        Self::paper_devices()
            .into_iter()
            .find(|d| d.name.eq_ignore_ascii_case(name))
    }

    pub fn accel_label(&self, a: Accel) -> (&'static str, &'static str) {
        match a {
            Accel::CpuNone => ("CPU", "None"),
            Accel::CpuBlas => ("CPU", self.framework_cpu_blas),
            Accel::Gpu => ("GPU", self.framework_gpu),
        }
    }

    // ---------------- compute model ------------------------------------

    /// Effective CPU GFLOPS at `threads` threads for `accel` (Fig 3a/3b).
    pub fn cpu_gflops(&self, accel: Accel, threads: usize) -> f64 {
        let per_core = match accel {
            Accel::CpuNone => self.naive_gflops_core,
            Accel::CpuBlas => self.blas_gflops_core,
            Accel::Gpu => return self.gpu_gflops,
        };
        let t = threads.max(1);
        let big = t.min(self.big_cores) as f64;
        let little = t.saturating_sub(self.big_cores) as f64 * self.little_core_ratio;
        let mut gf = per_core * (big + little);
        if t > self.bw_saturation_threads {
            // Memory-bandwidth contention: extra threads slow everyone.
            let sat = self.bw_saturation_threads as f64;
            gf *= (sat / t as f64).powf(self.contention_beta);
        }
        gf
    }

    /// The matmul FLOPS benchmark result (Table 6 FLOPS column) in GFLOPS.
    pub fn matmul_gflops(&self, accel: Accel, threads: usize) -> f64 {
        match accel {
            Accel::Gpu => self.gpu_gflops,
            _ => self.cpu_gflops(accel, threads),
        }
    }

    /// Achievable fraction of peak memory bandwidth for the decode loop
    /// (the MBU ceiling). Lower-bit formats pay more per-block unpack
    /// overhead, so the achievable fraction shrinks with bits-per-weight —
    /// the gradient visible down Table 6's MBU column.
    pub fn bw_fraction(&self, accel: Accel, qtype: QuantType) -> f64 {
        let base = match accel {
            Accel::CpuNone => self.mbu_base_cpu_none,
            Accel::CpuBlas => self.mbu_base_cpu_blas,
            Accel::Gpu => self.mbu_base_gpu,
        };
        // q8_0 (8.5 b/w) is the reference point; q4_0 (4.5) loses ~12%.
        let bpw = qtype.bits_per_weight();
        base * (0.78 + 0.22 * (bpw / 8.5)).min(1.0)
    }

    /// Effective decode memory bandwidth (bytes/s).
    pub fn decode_bw(&self, accel: Accel, qtype: QuantType) -> f64 {
        self.mem_bw * self.bw_fraction(accel, qtype)
    }

    // ---------------- latency model ------------------------------------

    /// Resolve this device into a [`DeviceClock`] — the pricing rule the
    /// solo grid and the serving simulator share (DESIGN.md §5).
    pub fn clock(&self, accel: Accel, qtype: QuantType, threads: usize) -> DeviceClock {
        DeviceClock::new(self, accel, qtype, threads)
    }

    /// Seconds per generated token: roofline of the decode step.
    pub fn tpot(&self, w: &Workload, accel: Accel, threads: usize) -> f64 {
        self.clock(accel, w.qtype, threads)
            .step_secs(w.bytes_per_token, w.flops_per_token)
    }

    /// Time-to-first-token: prompt processing (batched, compute-leaning) +
    /// one decode step. Prefill reads the weights once and does
    /// prompt_len × flops_per_token of work.
    pub fn ttft(&self, w: &Workload, prompt_len: usize, accel: Accel, threads: usize) -> f64 {
        let clock = self.clock(accel, w.qtype, threads);
        // Batched matmuls reach higher efficiency than token-at-a-time
        // decode, but prompt compute still dominates on weak devices.
        let compute = prompt_len as f64 * w.flops_per_token / clock.eff_flops;
        let weight_pass = w.model_bytes as f64 / clock.eff_bw;
        compute.max(weight_pass) + clock.step_secs(w.bytes_per_token, w.flops_per_token)
    }

    /// Time-to-load-model: storage → RAM (paper: dominated by model size
    /// and storage/RAM bandwidth), plus mmap/alloc overhead.
    pub fn ttlm(&self, model_bytes: u64) -> f64 {
        const SETUP_SECS: f64 = 0.35;
        model_bytes as f64 / self.storage_bw + SETUP_SECS
    }

    /// Simulated perplexity for a backend: `base_ppl` (measured on the
    /// real engine) times the device's GPU precision factor when running
    /// the OpenCL-class path. Larger-bit models move *more* data through
    /// the broken path, amplifying it slightly (paper: q8_0 GPU ppl 67.6
    /// vs q4_0 GPU 54.3 on NanoPI).
    pub fn simulated_ppl(&self, base_ppl: f64, accel: Accel, qtype: QuantType) -> f64 {
        match accel {
            Accel::Gpu if self.gpu_ppl_factor > 1.0 => {
                let bpw = qtype.bits_per_weight();
                base_ppl * self.gpu_ppl_factor * (bpw / 4.5).powf(0.35)
            }
            _ => base_ppl,
        }
    }

    /// RQ2 guard: does (model + KV + scratch) fit this device's RAM?
    pub fn fits_ram(&self, max_ram_bytes: u64) -> bool {
        max_ram_bytes <= self.ram_bytes
    }

    /// RAM-capacity admission for a serving deployment: the 7B-scale
    /// model in `qtype` plus `slots` full-context KV allocations (each
    /// admitted request owns a slot) must fit this device's RAM. This is
    /// the legacy slot-layout charge — the paged serve path admits with
    /// [`serve_capacity_tokens`](Self::serve_capacity_tokens) instead.
    pub fn serve_capacity(&self, qtype: QuantType, slots: usize) -> Capacity {
        Capacity {
            need_bytes: scale::max_ram_bytes(&LlamaConfig::llama_7b(), qtype, slots.max(1)),
            have_bytes: self.ram_bytes,
        }
    }

    /// Token-granular RAM admission for a paged-KV deployment: the
    /// 7B-scale model in `qtype` plus `slots` KV chains of at most
    /// `context_tokens` positions each — the *actual allocated blocks*,
    /// not the full context window. This is what lets q8_0 @ 8 slots fit
    /// a 16 GiB device on bounded serve traces (the feasible-frontier
    /// expansion of the paged-KV tentpole); callers round
    /// `context_tokens` up to a block multiple so the charge covers
    /// whole blocks. The fleet sweep rejects oversubscribed cells with
    /// the returned [`Capacity`] instead of running them.
    pub fn serve_capacity_tokens(
        &self,
        qtype: QuantType,
        slots: usize,
        context_tokens: usize,
    ) -> Capacity {
        Capacity {
            need_bytes: scale::ram_bytes_for_context(
                &LlamaConfig::llama_7b(),
                qtype,
                slots.max(1),
                context_tokens,
            ),
            have_bytes: self.ram_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LlamaConfig;

    #[test]
    fn specs_match_table1() {
        let d = DeviceSpec::paper_devices();
        assert_eq!(d.len(), 3);
        assert_eq!(d[0].name, "NanoPI");
        assert!((d[0].mem_bw - 34e9).abs() < 1.0);
        assert!((d[1].mem_bw - 26e9).abs() < 1.0);
        assert!((d[2].mem_bw - 50e9).abs() < 1.0);
        assert!(DeviceSpec::by_name("macbook").is_some());
        assert!(DeviceSpec::by_name("pixel").is_none());
    }

    #[test]
    fn fig3b_four_threads_beat_eight() {
        // The paper's counterintuitive core finding.
        for d in DeviceSpec::paper_devices() {
            for accel in [Accel::CpuNone, Accel::CpuBlas] {
                // Xiaomi naive path is the paper's own exception (t8 > t4
                // in Table 6); skip the exception, as the paper does in
                // its Fig-3b discussion.
                if d.name == "Xiaomi" && accel == Accel::CpuNone {
                    continue;
                }
                let t4 = d.cpu_gflops(accel, 4);
                let t8 = d.cpu_gflops(accel, 8);
                assert!(
                    t4 >= t8,
                    "{} {:?}: t4 {t4} < t8 {t8}",
                    d.name,
                    accel
                );
            }
        }
    }

    #[test]
    fn fig3a_acceleration_ordering() {
        // GPU > CPU-accelerated > CPU-naive at 4 threads (except the
        // MacBook where even naive clang output is vectorized, but the
        // ordering still holds).
        for d in DeviceSpec::paper_devices() {
            let none = d.matmul_gflops(Accel::CpuNone, 4);
            let blas = d.matmul_gflops(Accel::CpuBlas, 4);
            let gpu = d.matmul_gflops(Accel::Gpu, 4);
            assert!(blas > none, "{}: blas {blas} <= none {none}", d.name);
            assert!(gpu > blas, "{}: gpu {gpu} <= blas {blas}", d.name);
        }
    }

    #[test]
    fn table6_flops_magnitudes() {
        // Within ~2x of the paper's measured values.
        let nano = DeviceSpec::nanopi();
        assert!((20.0..60.0).contains(&nano.matmul_gflops(Accel::CpuNone, 4)));
        assert!((100.0..200.0).contains(&nano.matmul_gflops(Accel::Gpu, 4)));
        let mac = DeviceSpec::macbook();
        assert!((300.0..900.0).contains(&mac.matmul_gflops(Accel::CpuNone, 4)));
        assert!((900.0..1500.0).contains(&mac.matmul_gflops(Accel::Gpu, 4)));
    }

    #[test]
    fn ttlm_ordering_matches_fig5a() {
        // MacBook loads far faster than NanoPI/Xiaomi (paper: ~7s vs
        // ~55-75s for q4_0).
        let bytes = 3_500_000_000u64;
        let nano = DeviceSpec::nanopi().ttlm(bytes);
        let xiaomi = DeviceSpec::xiaomi().ttlm(bytes);
        let mac = DeviceSpec::macbook().ttlm(bytes);
        assert!((40.0..70.0).contains(&nano), "nano {nano}");
        assert!((60.0..90.0).contains(&xiaomi), "xiaomi {xiaomi}");
        assert!((5.0..10.0).contains(&mac), "mac {mac}");
    }

    #[test]
    fn decode_is_memory_bound_on_7b() {
        // For LLaMA-7B-class workloads, TPOT must sit on the memory side
        // of the roofline on every device/accelerator (the paper's RQ1
        // premise).
        let cfg = LlamaConfig::llama_7b();
        for d in DeviceSpec::paper_devices() {
            for q in QuantType::PAPER_SET {
                let w = Workload::decode(&cfg, q, 1, 128);
                // exception: naive Android CPU is so slow it goes
                // compute-bound — the paper's Xiaomi None rows (1.05 tok/s)
                if d.name == "Xiaomi" {
                    continue;
                }
                let mem = w.bytes_per_token as f64 / d.decode_bw(Accel::CpuBlas, q);
                let tpot = d.tpot(&w, Accel::CpuBlas, 4);
                // tpot is exactly mem-bound for most cells; q4_0 on the
                // NanoPI sits marginally past the roofline knee (also true
                // on the real RK3588) — allow a small compute excursion.
                assert!(
                    tpot >= mem && tpot <= mem * 1.15,
                    "{} {}: tpot {tpot} vs mem {mem}",
                    d.name,
                    q.name()
                );
            }
        }
    }

    #[test]
    fn mbu_band_matches_table6() {
        // Simulated MBU must land in the paper's observed 0.4-0.9 band,
        // rising with accelerator quality and bits-per-weight.
        for d in DeviceSpec::paper_devices() {
            let lo = d.bw_fraction(Accel::CpuNone, QuantType::Q4_0);
            let hi = d.bw_fraction(Accel::Gpu, QuantType::Q8_0);
            assert!(lo < hi);
            assert!((0.35..0.75).contains(&lo), "{} lo {lo}", d.name);
            assert!((0.5..0.95).contains(&hi), "{} hi {hi}", d.name);
        }
    }

    #[test]
    fn accel_keys_round_trip() {
        for a in Accel::ALL {
            assert_eq!(Accel::parse(a.key()), Some(a));
        }
        assert_eq!(Accel::parse("CPU"), Some(Accel::CpuNone));
        assert_eq!(Accel::parse("cpu-blas"), Some(Accel::CpuBlas));
        assert_eq!(Accel::parse("warp"), None);
    }

    /// The token-granular capacity-admission boundary: a 7B deployment
    /// whose paged-KV footprint is exactly the device's RAM is admitted;
    /// one more KV *block* of context is rejected as infeasible.
    #[test]
    fn serve_capacity_admits_just_under_and_rejects_one_block_over() {
        use crate::graph::kv::KV_BLOCK_TOKENS;
        let q = QuantType::Q8_0;
        let slots = 8;
        let ctx = 4 * KV_BLOCK_TOKENS;
        let need = scale::ram_bytes_for_context(&LlamaConfig::llama_7b(), q, slots, ctx);
        let mut spec = DeviceSpec::nanopi();
        spec.ram_bytes = need;
        let cap = spec.serve_capacity_tokens(q, slots, ctx);
        assert_eq!(cap.need_bytes, need);
        assert!(cap.fits(), "footprint == RAM must be admitted");
        assert!(
            !spec.serve_capacity_tokens(q, slots, ctx + KV_BLOCK_TOKENS).fits(),
            "one block of extra context must be rejected"
        );
        spec.ram_bytes = need - 1;
        assert!(
            !spec.serve_capacity_tokens(q, slots, ctx).fits(),
            "one byte over RAM must be rejected"
        );
    }

    #[test]
    fn serve_capacity_default_fleet_shape() {
        // The paged frontier expansion (ISSUE 6 acceptance): the legacy
        // full-context charge rejects q8_0 at 8 slots on every 16 GiB
        // paper device, but the token-granular paged charge at the
        // default fleet trace's bounded context admits it. q4_0 fits
        // either way.
        for d in DeviceSpec::paper_devices() {
            assert!(
                !d.serve_capacity(QuantType::Q8_0, 8).fits(),
                "{}: full-context q8_0 at 8 slots should oversubscribe 16 GiB",
                d.name
            );
            assert!(
                d.serve_capacity_tokens(QuantType::Q8_0, 8, 64).fits(),
                "{}: paged q8_0 at 8 slots × 64 tokens should fit 16 GiB",
                d.name
            );
            assert!(
                d.serve_capacity(QuantType::Q4_0, 8).fits(),
                "{}: q4_0 at 8 slots should fit 16 GiB",
                d.name
            );
        }
    }

    #[test]
    fn spec_tpot_equals_clock_step() {
        // The unification invariant: DeviceSpec::tpot is exactly the
        // clock's roofline on the workload's bytes/FLOPs.
        let cfg = LlamaConfig::llama_7b();
        for d in DeviceSpec::paper_devices() {
            for accel in Accel::ALL {
                let w = Workload::decode(&cfg, QuantType::Q5_0, 2, 256);
                let clock = d.clock(accel, w.qtype, 4);
                assert_eq!(
                    d.tpot(&w, accel, 4),
                    clock.step_secs(w.bytes_per_token, w.flops_per_token)
                );
            }
        }
    }

    #[test]
    fn gpu_ppl_blowup_only_on_opencl_devices() {
        let nano = DeviceSpec::nanopi();
        let mac = DeviceSpec::macbook();
        let base = 6.5;
        let p = nano.simulated_ppl(base, Accel::Gpu, QuantType::Q4_0);
        assert!(p / base > 5.0, "NanoPI OpenCL ppl factor too small: {p}");
        assert_eq!(mac.simulated_ppl(base, Accel::Gpu, QuantType::Q4_0), base);
        assert_eq!(nano.simulated_ppl(base, Accel::CpuBlas, QuantType::Q4_0), base);
        // Bigger-bit models amplify (paper: 67.6 > 54.3).
        assert!(
            nano.simulated_ppl(base, Accel::Gpu, QuantType::Q8_0)
                > nano.simulated_ppl(base, Accel::Gpu, QuantType::Q4_0)
        );
    }
}
