//! Workload descriptors: what one decode step of a given (model, format,
//! batch, context) costs in bytes and FLOPs. The device simulator prices
//! these; the native engine *measures* the same quantities — DESIGN.md §7
//! cross-checks them.

use crate::model::{scale, LlamaConfig};
use crate::quant::QuantType;

/// Cost description of a decode step.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub qtype: QuantType,
    pub batch: usize,
    /// Current context length (KV entries scanned per step).
    pub context_len: usize,
    /// Packed weight bytes streamed per token + KV traffic.
    pub bytes_per_token: u64,
    /// Weight bytes only (MBU's "Total Model Parameter Size").
    pub param_bytes: u64,
    /// KV-cache bytes at this batch/context (MBU's "KV Cache Size").
    pub kv_bytes: u64,
    pub flops_per_token: f64,
    /// Whole-model bytes (TTLM / prefill weight pass).
    pub model_bytes: u64,
}

impl Workload {
    /// Decode-step workload for `config` stored as `qtype`, at `batch`
    /// concurrent sequences and `context_len` tokens of history.
    /// KV cache uses f16 (data_byte = 2), matching llama.cpp.
    pub fn decode(config: &LlamaConfig, qtype: QuantType, batch: usize, context_len: usize) -> Self {
        let model_bytes = scale::model_file_bytes(config, qtype);
        let kv_bytes = scale::kv_cache_bytes(config, batch, context_len, 2);
        // Per decode step: all weights stream once (batch shares them),
        // and each sequence reads its own KV history.
        let bytes_per_token = model_bytes / batch.max(1) as u64
            + scale::kv_cache_bytes(config, 1, context_len, 2);
        Self {
            qtype,
            batch,
            context_len,
            bytes_per_token,
            param_bytes: model_bytes,
            kv_bytes,
            flops_per_token: flops_per_token(config, context_len),
            model_bytes,
        }
    }
}

/// FLOPs of one token's forward pass: 2·(matmul params) + attention.
pub fn flops_per_token(config: &LlamaConfig, context_len: usize) -> f64 {
    let d = config.d_model as f64;
    let kv_dim = (config.n_kv_heads * config.head_dim()) as f64;
    let per_layer = 2.0 * (2.0 * d * d + 2.0 * d * kv_dim + 3.0 * d * config.d_ff as f64)
        + 4.0 * context_len.max(1) as f64 * d;
    config.n_layers as f64 * per_layer + 2.0 * d * config.vocab_size as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seven_b_flops_approx_2n() {
        // Rule of thumb: decode FLOPs ≈ 2 × params.
        let c = LlamaConfig::llama_7b();
        let f = flops_per_token(&c, 1);
        let p2 = 2.0 * c.n_params() as f64;
        assert!((f / p2 - 1.0).abs() < 0.1, "f {f} vs 2N {p2}");
    }

    #[test]
    fn batch_amortizes_weight_traffic() {
        let c = LlamaConfig::llama_7b();
        let b1 = Workload::decode(&c, QuantType::Q4_0, 1, 128);
        let b8 = Workload::decode(&c, QuantType::Q4_0, 8, 128);
        assert!(b8.bytes_per_token < b1.bytes_per_token);
        // ~8x weight amortization (KV part doesn't amortize).
        assert!(b8.bytes_per_token > b1.bytes_per_token / 9);
        // Total KV grows with batch.
        assert_eq!(b8.kv_bytes, 8 * b1.kv_bytes);
    }

    #[test]
    fn context_grows_kv_traffic_only() {
        let c = LlamaConfig::llama_7b();
        let short = Workload::decode(&c, QuantType::Q8_0, 1, 64);
        let long = Workload::decode(&c, QuantType::Q8_0, 1, 1024);
        assert!(long.bytes_per_token > short.bytes_per_token);
        assert_eq!(long.param_bytes, short.param_bytes);
    }

    #[test]
    fn quant_shrinks_bytes_not_flops() {
        let c = LlamaConfig::llama_7b();
        let q4 = Workload::decode(&c, QuantType::Q4_0, 1, 128);
        let q8 = Workload::decode(&c, QuantType::Q8_0, 1, 128);
        assert!(q4.bytes_per_token < q8.bytes_per_token);
        assert_eq!(q4.flops_per_token, q8.flops_per_token);
    }
}
