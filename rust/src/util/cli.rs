//! Minimal CLI argument parser substrate (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands, with generated `--help` text — enough for the `elib`
//! launcher and the examples.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Declarative option spec.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub opts: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn parse_f64(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: `{v}` is not a number"))),
        }
    }

    pub fn parse_usize(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: `{v}` is not an integer"))),
        }
    }

    pub fn parse_u64(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{name}: `{v}` is not an integer"))),
        }
    }
}

/// A command with its option specs; `parse` validates against them.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self {
            name,
            about,
            opts: Vec::new(),
        }
    }

    pub fn opt(mut self, name: &'static str, default: Option<&'static str>, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            takes_value: true,
            default,
            help,
        });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            takes_value: false,
            default: None,
            help,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let head = if o.takes_value {
                format!("  --{} <value>", o.name)
            } else {
                format!("  --{}", o.name)
            };
            let default = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:<28}{}{default}\n", o.help));
        }
        s
    }

    /// Parse raw argv (without program/subcommand names).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = o.default {
                args.opts.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                if key == "help" {
                    return Err(CliError(self.usage()));
                }
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| CliError(format!("unknown option --{key}\n\n{}", self.usage())))?;
                if spec.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{key} needs a value")))?
                        }
                    };
                    args.opts.insert(key, v);
                } else {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{key} does not take a value")));
                    }
                    args.flags.push(key);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn cmd() -> Command {
        Command::new("bench", "run benchmarks")
            .opt("iters", Some("3"), "iteration count")
            .opt("device", None, "device name")
            .flag("verbose", "chatty output")
    }

    #[test]
    fn parses_values_and_defaults() {
        let a = cmd().parse(&sv(&["--device", "nanopi", "pos1"])).unwrap();
        assert_eq!(a.get("device"), Some("nanopi"));
        assert_eq!(a.parse_usize("iters", 0).unwrap(), 3);
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = cmd().parse(&sv(&["--iters=7", "--verbose"])).unwrap();
        assert_eq!(a.parse_usize("iters", 0).unwrap(), 7);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn unknown_option_errors() {
        assert!(cmd().parse(&sv(&["--nope"])).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(cmd().parse(&sv(&["--device"])).is_err());
    }

    #[test]
    fn bad_number_errors() {
        let a = cmd().parse(&sv(&["--iters", "xyz"])).unwrap();
        assert!(a.parse_usize("iters", 0).is_err());
    }

    #[test]
    fn usage_lists_options() {
        let u = cmd().usage();
        assert!(u.contains("--iters"));
        assert!(u.contains("default: 3"));
    }
}
