//! Criterion-like micro/macro benchmark harness (criterion is unavailable
//! offline). Provides warmup, adaptive iteration counts, wall-clock
//! sampling, and mean ± σ reporting; `cargo bench` targets use this with
//! `harness = false`.

use std::time::{Duration, Instant};

use crate::util::stats::Summary;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time summary, seconds.
    pub secs: Summary,
    /// Optional throughput basis (e.g. flops or bytes per iteration).
    pub work_per_iter: Option<f64>,
    pub work_unit: &'static str,
}

impl BenchResult {
    /// work_per_iter / mean_time — e.g. FLOP/s if work is FLOPs.
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / self.secs.mean)
    }

    pub fn line(&self) -> String {
        let base = format!(
            "{:<44} {:>12}/iter  ±{:>9}  (n={})",
            self.name,
            fmt_duration(self.secs.mean),
            fmt_duration(self.secs.std),
            self.secs.n
        );
        match self.throughput() {
            Some(t) => format!("{base}  {} {}/s", fmt_si(t), self.work_unit),
            None => base,
        }
    }
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // ELIB_BENCH_FAST=1 shrinks budgets so `cargo bench` smoke-runs in CI.
        let fast = std::env::var("ELIB_BENCH_FAST").is_ok();
        Self {
            warmup: Duration::from_millis(if fast { 20 } else { 200 }),
            measure: Duration::from_millis(if fast { 80 } else { 1000 }),
            min_samples: if fast { 5 } else { 10 },
            max_samples: if fast { 20 } else { 200 },
            results: Vec::new(),
        }
    }

    /// Benchmark `f`, which performs ONE iteration of the workload.
    pub fn run<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.run_with_work(name, None, "", f)
    }

    /// Benchmark with a throughput basis: `work` units are performed per call.
    pub fn run_with_work<F: FnMut()>(
        &mut self,
        name: &str,
        work: Option<f64>,
        unit: &'static str,
        mut f: F,
    ) -> &BenchResult {
        // Warmup.
        let wstart = Instant::now();
        let mut warm_iters = 0u64;
        while wstart.elapsed() < self.warmup || warm_iters == 0 {
            f();
            warm_iters += 1;
        }
        let est = wstart.elapsed().as_secs_f64() / warm_iters as f64;

        // Decide batching so each sample is >= ~1ms (timer noise floor).
        let batch = (1e-3 / est.max(1e-9)).ceil().max(1.0) as u64;
        let target_samples = ((self.measure.as_secs_f64() / (est * batch as f64).max(1e-9))
            .ceil() as usize)
            .clamp(self.min_samples, self.max_samples);

        let mut samples = Vec::with_capacity(target_samples);
        for _ in 0..target_samples {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            samples.push(t.elapsed().as_secs_f64() / batch as f64);
        }
        let result = BenchResult {
            name: name.to_string(),
            secs: Summary::of(&samples),
            work_per_iter: work,
            work_unit: unit,
        };
        println!("{}", result.line());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3}s")
    } else if secs >= 1e-3 {
        format!("{:.3}ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3}µs", secs * 1e6)
    } else {
        format!("{:.1}ns", secs * 1e9)
    }
}

pub fn fmt_si(x: f64) -> String {
    if x >= 1e12 {
        format!("{:.2}T", x / 1e12)
    } else if x >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2}k", x / 1e3)
    } else {
        format!("{x:.2}")
    }
}

/// Prevent the optimizer from deleting a computed value (std black_box is
/// stable since 1.66; thin wrapper so call sites read uniformly).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        std::env::set_var("ELIB_BENCH_FAST", "1");
        let mut b = Bench::new();
        let mut acc = 0u64;
        let r = b
            .run("spin", || {
                for i in 0..1000u64 {
                    acc = black_box(acc.wrapping_add(i));
                }
            })
            .clone();
        assert!(r.secs.mean > 0.0);
        assert!(r.secs.n >= 5);
    }

    #[test]
    fn throughput_uses_work() {
        std::env::set_var("ELIB_BENCH_FAST", "1");
        let mut b = Bench::new();
        let r = b
            .run_with_work("noopish", Some(1e6), "FLOP", || {
                black_box(0);
            })
            .clone();
        assert!(r.throughput().unwrap() > 0.0);
    }

    #[test]
    fn si_and_duration_formatting() {
        assert_eq!(fmt_si(2.5e9), "2.50G");
        assert_eq!(fmt_si(12.0), "12.00");
        assert_eq!(fmt_duration(0.0025), "2.500ms");
    }
}
