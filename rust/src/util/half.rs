//! IEEE-754 binary16 conversion substrate (no `half` crate offline).
//!
//! GGML block formats store per-block scales/zero-points as f16; the EGUF
//! container also supports f16 tensors. Conversions here are bit-exact with
//! the reference float16 semantics (round-to-nearest-even on encode),
//! matching what numpy's `astype(float16)` produces on the python side.

/// Convert an f32 to its f16 bit pattern, round-to-nearest-even.
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x7f_ffff;

    if exp == 0xff {
        // Inf / NaN
        return sign | 0x7c00 | if mant != 0 { 0x200 } else { 0 };
    }
    // Re-bias exponent: f32 bias 127 -> f16 bias 15.
    let unbiased = exp - 127;
    if unbiased > 15 {
        return sign | 0x7c00; // overflow -> inf
    }
    if unbiased >= -14 {
        // Normal f16. 23-bit mantissa -> 10-bit with RNE.
        let mant16 = mant >> 13;
        let rem = mant & 0x1fff;
        let mut h = sign | (((unbiased + 15) as u16) << 10) | mant16 as u16;
        if rem > 0x1000 || (rem == 0x1000 && (mant16 & 1) == 1) {
            h = h.wrapping_add(1); // may carry into exponent; that's correct RNE
        }
        return h;
    }
    if unbiased >= -25 {
        // Subnormal f16.
        let full = mant | 0x80_0000; // implicit leading 1: 1.mant * 2^23
        // Subnormal f16 mantissa counts units of 2^-24, so
        // mant16 = 1.mant * 2^(unbiased+24) = full * 2^(unbiased+1).
        let shift = (-1 - unbiased) as u32;
        let mant16 = full >> shift;
        let rem = full & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut h = sign | mant16 as u16;
        if rem > half || (rem == half && (mant16 & 1) == 1) {
            h = h.wrapping_add(1);
        }
        return h;
    }
    sign // underflow -> signed zero
}

/// Convert an f16 bit pattern to f32 (exact).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = match (exp, mant) {
        (0, 0) => sign,
        (0, m) => {
            // Subnormal: normalize.
            let mut e = -1i32;
            let mut m = m;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            m &= 0x3ff;
            // value = m * 2^-24 with highest bit k => exp = 127 + k - 24,
            // and the loop leaves e = k - 11, hence 127 + e - 13.
            sign | (((127 + e - 13) as u32) << 23) | (m << 13)
        }
        (0x1f, 0) => sign | 0x7f80_0000,
        (0x1f, m) => sign | 0x7f80_0000 | (m << 13),
        (e, m) => sign | ((e + 127 - 15) << 23) | (m << 13),
    };
    f32::from_bits(bits)
}

/// Round an f32 through f16 precision (quantize-dequantize).
pub fn round_f16(x: f32) -> f32 {
    f16_to_f32(f32_to_f16(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_small_values() {
        for (f, h) in [
            (0.0f32, 0x0000u16),
            (1.0, 0x3c00),
            (-1.0, 0xbc00),
            (2.0, 0x4000),
            (0.5, 0x3800),
            (65504.0, 0x7bff), // max finite f16
        ] {
            assert_eq!(f32_to_f16(f), h, "encode {f}");
            assert_eq!(f16_to_f32(h), f, "decode {h:#x}");
        }
    }

    #[test]
    fn overflow_to_inf() {
        assert_eq!(f32_to_f16(1e6), 0x7c00);
        assert_eq!(f32_to_f16(-1e6), 0xfc00);
        assert!(f16_to_f32(0x7c00).is_infinite());
    }

    #[test]
    fn nan_round_trips_as_nan() {
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
    }

    #[test]
    fn subnormals() {
        let tiny = 5.96e-8f32; // smallest positive subnormal f16 ~5.9604645e-8
        let h = f32_to_f16(tiny);
        assert_eq!(h, 0x0001);
        assert!((f16_to_f32(0x0001) - 5.9604645e-8).abs() < 1e-12);
    }

    #[test]
    fn round_trip_error_bounded() {
        // For normal-range values relative error is <= 2^-11.
        let mut x = 1e-3f32;
        while x < 6e4 {
            let r = round_f16(x);
            assert!(
                ((r - x) / x).abs() <= 1.0 / 2048.0 + 1e-7,
                "x={x} r={r}"
            );
            x *= 1.37;
        }
    }

    #[test]
    fn all_f16_bit_patterns_round_trip() {
        // decode -> encode is identity for every non-NaN pattern.
        for h in 0..=u16::MAX {
            let f = f16_to_f32(h);
            if f.is_nan() {
                continue;
            }
            assert_eq!(f32_to_f16(f), h, "pattern {h:#06x}");
        }
    }
}
