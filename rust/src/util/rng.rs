//! Deterministic PRNG substrate (the image has no `rand` crate offline).
//!
//! `SplitMix64` seeds `Xoshiro256**`, the same construction the `rand`
//! ecosystem recommends. Everything downstream of ELIB (weight init for
//! tests, workload generation, property-test case generation) draws from
//! this so runs are reproducible from a single `u64` seed.

/// SplitMix64 — tiny, used to expand a single seed into a full state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256** — the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`, 53-bit precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in `[0, n)` via Lemire's method (unbiased enough for
    /// our workloads; exact rejection is overkill here).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi.saturating_sub(lo))
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                let r = (-2.0 * u1.ln()).sqrt();
                return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
            }
        }
    }

    /// Vector of standard normals scaled by `scale` (weight-init helper).
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniform element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for n in [1u64, 2, 3, 17, 1000] {
            for _ in 0..1000 {
                assert!(r.below(n) < n);
            }
        }
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Rng::new(3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
