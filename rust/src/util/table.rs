//! ASCII table formatter used by the report generator and the bench
//! harness to print paper-style tables (Table 6 rows, figure series).

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table builder.
#[derive(Clone, Debug)]
pub struct Table {
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    title: Option<String>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Self {
            aligns: headers
                .iter()
                .map(|_| Align::Right)
                .collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: None,
        }
    }

    pub fn title(mut self, t: &str) -> Self {
        self.title = Some(t.to_string());
        self
    }

    /// Mark the first `n` columns as left-aligned (labels).
    pub fn left_cols(mut self, n: usize) -> Self {
        for a in self.aligns.iter_mut().take(n) {
            *a = Align::Left;
        }
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width {} != header width {}",
            cells.len(),
            self.headers.len()
        );
        self.rows.push(cells);
    }

    pub fn row_strs(&mut self, cells: &[&str]) {
        self.row(cells.iter().map(|s| s.to_string()).collect());
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let cell = &cells[i];
                let pad = widths[i] - cell.len();
                match self.aligns[i] {
                    Align::Left => s.push_str(&format!(" {}{} |", cell, " ".repeat(pad))),
                    Align::Right => s.push_str(&format!(" {}{} |", " ".repeat(pad), cell)),
                }
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Render as CSV (for figure series consumed by plotting tools).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format helpers used across reports.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}
pub fn f3(x: f64) -> String {
    format!("{x:.3}")
}

/// Human-readable byte size (GiB-style units with decimal display as the
/// paper uses, e.g. "3.5G").
pub fn human_bytes(bytes: u64) -> String {
    let b = bytes as f64;
    const G: f64 = 1024.0 * 1024.0 * 1024.0;
    const M: f64 = 1024.0 * 1024.0;
    const K: f64 = 1024.0;
    if b >= G {
        format!("{:.1}G", b / G)
    } else if b >= M {
        format!("{:.1}M", b / M)
    } else if b >= K {
        format!("{:.1}K", b / K)
    } else {
        format!("{bytes}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "val"]).left_cols(1);
        t.row_strs(&["a", "1.00"]);
        t.row_strs(&["long-name", "12.34"]);
        let s = t.render();
        assert!(s.contains("| a         |  1.00 |"), "{s}");
        assert!(s.contains("| long-name | 12.34 |"), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new(&["k", "v"]);
        t.row_strs(&["a,b", "he said \"hi\""]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\",\"he said \"\"hi\"\"\""), "{csv}");
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512B");
        assert_eq!(human_bytes(3 * 1024 * 1024 * 1024 + 512 * 1024 * 1024), "3.5G");
    }
}
