//! Minimal JSON codec substrate (no serde offline).
//!
//! Covers what ELIB needs: reading model metadata emitted by the python
//! compile path (`artifacts/model_meta.json`), reading benchmark configs,
//! and writing machine-readable benchmark reports. Supports the full JSON
//! data model; numbers are f64 (with an integer accessor).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors with contextual errors.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| JsonError::new(format!("missing/invalid number field `{key}`")))
    }

    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        Ok(self.req_f64(key)? as usize)
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError::new(format!("missing/invalid string field `{key}`")))
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl JsonError {
    fn new(msg: String) -> Self {
        Self { msg, offset: 0 }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{s}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences from raw bytes.
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let d = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            v = v * 16
                + (d as char)
                    .to_digit(16)
                    .ok_or_else(|| self.err("bad hex digit"))?;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

/// Serialize with 2-space indentation (deterministic key order).
pub fn to_string_pretty(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, 0, true, &mut out);
    out
}

/// Compact serialization.
pub fn to_string(v: &Json) -> String {
    let mut out = String::new();
    write_value(v, 0, false, &mut out);
    out
}

fn write_value(v: &Json, indent: usize, pretty: bool, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 9e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => write_string(s, out),
        Json::Arr(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                }
                write_value(item, indent + 1, pretty, out);
            }
            if pretty {
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
            }
            out.push(']');
        }
        Json::Obj(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent + 1));
                }
                write_string(k, out);
                out.push(':');
                if pretty {
                    out.push(' ');
                }
                write_value(val, indent + 1, pretty, out);
            }
            if pretty {
                out.push('\n');
                out.push_str(&"  ".repeat(indent));
            }
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.req_str("c").unwrap(), "x\ny");
    }

    #[test]
    fn round_trip() {
        let src = r#"{"model":{"d_model":128,"layers":[1,2,3]},"ok":true,"name":"tiny\"q\""}"#;
        let v = parse(src).unwrap();
        let out = to_string(&v);
        assert_eq!(parse(&out).unwrap(), v);
        let pretty = to_string_pretty(&v);
        assert_eq!(parse(&pretty).unwrap(), v);
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn raw_utf8_passthrough() {
        let v = parse("\"héllo 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo 世界");
    }

    #[test]
    fn errors_carry_offset() {
        let e = parse("{\"a\": }").unwrap_err();
        assert!(e.offset >= 6, "offset={}", e.offset);
        assert!(parse("[1,]").is_err());
        assert!(parse("[1] x").is_err());
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(to_string(&Json::Num(42.0)), "42");
        assert_eq!(to_string(&Json::Num(0.5)), "0.5");
    }
}
