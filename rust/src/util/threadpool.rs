//! Scoped thread-pool substrate (no rayon/tokio offline).
//!
//! Two facilities:
//!  * [`ThreadPool`] — a long-lived worker pool with a work queue, used by
//!    the `parallel` kernel backend (the OpenBLAS analogue) so repeated
//!    matmuls don't pay thread spawn cost; and
//!  * [`parallel_chunks`] — a convenience that splits an index range over
//!    `n` threads with `std::thread::scope` for one-shot jobs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<Vec<Job>>,
    cv: Condvar,
    shutdown: Mutex<bool>,
    inflight: AtomicUsize,
    done_cv: Condvar,
    done_mx: Mutex<()>,
}

/// Fixed-size worker pool. `execute` enqueues a job; `wait` blocks until
/// all enqueued jobs have completed (a barrier, used after fan-out).
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    n_threads: usize,
}

impl ThreadPool {
    pub fn new(n_threads: usize) -> Self {
        let n = n_threads.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(Vec::new()),
            cv: Condvar::new(),
            shutdown: Mutex::new(false),
            inflight: AtomicUsize::new(0),
            done_cv: Condvar::new(),
            done_mx: Mutex::new(()),
        });
        let workers = (0..n)
            .map(|_| {
                let sh = shared.clone();
                std::thread::spawn(move || worker_loop(sh))
            })
            .collect();
        Self {
            shared,
            workers,
            n_threads: n,
        }
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    pub fn execute<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        self.shared.queue.lock().unwrap().push(Box::new(f));
        self.shared.cv.notify_one();
    }

    /// Block until the queue is drained and all running jobs finished.
    pub fn wait(&self) {
        let mut guard = self.shared.done_mx.lock().unwrap();
        while self.shared.inflight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.done_cv.wait(guard).unwrap();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = sh.queue.lock().unwrap();
            loop {
                if let Some(j) = q.pop() {
                    break Some(j);
                }
                if *sh.shutdown.lock().unwrap() {
                    break None;
                }
                q = sh.cv.wait(q).unwrap();
            }
        };
        match job {
            Some(j) => {
                j();
                if sh.inflight.fetch_sub(1, Ordering::SeqCst) == 1 {
                    let _g = sh.done_mx.lock().unwrap();
                    sh.done_cv.notify_all();
                }
            }
            None => return,
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        *self.shared.shutdown.lock().unwrap() = true;
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split `0..len` into `n_threads` contiguous chunks and run `f(range)` on
/// scoped threads. `f` receives `(start, end)`; results are discarded —
/// callers communicate through output slices split with `split_at_mut` or
/// through interior atomics.
pub fn parallel_chunks<F>(len: usize, n_threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let n = n_threads.max(1).min(len.max(1));
    if n <= 1 || len == 0 {
        f(0, len);
        return;
    }
    let chunk = len.div_ceil(n);
    std::thread::scope(|s| {
        for t in 0..n {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(len);
            if start >= end {
                break;
            }
            let f = &f;
            s.spawn(move || f(start, end));
        }
    });
}

/// Map over items on scoped threads, preserving order.
pub fn parallel_map<T, R, F>(items: &[T], n_threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let mut out: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
    {
        let slots: Vec<(usize, &T, *mut Option<R>)> = out
            .iter_mut()
            .enumerate()
            .map(|(i, slot)| (i, &items[i], slot as *mut Option<R>))
            .collect();
        // SAFETY: each slot pointer is written by exactly one thread (disjoint
        // chunks of the index range) and `out` outlives the scope.
        struct SendPtr<R>(*mut Option<R>);
        unsafe impl<R> Send for SendPtr<R> {}
        unsafe impl<R> Sync for SendPtr<R> {}
        let ptrs: Vec<(usize, SendPtr<R>)> =
            slots.iter().map(|(i, _, p)| (*i, SendPtr(*p))).collect();
        let items_ref = items;
        parallel_chunks(items.len(), n_threads, |start, end| {
            for k in start..end {
                let r = f(&items_ref[k]);
                let (_, ptr) = &ptrs[k];
                unsafe {
                    *ptr.0 = Some(r);
                }
            }
        });
    }
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// A simple mpsc-backed oneshot used by the coordinator's timeout guard.
pub fn oneshot<T: Send + 'static>() -> (Sender<T>, Receiver<T>) {
    channel()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = counter.clone();
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn pool_wait_is_reusable() {
        let pool = ThreadPool::new(2);
        for round in 0..3 {
            let counter = Arc::new(AtomicU64::new(0));
            for _ in 0..10 {
                let c = counter.clone();
                pool.execute(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            pool.wait();
            assert_eq!(counter.load(Ordering::SeqCst), 10, "round {round}");
        }
    }

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits: Vec<AtomicU64> = (0..1000).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(1000, 7, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::SeqCst);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::SeqCst) == 1));
    }

    #[test]
    fn map_preserves_order() {
        let xs: Vec<u64> = (0..257).collect();
        let ys = parallel_map(&xs, 4, |x| x * 2);
        assert_eq!(ys, xs.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn chunks_single_thread_fallback() {
        let sum = AtomicU64::new(0);
        parallel_chunks(10, 1, |s, e| {
            for i in s..e {
                sum.fetch_add(i as u64, Ordering::SeqCst);
            }
        });
        assert_eq!(sum.load(Ordering::SeqCst), 45);
    }
}
