//! Small statistics toolkit used by the metrics engine, the bench harness
//! and the report generator (no external stats crates offline).

/// Summary statistics over a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p95: percentile_sorted(&sorted, 0.95),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }

    /// [`Summary::of`] that maps an empty sample to `None` instead of
    /// panicking — for populations that can legitimately vanish (e.g.
    /// latency summaries over *served* requests when an SLO-aware
    /// scheduler shed the whole trace). Consumers serialize `None` as
    /// `null`, never as fake zeros.
    pub fn of_opt(xs: &[f64]) -> Option<Self> {
        if xs.is_empty() {
            None
        } else {
            Some(Self::of(xs))
        }
    }
}

/// Linear-interpolated percentile of a pre-sorted sample, q in [0,1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Mean squared error between two equal-length slices.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| ((x - y) as f64).powi(2))
        .sum::<f64>()
        / a.len() as f64
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Root-mean-square of a slice.
pub fn rms(xs: &[f32]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| (*x as f64).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.std - (2.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn mse_and_max_diff() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.5, 2.0];
        assert!((mse(&a, &b) - (0.25 + 1.0) / 3.0).abs() < 1e-9);
        assert_eq!(max_abs_diff(&a, &b), 1.0);
    }

    #[test]
    fn of_opt_maps_empty_to_none() {
        assert_eq!(Summary::of_opt(&[]), None);
        assert_eq!(Summary::of_opt(&[7.0]), Some(Summary::of(&[7.0])));
    }

    #[test]
    fn single_sample_summary() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p95, 7.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn tail_percentiles_ordered() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!(s.p50 <= s.p90 && s.p90 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.p95 - 94.05).abs() < 1e-9, "p95 {}", s.p95);
    }
}
