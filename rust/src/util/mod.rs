//! Substrate utilities built from scratch because the offline image ships
//! no general-purpose crates (see DESIGN.md §8): PRNG, f16, stats, JSON,
//! tables, thread pool, CLI parsing and a bench harness.

pub mod bench;
pub mod cli;
pub mod half;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
