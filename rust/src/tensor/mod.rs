//! Minimal f32 tensor substrate for the graph layer (paper Fig 2: "the
//! abstraction of tensor library"). Quantized weights live in
//! [`crate::quant::QTensor`]; this module covers the dense f32 values that
//! flow between operators (activations, caches, logits) plus the dense
//! mat-mat multiply used by the paper's FLOPS benchmark (§5.2.1).

use crate::util::threadpool::parallel_chunks;

/// Row-major 2-D f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor2 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor2 {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(data: Vec<f32>, rows: usize, cols: usize) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Naive triple-loop matmul: `self (m×k) · other (k×n)`.
    pub fn matmul_naive(&self, other: &Tensor2) -> Tensor2 {
        assert_eq!(self.cols, other.rows, "inner dims");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Tensor2::zeros(m, n);
        for i in 0..m {
            for p in 0..k {
                let a = self.data[i * k + p];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    orow[j] += a * brow[j];
                }
            }
        }
        out
    }

    /// Cache-blocked, multi-threaded matmul (rows of the output are
    /// distributed over `n_threads`). This is the "accelerated BLAS"
    /// analogue the FLOPS benchmark exercises.
    pub fn matmul_blocked(&self, other: &Tensor2, n_threads: usize) -> Tensor2 {
        assert_eq!(self.cols, other.rows, "inner dims");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Tensor2::zeros(m, n);
        const KB: usize = 64; // k-blocking keeps a B panel in L1/L2
        let out_ptr = SyncPtr(out.data.as_mut_ptr());
        parallel_chunks(m, n_threads, |r0, r1| {
            let out_ptr = &out_ptr;
            for p0 in (0..k).step_by(KB) {
                let p1 = (p0 + KB).min(k);
                for i in r0..r1 {
                    // SAFETY: each thread owns disjoint output rows [r0,r1).
                    let orow = unsafe {
                        std::slice::from_raw_parts_mut(out_ptr.0.add(i * n), n)
                    };
                    for p in p0..p1 {
                        let a = self.data[i * k + p];
                        let brow = &other.data[p * n..(p + 1) * n];
                        for j in 0..n {
                            orow[j] += a * brow[j];
                        }
                    }
                }
            }
        });
        out
    }

    /// FLOP count of a matmul with these dims (2·m·k·n).
    pub fn matmul_flops(m: usize, k: usize, n: usize) -> f64 {
        2.0 * m as f64 * k as f64 * n as f64
    }
}

struct SyncPtr(*mut f32);
unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

/// y += x
pub fn vec_add_inplace(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x) {
        *a += b;
    }
}

/// y *= x (elementwise)
pub fn vec_mul_inplace(y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(y.len(), x.len());
    for (a, b) in y.iter_mut().zip(x) {
        *a *= b;
    }
}

/// SiLU(x) = x·σ(x), in place.
pub fn silu_inplace(x: &mut [f32]) {
    for v in x.iter_mut() {
        *v = *v / (1.0 + (-*v).exp());
    }
}

/// Numerically-stable softmax in place.
pub fn softmax_inplace(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let max = x.iter().fold(f32::NEG_INFINITY, |a, v| a.max(*v));
    let mut sum = 0f32;
    for v in x.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

/// log-softmax value of element `idx` (used by perplexity).
pub fn log_softmax_at(x: &[f32], idx: usize) -> f64 {
    let max = x.iter().fold(f32::NEG_INFINITY, |a, v| a.max(*v)) as f64;
    let lse: f64 = x.iter().map(|v| ((*v as f64) - max).exp()).sum::<f64>().ln() + max;
    x[idx] as f64 - lse
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn naive_matmul_small() {
        let a = Tensor2::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2);
        let b = Tensor2::from_vec(vec![1.0, 1.0, 1.0, 1.0], 2, 2);
        let c = a.matmul_naive(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn blocked_matches_naive() {
        let mut rng = Rng::new(4);
        for (m, k, n) in [(3, 5, 7), (16, 64, 16), (33, 130, 9)] {
            let a = Tensor2::from_vec(rng.normal_vec(m * k, 1.0), m, k);
            let b = Tensor2::from_vec(rng.normal_vec(k * n, 1.0), k, n);
            let c1 = a.matmul_naive(&b);
            for t in [1, 2, 4] {
                let c2 = a.matmul_blocked(&b, t);
                let md = crate::util::stats::max_abs_diff(&c1.data, &c2.data);
                assert!(md < 1e-4, "m{m} k{k} n{n} t{t}: {md}");
            }
        }
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut x = vec![1.0f32, 2.0, 3.0, -1000.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(x[3] < 1e-20);
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let x = vec![0.5f32, -0.7, 2.0, 1.1];
        let mut sm = x.clone();
        softmax_inplace(&mut sm);
        for i in 0..x.len() {
            assert!((log_softmax_at(&x, i) - (sm[i] as f64).ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn silu_known_values() {
        let mut x = vec![0.0f32, 1.0];
        silu_inplace(&mut x);
        assert_eq!(x[0], 0.0);
        assert!((x[1] - 0.731058).abs() < 1e-4);
    }
}
