//! Algorithm 1: the ELIB benchmark loop.
//!
//! For each iteration × quantized model × device × accelerator:
//! *adapt_and_deploy* (RAM guard against the device, engine construction
//! with the accelerator's backend), *run_inference* (batched generation +
//! held-out NLL on the native engine, guarded by a timeout), then metric
//! computation — FLOPS, throughput, TTLM, TTFT, MBU, perplexity — where
//! the *relationships* come from real measurements on the tiny model and
//! the device-scale numbers come from pricing the paper's 7B workload on
//! the device simulator (DESIGN.md §2).
//!
//! The grid is *scheduled concurrently*: host measurements (one per
//! quant × backend-class × batch-size) and device-grid cells fan out over
//! the shared threadpool (`util::threadpool::parallel_map`), while
//! results are committed in the sequential grid order — a run with
//! `scheduler_threads = N` produces records identical, in order and
//! content, to the sequential `N = 1` path (locked in by
//! `threaded_run_matches_sequential` below).

use std::sync::mpsc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::device::{Accel, Capacity, DeviceSpec, Workload};
use crate::gguf::ModelFile;
use crate::graph::{generate_batch, Engine, Sampler};
use crate::kernel::{BackendKind, Precision};
use crate::metrics::{self, MetricsRecord};
use crate::model::{scale, LlamaConfig, ModelWeights};
use crate::quant::QuantType;
use crate::util::threadpool::parallel_map;

use super::config::ElibConfig;
use super::flow::QuantizedModel;

/// Why a grid cell was skipped (Algorithm 1 Ln. 11–12).
#[derive(Clone, Debug)]
pub enum SkipReason {
    MemoryOverflow { need: u64, have: u64 },
    Timeout { after: Duration },
    Failure(String),
}

/// Host-side (real) measurement for one (quant, backend, batch) triple.
#[derive(Clone, Debug)]
pub struct HostMeasurement {
    pub qtype: QuantType,
    /// Typed backend — what grid lookups match on.
    pub backend_kind: BackendKind,
    /// Display label of the backend (kept for reports/JSON).
    pub backend: String,
    /// Sequences decoded per step.
    pub batch: usize,
    /// Aggregate tokens/s across the batch.
    pub throughput_tok_s: f64,
    pub tpot_secs: f64,
    pub prefill_secs: f64,
    /// Measured bytes moved per generated token (ledger; weights stream
    /// once per step, so this drops as batch grows).
    pub bytes_per_token: u64,
    /// Weight bytes streamed per decode step (MBU's parameter term).
    pub param_bytes: u64,
    /// KV bytes resident across all slots at end of generation (MBU's
    /// batch-aware KV term, eq. 3).
    pub kv_bytes: u64,
    pub host_mbu: f64,
    pub ppl: f64,
}

/// Outcome of the full run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub records: Vec<MetricsRecord>,
    pub skipped: Vec<(String, String)>,
    pub host: Vec<HostMeasurement>,
}

/// Map the paper's accelerator axis onto a native-engine backend,
/// respecting the device's GPU numerical fidelity.
pub fn backend_for(accel: Accel, device: &DeviceSpec) -> BackendKind {
    match accel {
        Accel::CpuNone => BackendKind::Naive,
        Accel::CpuBlas => BackendKind::Parallel(4),
        Accel::Gpu => BackendKind::Gpu(if device.gpu_ppl_factor > 1.0 {
            Precision::DegradedF16
        } else {
            Precision::Full
        }),
    }
}

/// Load eval-corpus tokens for the perplexity metric.
pub fn eval_tokens(config: &ElibConfig) -> Result<Vec<u32>> {
    let path = config.artifacts_dir.join("corpus_eval.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
    Ok(text
        .bytes()
        .take(config.bench.ppl_tokens.max(2))
        .map(|b| b as u32)
        .collect())
}

/// `run_inference_sweep` with the timeout guard: generation + NLL on a
/// worker thread, `recv_timeout` on the leader (Ln. 9–12). The worker
/// streams one result per batch size, so each measurement gets its own
/// `timeout` window (the shared NLL pass is charged to the first) and a
/// late-batch timeout or failure keeps the already-completed smaller
/// batches instead of discarding the whole sweep.
fn run_sweep_guarded(
    mf: ModelFile,
    backend: BackendKind,
    prompt: Vec<u32>,
    gen_tokens: usize,
    ppl_tokens: Vec<u32>,
    batch_sizes: Vec<usize>,
    timeout: Duration,
) -> Vec<Result<HostMeasurement, SkipReason>> {
    let n = batch_sizes.len();
    let (tx, rx) = mpsc::channel::<Result<HostMeasurement, String>>();
    // elib-lint: allow(raw-thread-spawn, reason = "timeout watchdog must outlive a hung sweep; the pool would block on it")
    std::thread::spawn(move || {
        let emit_tx = tx.clone();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_inference_sweep_with(
                &mf,
                backend,
                &prompt,
                gen_tokens,
                &ppl_tokens,
                &batch_sizes,
                &mut |m| {
                    let _ = emit_tx.send(Ok(m));
                },
            )
        }));
        match result {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                let _ = tx.send(Err(format!("{e:#}")));
            }
            Err(_) => {
                let _ = tx.send(Err("panic (deadlock-class failure)".to_string()));
            }
        }
    });
    let mut out: Vec<Result<HostMeasurement, SkipReason>> = Vec::with_capacity(n);
    while out.len() < n {
        match rx.recv_timeout(timeout) {
            Ok(Ok(m)) => out.push(Ok(m)),
            Ok(Err(e)) => {
                out.push(Err(SkipReason::Failure(e)));
                break;
            }
            Err(_) => {
                out.push(Err(SkipReason::Timeout { after: timeout }));
                break;
            }
        }
    }
    while out.len() < n {
        out.push(Err(SkipReason::Failure(
            "sweep aborted after earlier failure".to_string(),
        )));
    }
    out
}

/// Single-batch timeout guard (the seed API, kept for callers/tests).
pub fn run_inference_guarded(
    mf: ModelFile,
    backend: BackendKind,
    prompt: Vec<u32>,
    gen_tokens: usize,
    ppl_tokens: Vec<u32>,
    batch: usize,
    timeout: Duration,
) -> Result<HostMeasurement, SkipReason> {
    run_sweep_guarded(mf, backend, prompt, gen_tokens, ppl_tokens, vec![batch], timeout)
        .pop()
        .expect("one batch in, one outcome out")
}

/// The unguarded inference body: deploy + batched generation at every
/// requested batch size + perplexity, emitting each measurement as it
/// completes. Perplexity always runs on a batch-1 engine and is computed
/// once per sweep — the quantization effect does not depend on batching,
/// and the NLL pass dominates the wall clock.
fn run_inference_sweep_with(
    mf: &ModelFile,
    backend: BackendKind,
    prompt: &[u32],
    gen_tokens: usize,
    ppl_tokens: &[u32],
    batch_sizes: &[usize],
    emit: &mut dyn FnMut(HostMeasurement),
) -> Result<()> {
    anyhow::ensure!(!batch_sizes.is_empty(), "empty batch-size list");
    anyhow::ensure!(batch_sizes.iter().all(|b| *b >= 1), "batch must be >= 1");
    let mut nll_engine = Engine::new(ModelWeights::load(mf)?, backend);
    let (nll, count) = nll_engine.sequence_nll(ppl_tokens)?;
    let ppl = metrics::perplexity(nll, count);
    let qtype = nll_engine.weights.qtype;
    let param_bytes = nll_engine.weights.bytes_per_token();
    for &batch in batch_sizes {
        let mut engine = Engine::new_batched(ModelWeights::load(mf)?, backend, batch);
        let mut sampler = Sampler::Greedy;
        let prompts: Vec<Vec<u32>> = vec![prompt.to_vec(); batch];
        let stats = generate_batch(&mut engine, &prompts, gen_tokens, &mut sampler)?;
        emit(HostMeasurement {
            qtype,
            backend_kind: backend,
            backend: backend.label(),
            batch,
            throughput_tok_s: stats.decode_throughput(),
            tpot_secs: stats.tpot_secs(),
            prefill_secs: stats.prefill_secs,
            bytes_per_token: stats.bytes_per_token(),
            param_bytes,
            kv_bytes: engine.cache.bytes_in_use(),
            host_mbu: 0.0, // filled by caller (needs host_peak_bw)
            ppl,
        });
    }
    Ok(())
}

/// Collected sweep (convenience over [`run_inference_sweep_with`]).
pub fn run_inference_sweep(
    mf: &ModelFile,
    backend: BackendKind,
    prompt: &[u32],
    gen_tokens: usize,
    ppl_tokens: &[u32],
    batch_sizes: &[usize],
) -> Result<Vec<HostMeasurement>> {
    let mut out = Vec::with_capacity(batch_sizes.len());
    run_inference_sweep_with(mf, backend, prompt, gen_tokens, ppl_tokens, batch_sizes, &mut |m| {
        out.push(m)
    })?;
    Ok(out)
}

/// Single-batch inference body (the seed API, kept for callers/tests).
pub fn run_inference(
    mf: &ModelFile,
    backend: BackendKind,
    prompt: &[u32],
    gen_tokens: usize,
    ppl_tokens: &[u32],
    batch: usize,
) -> Result<HostMeasurement> {
    Ok(
        run_inference_sweep(mf, backend, prompt, gen_tokens, ppl_tokens, &[batch])?
            .pop()
            .expect("one batch in, one measurement out"),
    )
}

/// One scheduled host job: a (quant, backend-class) pair, swept over all
/// configured batch sizes.
struct HostJob {
    qname: &'static str,
    label: &'static str,
    backend: BackendKind,
    path: std::path::PathBuf,
}

/// Full Algorithm-1 execution, scheduled over the threadpool.
pub fn run(config: &ElibConfig, models: &[QuantizedModel], log: &mut dyn FnMut(&str)) -> Result<RunReport> {
    let mut report = RunReport::default();
    let ppl_toks = eval_tokens(config)?;
    let prompt: Vec<u32> = ppl_toks.iter().take(config.bench.prompt_tokens).copied().collect();
    let seven_b = LlamaConfig::llama_7b();
    let threads = config.bench.scheduler_threads.max(1);
    let batch_sizes: Vec<usize> = if config.bench.batch_sizes.is_empty() {
        vec![config.bench.batch_size.max(1)]
    } else {
        config.bench.batch_sizes.clone()
    };

    // --- host measurements: one per (quant, backend-class, batch), reused
    // across devices (the real engine doesn't change per simulated device).
    let backend_classes: [(&str, BackendKind); 3] = [
        ("cpu-naive", BackendKind::Naive),
        ("cpu-parallel", BackendKind::Parallel(4)),
        ("gpu-degraded", BackendKind::Gpu(Precision::DegradedF16)),
    ];
    let mut host_jobs = Vec::new();
    for m in models {
        for (label, backend) in backend_classes {
            host_jobs.push(HostJob {
                qname: m.qtype.name(),
                label,
                backend,
                path: m.path.clone(),
            });
        }
    }
    let gen_tokens = config.bench.gen_tokens;
    let timeout = config.bench.timeout;
    let outcomes = parallel_map(&host_jobs, threads, |job| {
        let mf = match ModelFile::load(&job.path) {
            Ok(mf) => mf,
            Err(e) => {
                return batch_sizes
                    .iter()
                    .map(|_| Err(SkipReason::Failure(format!("load model: {e:#}"))))
                    .collect();
            }
        };
        run_sweep_guarded(
            mf,
            job.backend,
            prompt.clone(),
            gen_tokens,
            ppl_toks.clone(),
            batch_sizes.clone(),
            timeout,
        )
    });
    for (job, sweep) in host_jobs.iter().zip(outcomes) {
        for (batch, outcome) in batch_sizes.iter().zip(sweep) {
            match outcome {
                Ok(mut h) => {
                    h.host_mbu = metrics::mbu(
                        h.param_bytes,
                        h.kv_bytes,
                        h.tpot_secs,
                        config.bench.host_peak_bw,
                    );
                    log(&format!(
                        "[host] {} {} b{}: {:.1} tok/s, {} B/token, ppl {:.3}",
                        job.qname, job.label, h.batch, h.throughput_tok_s, h.bytes_per_token, h.ppl
                    ));
                    report.host.push(h);
                }
                Err(r) => report.skipped.push((
                    format!("host/{}/{}/b{batch}", job.qname, job.label),
                    format!("{r:?}"),
                )),
            }
        }
    }

    // --- device grid (Table 6) -----------------------------------------
    let mut cells: Vec<(&QuantizedModel, &DeviceSpec, Accel)> = Vec::new();
    for _iter in 0..config.bench.iterations.max(1) {
        for m in models {
            for device in &config.devices {
                for accel in Accel::ALL {
                    let cell = format!("{}/{:?}/{}", device.name, accel, m.qtype.name());
                    // adapt_and_deploy: RAM guard on the 7B-scale
                    // deployment — the same structured capacity check the
                    // fleet sweep's admission gate uses.
                    let cap = Capacity {
                        need_bytes: scale::max_ram_bytes(
                            &seven_b,
                            m.qtype,
                            config.bench.batch_size,
                        ),
                        have_bytes: device.ram_bytes,
                    };
                    if !cap.fits() {
                        report.skipped.push((
                            cell,
                            format!(
                                "memory overflow: need {} > ram {}",
                                cap.need_bytes, cap.have_bytes
                            ),
                        ));
                        continue;
                    }
                    cells.push((m, device, accel));
                }
            }
        }
    }
    let host = &report.host;
    let priced = parallel_map(&cells, threads, |(m, device, accel)| {
        simulate_cell(config, device, *accel, m, host)
    });
    for record in priced {
        report.records.push(record?);
    }
    Ok(report)
}

/// Price one Table-6 cell on the device simulator, using host-measured
/// perplexity as the accuracy base.
pub fn simulate_cell(
    config: &ElibConfig,
    device: &DeviceSpec,
    accel: Accel,
    m: &QuantizedModel,
    host: &[HostMeasurement],
) -> Result<MetricsRecord> {
    let seven_b = LlamaConfig::llama_7b();
    let b = &config.bench;
    let w = Workload::decode(&seven_b, m.qtype, b.batch_size, b.context_len);
    // `DeviceSpec::tpot` resolves the same `DeviceClock` the serving
    // `SimLoop` owns (DESIGN.md §5): one roofline derivation prices the
    // solo grid and every serving scenario.
    let tpot = device.tpot(&w, accel, 4);
    let (acc_label, fw_label) = device.accel_label(accel);
    // Accuracy base: host CPU ppl for this quant (real quantization
    // effect); the device precision model adds the OpenCL pathology.
    // Matching is typed (BackendKind), not on the display label; ppl is
    // batch-independent, so any batch's naive measurement works.
    let base_ppl = host
        .iter()
        .find(|h| h.qtype == m.qtype && h.backend_kind == BackendKind::Naive)
        .map(|h| h.ppl)
        .ok_or_else(|| anyhow!("no host cpu measurement for {}", m.qtype.name()))?;
    Ok(MetricsRecord {
        device: device.name.to_string(),
        os: device.os.to_string(),
        accelerator: acc_label.to_string(),
        framework: fw_label.to_string(),
        qtype: m.qtype,
        flops_t4_giga: device.matmul_gflops(accel, 4),
        flops_t8_giga: device.matmul_gflops(accel, 8),
        throughput_tok_s: 1.0 / tpot,
        ttlm_secs: device.ttlm(w.model_bytes),
        ttft_secs: device.ttft(&w, b.prompt_tokens, accel, 4),
        mbu: metrics::mbu(w.param_bytes, w.kv_bytes, tpot, device.mem_bw),
        ppl: device.simulated_ppl(base_ppl, accel, m.qtype),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::flow;
    use crate::model::testutil::{random_model_file, random_weights};
    use crate::util::json::{self, Json};

    #[test]
    fn backend_mapping_respects_device_precision() {
        let nano = DeviceSpec::nanopi();
        let mac = DeviceSpec::macbook();
        assert_eq!(
            backend_for(Accel::Gpu, &nano),
            BackendKind::Gpu(Precision::DegradedF16)
        );
        assert_eq!(
            backend_for(Accel::Gpu, &mac),
            BackendKind::Gpu(Precision::Full)
        );
        assert_eq!(backend_for(Accel::CpuNone, &nano), BackendKind::Naive);
    }

    #[test]
    fn run_inference_produces_metrics() {
        let mf = random_model_file(QuantType::Q8_0, 3);
        let prompt = vec![1u32, 2, 3, 4];
        let ppl: Vec<u32> = (0..32u32).map(|i| i % 250).collect();
        let h = run_inference(&mf, BackendKind::Naive, &prompt, 4, &ppl, 1).unwrap();
        assert!(h.throughput_tok_s > 0.0);
        assert!(h.bytes_per_token > 0);
        assert!(h.ppl.is_finite() && h.ppl > 1.0);
        assert_eq!(h.backend_kind, BackendKind::Naive);
        assert_eq!(h.batch, 1);
    }

    #[test]
    fn batched_inference_amortizes_bytes_and_raises_mbu() {
        // The paper's central batching effect, measured end to end: at
        // batch 4, bytes/token drops strictly and batch-aware MBU rises.
        let mf = random_model_file(QuantType::Q4_0, 3);
        let prompt = vec![1u32, 2, 3, 4];
        let ppl: Vec<u32> = (0..32u32).map(|i| i % 250).collect();
        let h1 = run_inference(&mf, BackendKind::Naive, &prompt, 6, &ppl, 1).unwrap();
        let h4 = run_inference(&mf, BackendKind::Naive, &prompt, 6, &ppl, 4).unwrap();
        assert!(
            h4.bytes_per_token < h1.bytes_per_token,
            "b4 {} !< b1 {}",
            h4.bytes_per_token,
            h1.bytes_per_token
        );
        assert_eq!(h4.kv_bytes, 4 * h1.kv_bytes, "eq. 3 batch term");
        // Perplexity is batch-independent by construction.
        assert_eq!(h1.ppl, h4.ppl);
        let peak = 20e9;
        let m1 = metrics::mbu(h1.param_bytes, h1.kv_bytes, h1.tpot_secs, peak);
        let m4 = metrics::mbu(h4.param_bytes, h4.kv_bytes, h4.tpot_secs, peak);
        // Guard against wall-clock noise: compare at equal TPOT too.
        let m4_fixed = metrics::mbu(h4.param_bytes, h4.kv_bytes, h1.tpot_secs, peak);
        assert!(m4_fixed > m1, "batch-aware MBU must rise: {m4_fixed} vs {m1} (live {m4})");
    }

    #[test]
    fn guard_catches_timeout() {
        let mf = random_model_file(QuantType::Q4_0, 3);
        let prompt = vec![1u32, 2];
        let ppl: Vec<u32> = (0..200u32).map(|i| i % 250).collect();
        let out = run_inference_guarded(
            mf,
            BackendKind::Naive,
            prompt,
            200,
            ppl,
            1,
            Duration::from_millis(1),
        );
        assert!(matches!(out, Err(SkipReason::Timeout { .. })));
    }

    #[test]
    fn guard_catches_failure() {
        // Empty prompt is an error inside run_inference.
        let mf = random_model_file(QuantType::Q4_0, 3);
        let out = run_inference_guarded(
            mf,
            BackendKind::Naive,
            vec![],
            2,
            vec![1, 2, 3],
            1,
            Duration::from_secs(10),
        );
        assert!(matches!(out, Err(SkipReason::Failure(_))), "{out:?}");
    }

    /// Fabricate an artifacts dir (corpus + quantized models) so `run` is
    /// testable without `make artifacts`.
    fn fixture(name: &str, schemes: &[QuantType]) -> (ElibConfig, Vec<QuantizedModel>) {
        let dir = std::env::temp_dir().join("elib-runner-tests").join(name);
        std::fs::create_dir_all(&dir).unwrap();
        let corpus = "the cache streams the weights while the device measures bandwidth. "
            .repeat(4);
        std::fs::write(dir.join("corpus_eval.txt"), corpus).unwrap();
        let mcfg = LlamaConfig::tiny();
        let dense = random_weights(&mcfg, 3);
        let models = flow::quantization_flow(&mcfg, &dense, schemes, &dir).unwrap();
        let cfg = ElibConfig {
            artifacts_dir: dir.clone(),
            out_dir: dir,
            devices: vec![DeviceSpec::nanopi()],
            bench: crate::coordinator::BenchParams {
                gen_tokens: 4,
                prompt_tokens: 4,
                ppl_tokens: 48,
                ..Default::default()
            },
            ..Default::default()
        };
        (cfg, models)
    }

    fn records_json(report: &RunReport) -> String {
        json::to_string_pretty(&Json::Arr(
            report.records.iter().map(|r| r.to_json()).collect(),
        ))
    }

    /// The scheduler-determinism property: a threaded run produces records
    /// identical (order and content) to the sequential path.
    #[test]
    fn threaded_run_matches_sequential() {
        let (mut cfg, models) =
            fixture("determinism", &[QuantType::Q4_0, QuantType::Q8_0]);
        let mut reports = Vec::new();
        for threads in [1usize, 8] {
            cfg.bench.scheduler_threads = threads;
            let mut log = |_: &str| {};
            reports.push(run(&cfg, &models, &mut log).unwrap());
        }
        let (seq, par) = (&reports[0], &reports[1]);
        assert!(!seq.records.is_empty());
        assert_eq!(records_json(seq), records_json(par), "grid records must be identical");
        assert_eq!(seq.skipped, par.skipped);
        assert_eq!(seq.host.len(), par.host.len());
        for (a, b) in seq.host.iter().zip(&par.host) {
            // Wall-clock fields differ; everything deterministic must not.
            assert_eq!(a.qtype, b.qtype);
            assert_eq!(a.backend_kind, b.backend_kind);
            assert_eq!(a.batch, b.batch);
            assert_eq!(a.bytes_per_token, b.bytes_per_token);
            assert_eq!(a.param_bytes, b.param_bytes);
            assert_eq!(a.kv_bytes, b.kv_bytes);
            assert_eq!(a.ppl, b.ppl);
        }
    }

    #[test]
    fn batch_sweep_produces_one_host_row_per_batch() {
        let (mut cfg, models) = fixture("sweep", &[QuantType::Q4_0]);
        cfg.bench.batch_sizes = vec![1, 4];
        let mut log = |_: &str| {};
        let rep = run(&cfg, &models, &mut log).unwrap();
        assert_eq!(rep.host.len(), 3 * 2, "3 backend classes × 2 batches");
        // Acceptance shape on a real run: strictly lower bytes/token and
        // strictly higher MBU at batch 4 than batch 1 per backend class.
        for kind in [
            BackendKind::Naive,
            BackendKind::Parallel(4),
            BackendKind::Gpu(Precision::DegradedF16),
        ] {
            let pick = |batch: usize| {
                rep.host
                    .iter()
                    .find(|h| h.backend_kind == kind && h.batch == batch)
                    .unwrap()
            };
            let (h1, h4) = (pick(1), pick(4));
            assert!(
                h4.bytes_per_token < h1.bytes_per_token,
                "{kind:?}: {} !< {}",
                h4.bytes_per_token,
                h1.bytes_per_token
            );
            assert!(h4.kv_bytes > h1.kv_bytes);
        }
    }
}
