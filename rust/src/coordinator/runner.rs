//! Algorithm 1: the ELIB benchmark loop.
//!
//! For each iteration × quantized model × device × accelerator:
//! *adapt_and_deploy* (RAM guard against the device, engine construction
//! with the accelerator's backend), *run_inference* (generation + held-out
//! NLL on the native engine, guarded by a timeout), then metric
//! computation — FLOPS, throughput, TTLM, TTFT, MBU, perplexity — where
//! the *relationships* come from real measurements on the tiny model and
//! the device-scale numbers come from pricing the paper's 7B workload on
//! the device simulator (DESIGN.md §2).

use std::sync::mpsc;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::device::{Accel, DeviceSpec, Workload};
use crate::gguf::ModelFile;
use crate::graph::{generate, Engine, Sampler};
use crate::kernel::{BackendKind, Precision};
use crate::metrics::{self, MetricsRecord};
use crate::model::{scale, LlamaConfig, ModelWeights};
use crate::quant::QuantType;

use super::config::ElibConfig;
use super::flow::QuantizedModel;

/// Why a grid cell was skipped (Algorithm 1 Ln. 11–12).
#[derive(Clone, Debug)]
pub enum SkipReason {
    MemoryOverflow { need: u64, have: u64 },
    Timeout { after: Duration },
    Failure(String),
}

/// Host-side (real) measurement for one (quant, backend) pair.
#[derive(Clone, Debug)]
pub struct HostMeasurement {
    pub qtype: QuantType,
    pub backend: String,
    pub throughput_tok_s: f64,
    pub tpot_secs: f64,
    pub prefill_secs: f64,
    pub bytes_per_token: u64,
    pub host_mbu: f64,
    pub ppl: f64,
}

/// Outcome of the full run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub records: Vec<MetricsRecord>,
    pub skipped: Vec<(String, String)>,
    pub host: Vec<HostMeasurement>,
}

/// Map the paper's accelerator axis onto a native-engine backend,
/// respecting the device's GPU numerical fidelity.
pub fn backend_for(accel: Accel, device: &DeviceSpec) -> BackendKind {
    match accel {
        Accel::CpuNone => BackendKind::Naive,
        Accel::CpuBlas => BackendKind::Parallel(4),
        Accel::Gpu => BackendKind::Gpu(if device.gpu_ppl_factor > 1.0 {
            Precision::DegradedF16
        } else {
            Precision::Full
        }),
    }
}

/// Load eval-corpus tokens for the perplexity metric.
pub fn eval_tokens(config: &ElibConfig) -> Result<Vec<u32>> {
    let path = config.artifacts_dir.join("corpus_eval.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("read {} (run `make artifacts`)", path.display()))?;
    Ok(text
        .bytes()
        .take(config.bench.ppl_tokens.max(2))
        .map(|b| b as u32)
        .collect())
}

/// `run_inference` with the timeout guard: generation + NLL on a worker
/// thread, `recv_timeout` on the leader (Ln. 9–12).
pub fn run_inference_guarded(
    mf: ModelFile,
    backend: BackendKind,
    prompt: Vec<u32>,
    gen_tokens: usize,
    ppl_tokens: Vec<u32>,
    timeout: Duration,
) -> Result<HostMeasurement, SkipReason> {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_inference(&mf, backend, &prompt, gen_tokens, &ppl_tokens)
        }));
        let flat = match result {
            Ok(Ok(m)) => Ok(m),
            Ok(Err(e)) => Err(format!("{e:#}")),
            Err(_) => Err("panic (deadlock-class failure)".to_string()),
        };
        let _ = tx.send(flat);
    });
    match rx.recv_timeout(timeout) {
        Ok(Ok(m)) => Ok(m),
        Ok(Err(e)) => Err(SkipReason::Failure(e)),
        Err(_) => Err(SkipReason::Timeout { after: timeout }),
    }
}

/// The unguarded inference body: deploy + generate + perplexity.
pub fn run_inference(
    mf: &ModelFile,
    backend: BackendKind,
    prompt: &[u32],
    gen_tokens: usize,
    ppl_tokens: &[u32],
) -> Result<HostMeasurement> {
    let weights = ModelWeights::load(mf)?;
    let qtype = weights.qtype;
    let mut engine = Engine::new(weights, backend);
    let mut sampler = Sampler::Greedy;
    let stats = generate(&mut engine, prompt, gen_tokens, &mut sampler)?;
    let (nll, count) = engine.sequence_nll(ppl_tokens)?;
    let bytes_per_token = stats
        .decode_traffic
        .iter()
        .map(|t| t.total())
        .sum::<u64>()
        .checked_div(stats.generated_tokens as u64)
        .unwrap_or(0);
    Ok(HostMeasurement {
        qtype,
        backend: backend.label(),
        throughput_tok_s: stats.decode_throughput(),
        tpot_secs: stats.tpot_secs(),
        prefill_secs: stats.prefill_secs,
        bytes_per_token,
        host_mbu: 0.0, // filled by caller (needs host_peak_bw)
        ppl: metrics::perplexity(nll, count),
    })
}

/// Full Algorithm-1 execution.
pub fn run(config: &ElibConfig, models: &[QuantizedModel], log: &mut dyn FnMut(&str)) -> Result<RunReport> {
    let mut report = RunReport::default();
    let ppl_toks = eval_tokens(config)?;
    let prompt: Vec<u32> = ppl_toks.iter().take(config.bench.prompt_tokens).copied().collect();
    let seven_b = LlamaConfig::llama_7b();

    // --- host measurements: one per (quant, backend-class), reused across
    // devices (the real engine doesn't change per simulated device).
    let backend_classes: [(&str, BackendKind); 3] = [
        ("cpu-naive", BackendKind::Naive),
        ("cpu-parallel", BackendKind::Parallel(4)),
        ("gpu-degraded", BackendKind::Gpu(Precision::DegradedF16)),
    ];
    for m in models {
        let mf = ModelFile::load(&m.path)?;
        for (label, backend) in backend_classes {
            let outcome = run_inference_guarded(
                mf.clone(),
                backend,
                prompt.clone(),
                config.bench.gen_tokens,
                ppl_toks.clone(),
                config.bench.timeout,
            );
            match outcome {
                Ok(mut h) => {
                    h.host_mbu = metrics::mbu(
                        h.bytes_per_token,
                        0,
                        h.tpot_secs,
                        config.bench.host_peak_bw,
                    );
                    log(&format!(
                        "[host] {} {}: {:.1} tok/s, ppl {:.3}",
                        m.qtype.name(),
                        label,
                        h.throughput_tok_s,
                        h.ppl
                    ));
                    report.host.push(h);
                }
                Err(r) => report
                    .skipped
                    .push((format!("host/{}/{}", m.qtype.name(), label), format!("{r:?}"))),
            }
        }
    }

    // --- device grid (Table 6) -----------------------------------------
    for _iter in 0..config.bench.iterations.max(1) {
        for m in models {
            for device in &config.devices {
                for accel in Accel::ALL {
                    let cell = format!("{}/{:?}/{}", device.name, accel, m.qtype.name());
                    // adapt_and_deploy: RAM guard on the 7B-scale deployment.
                    let need = scale::max_ram_bytes(&seven_b, m.qtype, config.bench.batch_size);
                    if !device.fits_ram(need) {
                        report.skipped.push((
                            cell,
                            format!(
                                "memory overflow: need {} > ram {}",
                                need, device.ram_bytes
                            ),
                        ));
                        continue;
                    }
                    let record = simulate_cell(config, device, accel, m, &report.host)?;
                    report.records.push(record);
                }
            }
        }
    }
    Ok(report)
}

/// Price one Table-6 cell on the device simulator, using host-measured
/// perplexity as the accuracy base.
pub fn simulate_cell(
    config: &ElibConfig,
    device: &DeviceSpec,
    accel: Accel,
    m: &QuantizedModel,
    host: &[HostMeasurement],
) -> Result<MetricsRecord> {
    let seven_b = LlamaConfig::llama_7b();
    let b = &config.bench;
    let w = Workload::decode(&seven_b, m.qtype, b.batch_size, b.context_len);
    let tpot = device.tpot(&w, accel, 4);
    let (acc_label, fw_label) = device.accel_label(accel);
    // Accuracy base: host CPU ppl for this quant (real quantization
    // effect); the device precision model adds the OpenCL pathology.
    let base_ppl = host
        .iter()
        .find(|h| h.qtype == m.qtype && h.backend.starts_with("cpu/none"))
        .map(|h| h.ppl)
        .ok_or_else(|| anyhow!("no host cpu measurement for {}", m.qtype.name()))?;
    Ok(MetricsRecord {
        device: device.name.to_string(),
        os: device.os.to_string(),
        accelerator: acc_label.to_string(),
        framework: fw_label.to_string(),
        qtype: m.qtype,
        flops_t4_giga: device.matmul_gflops(accel, 4),
        flops_t8_giga: device.matmul_gflops(accel, 8),
        throughput_tok_s: 1.0 / tpot,
        ttlm_secs: device.ttlm(w.model_bytes),
        ttft_secs: device.ttft(&w, b.prompt_tokens, accel, 4),
        mbu: metrics::mbu(w.param_bytes, w.kv_bytes, tpot, device.mem_bw),
        ppl: device.simulated_ppl(base_ppl, accel, m.qtype),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::random_model_file;

    #[test]
    fn backend_mapping_respects_device_precision() {
        let nano = DeviceSpec::nanopi();
        let mac = DeviceSpec::macbook();
        assert_eq!(
            backend_for(Accel::Gpu, &nano),
            BackendKind::Gpu(Precision::DegradedF16)
        );
        assert_eq!(
            backend_for(Accel::Gpu, &mac),
            BackendKind::Gpu(Precision::Full)
        );
        assert_eq!(backend_for(Accel::CpuNone, &nano), BackendKind::Naive);
    }

    #[test]
    fn run_inference_produces_metrics() {
        let mf = random_model_file(QuantType::Q8_0, 3);
        let prompt = vec![1u32, 2, 3, 4];
        let ppl: Vec<u32> = (0..32u32).map(|i| i % 250).collect();
        let h = run_inference(&mf, BackendKind::Naive, &prompt, 4, &ppl).unwrap();
        assert!(h.throughput_tok_s > 0.0);
        assert!(h.bytes_per_token > 0);
        assert!(h.ppl.is_finite() && h.ppl > 1.0);
    }

    #[test]
    fn guard_catches_timeout() {
        let mf = random_model_file(QuantType::Q4_0, 3);
        let prompt = vec![1u32, 2];
        let ppl: Vec<u32> = (0..200u32).map(|i| i % 250).collect();
        let out = run_inference_guarded(
            mf,
            BackendKind::Naive,
            prompt,
            200,
            ppl,
            Duration::from_millis(1),
        );
        assert!(matches!(out, Err(SkipReason::Timeout { .. })));
    }

    #[test]
    fn guard_catches_failure() {
        // Empty prompt is an error inside run_inference.
        let mf = random_model_file(QuantType::Q4_0, 3);
        let out = run_inference_guarded(
            mf,
            BackendKind::Naive,
            vec![],
            2,
            vec![1, 2, 3],
            Duration::from_secs(10),
        );
        assert!(matches!(out, Err(SkipReason::Failure(_))), "{out:?}");
    }
}
