//! The pluggable serving API (DESIGN.md §5): `Workload` × `Scheduler` ×
//! [`SimLoop`].
//!
//! PR 2 grew `coordinator/serve.rs` into a monolith where arrival
//! generation, FCFS admission, roofline pricing and metrics were fused
//! inside one loop — every new serving scenario meant editing the hot
//! path. This module splits the three concerns behind traits:
//!
//! ```text
//!   Workload  ──build()──▶  Vec<Request> ──▶ ┌──────────────────────┐
//!     poisson │ closed │ chat │              │       SimLoop        │
//!     diurnal │ flash-crowd │ heavy-tail     │  engine · DeviceClock │
//!       ▲                                    │  event queue · series │
//!       └──on_finish()── releases ◀───────── └──────────▲───────────┘
//!   Scheduler ──select()/prefill_chunk()────────────────┤
//!     fcfs │ priority │ chunked │ slo-aware             │
//!             └──shed()/preempt() ◀── SloCx ────────────┘
//! ```
//!
//! * A [`Workload`] turns the trace RNG into timestamped [`Request`]s —
//!   open-loop Poisson arrivals, a closed loop of clients, or multi-turn
//!   chat sessions whose follow-up turns reuse their session's KV prefix
//!   instead of re-prefilling.
//! * A [`Scheduler`] owns admission (which queued request takes a freed
//!   slot) and the prefill policy (how many prompt tokens a slot may
//!   consume per engine step) — FCFS, priority tiers, or chunked
//!   prefill.
//! * [`SimLoop`] is the one serving loop everything drives: it owns the
//!   batched engine, the [`DeviceClock`](crate::device::DeviceClock)
//!   and the event queue, and it is deliberately policy-free — with the
//!   default `Fcfs` + `PoissonOpen` pair it reproduces the pre-split
//!   `run_serve` bench.json **bit for bit** (locked in by the parity
//!   test in `coordinator/serve.rs`).
//!
//! `run_serve` (and through it `elib serve`, `elib fleet` and the
//! coordinator) constructs the built-in policies from
//! [`ServeParams`](crate::coordinator::ServeParams); future scenario PRs
//! implement the traits and drive [`SimLoop::run`] directly.

pub mod scheduler;
pub mod sim_loop;
pub mod workload;

pub use scheduler::{ChunkedPrefill, Fcfs, PriorityTiers, Scheduler, SchedulerPolicy, SloAware};
pub use sim_loop::{KvReuse, PartialOutput, SimLoop, SimOutput, SimRun, TickStatus};
pub use workload::{
    ChatSessions, ClosedLoop, DiurnalPoisson, FlashCrowd, HeavyTail, PoissonOpen, Workload,
};

use crate::metrics::Slo;
use crate::util::rng::Rng;

/// One serving request, produced by a [`Workload`] before the clock
/// runs. `id` must equal the request's index in the built vector.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: usize,
    /// Virtual arrival time. `None` means the request is released
    /// dynamically by [`Workload::on_finish`] (closed-loop successors,
    /// chat follow-up turns).
    pub arrival: Option<f64>,
    /// Tokens this request feeds through the engine before it starts
    /// sampling. For chat follow-up turns this is the *delta* prompt of
    /// the new user turn — the loop prepends the session's bridging
    /// token (the previous turn's final output, never yet fed) at
    /// admission and reuses the slot's KV for everything before it.
    pub prompt: Vec<u32>,
    /// Output tokens to generate before retiring.
    pub target_out: usize,
    /// Scheduling tier, 0 = most urgent. Assigned by
    /// [`Scheduler::assign_priorities`]; `Fcfs` ignores it.
    pub priority: u8,
    /// Multi-turn session membership (chat workload only).
    pub session: Option<SessionLink>,
    /// Per-request service-level objective (TTFT/TPOT deadlines plus the
    /// seeded tier it was drawn from). `None` — the default everywhere
    /// SLOs are not requested — means every deadline is trivially met
    /// and no scheduler may shed or preempt the request.
    pub slo: Option<Slo>,
}

/// Chat-session linkage: which conversation a request belongs to and
/// which request continues it.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SessionLink {
    pub session: usize,
    /// Zero-based turn index within the session.
    pub turn: usize,
    /// The next turn's request id, if any. When set, the loop *parks*
    /// this request's slot at retirement instead of releasing it: the
    /// successor inherits the slot and its KV prefix.
    pub next: Option<usize>,
}

/// A queued request as the [`Scheduler`] sees it.
#[derive(Clone, Copy, Debug)]
pub struct QueueEntry {
    pub id: usize,
    pub arrival: f64,
    pub priority: u8,
}

/// A dynamically released request: `id` becomes visible to the queue at
/// virtual time `arrival`.
#[derive(Clone, Copy, Debug)]
pub struct Release {
    pub id: usize,
    pub arrival: f64,
}

/// What the loop tells SLO-capable schedulers each round: the virtual
/// clock and the run's measured per-token pace so far. `est_token_secs`
/// is cumulative busy engine time over cumulative processed tokens — a
/// pure function of the priced trace, so every estimate (and every shed
/// or preempt decision built on it) is bit-reproducible across machines
/// and `--threads`. `None` until the first step has been priced.
#[derive(Clone, Copy, Debug)]
pub struct SloCx {
    pub now: f64,
    pub est_token_secs: Option<f64>,
}

/// An in-flight request as [`Scheduler::preempt`] sees it.
#[derive(Clone, Copy, Debug)]
pub struct RunningEntry {
    pub id: usize,
    /// Virtual time the request was admitted to a slot.
    pub admit: f64,
    /// When the first output token landed; `None` while still prefilling.
    pub first_token: Option<f64>,
    /// Output tokens decoded so far.
    pub decoded: usize,
    /// Prompt tokens still to prefill plus output tokens still to decode.
    pub remaining_tokens: usize,
}

/// How requests enter the system. Implementations draw every shape from
/// the seeded trace RNG in `build` — the trace is a pure function of
/// (seed, params) no matter how the run interleaves — and may release
/// further arrivals from completions (`on_finish`).
pub trait Workload {
    /// Stable identity key (`bench.json` compares it across runs).
    fn label(&self) -> &'static str;

    /// Draw the full request set. Called exactly once, before the clock
    /// starts; `requests[i].id == i` must hold.
    fn build(&mut self, rng: &mut Rng, vocab: usize) -> Vec<Request>;

    /// Request `finished` retired at `now`; return any requests this
    /// releases (closed-loop successors, chat follow-up turns).
    fn on_finish(&mut self, finished: usize, now: f64) -> Vec<Release> {
        let _ = (finished, now);
        Vec::new()
    }
}

/// Admission + prefill policy. The loop calls `select` once per free
/// slot between steps and `prefill_chunk` once per step.
pub trait Scheduler {
    /// Stable identity key (`bench.json` compares it across runs).
    fn label(&self) -> &'static str;

    /// Assign scheduling tiers before the run starts. Policies that
    /// need per-request priorities draw them from their *own* seeded
    /// stream here, so the token trace stays identical across
    /// schedulers (the comparison the report section makes).
    fn assign_priorities(&mut self, requests: &mut [Request]) {
        let _ = requests;
    }

    /// Index into `queue` of the request to admit into the next free
    /// slot, or `None` to leave the slot idle this round.
    fn select(&mut self, queue: &[QueueEntry]) -> Option<usize>;

    /// Max prompt tokens a prefilling slot may consume in one engine
    /// step (1 = token-at-a-time, the FCFS baseline; chunked prefill
    /// raises it so prefill amortizes the weight stream).
    fn prefill_chunk(&self) -> usize {
        1
    }

    /// Queued requests to shed *now* (admission control): return
    /// ascending indices into `queue`. Shed requests retire immediately
    /// with zero output and are counted — never silently dropped. The
    /// default (every policy but `SloAware`) sheds nothing.
    fn shed(&mut self, cx: SloCx, queue: &[QueueEntry], requests: &[Request]) -> Vec<usize> {
        let _ = (cx, queue, requests);
        Vec::new()
    }

    /// In-flight requests to preempt *now* (free their slot and paged-KV
    /// blocks for meetable work): return request ids from `running`.
    /// Preempted requests retire with their partial output and are
    /// counted. The default preempts nothing.
    fn preempt(
        &mut self,
        cx: SloCx,
        running: &[RunningEntry],
        queue: &[QueueEntry],
        requests: &[Request],
    ) -> Vec<usize> {
        let _ = (cx, running, queue, requests);
        Vec::new()
    }
}
