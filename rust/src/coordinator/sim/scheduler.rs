//! Built-in [`Scheduler`] implementations — FCFS (the PR-2 baseline,
//! bit for bit), priority tiers, and chunked prefill — plus the
//! [`SchedulerPolicy`] descriptor `ServeParams` carries (DESIGN.md §5).

use anyhow::Result;

use crate::util::rng::Rng;

use super::{QueueEntry, Request, Scheduler};

/// Salt mixed into the trace seed for the priority stream, so assigning
/// tiers never perturbs the trace RNG: the token trace is identical
/// across schedulers, which is what makes them comparable.
const PRIORITY_SEED_SALT: u64 = 0x7072_696f_7269_7479; // "priority"

/// First-come first-served admission, token-at-a-time prefill — exactly
/// the PR-2 monolith's policy (the bitwise serve baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct Fcfs;

impl Scheduler for Fcfs {
    fn label(&self) -> &'static str {
        "fcfs"
    }

    fn select(&mut self, queue: &[QueueEntry]) -> Option<usize> {
        if queue.is_empty() {
            None
        } else {
            Some(0)
        }
    }
}

/// Three-tier priority admission: tier 0 (20% of requests) preempts the
/// queue order, tier 1 (30%) beats best-effort tier 2 (50%); FIFO
/// within a tier. Tiers are drawn from a salted side-stream of the
/// trace seed, so the token trace itself is identical to FCFS — only
/// *who waits* changes.
#[derive(Clone, Debug)]
pub struct PriorityTiers {
    rng: Rng,
}

impl PriorityTiers {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::new(seed ^ PRIORITY_SEED_SALT),
        }
    }
}

impl Scheduler for PriorityTiers {
    fn label(&self) -> &'static str {
        "priority"
    }

    fn assign_priorities(&mut self, requests: &mut [Request]) {
        for r in requests.iter_mut() {
            let d = self.rng.below(10);
            r.priority = if d < 2 {
                0
            } else if d < 5 {
                1
            } else {
                2
            };
        }
    }

    fn select(&mut self, queue: &[QueueEntry]) -> Option<usize> {
        // min_by_key keeps the first minimum → FIFO within a tier.
        queue
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.priority)
            .map(|(i, _)| i)
    }
}

/// FCFS admission with bounded multi-token prefill: a prefilling slot
/// may consume up to `chunk_tokens` prompt tokens per engine step
/// (decode slots still advance one sampled token). Each chunk step
/// charges the weight stream once for the whole chunk
/// ([`Engine::traffic_for_spans`](crate::graph::Engine::traffic_for_spans)),
/// so long prompts stop monopolizing steps: requests clear prefill in
/// `⌈prompt/chunk⌉` steps instead of `prompt`, time-in-system drops,
/// fewer slots are concurrently resident, and decode neighbors' tail
/// TPOT drops on long-prompt traces (the effect the scheduler-matrix CI
/// leg and the report comparison section surface).
#[derive(Clone, Copy, Debug)]
pub struct ChunkedPrefill {
    pub chunk_tokens: usize,
}

impl ChunkedPrefill {
    pub fn new(chunk_tokens: usize) -> Self {
        Self { chunk_tokens }
    }
}

impl Scheduler for ChunkedPrefill {
    fn label(&self) -> &'static str {
        "chunked"
    }

    fn select(&mut self, queue: &[QueueEntry]) -> Option<usize> {
        if queue.is_empty() {
            None
        } else {
            Some(0)
        }
    }

    fn prefill_chunk(&self) -> usize {
        self.chunk_tokens.max(1)
    }
}

/// The scheduler descriptor [`ServeParams`](crate::coordinator::ServeParams)
/// carries: a serializable identity (`bench.json` compares it) that
/// resolves to a boxed [`Scheduler`] at run time. Custom policies
/// bypass the descriptor and hand their own `Scheduler` to
/// [`SimLoop::run`](super::SimLoop::run).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SchedulerPolicy {
    #[default]
    Fcfs,
    Priority,
    Chunked {
        chunk_tokens: usize,
    },
}

impl SchedulerPolicy {
    /// Stable identity key (CLI `--scheduler`, `bench.json`).
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerPolicy::Fcfs => "fcfs",
            SchedulerPolicy::Priority => "priority",
            SchedulerPolicy::Chunked { .. } => "chunked",
        }
    }

    /// Parse a CLI/config key; `chunk_tokens` feeds the chunked policy.
    pub fn parse(s: &str, chunk_tokens: usize) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "fcfs" => Some(SchedulerPolicy::Fcfs),
            "priority" => Some(SchedulerPolicy::Priority),
            "chunked" => Some(SchedulerPolicy::Chunked { chunk_tokens }),
            _ => None,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if let SchedulerPolicy::Chunked { chunk_tokens } = self {
            anyhow::ensure!(*chunk_tokens >= 1, "chunked prefill needs chunk_tokens >= 1");
        }
        Ok(())
    }

    /// Resolve to the runtime policy. `seed` is the trace seed; the
    /// priority stream is salted off it so tiers never perturb the
    /// trace RNG.
    pub fn build(&self, seed: u64) -> Box<dyn Scheduler> {
        match self {
            SchedulerPolicy::Fcfs => Box::new(Fcfs),
            SchedulerPolicy::Priority => Box::new(PriorityTiers::new(seed)),
            SchedulerPolicy::Chunked { chunk_tokens } => {
                Box::new(ChunkedPrefill::new(*chunk_tokens))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: usize, priority: u8) -> QueueEntry {
        QueueEntry {
            id,
            arrival: id as f64,
            priority,
        }
    }

    #[test]
    fn fcfs_takes_the_queue_head() {
        let mut s = Fcfs;
        assert_eq!(s.select(&[]), None);
        assert_eq!(s.select(&[entry(3, 2), entry(4, 0)]), Some(0));
        assert_eq!(s.prefill_chunk(), 1, "fcfs prefills token-at-a-time");
    }

    #[test]
    fn priority_tiers_pick_most_urgent_fifo_within_tier() {
        let mut s = PriorityTiers::new(7);
        let q = [entry(0, 2), entry(1, 1), entry(2, 0), entry(3, 0)];
        assert_eq!(s.select(&q), Some(2), "tier 0 wins");
        let q = [entry(0, 1), entry(1, 1), entry(2, 2)];
        assert_eq!(s.select(&q), Some(0), "FIFO within a tier");
        assert_eq!(s.select(&[]), None);
    }

    #[test]
    fn priority_assignment_is_seeded_and_leaves_trace_rng_alone() {
        let mk = |id| Request {
            id,
            arrival: None,
            prompt: vec![1],
            target_out: 1,
            priority: 0,
            session: None,
        };
        let mut a: Vec<Request> = (0..64).map(mk).collect();
        let mut b: Vec<Request> = (0..64).map(mk).collect();
        PriorityTiers::new(9).assign_priorities(&mut a);
        PriorityTiers::new(9).assign_priorities(&mut b);
        let pa: Vec<u8> = a.iter().map(|r| r.priority).collect();
        let pb: Vec<u8> = b.iter().map(|r| r.priority).collect();
        assert_eq!(pa, pb, "same seed, same tiers");
        assert!(pa.iter().any(|p| *p == 0) && pa.iter().any(|p| *p == 2), "tiers are used");
        let mut c: Vec<Request> = (0..64).map(mk).collect();
        PriorityTiers::new(10).assign_priorities(&mut c);
        assert_ne!(pa, c.iter().map(|r| r.priority).collect::<Vec<_>>(), "seeded differently");
    }

    #[test]
    fn chunked_is_fcfs_admission_with_bounded_chunks() {
        let mut s = ChunkedPrefill::new(32);
        assert_eq!(s.select(&[entry(0, 2), entry(1, 0)]), Some(0));
        assert_eq!(s.prefill_chunk(), 32);
        assert_eq!(ChunkedPrefill::new(0).prefill_chunk(), 1, "clamped to 1");
    }

    #[test]
    fn policy_descriptor_round_trips() {
        assert_eq!(SchedulerPolicy::parse("fcfs", 8), Some(SchedulerPolicy::Fcfs));
        assert_eq!(SchedulerPolicy::parse("PRIORITY", 8), Some(SchedulerPolicy::Priority));
        assert_eq!(
            SchedulerPolicy::parse("chunked", 8),
            Some(SchedulerPolicy::Chunked { chunk_tokens: 8 })
        );
        assert_eq!(SchedulerPolicy::parse("sjf", 8), None);
        for p in [
            SchedulerPolicy::Fcfs,
            SchedulerPolicy::Priority,
            SchedulerPolicy::Chunked { chunk_tokens: 4 },
        ] {
            assert_eq!(SchedulerPolicy::parse(p.label(), 4), Some(p));
            assert!(p.validate().is_ok());
            assert_eq!(p.build(7).label(), p.label());
        }
        assert!(SchedulerPolicy::Chunked { chunk_tokens: 0 }.validate().is_err());
        assert_eq!(SchedulerPolicy::default(), SchedulerPolicy::Fcfs);
    }
}
