//! Built-in [`Scheduler`] implementations — FCFS (the PR-2 baseline,
//! bit for bit), priority tiers, chunked prefill, and the SLO-aware
//! shed/preempt policy — plus the [`SchedulerPolicy`] descriptor
//! `ServeParams` carries (DESIGN.md §5).

use anyhow::Result;

use crate::metrics::Slo;
use crate::util::rng::Rng;

use super::{QueueEntry, Request, RunningEntry, Scheduler, SloCx};

/// Salt mixed into the trace seed for the priority stream, so assigning
/// tiers never perturbs the trace RNG: the token trace is identical
/// across schedulers, which is what makes them comparable.
const PRIORITY_SEED_SALT: u64 = 0x7072_696f_7269_7479; // "priority"

/// First-come first-served admission, token-at-a-time prefill — exactly
/// the PR-2 monolith's policy (the bitwise serve baseline).
#[derive(Clone, Copy, Debug, Default)]
pub struct Fcfs;

impl Scheduler for Fcfs {
    fn label(&self) -> &'static str {
        "fcfs"
    }

    fn select(&mut self, queue: &[QueueEntry]) -> Option<usize> {
        if queue.is_empty() {
            None
        } else {
            Some(0)
        }
    }
}

/// Three-tier priority admission: tier 0 (20% of requests) preempts the
/// queue order, tier 1 (30%) beats best-effort tier 2 (50%); FIFO
/// within a tier. Tiers are drawn from a salted side-stream of the
/// trace seed, so the token trace itself is identical to FCFS — only
/// *who waits* changes.
#[derive(Clone, Debug)]
pub struct PriorityTiers {
    rng: Rng,
}

impl PriorityTiers {
    pub fn new(seed: u64) -> Self {
        Self {
            rng: Rng::new(seed ^ PRIORITY_SEED_SALT),
        }
    }
}

impl Scheduler for PriorityTiers {
    fn label(&self) -> &'static str {
        "priority"
    }

    fn assign_priorities(&mut self, requests: &mut [Request]) {
        for r in requests.iter_mut() {
            let d = self.rng.below(10);
            r.priority = if d < 2 {
                0
            } else if d < 5 {
                1
            } else {
                2
            };
        }
    }

    fn select(&mut self, queue: &[QueueEntry]) -> Option<usize> {
        // min_by_key keeps the first minimum → FIFO within a tier.
        queue
            .iter()
            .enumerate()
            .min_by_key(|(_, e)| e.priority)
            .map(|(i, _)| i)
    }
}

/// FCFS admission with bounded multi-token prefill: a prefilling slot
/// may consume up to `chunk_tokens` prompt tokens per engine step
/// (decode slots still advance one sampled token). Each chunk step
/// charges the weight stream once for the whole chunk
/// ([`Engine::traffic_for_spans`](crate::graph::Engine::traffic_for_spans)),
/// so long prompts stop monopolizing steps: requests clear prefill in
/// `⌈prompt/chunk⌉` steps instead of `prompt`, time-in-system drops,
/// fewer slots are concurrently resident, and decode neighbors' tail
/// TPOT drops on long-prompt traces (the effect the scheduler-matrix CI
/// leg and the report comparison section surface).
#[derive(Clone, Copy, Debug)]
pub struct ChunkedPrefill {
    pub chunk_tokens: usize,
}

impl ChunkedPrefill {
    pub fn new(chunk_tokens: usize) -> Self {
        Self { chunk_tokens }
    }
}

impl Scheduler for ChunkedPrefill {
    fn label(&self) -> &'static str {
        "chunked"
    }

    fn select(&mut self, queue: &[QueueEntry]) -> Option<usize> {
        if queue.is_empty() {
            None
        } else {
            Some(0)
        }
    }

    fn prefill_chunk(&self) -> usize {
        self.chunk_tokens.max(1)
    }
}

/// Deadline-aware admission (DESIGN.md §5): earliest-deadline-first
/// selection on each queued request's absolute TTFT deadline
/// (`arrival + ttft`), shedding queued requests whose deadline is
/// already — or provably about to be — unmeetable, and preempting
/// in-flight requests that cannot finish inside their deadlines while
/// SLO-meetable work waits (freeing the slot and its paged-KV blocks).
///
/// Every decision is a pure function of the virtual clock and the
/// loop-supplied [`SloCx::est_token_secs`] pace (busy virtual seconds
/// over processed tokens — itself derived from the roofline pricing),
/// with (deadline, arrival, id) tie-breaks: no RNG, no wall-clock, so
/// bench.json stays bit-for-bit across machines and `--threads`.
/// Requests without an SLO are never shed or preempted, and with no
/// SLOs anywhere the policy degrades to exact FCFS.
#[derive(Clone, Debug, Default)]
pub struct SloAware {
    /// Per-request SLOs captured at `assign_priorities` time, indexed by
    /// request id — `select` only sees [`QueueEntry`]s.
    slos: Vec<Option<Slo>>,
}

impl SloAware {
    pub fn new() -> Self {
        Self::default()
    }

    /// Absolute TTFT deadline of a queued request; ∞ without an SLO, so
    /// no-SLO requests sort last and the (arrival, id) tie-break makes
    /// the order plain FCFS among them.
    fn ttft_deadline(&self, e: &QueueEntry) -> f64 {
        match self.slos.get(e.id).copied().flatten() {
            Some(slo) => e.arrival + slo.ttft,
            None => f64::INFINITY,
        }
    }
}

impl Scheduler for SloAware {
    fn label(&self) -> &'static str {
        "slo-aware"
    }

    fn assign_priorities(&mut self, requests: &mut [Request]) {
        // Capture the SLO table and mirror each tier onto the priority
        // byte (0 = interactive). No RNG here: tiers were drawn from the
        // salted SLO side-stream upstream, so the token trace is exactly
        // the one every other scheduler sees.
        self.slos = requests.iter().map(|r| r.slo).collect();
        for r in requests.iter_mut() {
            r.priority = r.slo.map_or(0, |slo| slo.tier as u8);
        }
    }

    fn select(&mut self, queue: &[QueueEntry]) -> Option<usize> {
        queue
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                self.ttft_deadline(a)
                    .partial_cmp(&self.ttft_deadline(b))
                    .unwrap()
                    .then(a.arrival.partial_cmp(&b.arrival).unwrap())
                    .then(a.id.cmp(&b.id))
            })
            .map(|(i, _)| i)
    }

    fn shed(&mut self, cx: SloCx, queue: &[QueueEntry], requests: &[Request]) -> Vec<usize> {
        let mut out = Vec::new();
        for (i, e) in queue.iter().enumerate() {
            let Some(slo) = requests[e.id].slo else { continue };
            let waited = cx.now - e.arrival;
            // Optimistic finish-time estimate: even granted the whole
            // device from this instant, first token needs prompt+1 more
            // engine tokens. Optimism is deliberate — only requests
            // doomed under *any* schedule are shed.
            let doomed = match cx.est_token_secs {
                Some(est) => waited + (requests[e.id].prompt.len() + 1) as f64 * est > slo.ttft,
                None => false,
            };
            if waited > slo.ttft || doomed {
                out.push(i);
            }
        }
        out
    }

    fn preempt(
        &mut self,
        cx: SloCx,
        running: &[RunningEntry],
        queue: &[QueueEntry],
        requests: &[Request],
    ) -> Vec<usize> {
        // Preemption only helps if there is queued work to hand the slot
        // (and its freed paged-KV blocks) to.
        if queue.is_empty() {
            return Vec::new();
        }
        let Some(est) = cx.est_token_secs else {
            return Vec::new();
        };
        let mut out = Vec::new();
        for r in running {
            let Some(slo) = requests[r.id].slo else { continue };
            let arrival = requests[r.id].arrival.unwrap_or(r.admit);
            let doomed = match r.first_token {
                // Still prefilling with its TTFT deadline already blown.
                None => cx.now - arrival > slo.ttft,
                // Decoding: even at best-case pace the final per-token
                // latency lands past the TPOT deadline.
                Some(ft) => {
                    let target = requests[r.id].target_out;
                    target > 1 && {
                        let finish = cx.now + r.remaining_tokens as f64 * est;
                        (finish - ft) / (target - 1) as f64 > slo.tpot
                    }
                }
            };
            if doomed {
                out.push(r.id);
            }
        }
        out
    }
}

/// The scheduler descriptor [`ServeParams`](crate::coordinator::ServeParams)
/// carries: a serializable identity (`bench.json` compares it) that
/// resolves to a boxed [`Scheduler`] at run time. Custom policies
/// bypass the descriptor and hand their own `Scheduler` to
/// [`SimLoop::run`](super::SimLoop::run).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum SchedulerPolicy {
    #[default]
    Fcfs,
    Priority,
    Chunked {
        chunk_tokens: usize,
    },
    SloAware,
}

impl SchedulerPolicy {
    /// Stable identity key (CLI `--scheduler`, `bench.json`).
    pub fn label(&self) -> &'static str {
        match self {
            SchedulerPolicy::Fcfs => "fcfs",
            SchedulerPolicy::Priority => "priority",
            SchedulerPolicy::Chunked { .. } => "chunked",
            SchedulerPolicy::SloAware => "slo-aware",
        }
    }

    /// Parse a CLI/config key; `chunk_tokens` feeds the chunked policy.
    /// Thin wrapper over the
    /// [registry](crate::coordinator::registry::scheduler_entry), so
    /// the accepted names — and the `bench.json` strings they round-trip
    /// to — live in exactly one table.
    pub fn parse(s: &str, chunk_tokens: usize) -> Option<Self> {
        let key = s.trim().to_ascii_lowercase();
        let entry = crate::coordinator::registry::scheduler_entry(&key)?;
        Some(match entry.name {
            "priority" => SchedulerPolicy::Priority,
            "chunked" => SchedulerPolicy::Chunked { chunk_tokens },
            "slo-aware" => SchedulerPolicy::SloAware,
            _ => SchedulerPolicy::Fcfs,
        })
    }

    pub fn validate(&self) -> Result<()> {
        if let SchedulerPolicy::Chunked { chunk_tokens } = self {
            anyhow::ensure!(*chunk_tokens >= 1, "chunked prefill needs chunk_tokens >= 1");
        }
        Ok(())
    }

    /// Resolve to the runtime policy through the
    /// [registry](crate::coordinator::registry::scheduler_entry). `seed`
    /// is the trace seed; the priority stream is salted off it so tiers
    /// never perturb the trace RNG.
    pub fn build(&self, seed: u64) -> Box<dyn Scheduler> {
        let entry = crate::coordinator::registry::scheduler_entry(self.label())
            .expect("every SchedulerPolicy label is registered");
        let chunk = match self {
            SchedulerPolicy::Chunked { chunk_tokens } => *chunk_tokens,
            _ => 1,
        };
        (entry.build)(seed, chunk)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: usize, priority: u8) -> QueueEntry {
        QueueEntry {
            id,
            arrival: id as f64,
            priority,
        }
    }

    #[test]
    fn fcfs_takes_the_queue_head() {
        let mut s = Fcfs;
        assert_eq!(s.select(&[]), None);
        assert_eq!(s.select(&[entry(3, 2), entry(4, 0)]), Some(0));
        assert_eq!(s.prefill_chunk(), 1, "fcfs prefills token-at-a-time");
    }

    #[test]
    fn priority_tiers_pick_most_urgent_fifo_within_tier() {
        let mut s = PriorityTiers::new(7);
        let q = [entry(0, 2), entry(1, 1), entry(2, 0), entry(3, 0)];
        assert_eq!(s.select(&q), Some(2), "tier 0 wins");
        let q = [entry(0, 1), entry(1, 1), entry(2, 2)];
        assert_eq!(s.select(&q), Some(0), "FIFO within a tier");
        assert_eq!(s.select(&[]), None);
    }

    #[test]
    fn priority_assignment_is_seeded_and_leaves_trace_rng_alone() {
        let mk = |id| Request {
            id,
            arrival: None,
            prompt: vec![1],
            target_out: 1,
            priority: 0,
            session: None,
            slo: None,
        };
        let mut a: Vec<Request> = (0..64).map(mk).collect();
        let mut b: Vec<Request> = (0..64).map(mk).collect();
        PriorityTiers::new(9).assign_priorities(&mut a);
        PriorityTiers::new(9).assign_priorities(&mut b);
        let pa: Vec<u8> = a.iter().map(|r| r.priority).collect();
        let pb: Vec<u8> = b.iter().map(|r| r.priority).collect();
        assert_eq!(pa, pb, "same seed, same tiers");
        assert!(pa.iter().any(|p| *p == 0) && pa.iter().any(|p| *p == 2), "tiers are used");
        let mut c: Vec<Request> = (0..64).map(mk).collect();
        PriorityTiers::new(10).assign_priorities(&mut c);
        assert_ne!(pa, c.iter().map(|r| r.priority).collect::<Vec<_>>(), "seeded differently");
    }

    #[test]
    fn chunked_is_fcfs_admission_with_bounded_chunks() {
        let mut s = ChunkedPrefill::new(32);
        assert_eq!(s.select(&[entry(0, 2), entry(1, 0)]), Some(0));
        assert_eq!(s.prefill_chunk(), 32);
        assert_eq!(ChunkedPrefill::new(0).prefill_chunk(), 1, "clamped to 1");
    }

    fn slo_req(id: usize, arrival: f64, ttft: f64, tpot: f64, plen: usize, out: usize) -> Request {
        use crate::metrics::{Slo, SloTier};
        Request {
            id,
            arrival: Some(arrival),
            prompt: vec![0; plen],
            target_out: out,
            priority: 0,
            session: None,
            slo: Some(Slo { tier: SloTier::Interactive, ttft, tpot }),
        }
    }

    fn plain_req(id: usize, arrival: f64) -> Request {
        Request {
            id,
            arrival: Some(arrival),
            prompt: vec![0; 2],
            target_out: 2,
            priority: 0,
            session: None,
            slo: None,
        }
    }

    #[test]
    fn slo_aware_selects_earliest_deadline_and_degrades_to_fcfs() {
        let mut s = SloAware::new();
        // req 0: deadline 0.0 + 10.0 = 10; req 1: deadline 5.0 + 1.0 = 6.
        let mut reqs = vec![slo_req(0, 0.0, 10.0, 1.0, 2, 2), slo_req(1, 5.0, 1.0, 1.0, 2, 2)];
        s.assign_priorities(&mut reqs);
        let q = [
            QueueEntry { id: 0, arrival: 0.0, priority: 0 },
            QueueEntry { id: 1, arrival: 5.0, priority: 0 },
        ];
        assert_eq!(s.select(&q), Some(1), "later arrival but earlier deadline wins");
        // No SLOs anywhere: exact FCFS (arrival, then id).
        let mut s = SloAware::new();
        let mut reqs = vec![plain_req(0, 1.0), plain_req(1, 0.5)];
        s.assign_priorities(&mut reqs);
        let q = [
            QueueEntry { id: 0, arrival: 1.0, priority: 0 },
            QueueEntry { id: 1, arrival: 0.5, priority: 0 },
        ];
        assert_eq!(s.select(&q), Some(1), "earliest arrival without SLOs");
        assert_eq!(s.select(&[]), None);
        assert_eq!(s.prefill_chunk(), 1);
    }

    #[test]
    fn slo_aware_sheds_blown_and_provably_doomed_queued_requests() {
        use super::super::SloCx;
        let mut s = SloAware::new();
        let reqs = vec![
            slo_req(0, 0.0, 2.0, 1.0, 2, 2),  // waited 5.0 > 2.0: blown
            slo_req(1, 4.9, 10.0, 1.0, 3, 2), // 0.1 + 4·0.1 = 0.5 ≤ 10: meetable
            slo_req(2, 4.5, 0.6, 1.0, 10, 2), // 0.5 + 11·0.1 = 1.6 > 0.6: doomed
            plain_req(3, 0.0),                // no SLO: never shed
        ];
        let queue: Vec<QueueEntry> = reqs
            .iter()
            .map(|r| QueueEntry { id: r.id, arrival: r.arrival.unwrap(), priority: 0 })
            .collect();
        let cx = SloCx { now: 5.0, est_token_secs: Some(0.1) };
        assert_eq!(s.shed(cx, &queue, &reqs), vec![0, 2], "ascending queue indices");
        // Without a pace estimate only already-blown requests go.
        let cx = SloCx { now: 5.0, est_token_secs: None };
        assert_eq!(s.shed(cx, &queue, &reqs), vec![0]);
        // Other policies shed nothing by default.
        assert!(Fcfs.shed(cx, &queue, &reqs).is_empty());
    }

    #[test]
    fn slo_aware_preempts_doomed_work_only_under_queue_pressure() {
        use super::super::{RunningEntry, SloCx};
        let mut s = SloAware::new();
        let reqs = vec![
            slo_req(0, 0.0, 10.0, 0.2, 2, 5), // decoding, doomed on TPOT
            slo_req(1, 0.0, 10.0, 9.0, 2, 5), // decoding, meetable
            slo_req(2, 0.0, 0.5, 1.0, 8, 2),  // prefilling, TTFT blown
            plain_req(3, 0.0),                // no SLO: untouchable
        ];
        let running = vec![
            RunningEntry { id: 0, admit: 0.5, first_token: Some(1.0), decoded: 1, remaining_tokens: 4 },
            RunningEntry { id: 1, admit: 0.5, first_token: Some(1.0), decoded: 1, remaining_tokens: 4 },
            RunningEntry { id: 2, admit: 0.5, first_token: None, decoded: 0, remaining_tokens: 9 },
            RunningEntry { id: 3, admit: 0.5, first_token: None, decoded: 0, remaining_tokens: 3 },
        ];
        let queue = [QueueEntry { id: 9, arrival: 1.0, priority: 0 }];
        let cx = SloCx { now: 2.0, est_token_secs: Some(0.5) };
        // req 0: finish = 2 + 4·0.5 = 4, final TPOT = (4−1)/4 = 0.75 > 0.2.
        // req 1: 0.75 ≤ 9. req 2: now−arrival = 2 > 0.5.
        assert_eq!(s.preempt(cx, &running, &queue, &reqs), vec![0, 2]);
        assert!(
            s.preempt(cx, &running, &[], &reqs).is_empty(),
            "no queued work, nothing to free capacity for"
        );
        let cold = SloCx { now: 2.0, est_token_secs: None };
        assert!(s.preempt(cold, &running, &queue, &reqs).is_empty());
        assert!(Fcfs.preempt(cx, &running, &queue, &reqs).is_empty(), "default preempts nothing");
    }

    #[test]
    fn policy_descriptor_round_trips() {
        assert_eq!(SchedulerPolicy::parse("fcfs", 8), Some(SchedulerPolicy::Fcfs));
        assert_eq!(SchedulerPolicy::parse("PRIORITY", 8), Some(SchedulerPolicy::Priority));
        assert_eq!(
            SchedulerPolicy::parse("chunked", 8),
            Some(SchedulerPolicy::Chunked { chunk_tokens: 8 })
        );
        assert_eq!(SchedulerPolicy::parse("sjf", 8), None);
        assert_eq!(SchedulerPolicy::parse("SLO-AWARE", 8), Some(SchedulerPolicy::SloAware));
        for p in [
            SchedulerPolicy::Fcfs,
            SchedulerPolicy::Priority,
            SchedulerPolicy::Chunked { chunk_tokens: 4 },
            SchedulerPolicy::SloAware,
        ] {
            assert_eq!(SchedulerPolicy::parse(p.label(), 4), Some(p));
            assert!(p.validate().is_ok());
            assert_eq!(p.build(7).label(), p.label());
        }
        assert!(SchedulerPolicy::Chunked { chunk_tokens: 0 }.validate().is_err());
        assert_eq!(SchedulerPolicy::default(), SchedulerPolicy::Fcfs);
    }
}
