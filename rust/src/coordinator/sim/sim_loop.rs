//! [`SimLoop`]: the one continuous-batching serving loop (DESIGN.md §5).
//!
//! The loop owns the batched [`Engine`], the pricing
//! [`DeviceClock`] and the event queue, and is policy-free: *which*
//! request takes a freed slot and *how many* prompt tokens a slot
//! consumes per step come from the [`Scheduler`]; *when* requests
//! become visible comes from the [`Workload`]. With `Fcfs` +
//! `PoissonOpen` it executes the exact step/admission/pricing sequence
//! of the PR-2 monolith, so the default `bench.json` is bit-identical
//! across the trait split (the parity test in `coordinator/serve.rs`).
//!
//! Beyond the monolith it adds two slot-lifecycle mechanisms:
//!
//! * **chunked prefill** — when the scheduler's `prefill_chunk` is > 1,
//!   a prefilling slot feeds a bounded *span* of prompt tokens per step
//!   ([`Engine::forward_spans`]), priced with the weight stream charged
//!   once per step;
//! * **slot parking** — a retiring chat turn with a successor parks its
//!   slot instead of releasing it; the follow-up turn is admitted onto
//!   the parked slot, the KV prefix is pinned with
//!   [`Engine::truncate_slot`] and *reused* rather than re-prefilled
//!   (reported as [`KvReuse`]).
//!
//! The loop runs in two shapes. [`SimLoop::run`] is the solo shape:
//! one device, arrivals owned by the [`Workload`], driven to completion
//! in one call. [`SimLoop::start`] / [`SimRun::tick`] /
//! [`SimRun::finish`] expose the same loop one step at a time —
//! `run` is literally `start` + `tick` until [`TickStatus::Done`] +
//! `finish`, so the stepwise API cannot drift from the one-shot one.
//! [`SimLoop::start_routed`] is the cluster shape (DESIGN.md §9): the
//! replica starts with an *empty* arrival stream and a router feeds it
//! requests via [`SimRun::push_arrival`]; `tick` then reports
//! retirements back ([`SimRun::take_finishes`]) instead of calling
//! [`Workload::on_finish`], because in a cluster the workload is global
//! and release ordering across replicas belongs to the router's pump.

use anyhow::{anyhow, Result};

use crate::device::DeviceClock;
use crate::graph::sampler::argmax;
use crate::graph::{Engine, KvPoolStats};
use crate::metrics::{self, Outcome, RequestRecord};

use super::{QueueEntry, Release, Request, RunningEntry, Scheduler, SloCx, Workload};

/// KV-prefix reuse accounting of the chat workload: follow-up turns
/// admitted onto their session's parked slot, and the prefix tokens
/// they did not re-prefill.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvReuse {
    pub reused_turns: usize,
    pub reused_tokens: usize,
}

/// Everything one simulated serving run produced (the raw material of
/// [`ServeReport`](crate::coordinator::ServeReport)).
#[derive(Clone, Debug)]
pub struct SimOutput {
    /// One record per request, indexed by request id.
    pub records: Vec<RequestRecord>,
    /// Fed tokens + outputs per request id (for chat follow-up turns:
    /// bridge token + delta prompt + outputs).
    pub sequences: Vec<Vec<u32>>,
    /// Per request: logits at each sampling event (only when capturing).
    pub captured_logits: Vec<Vec<Vec<f32>>>,
    /// Virtual clock after each engine step.
    pub step_t: Vec<f64>,
    /// Requests waiting (not yet admitted) at each step.
    pub step_queue: Vec<usize>,
    /// Active slots at each step (parked slots are not active).
    pub step_active: Vec<usize>,
    /// Batch-aware MBU at each step (0.0 for pure-prefill steps).
    pub step_mbu: Vec<f64>,
    pub output_tokens: usize,
    /// Virtual time of the last completion.
    pub makespan_secs: f64,
    pub reuse: KvReuse,
    /// Admissions the kv pool block budget pushed to a later step
    /// (always 0 without a budget).
    pub deferred_admissions: usize,
    /// Queued requests the scheduler shed before admission (outcome
    /// [`Outcome::Shed`], zero output; always 0 without SLOs).
    pub shed_requests: usize,
    /// In-flight requests the scheduler preempted (outcome
    /// [`Outcome::Preempted`], partial output; always 0 without SLOs).
    pub preempted_requests: usize,
    /// Paged-pool counters at the end of the run (`None` on the
    /// slot-layout reference engine).
    pub kv_pool: Option<KvPoolStats>,
    /// Cumulative stepping virtual time — the utilization numerator
    /// (`busy / makespan`).
    pub busy_secs: f64,
    /// Total tokens fed through the engine (prompt + decode).
    pub processed_tokens: usize,
}

/// What one routed (cluster) replica produced. Unlike [`SimOutput`],
/// records are sparse: a replica only holds records for the requests
/// the router dispatched to it.
#[derive(Clone, Debug)]
pub struct PartialOutput {
    /// Indexed by global request id; `None` where this replica never
    /// saw the request.
    pub records: Vec<Option<RequestRecord>>,
    pub sequences: Vec<Vec<u32>>,
    pub step_t: Vec<f64>,
    pub step_queue: Vec<usize>,
    pub step_active: Vec<usize>,
    pub step_mbu: Vec<f64>,
    pub output_tokens: usize,
    pub makespan_secs: f64,
    pub reuse: KvReuse,
    pub deferred_admissions: usize,
    pub shed_requests: usize,
    pub preempted_requests: usize,
    pub kv_pool: Option<KvPoolStats>,
    pub busy_secs: f64,
    pub processed_tokens: usize,
    /// Requests the router dispatched here ([`SimRun::push_arrival`]).
    pub routed: usize,
}

/// What one [`SimRun::tick`] did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TickStatus {
    /// Every request has a record — the run is complete (solo mode
    /// only; routed replicas go [`TickStatus::Idle`] instead, because
    /// the router may still dispatch more work).
    Done,
    /// The tick moved: priced a step, shed/preempted, or jumped the
    /// idle clock to the next arrival.
    Progress,
    /// Nothing running and no pending arrival to jump to (routed mode
    /// only): the replica cannot move until the router pushes work.
    Idle,
}

/// What occupies one engine slot between steps.
enum Slot {
    Free,
    /// Held for a chat session between turns: the successor request
    /// `next_id` will inherit the slot, reusing `kv_len` cached
    /// positions and feeding `bridge` (the previous turn's final
    /// output, never yet forwarded) first.
    Parked { next_id: usize, kv_len: usize, bridge: u32 },
    Busy(InFlight),
}

/// A request occupying an engine slot.
struct InFlight {
    rid: usize,
    /// Tokens of `sequences[rid]` already fed through the engine.
    fed: usize,
    /// Fed tokens that are prompt (the prefill/decode boundary).
    prompt_feed: usize,
    admit: f64,
    first_token: Option<f64>,
}

/// Worst-case block reservation of every occupied slot except `skip`:
/// a busy slot reserves its final chain length (what is cached plus
/// every token it will still feed), a parked slot its held chain. The
/// admission gate charges forked prefixes at full price (conservative:
/// a shared block may be copied-on-write at any step).
fn reserved_blocks(
    state: &[Slot],
    requests: &[Request],
    engine: &Engine,
    bt: usize,
    skip: usize,
) -> usize {
    state
        .iter()
        .enumerate()
        .filter(|(slot, _)| *slot != skip)
        .map(|(slot, st)| match st {
            Slot::Free => 0,
            Slot::Parked { kv_len, .. } => kv_len.div_ceil(bt),
            Slot::Busy(a) => {
                // The final sampled token is never fed, so a request's
                // lifetime feed is prompt_feed + target_out - 1.
                let total_feed = a.prompt_feed + requests[a.rid].target_out - 1;
                let final_len = engine.cache.slot_len(slot) + (total_feed - a.fed);
                final_len.div_ceil(bt)
            }
        })
        .sum()
}

/// The serving loop core: engine + clock + event queue.
pub struct SimLoop {
    engine: Engine,
    clock: DeviceClock,
    capture_logits: bool,
    /// Block-budget admission gate: when `Some(b)`, a request is only
    /// admitted while every occupied slot's worst-case chain plus its
    /// own fits in `b` paged KV blocks; otherwise admission is deferred
    /// until retirements free blocks. `None` (the default) admits on
    /// free slots alone — bit-identical to the pre-paged loop.
    pool_blocks: Option<usize>,
    /// When set, a freshly admitted request whose prompt starts with
    /// tokens another chain already cached forks that prefix
    /// (copy-on-write) instead of re-prefilling it. Off by default —
    /// sharing never changes tokens (the KV at a position is a pure
    /// function of the tokens up to it), but it does change step
    /// timing, so the parity baseline keeps it off.
    prefix_share: bool,
}

impl SimLoop {
    /// The engine's slot count (`Engine::batch`) is the max concurrency.
    pub fn new(engine: Engine, clock: DeviceClock, capture_logits: bool) -> Self {
        Self {
            engine,
            clock,
            capture_logits,
            pool_blocks: None,
            prefix_share: false,
        }
    }

    /// Cap the paged pool at `blocks` (admission gate); `None` = no gate.
    pub fn with_pool_blocks(mut self, blocks: Option<usize>) -> Self {
        self.pool_blocks = blocks;
        self
    }

    /// Enable copy-on-write prompt-prefix sharing at admission.
    pub fn with_prefix_share(mut self, share: bool) -> Self {
        self.prefix_share = share;
        self
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Drive `requests` (from `workload.build`) to completion under the
    /// given scheduler. Consumes the loop; returns the full output.
    pub fn run(
        self,
        requests: Vec<Request>,
        workload: &mut dyn Workload,
        scheduler: &mut dyn Scheduler,
    ) -> Result<SimOutput> {
        let mut run = self.start(requests, scheduler)?;
        while run.tick(workload, scheduler)? != TickStatus::Done {}
        Ok(run.finish())
    }

    /// Validate `requests`, assign priorities and freeze the initial
    /// event queue — everything [`run`](Self::run) does before its
    /// first step. The returned [`SimRun`] is driven by
    /// [`tick`](SimRun::tick).
    pub fn start(self, requests: Vec<Request>, scheduler: &mut dyn Scheduler) -> Result<SimRun> {
        self.start_inner(requests, scheduler, false)
    }

    /// Start in *routed* mode (cluster replica): the statically
    /// timestamped arrivals in `requests` are ignored — nothing enters
    /// the queue until the router calls [`SimRun::push_arrival`] — and
    /// retirements are buffered for [`SimRun::take_finishes`] instead
    /// of firing `Workload::on_finish`.
    pub fn start_routed(
        self,
        requests: Vec<Request>,
        scheduler: &mut dyn Scheduler,
    ) -> Result<SimRun> {
        self.start_inner(requests, scheduler, true)
    }

    fn start_inner(
        self,
        mut requests: Vec<Request>,
        scheduler: &mut dyn Scheduler,
        external: bool,
    ) -> Result<SimRun> {
        let n = requests.len();
        anyhow::ensure!(n >= 1, "sim loop needs at least one request");
        for (i, r) in requests.iter().enumerate() {
            anyhow::ensure!(r.id == i, "request ids must be dense: index {i} has id {}", r.id);
            anyhow::ensure!(!r.prompt.is_empty(), "request {i} has an empty prompt");
            anyhow::ensure!(r.target_out >= 1, "request {i} wants zero output tokens");
        }
        scheduler.assign_priorities(&mut requests);
        let bt = self.engine.cache.block_tokens();
        anyhow::ensure!(
            self.pool_blocks.is_none() || bt.is_some(),
            "kv pool budget requires the paged KV layout"
        );
        anyhow::ensure!(
            !self.prefix_share || bt.is_some(),
            "kv prefix sharing requires the paged KV layout"
        );
        if let (Some(budget), Some(bt)) = (self.pool_blocks, bt) {
            // A chain's blocks are only released when its final turn
            // retires, so the longest session chain must fit the budget
            // by itself or no gate decision can ever admit it.
            let mut max_chain = 0usize;
            for r in &requests {
                if r.session.as_ref().is_some_and(|s| s.turn > 0) {
                    continue; // counted from its chain's first turn
                }
                // Final cached length: the last sampled token of each
                // turn is fed as the next turn's bridge, so every turn
                // adds exactly prompt + target_out positions (minus one
                // for the chain's very last token, never fed).
                let mut len = r.prompt.len() + r.target_out - 1;
                let mut next = r.session.as_ref().and_then(|s| s.next);
                while let Some(id) = next {
                    let f = &requests[id];
                    len += f.prompt.len() + f.target_out;
                    next = f.session.as_ref().and_then(|s| s.next);
                }
                max_chain = max_chain.max(len);
            }
            let need = max_chain.div_ceil(bt);
            anyhow::ensure!(
                need <= budget,
                "kv pool budget too small: a single request chain needs {need} \
                 block(s) ({max_chain} tokens at {bt}/block) but the budget is {budget}"
            );
        }
        let slots = self.engine.batch();
        let vocab = self.engine.config().vocab_size;

        // Statically-timestamped arrivals, sorted by (arrival, id);
        // dynamic releases are inserted in order as they happen. A
        // routed replica starts empty — its router owns dispatch.
        let mut pending: Vec<(f64, usize)> = if external {
            Vec::new()
        } else {
            requests.iter().filter_map(|r| r.arrival.map(|a| (a, r.id))).collect()
        };
        pending.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("finite arrivals").then(a.1.cmp(&b.1)));

        // The shed/preempt pass only runs when some request carries an
        // SLO, so non-SLO runs take the exact pre-SLO path.
        let has_slos = requests.iter().any(|r| r.slo.is_some());
        // Every step feeds ≥1 token of some request, so this bounds the
        // loop (chat bridge tokens add one feed per follow-up turn).
        let step_limit = requests
            .iter()
            .map(|r| r.prompt.len() + 1 + r.target_out)
            .sum::<usize>()
            + 16;

        Ok(SimRun {
            engine: self.engine,
            clock: self.clock,
            capture_logits: self.capture_logits,
            pool_blocks: self.pool_blocks,
            prefix_share: self.prefix_share,
            external,
            n,
            bt,
            slots,
            vocab,
            pending,
            next_pending: 0,
            queue: Vec::new(),
            arrived_at: vec![0.0; n],
            now: 0.0,
            state: (0..slots).map(|_| Slot::Free).collect(),
            records: vec![None; n],
            sequences: vec![Vec::new(); n],
            captured: vec![Vec::new(); n],
            step_t: Vec::new(),
            step_queue: Vec::new(),
            step_active: Vec::new(),
            step_mbu: Vec::new(),
            completed: 0,
            output_tokens: 0,
            makespan: 0.0,
            reuse: KvReuse::default(),
            deferred_admissions: 0,
            shed_requests: 0,
            preempted_requests: 0,
            busy_secs: 0.0,
            processed_tokens: 0,
            has_slos,
            slot_tokens: vec![Vec::new(); slots],
            step_limit,
            routed: 0,
            finishes: Vec::new(),
            requests,
            slots_vec: Vec::with_capacity(slots),
            span_lens: Vec::with_capacity(slots),
            span_from: Vec::with_capacity(slots),
        })
    }
}

/// A started serving run: the loop state between steps. Produced by
/// [`SimLoop::start`] / [`SimLoop::start_routed`], advanced by
/// [`tick`](Self::tick), consumed by [`finish`](Self::finish) /
/// [`finish_routed`](Self::finish_routed).
pub struct SimRun {
    engine: Engine,
    clock: DeviceClock,
    capture_logits: bool,
    pool_blocks: Option<usize>,
    prefix_share: bool,
    /// Routed (cluster-replica) mode: arrivals come from
    /// [`push_arrival`](Self::push_arrival), retirements go to the
    /// finish buffer, and an empty machine is [`TickStatus::Idle`]
    /// rather than a stall error.
    external: bool,
    requests: Vec<Request>,
    n: usize,
    bt: Option<usize>,
    slots: usize,
    vocab: usize,
    pending: Vec<(f64, usize)>,
    next_pending: usize,
    queue: Vec<QueueEntry>,
    arrived_at: Vec<f64>,
    now: f64,
    state: Vec<Slot>,
    records: Vec<Option<RequestRecord>>,
    sequences: Vec<Vec<u32>>,
    captured: Vec<Vec<Vec<f32>>>,
    step_t: Vec<f64>,
    step_queue: Vec<usize>,
    step_active: Vec<usize>,
    step_mbu: Vec<f64>,
    completed: usize,
    output_tokens: usize,
    makespan: f64,
    reuse: KvReuse,
    deferred_admissions: usize,
    shed_requests: usize,
    preempted_requests: usize,
    // Cumulative busy virtual time and fed tokens: the thermal
    // derate's load input and the SLO pace estimate — both pure
    // functions of the priced trace.
    busy_secs: f64,
    processed_tokens: usize,
    has_slos: bool,
    /// Tokens currently cached in each slot, in position order —
    /// prefix-share bookkeeping, maintained only when sharing is on.
    slot_tokens: Vec<Vec<u32>>,
    step_limit: usize,
    /// Requests dispatched here via `push_arrival` (routed mode).
    routed: usize,
    /// Retirements `(finish_time, id)` not yet taken by the router.
    finishes: Vec<(f64, usize)>,
    slots_vec: Vec<usize>,
    span_lens: Vec<usize>,
    span_from: Vec<(usize, usize)>,
}

impl SimRun {
    /// One solo-mode iteration of the serving loop.
    pub fn tick(
        &mut self,
        workload: &mut dyn Workload,
        scheduler: &mut dyn Scheduler,
    ) -> Result<TickStatus> {
        self.tick_inner(Some(workload), scheduler)
    }

    /// One routed-mode iteration: never calls `Workload::on_finish`
    /// (retirements land in [`take_finishes`](Self::take_finishes)).
    pub fn tick_routed(&mut self, scheduler: &mut dyn Scheduler) -> Result<TickStatus> {
        debug_assert!(self.external, "tick_routed on a solo run");
        self.tick_inner(None, scheduler)
    }

    fn tick_inner(
        &mut self,
        mut workload: Option<&mut dyn Workload>,
        scheduler: &mut dyn Scheduler,
    ) -> Result<TickStatus> {
        if !self.external && self.completed >= self.n {
            return Ok(TickStatus::Done);
        }
        anyhow::ensure!(
            self.step_t.len() <= self.step_limit,
            "serve loop exceeded its step bound (internal error)"
        );
        // Arrivals whose time has come join the queue (admissions
        // happen between steps — tokens in flight are never
        // preempted).
        self.drain_arrivals();
        // SLO shed/preempt pass (between steps, tokens in flight are
        // never cut mid-step): doomed queued requests retire before
        // they waste a slot; doomed in-flight requests release their
        // slot and paged-KV blocks for meetable work. Both retire
        // with a counted record — never a silent drop — and neither
        // fires `Workload::on_finish` (SLOs are validated upstream to
        // open-loop workloads, which release nothing).
        if self.has_slos {
            let cx = SloCx {
                now: self.now,
                est_token_secs: if self.processed_tokens > 0 {
                    Some(self.busy_secs / self.processed_tokens as f64)
                } else {
                    None
                },
            };
            let shed = scheduler.shed(cx, &self.queue, &self.requests);
            anyhow::ensure!(
                shed.windows(2).all(|w| w[0] < w[1])
                    && shed.last().map_or(true, |&i| i < self.queue.len()),
                "scheduler shed indices must be strictly ascending and in range"
            );
            for &qi in shed.iter().rev() {
                let e = self.queue.remove(qi);
                let rid = e.id;
                self.records[rid] = Some(RequestRecord {
                    id: rid,
                    arrival: self.arrived_at[rid],
                    admit: self.now,
                    first_token: self.now,
                    finish: self.now,
                    prompt_tokens: self.requests[rid].prompt.len(),
                    output_tokens: 0,
                    slo: self.requests[rid].slo,
                    outcome: Outcome::Shed,
                    target_tokens: self.requests[rid].target_out,
                });
                self.completed += 1;
                self.shed_requests += 1;
            }
            let running: Vec<RunningEntry> = self
                .state
                .iter()
                .filter_map(|st| match st {
                    Slot::Busy(a) => Some(RunningEntry {
                        id: a.rid,
                        admit: a.admit,
                        first_token: a.first_token,
                        decoded: self.sequences[a.rid].len().saturating_sub(a.prompt_feed),
                        // Lifetime feed is prompt + target_out − 1
                        // (the final sampled token is never fed).
                        remaining_tokens: a.prompt_feed + self.requests[a.rid].target_out
                            - 1
                            - a.fed,
                    }),
                    _ => None,
                })
                .collect();
            for rid in scheduler.preempt(cx, &running, &self.queue, &self.requests) {
                let slot = self
                    .state
                    .iter()
                    .position(|st| matches!(st, Slot::Busy(a) if a.rid == rid))
                    .ok_or_else(|| {
                        anyhow!("scheduler preempted request {rid} which is not running")
                    })?;
                let Slot::Busy(a) = &self.state[slot] else { unreachable!() };
                self.records[rid] = Some(RequestRecord {
                    id: rid,
                    arrival: self.arrived_at[rid],
                    admit: a.admit,
                    first_token: a.first_token.unwrap_or(self.now),
                    finish: self.now,
                    prompt_tokens: a.prompt_feed,
                    output_tokens: self.sequences[rid].len().saturating_sub(a.prompt_feed),
                    slo: self.requests[rid].slo,
                    outcome: Outcome::Preempted,
                    target_tokens: self.requests[rid].target_out,
                });
                self.state[slot] = Slot::Free;
                self.engine.reset_slot(slot);
                self.slot_tokens[slot].clear();
                self.completed += 1;
                self.preempted_requests += 1;
            }
            if !self.external && self.completed >= self.n {
                return Ok(TickStatus::Done);
            }
        }
        // Parked handoffs first: a queued follow-up turn reclaims
        // its session's slot, pins the reused KV prefix and bridges
        // from the previous turn's final token.
        for slot in 0..self.slots {
            let Slot::Parked { next_id, kv_len, bridge } = self.state[slot] else { continue };
            let Some(qpos) = self.queue.iter().position(|e| e.id == next_id) else { continue };
            if let (Some(budget), Some(bt)) = (self.pool_blocks, self.bt) {
                // The handoff keeps kv_len cached positions and then
                // feeds bridge + delta prompt + all but the final
                // output token: kv_len + prompt + target_out total.
                let req = &self.requests[next_id];
                let need = (kv_len + req.prompt.len() + req.target_out).div_ceil(bt);
                if reserved_blocks(&self.state, &self.requests, &self.engine, bt, slot) + need
                    > budget
                {
                    self.deferred_admissions += 1;
                    continue;
                }
            }
            self.queue.remove(qpos);
            self.engine.truncate_slot(slot, kv_len);
            if self.prefix_share {
                self.slot_tokens[slot].truncate(kv_len);
            }
            self.reuse.reused_turns += 1;
            self.reuse.reused_tokens += kv_len;
            let req = &self.requests[next_id];
            let mut seq = Vec::with_capacity(1 + req.prompt.len() + req.target_out);
            seq.push(bridge);
            seq.extend_from_slice(&req.prompt);
            let prompt_feed = seq.len();
            self.sequences[next_id] = seq;
            self.state[slot] = Slot::Busy(InFlight {
                rid: next_id,
                fed: 0,
                prompt_feed,
                admit: self.now,
                first_token: None,
            });
        }
        // Scheduler admission into free slots; claiming resets the
        // slot so a retired sequence's stale KV can never leak in.
        for slot in 0..self.slots {
            if !matches!(self.state[slot], Slot::Free) {
                continue;
            }
            let Some(idx) = scheduler.select(&self.queue) else { continue };
            anyhow::ensure!(
                idx < self.queue.len(),
                "scheduler selected queue index {idx} of {}",
                self.queue.len()
            );
            if let (Some(budget), Some(bt)) = (self.pool_blocks, self.bt) {
                // Peek before removing (`select` is pure): when the
                // pick does not fit the block budget, defer it and
                // stop filling slots this step — head-of-line
                // deferral keeps the gate deterministic. The gate
                // charges a forked prefix at full price: a shared
                // block may be copied-on-write at any later step.
                let req = &self.requests[self.queue[idx].id];
                let need = (req.prompt.len() + req.target_out - 1).div_ceil(bt);
                if reserved_blocks(&self.state, &self.requests, &self.engine, bt, slot) + need
                    > budget
                {
                    self.deferred_admissions += 1;
                    break;
                }
            }
            let e = self.queue.remove(idx);
            let rid = e.id;
            self.engine.reset_slot(slot);
            self.sequences[rid] = self.requests[rid].prompt.clone();
            let mut fed = 0usize;
            if self.prefix_share {
                self.slot_tokens[slot].clear();
                // Fork the longest common prefix any other chain has
                // cached, capped so at least one prompt token is
                // left to feed (every admitted slot must move).
                let prompt = &self.requests[rid].prompt;
                let cap = prompt.len() - 1;
                let (mut donor, mut lcp) = (0usize, 0usize);
                for (other, cached) in self.slot_tokens.iter().enumerate() {
                    if other == slot {
                        continue;
                    }
                    let m = cached
                        .iter()
                        .zip(prompt.iter())
                        .take(cap)
                        .take_while(|(a, b)| a == b)
                        .count();
                    if m > lcp {
                        (donor, lcp) = (other, m);
                    }
                }
                if lcp > 0 {
                    // The forked KV is bitwise what prefilling those
                    // tokens here would produce (causal attention),
                    // so only timing changes, never tokens.
                    self.engine.fork_slot(donor, slot, lcp);
                    let shared: Vec<u32> = prompt[..lcp].to_vec();
                    self.slot_tokens[slot] = shared;
                    fed = lcp;
                }
            }
            self.state[slot] = Slot::Busy(InFlight {
                rid,
                fed,
                prompt_feed: self.requests[rid].prompt.len(),
                admit: self.now,
                first_token: None,
            });
        }
        if !self.state.iter().any(|s| matches!(s, Slot::Busy(_))) {
            // Idle: jump the clock to the next arrival (a future
            // open-loop request, or a parked session's next turn).
            // With nothing pending either, nothing can ever wake the
            // loop again — a routed replica reports Idle and waits for
            // its router; a solo run distinguishes a scheduler that
            // deferred itself into a corner from a genuine internal
            // error.
            if self.next_pending >= self.pending.len() {
                if self.external {
                    return Ok(TickStatus::Idle);
                }
                if self.queue.is_empty() {
                    return Err(anyhow!(
                        "serve loop stalled with work outstanding (internal error)"
                    ));
                }
                if self.deferred_admissions > 0 && self.pool_blocks.is_some() {
                    // Parked chains hold their reservations until
                    // their next turn is admitted, so two sessions
                    // can each starve the other's handoff.
                    return Err(anyhow!(
                        "kv pool budget of {} block(s) cannot admit the {} queued \
                         request(s) ({} deferred admission(s)) — raise the pool \
                         budget or lower concurrency",
                        self.pool_blocks.unwrap_or(0),
                        self.queue.len(),
                        self.deferred_admissions
                    ));
                }
                return Err(anyhow!(
                    "scheduler left {} queued request(s) unadmitted with no engine \
                     work and no future arrivals — a Scheduler may return None only \
                     while running slots or pending arrivals can wake it",
                    self.queue.len()
                ));
            }
            self.now = self.pending[self.next_pending].0;
            return Ok(TickStatus::Progress);
        }

        // One continuous-batching step over the active slots: decode
        // slots feed their next token, prefilling slots feed up to
        // `prefill_chunk` prompt tokens as one span.
        let chunk = scheduler.prefill_chunk().max(1);
        self.slots_vec.clear();
        self.span_lens.clear();
        self.span_from.clear();
        for (slot, st) in self.state.iter().enumerate() {
            if let Slot::Busy(a) = st {
                let remaining_prompt = a.prompt_feed - a.fed.min(a.prompt_feed);
                let take = if remaining_prompt > 0 { chunk.min(remaining_prompt) } else { 1 };
                self.slots_vec.push(slot);
                self.span_lens.push(take);
                self.span_from.push((a.rid, a.fed));
            }
        }
        let (logits, traffic, flops) = {
            let spans: Vec<&[u32]> = self
                .span_from
                .iter()
                .zip(&self.span_lens)
                .map(|(&(rid, fed), &len)| &self.sequences[rid][fed..fed + len])
                .collect();
            let logits = self.engine.forward_spans(&self.slots_vec, &spans)?.to_vec();
            let traffic = self.engine.traffic_for_spans(&self.slots_vec, &self.span_lens);
            let flops = self.engine.flops_for_spans(&self.slots_vec, &self.span_lens);
            (logits, traffic, flops)
        };
        // Thermal-aware pricing: with no thermal model this is
        // *exactly* `step_secs` (derate 1.0 is an IEEE identity), so
        // un-throttled runs never move a bit.
        let step_secs = self.clock.step_secs_at(traffic.total(), flops, self.busy_secs);
        self.now += step_secs;
        self.busy_secs += step_secs;
        self.processed_tokens += self.span_lens.iter().sum::<usize>();

        let mut generated = 0usize;
        for i in 0..self.slots_vec.len() {
            let slot = self.slots_vec[i];
            // Advance the slot's fed count; decide whether this step
            // forwarded the request's latest token (scoped borrow so
            // the slot can be re-stated at retirement below).
            let (rid, from, sampling) = {
                let Slot::Busy(a) = &mut self.state[slot] else {
                    return Err(anyhow!("active slot vanished mid-step (internal error)"));
                };
                let from = a.fed;
                a.fed += self.span_lens[i];
                (a.rid, from, a.fed >= a.prompt_feed)
            };
            if self.prefix_share {
                let span = self.sequences[rid][from..from + self.span_lens[i]].to_vec();
                self.slot_tokens[slot].extend_from_slice(&span);
            }
            if !sampling {
                continue; // still prefilling
            }
            let lg = &logits[i * self.vocab..(i + 1) * self.vocab];
            if self.capture_logits {
                self.captured[rid].push(lg.to_vec());
            }
            let tok = argmax(lg);
            self.sequences[rid].push(tok);
            generated += 1;
            self.output_tokens += 1;
            let retired = {
                let Slot::Busy(a) = &mut self.state[slot] else { unreachable!() };
                if a.first_token.is_none() {
                    a.first_token = Some(self.now);
                }
                if self.sequences[rid].len() - a.prompt_feed >= self.requests[rid].target_out {
                    Some((
                        a.admit,
                        a.first_token.expect("finished without a first token"),
                        a.prompt_feed,
                    ))
                } else {
                    None
                }
            };
            if let Some((admit, first_token, prompt_feed)) = retired {
                // Retire: record, then release the slot — or park it
                // for the session's next turn.
                self.records[rid] = Some(RequestRecord {
                    id: rid,
                    arrival: self.arrived_at[rid],
                    admit,
                    first_token,
                    finish: self.now,
                    prompt_tokens: prompt_feed,
                    output_tokens: self.requests[rid].target_out,
                    slo: self.requests[rid].slo,
                    outcome: Outcome::Served,
                    target_tokens: self.requests[rid].target_out,
                });
                // The successor may attend over everything this slot
                // has cached — including a prefix this turn itself
                // inherited — so park the *cache* length, not the
                // turn's own fed count.
                let kv_len = self.engine.cache.slot_len(slot);
                let next = self.requests[rid].session.as_ref().and_then(|s| s.next);
                match next {
                    Some(next_id) => {
                        self.state[slot] = Slot::Parked { next_id, kv_len, bridge: tok };
                    }
                    None => {
                        self.state[slot] = Slot::Free;
                        self.engine.reset_slot(slot);
                        self.slot_tokens[slot].clear();
                    }
                }
                self.completed += 1;
                self.makespan = self.now;
                match workload.as_deref_mut() {
                    Some(w) => {
                        for Release { id, arrival } in w.on_finish(rid, self.now) {
                            anyhow::ensure!(
                                id < self.n && self.records[id].is_none(),
                                "workload released invalid request id {id}"
                            );
                            anyhow::ensure!(
                                arrival >= self.now,
                                "workload released request {id} in the past"
                            );
                            let at = self.pending[self.next_pending..].partition_point(
                                |&(t, i)| t < arrival || (t == arrival && i < id),
                            );
                            self.pending.insert(self.next_pending + at, (arrival, id));
                        }
                    }
                    // Routed mode: the router's pump owns on_finish
                    // ordering across replicas — buffer the event.
                    None => self.finishes.push((self.now, rid)),
                }
            }
        }
        // Sample the series at the step's *end* time — so pull in
        // the arrivals that landed during the step first, or the
        // queue depth at `now` would be understated (the loop-top
        // drain is idempotent and handles the idle-jump case).
        self.drain_arrivals();
        self.step_t.push(self.now);
        self.step_queue.push(self.queue.len());
        self.step_active.push(self.slots_vec.len());
        // Batch-aware MBU at this load point (eq. 1–3): parameter
        // bytes + the active slots' KV traffic, over the
        // per-generated-token latency of this step. Pure-prefill
        // steps record 0. MBU is reported against *peak* bandwidth
        // while pricing ran at *achievable* bandwidth.
        self.step_mbu.push(if generated > 0 {
            metrics::mbu(
                self.engine.weights.bytes_per_token(),
                traffic.kv_read_bytes,
                step_secs / generated as f64,
                self.clock.peak_bw,
            )
        } else {
            0.0
        });
        Ok(TickStatus::Progress)
    }

    fn drain_arrivals(&mut self) {
        while self.next_pending < self.pending.len() && self.pending[self.next_pending].0 <= self.now
        {
            let (t, id) = self.pending[self.next_pending];
            self.next_pending += 1;
            self.arrived_at[id] = t;
            self.queue.push(QueueEntry {
                id,
                arrival: t,
                priority: self.requests[id].priority,
            });
        }
    }

    /// Routed mode: make request `id` visible to this replica's queue
    /// at virtual time `arrival`. An `arrival` at or before the
    /// replica's clock joins the queue on the next tick.
    pub fn push_arrival(&mut self, id: usize, arrival: f64) -> Result<()> {
        anyhow::ensure!(self.external, "push_arrival is only for routed runs");
        anyhow::ensure!(id < self.n, "routed request id {id} out of range");
        anyhow::ensure!(
            self.records[id].is_none(),
            "request {id} already retired on this replica"
        );
        let at = self.pending[self.next_pending..]
            .partition_point(|&(t, i)| t < arrival || (t == arrival && i < id));
        self.pending.insert(self.next_pending + at, (arrival, id));
        self.routed += 1;
        Ok(())
    }

    /// Routed mode: replace request `id`'s prompt and target length
    /// before it is pushed. The wall-clock daemon pre-allocates a ring
    /// of placeholder requests at [`start_routed`](Self::start_routed)
    /// (live HTTP prompts are unknown at startup) and swaps the real
    /// body in here right before [`push_arrival`](Self::push_arrival).
    /// The step bound computed at start from the placeholder sizes is
    /// adjusted by the cost delta, so the "serve loop exceeded its
    /// step bound" invariant keeps holding for live traffic.
    pub fn set_request(&mut self, id: usize, prompt: Vec<u32>, target_out: usize) -> Result<()> {
        anyhow::ensure!(self.external, "set_request is only for routed runs");
        anyhow::ensure!(id < self.n, "routed request id {id} out of range");
        anyhow::ensure!(!prompt.is_empty(), "request {id} must have a non-empty prompt");
        anyhow::ensure!(target_out >= 1, "request {id} must decode at least one token");
        anyhow::ensure!(
            self.records[id].is_none(),
            "request {id} already retired on this replica"
        );
        let dispatched = self.pending[self.next_pending..].iter().any(|&(_, i)| i == id)
            || self.queue.iter().any(|e| e.id == id)
            || self.state.iter().any(|s| matches!(s, Slot::Busy(a) if a.rid == id));
        anyhow::ensure!(!dispatched, "request {id} already dispatched; too late to rewrite");
        let old_cost = self.requests[id].prompt.len() + 1 + self.requests[id].target_out;
        let new_cost = prompt.len() + 1 + target_out;
        self.step_limit = self.step_limit - old_cost + new_cost;
        self.requests[id].prompt = prompt;
        self.requests[id].target_out = target_out;
        Ok(())
    }

    /// Request `id`'s record, if it has retired. Routed callers that
    /// track completions outside the SLO shed/preempt paths (which
    /// bypass the [`take_finishes`](Self::take_finishes) buffer) poll
    /// this after each tick.
    pub fn record(&self, id: usize) -> Option<&RequestRecord> {
        self.records[id].as_ref()
    }

    /// Request `id`'s token sequence so far (prompt followed by the
    /// decoded tokens) — empty until admission builds it. The daemon
    /// streams `sequence(id)[prompt_len..]` as tokens land.
    pub fn sequence(&self, id: usize) -> &[u32] {
        &self.sequences[id]
    }

    /// Live views of the per-step series (virtual step-end times, queue
    /// depth, batch-aware MBU) — the daemon's `/metrics` endpoint
    /// streams their tails mid-run; the full copies still arrive with
    /// [`finish_routed`](Self::finish_routed).
    pub fn step_t(&self) -> &[f64] {
        &self.step_t
    }

    pub fn step_queue(&self) -> &[usize] {
        &self.step_queue
    }

    pub fn step_mbu(&self) -> &[f64] {
        &self.step_mbu
    }

    /// Routed mode: a chat follow-up turn was dispatched to a
    /// *different* replica, so the slot parked for it here will never
    /// be claimed — free it and hand back the bridge token (the
    /// previous turn's final output) so the router can prepend it to
    /// the successor's prompt wherever it lands. `None` when no slot
    /// is parked for `next_id`.
    pub fn cancel_park(&mut self, next_id: usize) -> Option<u32> {
        for slot in 0..self.state.len() {
            if let Slot::Parked { next_id: nid, bridge, .. } = self.state[slot] {
                if nid == next_id {
                    self.state[slot] = Slot::Free;
                    self.engine.reset_slot(slot);
                    self.slot_tokens[slot].clear();
                    return Some(bridge);
                }
            }
        }
        None
    }

    /// Routed mode: prepend `tok` to request `id`'s prompt — the
    /// bridge token recovered by [`cancel_park`](Self::cancel_park) on
    /// the replica that served the previous turn. Must happen before
    /// the request is pushed (its sequence is built at admission).
    pub fn prepend_prompt(&mut self, id: usize, tok: u32) {
        self.requests[id].prompt.insert(0, tok);
    }

    /// Retirements `(finish_time, id)` since the last take, in
    /// retirement order (routed mode).
    pub fn take_finishes(&mut self) -> Vec<(f64, usize)> {
        std::mem::take(&mut self.finishes)
    }

    /// The replica's virtual clock.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Outstanding work: queued + pending-dispatch + busy slots (the
    /// router's least-load signal).
    pub fn load(&self) -> usize {
        self.queue.len()
            + (self.pending.len() - self.next_pending)
            + self.state.iter().filter(|s| matches!(s, Slot::Busy(_))).count()
    }

    /// Nothing queued, pending, busy or parked — every routed request
    /// has retired.
    pub fn drained(&self) -> bool {
        self.queue.is_empty()
            && self.next_pending >= self.pending.len()
            && self.state.iter().all(|s| matches!(s, Slot::Free))
    }

    pub fn busy_secs(&self) -> f64 {
        self.busy_secs
    }

    pub fn processed_tokens(&self) -> usize {
        self.processed_tokens
    }

    /// Fresh-machine price of one engine step feeding a span of `len`
    /// prompt tokens into slot 0: the offload certificate's raw
    /// ingredient. Only meaningful before the first tick (empty cache,
    /// zero thermal load) — that price is a provable lower bound on
    /// any later step of the same span, since cached context, batch
    /// companions and thermal derating only add cost.
    pub fn span_floor_secs(&self, len: usize) -> f64 {
        let traffic = self.engine.traffic_for_spans(&[0], &[len]);
        let flops = self.engine.flops_for_spans(&[0], &[len]);
        self.clock.step_secs_at(traffic.total(), flops, 0.0)
    }

    /// Solo mode: the complete output. Panics if any request lacks a
    /// record (impossible after [`TickStatus::Done`]).
    pub fn finish(self) -> SimOutput {
        SimOutput {
            records: self
                .records
                .into_iter()
                .map(|r| r.expect("request completed without a record"))
                .collect(),
            sequences: self.sequences,
            captured_logits: self.captured,
            step_t: self.step_t,
            step_queue: self.step_queue,
            step_active: self.step_active,
            step_mbu: self.step_mbu,
            output_tokens: self.output_tokens,
            makespan_secs: self.makespan,
            reuse: self.reuse,
            deferred_admissions: self.deferred_admissions,
            shed_requests: self.shed_requests,
            preempted_requests: self.preempted_requests,
            kv_pool: self.engine.kv_pool_stats(),
            busy_secs: self.busy_secs,
            processed_tokens: self.processed_tokens,
        }
    }

    /// Routed mode: the replica's partial output (sparse records).
    pub fn finish_routed(self) -> PartialOutput {
        PartialOutput {
            records: self.records,
            sequences: self.sequences,
            step_t: self.step_t,
            step_queue: self.step_queue,
            step_active: self.step_active,
            step_mbu: self.step_mbu,
            output_tokens: self.output_tokens,
            makespan_secs: self.makespan,
            reuse: self.reuse,
            deferred_admissions: self.deferred_admissions,
            shed_requests: self.shed_requests,
            preempted_requests: self.preempted_requests,
            kv_pool: self.engine.kv_pool_stats(),
            busy_secs: self.busy_secs,
            processed_tokens: self.processed_tokens,
            routed: self.routed,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sim::{Fcfs, PoissonOpen};
    use crate::kernel::BackendKind;
    use crate::model::testutil::random_model_file;
    use crate::model::ModelWeights;
    use crate::quant::QuantType;
    use crate::util::rng::Rng;

    fn loop_for(slots: usize) -> SimLoop {
        let mf = random_model_file(QuantType::Q8_0, 19);
        let engine = Engine::new_batched(ModelWeights::load(&mf).unwrap(), BackendKind::Naive, slots);
        SimLoop::new(engine, DeviceClock::flat(100e6, 2e9), false)
    }

    fn poisson() -> PoissonOpen {
        PoissonOpen {
            rate: 30.0,
            n: 5,
            prompt_len: (2, 4),
            output_len: (2, 3),
        }
    }

    #[test]
    fn sim_loop_rejects_malformed_request_sets() {
        let sim = loop_for(2);
        let mut w = poisson();
        let mut s = Fcfs;
        assert!(sim.run(Vec::new(), &mut w, &mut s).is_err(), "empty set");
        let sim = loop_for(2);
        let mut reqs = w.build(&mut Rng::new(3), 256);
        reqs[1].id = 7;
        assert!(sim.run(reqs, &mut w, &mut s).is_err(), "non-dense ids");
        let sim = loop_for(2);
        let mut reqs = w.build(&mut Rng::new(3), 256);
        reqs[0].prompt.clear();
        assert!(sim.run(reqs, &mut w, &mut s).is_err(), "empty prompt");
    }

    /// The extension point works end to end: a custom (test-local) LIFO
    /// scheduler plugs into the loop through nothing but the trait and
    /// still completes every request with valid lifecycle records.
    #[test]
    fn custom_scheduler_plugs_in_through_the_trait() {
        struct Lifo;
        impl Scheduler for Lifo {
            fn label(&self) -> &'static str {
                "lifo"
            }
            fn select(&mut self, queue: &[QueueEntry]) -> Option<usize> {
                queue.len().checked_sub(1)
            }
        }
        let sim = loop_for(1);
        // Arrival gaps (~1 ms at rate 1000) are far below a step's
        // virtual cost, so everyone queues behind slot 0.
        let mut w = PoissonOpen { rate: 1000.0, ..poisson() };
        let reqs = w.build(&mut Rng::new(5), 256);
        let out = sim.run(reqs, &mut w, &mut Lifo).unwrap();
        assert_eq!(out.records.len(), 5);
        for r in &out.records {
            assert!(r.arrival <= r.admit && r.admit < r.first_token && r.first_token <= r.finish);
        }
        // Everything queues behind slot 0, so LIFO must finish some
        // later-arriving request before an earlier one.
        let fifo_order = out
            .records
            .windows(2)
            .all(|w| w[0].finish <= w[1].finish);
        assert!(!fifo_order, "LIFO under contention must reorder completions");
    }

    /// A one-block budget turns the 2-slot loop into serial service:
    /// admissions are deferred (not failed), every request still
    /// completes, and in-use blocks never exceed the budget.
    #[test]
    fn pool_budget_defers_admissions_and_serializes_the_loop() {
        // Arrival gaps (~1 ms at rate 1000) are far below a step's
        // virtual cost, so the whole trace contends for the one block.
        let mut w = PoissonOpen { rate: 1000.0, ..poisson() };
        let reqs = w.build(&mut Rng::new(5), 256);
        let sim = loop_for(2).with_pool_blocks(Some(1));
        let out = sim.run(reqs, &mut w, &mut Fcfs).unwrap();
        assert_eq!(out.records.len(), 5);
        assert!(out.deferred_admissions > 0, "contention must defer admissions");
        assert!(out.step_active.iter().all(|&a| a <= 1), "one block, one chain");
        let pool = out.kv_pool.expect("paged engine reports pool stats");
        assert!(pool.peak_blocks_in_use <= 1, "in-use may never exceed the budget");
        assert_eq!(pool.blocks_in_use, 0, "all blocks return at retirement");
    }

    #[test]
    fn pool_budget_smaller_than_one_chain_is_rejected_up_front() {
        let mut w = poisson();
        let reqs = w.build(&mut Rng::new(5), 256);
        let sim = loop_for(2).with_pool_blocks(Some(0));
        let err = sim.run(reqs, &mut w, &mut Fcfs).unwrap_err().to_string();
        assert!(err.contains("kv pool budget too small"), "{err}");
    }

    /// A budget the trace never reaches is a no-op: the gated run is
    /// identical to the ungated one, token for token and timestamp for
    /// timestamp.
    #[test]
    fn slack_pool_budget_is_bit_identical_to_no_budget() {
        let mut w = poisson();
        let reqs = w.build(&mut Rng::new(7), 256);
        let base = loop_for(2).run(reqs.clone(), &mut w, &mut Fcfs).unwrap();
        let gated = loop_for(2)
            .with_pool_blocks(Some(1000))
            .run(reqs, &mut w, &mut Fcfs)
            .unwrap();
        assert_eq!(base.sequences, gated.sequences);
        assert_eq!(base.step_t, gated.step_t);
        assert_eq!(base.step_active, gated.step_active);
        assert_eq!(base.output_tokens, gated.output_tokens);
        assert_eq!(gated.deferred_admissions, 0);
    }

    /// Impossible TTFT deadlines on part of the trace: the SLO-aware
    /// policy sheds exactly those requests (counted, zero output,
    /// outcome recorded), serves the rest, and the accounting conserves:
    /// served + shed + preempted = offered.
    #[test]
    fn slo_shed_retires_counted_records_and_conserves_the_trace() {
        use crate::coordinator::sim::SloAware;
        use crate::metrics::{Outcome, Slo, SloTier};
        let mut w = PoissonOpen { rate: 1000.0, ..poisson() };
        let mut reqs = w.build(&mut Rng::new(5), 256);
        for r in reqs.iter_mut().filter(|r| r.id % 2 == 1) {
            r.slo = Some(Slo {
                tier: SloTier::Interactive,
                ttft: 0.0,
                tpot: f64::INFINITY,
            });
        }
        let out = loop_for(2).run(reqs, &mut w, &mut SloAware::new()).unwrap();
        assert_eq!(out.records.len(), 5);
        let shed: Vec<_> =
            out.records.iter().filter(|r| r.outcome == Outcome::Shed).collect();
        assert_eq!(shed.len(), out.shed_requests);
        assert_eq!(out.shed_requests, 2, "both impossible-TTFT requests go");
        assert!(shed.iter().all(|r| r.output_tokens == 0 && r.target_tokens > 0));
        assert!(shed.iter().all(|r| !r.attained()));
        let served =
            out.records.iter().filter(|r| r.outcome == Outcome::Served).count();
        assert_eq!(served + out.shed_requests + out.preempted_requests, 5);
        assert!(out.records.iter().filter(|r| r.slo.is_none()).all(|r| r.attained()));
    }

    /// An unmeetable TPOT deadline on an admitted request: once queued
    /// work needs the slot, the SLO-aware policy preempts it — partial
    /// output recorded, slot freed for meetable requests.
    #[test]
    fn slo_preempt_frees_the_slot_for_meetable_work() {
        use crate::coordinator::sim::SloAware;
        use crate::metrics::{Outcome, Slo, SloTier};
        let mut w = PoissonOpen { rate: 1000.0, ..poisson() };
        let mut reqs = w.build(&mut Rng::new(5), 256);
        reqs[0].slo = Some(Slo {
            tier: SloTier::Interactive,
            ttft: f64::INFINITY,
            tpot: 0.0,
        });
        let out = loop_for(1).run(reqs, &mut w, &mut SloAware::new()).unwrap();
        assert_eq!(out.preempted_requests, 1);
        let p = out.records.iter().find(|r| r.outcome == Outcome::Preempted).unwrap();
        assert_eq!(p.id, 0);
        assert!(p.output_tokens < p.target_tokens, "partial output only");
        assert!(!p.attained());
        let served =
            out.records.iter().filter(|r| r.outcome == Outcome::Served).count();
        assert_eq!(served + out.shed_requests + out.preempted_requests, 5);
    }

    /// Three requests with the same prompt: sharing forks the cached
    /// prefix (copy-on-write) instead of re-prefilling it, and the
    /// generated tokens are identical to the unshared run — the KV at a
    /// position is a pure function of the tokens up to it.
    #[test]
    fn prefix_sharing_forks_cached_prompts_without_changing_tokens() {
        let prompt: Vec<u32> = vec![9, 120, 7, 44, 201, 63, 18, 5];
        let build = || -> Vec<Request> {
            (0..3)
                .map(|i| Request {
                    id: i,
                    // Staggered far below the step cost: request 0 is
                    // admitted alone, 1 and 2 find its cache warm.
                    arrival: Some(i as f64 * 1e-6),
                    prompt: prompt.clone(),
                    target_out: 3,
                    priority: 0,
                    session: None,
                    slo: None,
                })
                .collect()
        };
        let mut w = poisson(); // only its (empty) on_finish hook is used
        let plain = loop_for(2).run(build(), &mut w, &mut Fcfs).unwrap();
        let shared = loop_for(2)
            .with_prefix_share(true)
            .run(build(), &mut w, &mut Fcfs)
            .unwrap();
        assert_eq!(plain.sequences, shared.sequences, "sharing must not change tokens");
        let pool = shared.kv_pool.unwrap();
        assert!(pool.prefix_forks >= 1, "identical prompts must fork");
        assert!(pool.shared_tokens >= 1);
        assert!(pool.cow_copies >= 1, "writing past a shared prefix must copy");
        let replain = loop_for(2).run(build(), &mut w, &mut Fcfs).unwrap();
        assert_eq!(replain.kv_pool.unwrap().prefix_forks, 0);
    }

    /// A routed run fed the exact arrivals the workload stamped is
    /// bit-identical to the solo run: same sequences, same step clock,
    /// and the finish buffer reports every retirement in order.
    #[test]
    fn routed_mode_with_the_same_arrivals_matches_the_solo_run() {
        let mut w = poisson();
        let reqs = w.build(&mut Rng::new(7), 256);
        let solo = loop_for(2).run(reqs.clone(), &mut w, &mut Fcfs).unwrap();
        let mut run = loop_for(2).start_routed(reqs.clone(), &mut Fcfs).unwrap();
        for r in &reqs {
            run.push_arrival(r.id, r.arrival.unwrap()).unwrap();
        }
        assert_eq!(run.load(), reqs.len(), "everything pending, nothing busy");
        let mut fins = Vec::new();
        while run.tick_routed(&mut Fcfs).unwrap() != TickStatus::Idle {
            fins.extend(run.take_finishes());
        }
        assert!(run.drained());
        assert_eq!(run.load(), 0);
        assert_eq!(fins.len(), reqs.len());
        assert!(fins.windows(2).all(|w| w[0].0 <= w[1].0), "retirement order");
        let out = run.finish_routed();
        assert_eq!(out.routed, reqs.len());
        assert_eq!(out.sequences, solo.sequences, "same arrivals, same tokens");
        assert_eq!(out.step_t, solo.step_t, "same arrivals, same clock");
        assert_eq!(out.makespan_secs, solo.makespan_secs);
        assert_eq!(out.busy_secs, solo.busy_secs);
        for (id, rec) in out.records.iter().enumerate() {
            let rec = rec.as_ref().expect("every routed request retires");
            assert_eq!(rec.finish, solo.records[id].finish);
        }
    }

    /// Double-dispatch and out-of-range ids are rejected; solo runs
    /// refuse push_arrival outright.
    #[test]
    fn push_arrival_guards_the_routed_contract() {
        let mut w = poisson();
        let reqs = w.build(&mut Rng::new(7), 256);
        let mut solo = loop_for(2).start(reqs.clone(), &mut Fcfs).unwrap();
        assert!(solo.push_arrival(0, 0.0).is_err(), "solo runs own their arrivals");
        let mut run = loop_for(2).start_routed(reqs, &mut Fcfs).unwrap();
        assert!(run.push_arrival(99, 0.0).is_err(), "out of range");
        run.push_arrival(0, 0.0).unwrap();
        while run.tick_routed(&mut Fcfs).unwrap() != TickStatus::Idle {}
        assert!(run.push_arrival(0, run.now()).is_err(), "already retired here");
    }

    /// The daemon's pre-allocation pattern: start a routed run on
    /// placeholder requests, swap the real prompts in via `set_request`
    /// as they "arrive", and get sequences bit-identical to a solo run
    /// of the real trace — the step bound tracks the rewrites.
    #[test]
    fn set_request_rewrites_placeholders_to_match_the_solo_run() {
        let mut w = poisson();
        let reqs = w.build(&mut Rng::new(7), 256);
        let solo = loop_for(2).run(reqs.clone(), &mut w, &mut Fcfs).unwrap();
        let placeholders: Vec<Request> = (0..reqs.len())
            .map(|id| Request {
                id,
                arrival: None,
                prompt: vec![0],
                target_out: 1,
                priority: 0,
                session: None,
                slo: None,
            })
            .collect();
        let mut run = loop_for(2).start_routed(placeholders, &mut Fcfs).unwrap();
        for r in &reqs {
            run.set_request(r.id, r.prompt.clone(), r.target_out).unwrap();
            run.push_arrival(r.id, r.arrival.unwrap()).unwrap();
        }
        while run.tick_routed(&mut Fcfs).unwrap() != TickStatus::Idle {}
        for (id, seq) in solo.sequences.iter().enumerate() {
            assert_eq!(run.sequence(id), &seq[..], "request {id} tokens");
            assert!(run.record(id).is_some(), "request {id} retired");
        }
        let out = run.finish_routed();
        assert_eq!(out.sequences, solo.sequences);
        assert_eq!(out.output_tokens, solo.output_tokens);
    }

    /// Rewrites are refused once a request is dispatched or retired,
    /// and malformed bodies never reach the queue.
    #[test]
    fn set_request_guards_the_rewrite_window() {
        let mut w = poisson();
        let reqs = w.build(&mut Rng::new(7), 256);
        let mut solo = loop_for(2).start(reqs.clone(), &mut Fcfs).unwrap();
        assert!(solo.set_request(0, vec![1], 1).is_err(), "solo runs are immutable");
        let mut run = loop_for(2).start_routed(reqs, &mut Fcfs).unwrap();
        assert!(run.set_request(99, vec![1], 1).is_err(), "out of range");
        assert!(run.set_request(0, Vec::new(), 1).is_err(), "empty prompt");
        assert!(run.set_request(0, vec![1], 0).is_err(), "zero target");
        run.push_arrival(0, 0.0).unwrap();
        assert!(run.set_request(0, vec![1], 1).is_err(), "already dispatched");
        run.set_request(1, vec![4, 5], 2).unwrap();
        while run.tick_routed(&mut Fcfs).unwrap() != TickStatus::Idle {}
        assert!(run.set_request(0, vec![1], 1).is_err(), "already retired");
    }

    /// The fresh-machine span floor is monotone and convex-priced: the
    /// marginal token price never understates a longer span's cost, so
    /// `c1 + (len-1)·(c2-c1)` is a sound TTFT lower bound.
    #[test]
    fn span_floor_is_a_sound_lower_bound_on_prefill_cost() {
        let mut w = poisson();
        let reqs = w.build(&mut Rng::new(7), 256);
        let run = loop_for(2).start_routed(reqs, &mut Fcfs).unwrap();
        let c1 = run.span_floor_secs(1);
        let c2 = run.span_floor_secs(2);
        assert!(c1 > 0.0 && c2 > c1);
        for len in 3..32usize {
            let floor = c1 + (len as f64 - 1.0) * (c2 - c1);
            let actual = run.span_floor_secs(len);
            assert!(
                floor <= actual * (1.0 + 1e-12),
                "len {len}: floor {floor} exceeds actual single-step cost {actual}"
            );
        }
    }
}
