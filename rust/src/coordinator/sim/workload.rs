//! Built-in [`Workload`] implementations: Poisson open loop, closed
//! loop, multi-turn chat sessions, and the hostile non-stationary
//! trio — diurnal sine-modulated Poisson, flash-crowd burst, and
//! heavy-tailed prompt lengths (DESIGN.md §5).
//!
//! All of them draw request shapes from the seeded trace RNG in a fixed
//! documented order, so the token trace is a pure function of
//! (seed, params). `PoissonOpen` and `ClosedLoop` reproduce the PR-2
//! monolith's draws exactly — per request: prompt length, output
//! length, prompt tokens; then (Poisson) all arrival gaps — which is
//! what keeps the default `bench.json` bit-identical across the
//! trait split (the parity test in `coordinator/serve.rs`). The hostile
//! workloads keep the same shapes-then-arrivals framing with their own
//! documented draw orders; their tunables are compiled-in constants
//! ([`DIURNAL_AMPLITUDE`], [`DIURNAL_CYCLES`], [`FLASH_CROWD_MULTIPLIER`],
//! [`HEAVY_TAIL_SIGMA`]) so the workload key alone pins the trace.

use crate::util::rng::Rng;

use super::{Release, Request, SessionLink, Workload};

/// Exponential inter-arrival sample at `rate` events per second.
pub(crate) fn exp_sample(rng: &mut Rng, rate: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / rate
}

/// Draw one request's shape. The draw order (prompt length, output
/// length, prompt tokens) is the serialization format of the trace —
/// changing it invalidates every committed baseline.
fn draw_shape(
    rng: &mut Rng,
    prompt_len: (usize, usize),
    output_len: (usize, usize),
    vocab: usize,
) -> (Vec<u32>, usize) {
    let plen = rng.range_u64(prompt_len.0 as u64, prompt_len.1 as u64 + 1) as usize;
    let target_out = rng.range_u64(output_len.0 as u64, output_len.1 as u64 + 1) as usize;
    let prompt = (0..plen).map(|_| rng.below(vocab as u64) as u32).collect();
    (prompt, target_out)
}

/// Open loop: `n` requests arriving as a Poisson process at `rate`
/// req/s, every arrival known up front.
#[derive(Clone, Debug)]
pub struct PoissonOpen {
    pub rate: f64,
    pub n: usize,
    pub prompt_len: (usize, usize),
    pub output_len: (usize, usize),
}

impl Workload for PoissonOpen {
    fn label(&self) -> &'static str {
        "poisson"
    }

    fn build(&mut self, rng: &mut Rng, vocab: usize) -> Vec<Request> {
        // Shapes first, arrivals second — the monolith's draw order.
        let mut reqs: Vec<Request> = (0..self.n)
            .map(|id| {
                let (prompt, target_out) =
                    draw_shape(rng, self.prompt_len, self.output_len, vocab);
                Request {
                    id,
                    arrival: None,
                    prompt,
                    target_out,
                    priority: 0,
                    session: None,
                    slo: None,
                }
            })
            .collect();
        let mut t = 0.0;
        for r in reqs.iter_mut() {
            t += exp_sample(rng, self.rate);
            r.arrival = Some(t);
        }
        reqs
    }
}

/// Closed loop: `clients` users, each submitting its next request the
/// moment the previous one finishes (arrival = completion time).
#[derive(Clone, Debug)]
pub struct ClosedLoop {
    pub clients: usize,
    pub n: usize,
    pub prompt_len: (usize, usize),
    pub output_len: (usize, usize),
    submitted: usize,
}

impl ClosedLoop {
    pub fn new(clients: usize, n: usize, prompt_len: (usize, usize), output_len: (usize, usize)) -> Self {
        Self {
            clients,
            n,
            prompt_len,
            output_len,
            submitted: 0,
        }
    }
}

impl Workload for ClosedLoop {
    fn label(&self) -> &'static str {
        "closed"
    }

    fn build(&mut self, rng: &mut Rng, vocab: usize) -> Vec<Request> {
        let mut reqs: Vec<Request> = (0..self.n)
            .map(|id| {
                let (prompt, target_out) =
                    draw_shape(rng, self.prompt_len, self.output_len, vocab);
                Request {
                    id,
                    arrival: None,
                    prompt,
                    target_out,
                    priority: 0,
                    session: None,
                    slo: None,
                }
            })
            .collect();
        // Each client submits its first request at t = 0.
        self.submitted = self.clients.min(self.n);
        for r in reqs.iter_mut().take(self.submitted) {
            r.arrival = Some(0.0);
        }
        reqs
    }

    fn on_finish(&mut self, _finished: usize, now: f64) -> Vec<Release> {
        if self.submitted < self.n {
            let id = self.submitted;
            self.submitted += 1;
            vec![Release { id, arrival: now }]
        } else {
            Vec::new()
        }
    }
}

/// Multi-turn chat sessions (the interactive edge workload of
/// 2503.09114): `sessions` conversations arrive as a Poisson process at
/// `rate`; each has `turns ∈ [lo, hi]` turns. A turn is one request —
/// its *delta* prompt (the new user message) plus `target_out` output
/// tokens. Follow-up turns arrive `Exp(rate)` think-time after the
/// previous turn finishes and inherit their session's engine slot, so
/// the conversation prefix already in that slot's KV is **reused**, not
/// re-prefilled — the loop reports the saved tokens as
/// [`KvReuse`](super::KvReuse).
///
/// Draw order per session: turn count; then per turn: delta-prompt
/// length, output length, think-time gap (turns > 0), prompt tokens.
/// After all sessions: the session arrival gaps. Request ids are
/// assigned in (session, turn) order, so a session's turns are
/// contiguous.
#[derive(Clone, Debug)]
pub struct ChatSessions {
    pub rate: f64,
    pub sessions: usize,
    pub turns: (usize, usize),
    pub prompt_len: (usize, usize),
    pub output_len: (usize, usize),
    /// Think-time before each request's arrival (0.0 for first turns);
    /// indexed by request id, filled by `build`.
    think: Vec<f64>,
    /// Successor request id per request id, filled by `build`.
    next_of: Vec<Option<usize>>,
}

impl ChatSessions {
    pub fn new(
        rate: f64,
        sessions: usize,
        turns: (usize, usize),
        prompt_len: (usize, usize),
        output_len: (usize, usize),
    ) -> Self {
        Self {
            rate,
            sessions,
            turns,
            prompt_len,
            output_len,
            think: Vec::new(),
            next_of: Vec::new(),
        }
    }
}

impl Workload for ChatSessions {
    fn label(&self) -> &'static str {
        "chat"
    }

    fn build(&mut self, rng: &mut Rng, vocab: usize) -> Vec<Request> {
        let mut reqs = Vec::new();
        self.think.clear();
        self.next_of.clear();
        let mut first_turn_ids = Vec::with_capacity(self.sessions);
        for session in 0..self.sessions {
            let nturns =
                rng.range_u64(self.turns.0 as u64, self.turns.1 as u64 + 1) as usize;
            first_turn_ids.push(reqs.len());
            for turn in 0..nturns {
                let id = reqs.len();
                let plen = rng
                    .range_u64(self.prompt_len.0 as u64, self.prompt_len.1 as u64 + 1)
                    as usize;
                let target_out = rng
                    .range_u64(self.output_len.0 as u64, self.output_len.1 as u64 + 1)
                    as usize;
                let think = if turn > 0 { exp_sample(rng, self.rate) } else { 0.0 };
                let prompt = (0..plen).map(|_| rng.below(vocab as u64) as u32).collect();
                // One computation feeds both the loop's parking link
                // (SessionLink::next) and on_finish's release table, so
                // the two can never drift apart.
                let next = if turn + 1 < nturns { Some(id + 1) } else { None };
                self.think.push(think);
                self.next_of.push(next);
                reqs.push(Request {
                    id,
                    arrival: None,
                    prompt,
                    target_out,
                    priority: 0,
                    session: Some(SessionLink { session, turn, next }),
                    slo: None,
                });
            }
        }
        // Session arrivals last, mirroring the open-loop draw order.
        let mut t = 0.0;
        for &first in &first_turn_ids {
            t += exp_sample(rng, self.rate);
            reqs[first].arrival = Some(t);
        }
        reqs
    }

    fn on_finish(&mut self, finished: usize, now: f64) -> Vec<Release> {
        match self.next_of.get(finished).copied().flatten() {
            Some(next) => vec![Release {
                id: next,
                arrival: now + self.think[next],
            }],
            None => Vec::new(),
        }
    }
}

/// Peak-to-mean modulation of the diurnal rate: λ(t) swings ±80% around
/// the base rate.
pub const DIURNAL_AMPLITUDE: f64 = 0.8;

/// Full sine cycles the diurnal pattern completes over the trace's
/// expected stationary span (`n / rate` seconds).
pub const DIURNAL_CYCLES: f64 = 2.0;

/// Arrival-rate multiplier during the flash-crowd burst window (the
/// middle 50% of requests).
pub const FLASH_CROWD_MULTIPLIER: f64 = 8.0;

/// Log-normal shape parameter σ for heavy-tailed prompt lengths.
pub const HEAVY_TAIL_SIGMA: f64 = 0.75;

/// Open loop with diurnal (sine-modulated) Poisson arrivals: the
/// instantaneous rate is
///
/// ```text
///   λ(t) = rate · (1 + A · sin(2π · C · t / span)),  span = n / rate
/// ```
///
/// with `A =` [`DIURNAL_AMPLITUDE`] and `C =` [`DIURNAL_CYCLES`], sampled
/// by thinning against the envelope `rate · (1 + A)`. Draw order: all
/// request shapes first (same per-request order as [`PoissonOpen`]),
/// then the thinned arrival stream — one gap draw plus one acceptance
/// draw per *candidate* event, so the trace is still a pure function of
/// (seed, params).
#[derive(Clone, Debug)]
pub struct DiurnalPoisson {
    pub rate: f64,
    pub n: usize,
    pub prompt_len: (usize, usize),
    pub output_len: (usize, usize),
}

impl Workload for DiurnalPoisson {
    fn label(&self) -> &'static str {
        "diurnal"
    }

    fn build(&mut self, rng: &mut Rng, vocab: usize) -> Vec<Request> {
        let mut reqs: Vec<Request> = (0..self.n)
            .map(|id| {
                let (prompt, target_out) =
                    draw_shape(rng, self.prompt_len, self.output_len, vocab);
                Request {
                    id,
                    arrival: None,
                    prompt,
                    target_out,
                    priority: 0,
                    session: None,
                    slo: None,
                }
            })
            .collect();
        let span = self.n as f64 / self.rate;
        let rate_max = self.rate * (1.0 + DIURNAL_AMPLITUDE);
        let mut t = 0.0;
        for r in reqs.iter_mut() {
            loop {
                t += exp_sample(rng, rate_max);
                let phase = 2.0 * std::f64::consts::PI * DIURNAL_CYCLES * t / span;
                let lambda = self.rate * (1.0 + DIURNAL_AMPLITUDE * phase.sin());
                // Thinning acceptance: keep the candidate with
                // probability λ(t) / λ_max (λ ≥ 0 since A ≤ 1).
                if rng.next_f64() * rate_max <= lambda {
                    break;
                }
            }
            r.arrival = Some(t);
        }
        reqs
    }
}

/// Open loop with a flash-crowd burst: the first quarter of requests
/// arrive at the base rate, the middle half at
/// [`FLASH_CROWD_MULTIPLIER`]`× rate`, and the final quarter at the base
/// rate again — a queue that builds faster than it can drain, then
/// releases. Draw order: all shapes first, then one gap per request at
/// that request's regime rate.
#[derive(Clone, Debug)]
pub struct FlashCrowd {
    pub rate: f64,
    pub n: usize,
    pub prompt_len: (usize, usize),
    pub output_len: (usize, usize),
}

impl Workload for FlashCrowd {
    fn label(&self) -> &'static str {
        "flash-crowd"
    }

    fn build(&mut self, rng: &mut Rng, vocab: usize) -> Vec<Request> {
        let mut reqs: Vec<Request> = (0..self.n)
            .map(|id| {
                let (prompt, target_out) =
                    draw_shape(rng, self.prompt_len, self.output_len, vocab);
                Request {
                    id,
                    arrival: None,
                    prompt,
                    target_out,
                    priority: 0,
                    session: None,
                    slo: None,
                }
            })
            .collect();
        let burst = self.n / 4..self.n - self.n / 4;
        let mut t = 0.0;
        for (i, r) in reqs.iter_mut().enumerate() {
            let rate = if burst.contains(&i) {
                self.rate * FLASH_CROWD_MULTIPLIER
            } else {
                self.rate
            };
            t += exp_sample(rng, rate);
            r.arrival = Some(t);
        }
        reqs
    }
}

/// Open loop with heavy-tailed (log-normal) prompt lengths: Poisson
/// arrivals at the base rate, but each prompt length is drawn as
///
/// ```text
///   plen = clamp(round(lo · e^(σ·z)), lo, hi),   z ~ N(0, 1)
/// ```
///
/// with `σ =` [`HEAVY_TAIL_SIGMA`] and `(lo, hi)` the configured prompt
/// bounds — median `lo`, a long right tail toward `hi`. Draw order per
/// request: two uniforms for the Box–Muller normal, output length,
/// prompt tokens; then all Poisson arrival gaps.
#[derive(Clone, Debug)]
pub struct HeavyTail {
    pub rate: f64,
    pub n: usize,
    pub prompt_len: (usize, usize),
    pub output_len: (usize, usize),
}

impl Workload for HeavyTail {
    fn label(&self) -> &'static str {
        "heavy-tail"
    }

    fn build(&mut self, rng: &mut Rng, vocab: usize) -> Vec<Request> {
        let mut reqs: Vec<Request> = (0..self.n)
            .map(|id| {
                let u1 = 1.0 - rng.next_f64(); // (0, 1]: ln never sees 0
                let u2 = rng.next_f64();
                let z = (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
                let (lo, hi) = (self.prompt_len.0 as f64, self.prompt_len.1 as f64);
                let plen = (lo * (HEAVY_TAIL_SIGMA * z).exp()).round().clamp(lo, hi) as usize;
                let target_out = rng
                    .range_u64(self.output_len.0 as u64, self.output_len.1 as u64 + 1)
                    as usize;
                let prompt = (0..plen).map(|_| rng.below(vocab as u64) as u32).collect();
                Request {
                    id,
                    arrival: None,
                    prompt,
                    target_out,
                    priority: 0,
                    session: None,
                    slo: None,
                }
            })
            .collect();
        let mut t = 0.0;
        for r in reqs.iter_mut() {
            t += exp_sample(rng, self.rate);
            r.arrival = Some(t);
        }
        reqs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_build_is_deterministic_with_sorted_arrivals() {
        let mut w = PoissonOpen {
            rate: 4.0,
            n: 16,
            prompt_len: (2, 5),
            output_len: (1, 3),
        };
        let a = w.build(&mut Rng::new(7), 256);
        let b = w.build(&mut Rng::new(7), 256);
        assert_eq!(a.len(), 16);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.target_out, y.target_out);
            assert_eq!(x.arrival, y.arrival);
        }
        for (i, r) in a.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!((2..=5).contains(&r.prompt.len()));
            assert!((1..=3).contains(&r.target_out));
            assert!(r.session.is_none());
        }
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(w.on_finish(0, 1.0).is_empty(), "open loop releases nothing");
    }

    #[test]
    fn closed_loop_releases_one_successor_per_finish() {
        let mut w = ClosedLoop::new(2, 5, (2, 3), (1, 2));
        let reqs = w.build(&mut Rng::new(3), 256);
        assert_eq!(reqs[0].arrival, Some(0.0));
        assert_eq!(reqs[1].arrival, Some(0.0));
        assert!(reqs[2..].iter().all(|r| r.arrival.is_none()));
        let rel = w.on_finish(0, 1.5);
        assert_eq!(rel.len(), 1);
        assert_eq!((rel[0].id, rel[0].arrival), (2, 1.5));
        assert_eq!(w.on_finish(1, 2.0)[0].id, 3);
        assert_eq!(w.on_finish(2, 2.5)[0].id, 4);
        assert!(w.on_finish(3, 3.0).is_empty(), "all submitted");
    }

    #[test]
    fn diurnal_is_deterministic_with_sorted_arrivals() {
        let mut w = DiurnalPoisson {
            rate: 4.0,
            n: 32,
            prompt_len: (2, 5),
            output_len: (1, 3),
        };
        let a = w.build(&mut Rng::new(7), 256);
        let b = w.build(&mut Rng::new(7), 256);
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.arrival, y.arrival);
        }
        for (i, r) in a.iter().enumerate() {
            assert_eq!(r.id, i);
            assert!((2..=5).contains(&r.prompt.len()));
            assert!(r.slo.is_none() && r.session.is_none());
        }
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(w.on_finish(0, 1.0).is_empty(), "open loop releases nothing");
    }

    #[test]
    fn flash_crowd_compresses_the_middle_gaps() {
        let n = 64;
        let mut w = FlashCrowd {
            rate: 2.0,
            n,
            prompt_len: (2, 3),
            output_len: (1, 2),
        };
        let reqs = w.build(&mut Rng::new(5), 256);
        let arr: Vec<f64> = reqs.iter().map(|r| r.arrival.unwrap()).collect();
        assert!(arr.windows(2).all(|w| w[0] <= w[1]));
        let gap_mean = |lo: usize, hi: usize| {
            let gaps: Vec<f64> = (lo.max(1)..hi).map(|i| arr[i] - arr[i - 1]).collect();
            gaps.iter().sum::<f64>() / gaps.len() as f64
        };
        let outer = (gap_mean(0, n / 4) + gap_mean(3 * n / 4, n)) / 2.0;
        let burst = gap_mean(n / 4, 3 * n / 4);
        assert!(
            burst < outer / 2.0,
            "burst gaps ({burst:.3}s) should be far below base gaps ({outer:.3}s)"
        );
    }

    #[test]
    fn heavy_tail_prompts_are_clamped_and_right_skewed() {
        let mut w = HeavyTail {
            rate: 4.0,
            n: 256,
            prompt_len: (4, 64),
            output_len: (1, 2),
        };
        let reqs = w.build(&mut Rng::new(9), 256);
        let lens: Vec<usize> = reqs.iter().map(|r| r.prompt.len()).collect();
        assert!(lens.iter().all(|&l| (4..=64).contains(&l)));
        let mut sorted = lens.clone();
        sorted.sort_unstable();
        let median = sorted[sorted.len() / 2] as f64;
        let mean = lens.iter().sum::<usize>() as f64 / lens.len() as f64;
        assert!(
            mean > median,
            "log-normal lengths are right-skewed (mean {mean:.1} ≤ median {median})"
        );
        assert!(sorted[sorted.len() - 1] > sorted[0], "tail is exercised");
        let again = w.build(&mut Rng::new(9), 256);
        assert!(reqs.iter().zip(&again).all(|(a, b)| a.prompt == b.prompt));
    }

    #[test]
    fn chat_sessions_link_contiguous_turns_with_think_time() {
        let mut w = ChatSessions::new(4.0, 6, (2, 4), (2, 5), (1, 3));
        let reqs = w.build(&mut Rng::new(11), 256);
        assert!(reqs.len() >= 12, "6 sessions × ≥2 turns");
        for r in &reqs {
            let s = r.session.expect("every chat request belongs to a session");
            if s.turn == 0 {
                assert!(r.arrival.is_some(), "first turns arrive by Poisson");
            } else {
                assert!(r.arrival.is_none(), "follow-ups are released on finish");
            }
            match s.next {
                Some(next) => {
                    assert_eq!(next, r.id + 1, "turns are contiguous");
                    assert_eq!(reqs[next].session.unwrap().session, s.session);
                    assert_eq!(reqs[next].session.unwrap().turn, s.turn + 1);
                }
                None => {
                    // Last turn of its session: the next request (if any)
                    // starts a new session.
                    if let Some(n) = reqs.get(r.id + 1) {
                        assert_eq!(n.session.unwrap().turn, 0);
                    }
                }
            }
        }
        // A finished non-final turn releases exactly its successor, with
        // positive think-time; a final turn releases nothing.
        let non_final = reqs.iter().find(|r| r.session.unwrap().next.is_some()).unwrap();
        let rel = w.on_finish(non_final.id, 10.0);
        assert_eq!(rel.len(), 1);
        assert_eq!(rel[0].id, non_final.id + 1);
        assert!(rel[0].arrival > 10.0, "think time must be positive");
        let final_turn = reqs.iter().find(|r| r.session.unwrap().next.is_none()).unwrap();
        assert!(w.on_finish(final_turn.id, 10.0).is_empty());
    }
}
