//! The automatic quantization flow (Algorithm 1, Ln. 2): one original
//! f32 model in, one EGUF file per requested scheme out, with
//! reconstruction-error accounting per tensor.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::gguf::ModelFile;
use crate::model::testutil::DenseWeights;
use crate::model::{testutil, LlamaConfig};
use crate::quant::{measure_error, QuantType};
use crate::util::json::Json;

/// One quantized model the flow produced.
#[derive(Clone, Debug)]
pub struct QuantizedModel {
    pub qtype: QuantType,
    pub path: PathBuf,
    pub file_bytes: u64,
    pub n_params: u64,
    /// Worst relative RMSE across projection tensors (accuracy early
    /// signal, before any perplexity run).
    pub max_rel_rmse: f64,
}

/// Extract dense f32 weights (+ config) from the original EGUF.
pub fn load_original(path: &Path) -> Result<(LlamaConfig, DenseWeights)> {
    let mf = ModelFile::load(path).context("load original model")?;
    let config = LlamaConfig::from_json(
        mf.meta
            .get("config")
            .context("original model meta missing config")?,
    )?;
    let mut dense = DenseWeights::new();
    for (name, t) in &mf.tensors {
        dense.insert(name.clone(), (t.dequantize(), t.rows, t.cols));
    }
    Ok((config, dense))
}

/// Run the flow: quantize `dense` into every scheme, write
/// `<out_dir>/tiny_llama_<scheme>.eguf`.
pub fn quantization_flow(
    config: &LlamaConfig,
    dense: &DenseWeights,
    schemes: &[QuantType],
    out_dir: &Path,
) -> Result<Vec<QuantizedModel>> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("create {}", out_dir.display()))?;
    let mut out = Vec::with_capacity(schemes.len());
    for &q in schemes {
        let mf = testutil::build_model_file(config, q, dense);
        let path = out_dir.join(format!("tiny_llama_{}.eguf", q.name()));
        mf.save(&path)?;
        let mut max_rel = 0.0f64;
        for (name, (data, _, _)) in dense {
            if name.contains("norm") {
                continue;
            }
            let e = measure_error(q, data);
            max_rel = max_rel.max(e.relative_rmse);
        }
        out.push(QuantizedModel {
            qtype: q,
            file_bytes: mf.tensor_bytes(),
            n_params: mf.n_parameters(),
            path,
            max_rel_rmse: max_rel,
        });
    }
    Ok(out)
}

/// Flow summary as JSON (persisted next to the models).
pub fn flow_report(models: &[QuantizedModel]) -> Json {
    Json::Arr(
        models
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("qtype", Json::Str(m.qtype.name().into())),
                    ("path", Json::Str(m.path.display().to_string())),
                    ("file_bytes", Json::Num(m.file_bytes as f64)),
                    ("n_params", Json::Num(m.n_params as f64)),
                    ("max_rel_rmse", Json::Num(m.max_rel_rmse)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::{random_weights, tensor_specs};
    use crate::quant::QuantType;

    fn tmpdir(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join("elib-flow-tests").join(name);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn flow_produces_all_schemes_ordered_by_size() {
        let cfg = LlamaConfig::tiny();
        let dense = random_weights(&cfg, 5);
        let out = tmpdir("all");
        let models =
            quantization_flow(&cfg, &dense, &QuantType::PAPER_SET, &out).unwrap();
        assert_eq!(models.len(), 5);
        for w in models.windows(2) {
            assert!(w[0].file_bytes < w[1].file_bytes, "sizes must increase");
            assert!(
                w[0].max_rel_rmse > w[1].max_rel_rmse,
                "error must decrease: {:?}",
                models.iter().map(|m| m.max_rel_rmse).collect::<Vec<_>>()
            );
        }
        // Files are loadable and carry the right format.
        for m in &models {
            let mf = ModelFile::load(&m.path).unwrap();
            assert_eq!(
                mf.get("layers.0.wq").unwrap().qtype,
                m.qtype,
                "{}",
                m.qtype.name()
            );
        }
    }

    #[test]
    fn original_roundtrip_through_flow_input() {
        let cfg = LlamaConfig::tiny();
        let dense = random_weights(&cfg, 6);
        let mf = testutil::build_model_file(&cfg, QuantType::F32, &dense);
        let p = tmpdir("orig").join("orig.eguf");
        mf.save(&p).unwrap();
        let (cfg2, dense2) = load_original(&p).unwrap();
        assert_eq!(cfg, cfg2);
        assert_eq!(dense.len(), dense2.len());
        assert_eq!(dense2.len(), tensor_specs(&cfg).len());
        let (a, _, _) = &dense["layers.0.wq"];
        let (b, _, _) = &dense2["layers.0.wq"];
        assert_eq!(a, b, "f32 container must be lossless");
    }
}
