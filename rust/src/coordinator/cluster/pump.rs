//! The deterministic cluster pump (DESIGN.md §9).
//!
//! Each replica is an actor: private state (its own routed
//! [`SimRun`] — engine, `DeviceClock`, scheduler — nothing shared),
//! a typed mailbox of [`ReplicaMsg`]s, and no channel to any other
//! replica. All coordination flows through the pump, which owns the
//! one global virtual-time event queue:
//!
//! ```text
//!             pending (t, id) — global arrival queue
//!                        │ next dispatch instant t*
//!          ┌─────────────┴──────────────┐
//!          ▼ run_until(t*)              ▼ run_until(t*)
//!   ┌────────────┐              ┌────────────┐
//!   │ replica 0  │   mailbox    │ replica 1  │   …
//!   │ SimRun ◀───┼── Dispatch ──┼──▶ SimRun  │
//!   └─────┬──────┘              └─────┬──────┘
//!         │ take_finishes()           │
//!         └──────────┬────────────────┘
//!                    ▼ sorted by (time, replica, order)
//!            Workload::on_finish ──▶ new releases into `pending`
//! ```
//!
//! Determinism argument: every step below is a pure function of the
//! seeded trace and fixed orderings, never of wall-clock or thread
//! interleaving. (1) The pump drives replicas *in fleet order* up to
//! the next dispatch instant; each replica's virtual clock advances by
//! priced engine steps only. (2) Completions from all replicas are
//! merged and fed to the (order-sensitive) global workload in the
//! total order (virtual time, replica index, per-replica retirement
//! order). (3) Released arrivals are inserted into the global queue by
//! (time, id). (4) The router sees snapshots taken at the same virtual
//! instant and is itself deterministic. `--threads` parallelizes
//! *across policies* (disjoint pumps), so `cluster.json` is bit-for-bit
//! identical at any thread count — the property the cluster determinism
//! test locks in.

use anyhow::Result;

use crate::coordinator::sim::{Request, Scheduler, SimRun, TickStatus, Workload};

use super::router::{ReplicaView, Router};
use super::Tier;

/// Message a replica actor accepts. The router dispatches a request at
/// a virtual arrival time; a chat follow-up turn that migrated from
/// another replica carries the bridge token recovered from the
/// origin's parked slot (its delta prompt would otherwise be missing
/// the previous turn's final output, which was never fed anywhere).
#[derive(Clone, Copy, Debug)]
pub(super) enum ReplicaMsg {
    Dispatch {
        id: usize,
        arrival: f64,
        bridge: Option<u32>,
    },
}

/// One replica actor: name + tier for reporting, the routed run, its
/// own scheduler, the pre-tick TTFT floor coefficients, and the
/// mailbox the pump delivers into.
pub(super) struct ReplicaActor {
    pub name: String,
    pub tier: Tier,
    pub run: SimRun,
    scheduler: Box<dyn Scheduler>,
    floor_c1: f64,
    floor_marginal: f64,
    mailbox: Vec<ReplicaMsg>,
}

impl ReplicaActor {
    /// Wrap a freshly started routed run. Must be called before the
    /// run's first tick: the TTFT floor coefficients are fresh-engine
    /// span prices, only meaningful while the cache is empty and the
    /// thermal state cold.
    pub fn new(name: String, tier: Tier, run: SimRun, scheduler: Box<dyn Scheduler>) -> Self {
        let c1 = run.span_floor_secs(1);
        let marginal = run.span_floor_secs(2) - c1;
        Self {
            name,
            tier,
            run,
            scheduler,
            floor_c1: c1,
            floor_marginal: marginal,
            mailbox: Vec::new(),
        }
    }

    pub fn send(&mut self, msg: ReplicaMsg) {
        self.mailbox.push(msg);
    }

    /// Drain the mailbox into the run, in delivery order.
    pub fn process_mailbox(&mut self) -> Result<()> {
        for msg in std::mem::take(&mut self.mailbox) {
            match msg {
                ReplicaMsg::Dispatch { id, arrival, bridge } => {
                    if let Some(tok) = bridge {
                        self.run.prepend_prompt(id, tok);
                    }
                    self.run.push_arrival(id, arrival)?;
                }
            }
        }
        Ok(())
    }

    /// Tick the run until its virtual clock reaches `target` or it has
    /// nothing left to do.
    pub fn run_until(&mut self, target: f64) -> Result<()> {
        while self.run.now() < target {
            if self.run.tick_routed(self.scheduler.as_mut())? == TickStatus::Idle {
                break;
            }
        }
        Ok(())
    }

    pub fn view(&self, index: usize) -> ReplicaView {
        ReplicaView {
            index,
            tier: self.tier,
            load: self.run.load(),
            floor_c1: self.floor_c1,
            floor_marginal: self.floor_marginal,
        }
    }

    /// Consume the actor, keeping the run for `finish_routed`.
    pub fn into_run(self) -> SimRun {
        self.run
    }
}

/// Drive the whole fleet to completion: admit the global trace in
/// arrival order, route each request as its timestamp comes due, feed
/// retirements to the workload in global order, and insert any
/// released follow-ups back into the queue. Returns once every replica
/// has drained.
pub(super) fn pump(
    requests: &[Request],
    workload: &mut dyn Workload,
    router: &mut dyn Router,
    replicas: &mut [ReplicaActor],
) -> Result<()> {
    anyhow::ensure!(!replicas.is_empty(), "cluster pump needs at least one replica");
    // Global arrival queue, (time, id)-sorted; dynamically released
    // requests are inserted behind the cursor as they appear.
    let mut pending: Vec<(f64, usize)> = requests
        .iter()
        .filter_map(|r| r.arrival.map(|t| (t, r.id)))
        .collect();
    pending.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
    let mut cursor = 0usize;
    // Which replica retired the turn preceding each request (chat
    // linkage): set when a turn with `session.next` finishes, consumed
    // when the follow-up is dispatched — possibly to another replica,
    // in which case the origin's parked slot is cancelled and the
    // bridge token migrates with the request.
    let mut origin: Vec<Option<usize>> = vec![None; requests.len()];
    loop {
        let target = pending.get(cursor).map_or(f64::INFINITY, |p| p.0);
        // Drive every replica up to the dispatch instant, in fleet
        // order, and collect retirements.
        let mut fins: Vec<(f64, usize, usize, usize)> = Vec::new();
        for (ri, rep) in replicas.iter_mut().enumerate() {
            rep.run_until(target)?;
            for (order, (t, rid)) in rep.run.take_finishes().into_iter().enumerate() {
                fins.push((t, ri, order, rid));
            }
        }
        if !fins.is_empty() {
            // Global retirement order: (virtual time, replica index,
            // per-replica order). The workload may be order-sensitive
            // (closed-loop counters), so this order is part of the
            // determinism contract.
            fins.sort_by(|a, b| {
                a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
            });
            for (t, ri, _, rid) in fins {
                if let Some(next) = requests[rid].session.and_then(|s| s.next) {
                    origin[next] = Some(ri);
                }
                for rel in workload.on_finish(rid, t) {
                    anyhow::ensure!(
                        rel.id < requests.len(),
                        "workload released unknown request {}",
                        rel.id
                    );
                    let at = pending[cursor..].partition_point(|&(pt, pid)| {
                        pt < rel.arrival || (pt == rel.arrival && pid < rel.id)
                    });
                    pending.insert(cursor + at, (rel.arrival, rel.id));
                }
            }
            // Releases may predate the old target; recompute it.
            continue;
        }
        if cursor >= pending.len() {
            break;
        }
        let (t, id) = pending[cursor];
        cursor += 1;
        let views: Vec<ReplicaView> = replicas
            .iter()
            .enumerate()
            .map(|(i, r)| r.view(i))
            .collect();
        let choice = router.route(&requests[id], &views);
        anyhow::ensure!(
            choice < replicas.len(),
            "router `{}` returned replica {choice} of {}",
            router.label(),
            replicas.len()
        );
        // Chat follow-up migrating off its origin: the parked slot
        // there will never be claimed — cancel it and carry the bridge.
        let bridge = match origin[id] {
            Some(o) if o != choice => replicas[o].run.cancel_park(id),
            _ => None,
        };
        replicas[choice].send(ReplicaMsg::Dispatch { id, arrival: t, bridge });
        replicas[choice].process_mailbox()?;
    }
    for rep in replicas.iter() {
        anyhow::ensure!(
            rep.run.drained(),
            "replica {} stalled with unretired work after the trace drained",
            rep.name
        );
    }
    Ok(())
}
