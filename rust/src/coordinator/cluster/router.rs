//! Routing policies for the simulated cluster (DESIGN.md §9).
//!
//! A [`Router`] sees one request at a time plus a deterministic
//! [`ReplicaView`] snapshot per replica — refreshed by the pump after
//! every replica has been driven up to the dispatch instant — and
//! answers with a replica index. Policies are pure functions of
//! (dispatch order, snapshots, own state), so a seeded cluster run
//! routes identically on every machine and `--threads` value.

use std::collections::BTreeMap;

use crate::coordinator::sim::Request;

use super::Tier;

/// What the router sees of one replica at dispatch time.
#[derive(Clone, Copy, Debug)]
pub struct ReplicaView {
    /// Index into the fleet (the value `route` returns).
    pub index: usize,
    pub tier: Tier,
    /// Outstanding work: queued + pending-dispatch + busy slots
    /// ([`SimRun::load`](crate::coordinator::sim::SimRun::load)).
    pub load: usize,
    /// Fresh-engine price of a 1-token prefill step, virtual seconds
    /// (`span_floor_secs(1)`, captured before the replica's first tick).
    pub floor_c1: f64,
    /// Marginal fresh-engine price of one extra prompt token in the
    /// same span (`span_floor_secs(2) - span_floor_secs(1)`).
    pub floor_marginal: f64,
}

impl ReplicaView {
    /// Provable lower bound on this replica's TTFT for a `plen`-token
    /// prompt:
    ///
    /// ```text
    ///   min_ttft(plen) = c1 + (plen − 1)·(c2 − c1)
    /// ```
    ///
    /// A fresh single-step prefill of `L` tokens prices as
    /// `a + bL + cL²` on the roofline (weights streamed once per step,
    /// linear FLOPs, quadratic attention), and the line through the
    /// `L = 1` and `L = 2` points under-estimates every `L ≥ 2` of
    /// that convex curve (`est − cost = −c(L−1)(L−2) ≤ 0`). Queueing,
    /// cached context, batch companions, chunked multi-step prefill
    /// (weights re-streamed per chunk) and thermal derating only add
    /// cost, so no schedule on this replica can beat the bound.
    pub fn ttft_floor(&self, plen: usize) -> f64 {
        self.floor_c1 + plen.saturating_sub(1) as f64 * self.floor_marginal
    }
}

/// A cluster routing policy: assigns each dispatched request to a
/// replica. Stateful (round-robin cursors, session pins) but strictly
/// deterministic.
pub trait Router {
    /// Stable policy name (`cluster.json` key).
    fn label(&self) -> &'static str;

    /// Pick the replica for `req`. `replicas` holds one view per fleet
    /// member, in fleet order; the return value must be a valid
    /// `ReplicaView::index`.
    fn route(&mut self, req: &Request, replicas: &[ReplicaView]) -> usize;

    /// How many requests the deadline certificate spilled to the cloud
    /// tier (0 for every policy but deadline-offload).
    fn offloaded(&self) -> usize {
        0
    }
}

/// Least-load choice with a lowest-index tie-break (the comparator is
/// total, so `min_by` cannot fall into its last-of-equals behavior).
fn least_load<'a>(views: impl Iterator<Item = &'a ReplicaView>) -> Option<usize> {
    views
        .min_by(|a, b| a.load.cmp(&b.load).then(a.index.cmp(&b.index)))
        .map(|v| v.index)
}

/// Dispatch-order rotation over the fleet, blind to load.
struct RoundRobin {
    next: usize,
}

impl Router for RoundRobin {
    fn label(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _req: &Request, replicas: &[ReplicaView]) -> usize {
        let i = self.next % replicas.len();
        self.next += 1;
        replicas[i].index
    }
}

/// Smallest outstanding-work snapshot wins; ties to the lowest index.
struct LeastQueue;

impl Router for LeastQueue {
    fn label(&self) -> &'static str {
        "least-queue"
    }

    fn route(&mut self, _req: &Request, replicas: &[ReplicaView]) -> usize {
        least_load(replicas.iter()).expect("route needs at least one replica")
    }
}

/// Pin each chat session to the replica its first turn landed on, so
/// follow-up turns claim the parked slot and reuse the session's KV
/// prefix instead of re-prefilling on a cold replica. Sessionless
/// requests (and first turns) go least-load.
struct SessionAffinity {
    pins: BTreeMap<usize, usize>,
}

impl Router for SessionAffinity {
    fn label(&self) -> &'static str {
        "session-affinity"
    }

    fn route(&mut self, req: &Request, replicas: &[ReplicaView]) -> usize {
        let fallback = || least_load(replicas.iter()).expect("route needs at least one replica");
        match &req.session {
            Some(link) => *self
                .pins
                .entry(link.session)
                .or_insert_with(fallback),
            None => fallback(),
        }
    }
}

/// Cloud–edge offload on a provable deadline certificate: when the
/// request carries a finite TTFT deadline and *every* edge replica's
/// [`ReplicaView::ttft_floor`] already exceeds it — the deadline is
/// unmeetable on the edge tier under any schedule — spill to the
/// least-loaded cloud replica. Everything else stays on the
/// least-loaded edge replica (the cloud is reserved for doomed work,
/// which is what makes the policy's edge tail comparable to
/// least-queue's).
struct DeadlineOffload {
    offloaded: usize,
}

impl Router for DeadlineOffload {
    fn label(&self) -> &'static str {
        "deadline-offload"
    }

    fn route(&mut self, req: &Request, replicas: &[ReplicaView]) -> usize {
        let edge = || replicas.iter().filter(|v| v.tier == Tier::Edge);
        let cloud = || replicas.iter().filter(|v| v.tier == Tier::Cloud);
        let has_both = edge().next().is_some() && cloud().next().is_some();
        if let (Some(slo), true) = (req.slo, has_both) {
            if slo.ttft.is_finite()
                && edge().all(|v| v.ttft_floor(req.prompt.len()) > slo.ttft)
            {
                self.offloaded += 1;
                return least_load(cloud()).expect("cloud tier checked non-empty");
            }
        }
        least_load(edge())
            .or_else(|| least_load(replicas.iter()))
            .expect("route needs at least one replica")
    }

    fn offloaded(&self) -> usize {
        self.offloaded
    }
}

/// Serializable routing-policy descriptor (the `--policies` grammar and
/// the `cluster.json` key), mirroring
/// [`SchedulerPolicy`](crate::coordinator::sim::SchedulerPolicy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastQueue,
    SessionAffinity,
    DeadlineOffload,
}

impl RoutePolicy {
    pub const ALL: [RoutePolicy; 4] = [
        RoutePolicy::RoundRobin,
        RoutePolicy::LeastQueue,
        RoutePolicy::SessionAffinity,
        RoutePolicy::DeadlineOffload,
    ];

    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "round-robin" => Some(RoutePolicy::RoundRobin),
            "least-queue" => Some(RoutePolicy::LeastQueue),
            "session-affinity" => Some(RoutePolicy::SessionAffinity),
            "deadline-offload" => Some(RoutePolicy::DeadlineOffload),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "round-robin",
            RoutePolicy::LeastQueue => "least-queue",
            RoutePolicy::SessionAffinity => "session-affinity",
            RoutePolicy::DeadlineOffload => "deadline-offload",
        }
    }

    /// The accepted names, ` | `-joined (for error messages).
    pub fn names() -> String {
        Self::ALL
            .iter()
            .map(|p| p.label())
            .collect::<Vec<_>>()
            .join(" | ")
    }

    pub fn build(&self) -> Box<dyn Router> {
        match self {
            RoutePolicy::RoundRobin => Box::new(RoundRobin { next: 0 }),
            RoutePolicy::LeastQueue => Box::new(LeastQueue),
            RoutePolicy::SessionAffinity => Box::new(SessionAffinity {
                pins: BTreeMap::new(),
            }),
            RoutePolicy::DeadlineOffload => Box::new(DeadlineOffload { offloaded: 0 }),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sim::SessionLink;
    use crate::metrics::{Slo, SloTier};

    fn req(id: usize) -> Request {
        Request {
            id,
            arrival: Some(0.0),
            prompt: vec![1, 2, 3, 4],
            target_out: 2,
            priority: 0,
            session: None,
            slo: None,
        }
    }

    fn view(index: usize, tier: Tier, load: usize, c1: f64, marginal: f64) -> ReplicaView {
        ReplicaView {
            index,
            tier,
            load,
            floor_c1: c1,
            floor_marginal: marginal,
        }
    }

    #[test]
    fn policy_names_parse_round_trip() {
        for p in RoutePolicy::ALL {
            assert_eq!(RoutePolicy::parse(p.label()), Some(p));
            assert_eq!(p.build().label(), p.label());
        }
        assert_eq!(RoutePolicy::parse(" Round-Robin "), Some(RoutePolicy::RoundRobin));
        assert_eq!(RoutePolicy::parse("nope"), None);
        assert!(RoutePolicy::names().contains("deadline-offload"));
    }

    #[test]
    fn round_robin_cycles_in_dispatch_order() {
        let views: Vec<ReplicaView> = (0..3)
            .map(|i| view(i, Tier::Edge, 9 - i, 0.1, 0.01))
            .collect();
        let mut r = RoutePolicy::RoundRobin.build();
        let picks: Vec<usize> = (0..7).map(|i| r.route(&req(i), &views)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn least_queue_picks_min_load_lowest_index_tie() {
        let views = vec![
            view(0, Tier::Edge, 4, 0.1, 0.01),
            view(1, Tier::Edge, 2, 0.1, 0.01),
            view(2, Tier::Edge, 2, 0.1, 0.01),
        ];
        let mut r = RoutePolicy::LeastQueue.build();
        assert_eq!(r.route(&req(0), &views), 1, "tie breaks to the lowest index");
    }

    #[test]
    fn session_affinity_pins_follow_up_turns() {
        let views = vec![
            view(0, Tier::Edge, 5, 0.1, 0.01),
            view(1, Tier::Edge, 0, 0.1, 0.01),
        ];
        let mut r = RoutePolicy::SessionAffinity.build();
        let mut first = req(0);
        first.session = Some(SessionLink { session: 7, turn: 0, next: Some(1) });
        assert_eq!(r.route(&first, &views), 1, "first turn goes least-load");
        // The follow-up turn sticks to the pin even though replica 0 is
        // now the less loaded one.
        let busy = vec![
            view(0, Tier::Edge, 0, 0.1, 0.01),
            view(1, Tier::Edge, 9, 0.1, 0.01),
        ];
        let mut second = req(1);
        second.session = Some(SessionLink { session: 7, turn: 1, next: None });
        assert_eq!(r.route(&second, &busy), 1, "pinned to the session's replica");
        assert_eq!(r.route(&req(2), &busy), 0, "sessionless traffic goes least-load");
    }

    #[test]
    fn ttft_floor_is_the_two_point_secant() {
        let v = view(0, Tier::Edge, 0, 0.5, 0.125);
        assert!((v.ttft_floor(1) - 0.5).abs() < 1e-12, "plen 1 is c1 itself");
        assert!((v.ttft_floor(2) - 0.625).abs() < 1e-12, "plen 2 is c2");
        assert!((v.ttft_floor(9) - (0.5 + 8.0 * 0.125)).abs() < 1e-12);
    }

    #[test]
    fn deadline_offload_fires_only_when_every_edge_floor_exceeds_the_deadline() {
        let views = vec![
            view(0, Tier::Edge, 0, 0.5, 0.1),
            view(1, Tier::Edge, 3, 0.4, 0.1),
            view(2, Tier::Cloud, 9, 0.01, 0.001),
        ];
        let mut r = RoutePolicy::DeadlineOffload.build();
        let slo = |ttft: f64| {
            Some(Slo { tier: SloTier::Interactive, ttft, tpot: f64::INFINITY })
        };
        // 4-token prompt: edge floors are 0.8 and 0.7.
        let mut doomed = req(0);
        doomed.slo = slo(0.6);
        assert_eq!(r.route(&doomed, &views), 2, "unmeetable on every edge -> cloud");
        assert_eq!(r.offloaded(), 1);
        // A deadline one edge replica can still (provably possibly) meet
        // stays on the edge tier, least-load.
        let mut meetable = req(1);
        meetable.slo = slo(0.75);
        assert_eq!(r.route(&meetable, &views), 0);
        // No SLO, or an infinite deadline: never offloads.
        assert_eq!(r.route(&req(2), &views), 0);
        let mut unbounded = req(3);
        unbounded.slo = slo(f64::INFINITY);
        assert_eq!(r.route(&unbounded, &views), 0);
        assert_eq!(r.offloaded(), 1, "only the doomed request spilled");
        // Without a cloud tier the certificate is moot.
        let edge_only = &views[..2];
        let mut stuck = req(4);
        stuck.slo = slo(0.01);
        assert_eq!(r.route(&stuck, edge_only), 1, "least-load edge");
        assert_eq!(r.offloaded(), 1);
    }
}
