//! Deterministic simulated cluster serving (`elib cluster`, DESIGN.md
//! §9): a router admits one seeded traffic stream and dispatches to a
//! heterogeneous fleet of replica actors.
//!
//! Each replica wraps its own routed
//! [`SimLoop`](crate::coordinator::sim::SimLoop) — engine, scheduler
//! and [`DeviceClock`](crate::device::DeviceClock) are private actor
//! state, so the fleet can mix devices, accelerators and quant formats
//! freely (device-priced replicas go through the same
//! [`resolve_clock`] calibration + RAM-admission gate as `elib serve`
//! and `elib fleet`). Replicas communicate only through typed
//! mailboxes driven by the pump in [`pump`], and the *global*
//! virtual-time event queue stays authoritative: `cluster.json` is
//! bit-for-bit identical across `--threads` (which fans out across
//! *policies*, never inside a pump).
//!
//! The same [`ScenarioSpec`] that configures `elib serve` describes the
//! traffic here — workload, scheduler, SLOs and KV knobs resolve once
//! and the identical decorated trace is offered to every policy, so
//! the per-policy comparison ([`crate::report::cluster_section`]) is
//! about routing and nothing else.

pub mod router;

mod pump;

pub use router::{ReplicaView, RoutePolicy, Router};

use std::collections::BTreeMap;

use anyhow::{anyhow, Context, Result};

use crate::device::{Accel, DeviceSpec};
use crate::gguf::ModelFile;
use crate::graph::Engine;
use crate::kernel::BackendKind;
use crate::metrics::{self, Outcome, RequestRecord};
use crate::model::testutil::{build_model_file, DenseWeights};
use crate::model::{LlamaConfig, ModelWeights};
use crate::quant::QuantType;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Summary;
use crate::util::threadpool::parallel_map;

use super::runner::backend_for;
use super::scenario::ScenarioSpec;
use super::serve::{decorate_requests, resolve_clock, ArrivalMode, DeviceTarget, ServeParams};
use super::sim::{KvReuse, PartialOutput, SimLoop};

use pump::{pump, ReplicaActor};

/// Which side of the cloud–edge split a replica sits on. Only the
/// deadline-offload policy distinguishes tiers; every other policy
/// treats the fleet as flat.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tier {
    Edge,
    Cloud,
}

impl Tier {
    pub fn key(&self) -> &'static str {
        match self {
            Tier::Edge => "edge",
            Tier::Cloud => "cloud",
        }
    }
}

/// One replica of the fleet: its own engine slots, quant format, and
/// pricing — either a calibrated device (with the RAM-capacity
/// admission gate) or a flat roofline.
#[derive(Clone, Debug)]
pub struct ReplicaSpec {
    /// Unique fleet-wide name (`cluster.json` key, e.g. `edge0:NanoPI`).
    pub name: String,
    pub tier: Tier,
    pub quant: QuantType,
    /// Engine slots (continuous-batching concurrency) on this replica.
    pub slots: usize,
    /// Device-priced replica; `None` prices on the flat roofline below.
    pub device: Option<DeviceTarget>,
    pub peak_bw: f64,
    pub peak_flops: f64,
}

impl ReplicaSpec {
    /// A calibrated-device replica (the `elib cluster` CLI shape).
    pub fn on_device(
        name: &str,
        tier: Tier,
        device: &str,
        accel: Accel,
        quant: QuantType,
        slots: usize,
        threads: usize,
    ) -> Self {
        let d = ServeParams::default();
        Self {
            name: name.to_string(),
            tier,
            quant,
            slots,
            device: Some(DeviceTarget {
                device: device.to_string(),
                accel,
                threads,
            }),
            peak_bw: d.peak_bw,
            peak_flops: d.peak_flops,
        }
    }

    /// A flat-roofline replica (tests and synthetic what-ifs).
    pub fn flat(
        name: &str,
        tier: Tier,
        peak_bw: f64,
        peak_flops: f64,
        quant: QuantType,
        slots: usize,
    ) -> Self {
        Self {
            name: name.to_string(),
            tier,
            quant,
            slots,
            device: None,
            peak_bw,
            peak_flops,
        }
    }
}

/// Inputs of one cluster run: the traffic scenario, the fleet, and the
/// routing policies to compare on it.
#[derive(Clone, Debug)]
pub struct ClusterParams {
    /// The unified traffic description (workload + scheduler + SLOs +
    /// KV knobs). `slots` and `device` inside it are per-replica
    /// concerns and must be left to the [`ReplicaSpec`]s.
    pub scenario: ScenarioSpec,
    pub replicas: Vec<ReplicaSpec>,
    pub policies: Vec<RoutePolicy>,
    /// Fan-out across policies over the shared threadpool. Result
    /// order — and `cluster.json` — is identical for any value.
    pub threads: usize,
}

impl ClusterParams {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.replicas.is_empty(), "cluster needs at least one replica");
        anyhow::ensure!(!self.policies.is_empty(), "cluster needs at least one policy");
        let mut names: Vec<&str> = self.replicas.iter().map(|r| r.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        anyhow::ensure!(
            names.len() == self.replicas.len(),
            "replica names must be unique"
        );
        let mut pols = self.policies.clone();
        pols.sort_unstable_by_key(|p| p.label());
        pols.dedup();
        anyhow::ensure!(
            pols.len() == self.policies.len(),
            "policies must be unique (cluster.json is keyed by policy name)"
        );
        anyhow::ensure!(
            self.scenario.device.is_none(),
            "the cluster scenario must not pin a device — devices belong to replicas"
        );
        for r in &self.replicas {
            anyhow::ensure!(r.slots >= 1, "replica {} needs at least one slot", r.name);
        }
        Ok(())
    }
}

/// Per-replica rollup inside one policy's run.
#[derive(Clone, Debug)]
pub struct ReplicaStats {
    pub name: String,
    /// Requests the router dispatched here.
    pub routed: usize,
    /// Requests that retired here with output (`Outcome::Served`).
    pub served: usize,
    /// Engine-busy virtual seconds on this replica.
    pub busy_secs: f64,
    /// `busy_secs` over the *fleet* makespan — comparable across
    /// replicas because every replica shares the global clock span.
    pub utilization: f64,
    pub queue_depth_mean: f64,
    pub queue_depth_max: usize,
    /// Mean MBU-under-load over this replica's token-generating steps;
    /// `None` when it had none (serialized `null`, never a fake 0.0).
    pub mbu_mean: Option<f64>,
    /// Prompt + output tokens processed here (the fleet-MBU weight).
    pub processed_tokens: usize,
}

/// Everything one routing policy produced on the shared trace.
#[derive(Clone, Debug)]
pub struct PolicyReport {
    pub policy: RoutePolicy,
    /// Requests in the offered trace (== served + shed + preempted:
    /// the conservation law the cluster tests assert).
    pub offered: usize,
    pub output_tokens: usize,
    /// Fleet makespan: the latest virtual instant any replica reached.
    pub makespan_secs: f64,
    pub shed: usize,
    pub preempted: usize,
    /// Requests the deadline certificate spilled to the cloud tier.
    pub offloaded: usize,
    /// Chat KV-prefix reuse summed across replicas.
    pub reuse: KvReuse,
    /// Merged per-request records (each request retires on exactly one
    /// replica).
    pub records: Vec<RequestRecord>,
    pub replicas: Vec<ReplicaStats>,
    /// Traffic-weighted fleet MBU ([`metrics::fleet_mbu`]).
    pub fleet_mbu: Option<f64>,
    /// FNV-1a over the merged token sequences, global request order.
    pub tokens_fnv: u64,
}

impl PolicyReport {
    fn served_records(&self) -> impl Iterator<Item = &RequestRecord> {
        self.records
            .iter()
            .filter(|r| matches!(r.outcome, Outcome::Served))
    }

    pub fn served(&self) -> usize {
        self.served_records().count()
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.makespan_secs <= 0.0 {
            0.0
        } else {
            self.output_tokens as f64 / self.makespan_secs
        }
    }

    /// `None` when no request was served.
    pub fn ttft_summary(&self) -> Option<Summary> {
        Summary::of_opt(&self.served_records().map(RequestRecord::ttft).collect::<Vec<_>>())
    }

    /// `None` when no request was served.
    pub fn tpot_summary(&self) -> Option<Summary> {
        Summary::of_opt(&self.served_records().map(RequestRecord::tpot).collect::<Vec<_>>())
    }

    /// SLO-attained token fraction; `None` without SLOs.
    pub fn goodput(&self) -> Option<f64> {
        metrics::goodput(&self.records)
    }

    fn to_json(&self, chat: bool, slo: bool) -> Json {
        let sum = |s: &Option<Summary>| match s {
            Some(s) => Json::obj(vec![
                ("mean", Json::Num(s.mean)),
                ("p50", Json::Num(s.p50)),
                ("p95", Json::Num(s.p95)),
                ("p99", Json::Num(s.p99)),
                ("max", Json::Num(s.max)),
            ]),
            None => Json::Null,
        };
        let mut aggregate = vec![
            ("offered", Json::Num(self.offered as f64)),
            ("served", Json::Num(self.served() as f64)),
            ("shed", Json::Num(self.shed as f64)),
            ("preempted", Json::Num(self.preempted as f64)),
            ("output_tokens", Json::Num(self.output_tokens as f64)),
            ("makespan_secs", Json::Num(self.makespan_secs)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s())),
            ("ttft", sum(&self.ttft_summary())),
            ("tpot", sum(&self.tpot_summary())),
            (
                "fleet_mbu",
                self.fleet_mbu.map_or(Json::Null, Json::Num),
            ),
            ("offloaded", Json::Num(self.offloaded as f64)),
            ("tokens_fnv", Json::Str(format!("{:016x}", self.tokens_fnv))),
        ];
        // Additive keys, same convention as bench.json: goodput only
        // with SLOs, kv_reuse only for the chat workload.
        if slo {
            aggregate.push(("goodput", self.goodput().map_or(Json::Null, Json::Num)));
        }
        if chat {
            aggregate.push((
                "kv_reuse",
                Json::obj(vec![
                    ("reused_turns", Json::Num(self.reuse.reused_turns as f64)),
                    ("reused_tokens", Json::Num(self.reuse.reused_tokens as f64)),
                ]),
            ));
        }
        Json::obj(vec![
            ("policy", Json::Str(self.policy.label().into())),
            ("aggregate", Json::obj(aggregate)),
            (
                "replicas",
                Json::Arr(
                    self.replicas
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("name", Json::Str(r.name.clone())),
                                ("routed", Json::Num(r.routed as f64)),
                                ("served", Json::Num(r.served as f64)),
                                ("busy_secs", Json::Num(r.busy_secs)),
                                ("utilization", Json::Num(r.utilization)),
                                ("queue_depth_mean", Json::Num(r.queue_depth_mean)),
                                ("queue_depth_max", Json::Num(r.queue_depth_max as f64)),
                                (
                                    "mbu_mean",
                                    r.mbu_mean.map_or(Json::Null, Json::Num),
                                ),
                                (
                                    "processed_tokens",
                                    Json::Num(r.processed_tokens as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Everything one cluster run produced.
#[derive(Clone, Debug)]
pub struct ClusterReport {
    pub params: ClusterParams,
    pub policies: Vec<PolicyReport>,
}

impl ClusterReport {
    /// The deterministic `cluster.json` document.
    pub fn to_json(&self) -> Json {
        let chat = self.params.scenario.workload == "chat";
        let slo = self.params.scenario.slo.is_some();
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("scenario", Json::Str("cluster".into())),
            ("spec", self.params.scenario.to_json()),
            (
                "replicas",
                Json::Arr(
                    self.params
                        .replicas
                        .iter()
                        .map(|r| {
                            let mut row = vec![
                                ("name", Json::Str(r.name.clone())),
                                ("tier", Json::Str(r.tier.key().into())),
                                ("quant", Json::Str(r.quant.name().into())),
                                ("slots", Json::Num(r.slots as f64)),
                            ];
                            match &r.device {
                                Some(t) => row.push((
                                    "device",
                                    Json::obj(vec![
                                        ("name", Json::Str(t.device.clone())),
                                        ("accel", Json::Str(t.accel.key().into())),
                                        ("threads", Json::Num(t.threads as f64)),
                                    ]),
                                )),
                                None => {
                                    row.push(("peak_bw", Json::Num(r.peak_bw)));
                                    row.push(("peak_flops", Json::Num(r.peak_flops)));
                                }
                            }
                            Json::obj(row)
                        })
                        .collect(),
                ),
            ),
            (
                "policies",
                Json::Arr(self.policies.iter().map(|p| p.to_json(chat, slo)).collect()),
            ),
        ])
    }
}

/// FNV-1a over token sequences in global request order (the same fold
/// `ServeReport::tokens_fnv` uses, applied to the merged cluster
/// trace).
fn tokens_fnv(sequences: &[Vec<u32>]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut mix = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    };
    for seq in sequences {
        for b in (seq.len() as u32).to_le_bytes() {
            mix(b);
        }
        for t in seq {
            for b in t.to_le_bytes() {
                mix(b);
            }
        }
    }
    h
}

/// Run the cluster: quantize the model once per distinct format, then
/// offer the identical decorated trace to every routing policy, fanned
/// out over the threadpool in fixed policy order.
pub fn run_cluster(
    mcfg: &LlamaConfig,
    dense: &DenseWeights,
    p: &ClusterParams,
) -> Result<ClusterReport> {
    p.validate()?;
    let base = p.scenario.resolve()?;
    let mut files: BTreeMap<String, ModelFile> = BTreeMap::new();
    for r in &p.replicas {
        files
            .entry(r.quant.name().to_string())
            .or_insert_with(|| build_model_file(mcfg, r.quant, dense));
    }
    let outcomes = parallel_map(&p.policies, p.threads.max(1), |pol| {
        run_policy(mcfg, &files, p, &base, *pol)
            .with_context(|| format!("policy {}", pol.label()))
    });
    let mut policies = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        policies.push(o?);
    }
    Ok(ClusterReport {
        params: p.clone(),
        policies,
    })
}

/// One policy's complete pass: fresh workload + router + fleet, the
/// pump to completion, then the merged report.
fn run_policy(
    mcfg: &LlamaConfig,
    files: &BTreeMap<String, ModelFile>,
    p: &ClusterParams,
    base: &ServeParams,
    policy: RoutePolicy,
) -> Result<PolicyReport> {
    let vocab = mcfg.vocab_size;
    // The trace is drawn once per policy from the same seed, so every
    // policy routes the identical decorated request set.
    let mut workload = p.scenario.build_workload()?;
    let mut rng = Rng::new(base.seed);
    let mut requests = workload.build(&mut rng, vocab);
    decorate_requests(&mut requests, base, vocab);

    let mut actors: Vec<ReplicaActor> = Vec::with_capacity(p.replicas.len());
    for r in &p.replicas {
        let mut sp = base.clone();
        sp.slots = r.slots;
        sp.device = r.device.clone();
        sp.peak_bw = r.peak_bw;
        sp.peak_flops = r.peak_flops;
        let mf = files
            .get(r.quant.name())
            .ok_or_else(|| anyhow!("no model file for quant {}", r.quant.name()))?;
        let weights = ModelWeights::load(mf)?;
        let qtype = weights.qtype;
        let backend = match &r.device {
            Some(t) => {
                let spec = DeviceSpec::by_name(&t.device).ok_or_else(|| {
                    anyhow!("unknown device `{}` for replica {}", t.device, r.name)
                })?;
                backend_for(t.accel, &spec)
            }
            None => BackendKind::Naive,
        };
        let engine = Engine::new_batched(weights, backend, sp.slots);
        let max_seq = engine.config().max_seq_len;
        let worst = match sp.mode {
            ArrivalMode::Chat { turns } => turns.1 * (sp.prompt_len.1 + sp.output_len.1 + 1),
            _ => sp.prompt_len.1 + sp.output_len.1,
        } + sp.system_prompt;
        anyhow::ensure!(
            worst <= max_seq,
            "replica {}: worst-case context {worst} exceeds the window {max_seq}",
            r.name
        );
        // Device replicas go through the calibrated clock + RAM
        // admission gate; an infeasible replica is a configuration
        // error, not a silent skip — a cluster with a phantom member
        // would misreport every policy.
        let mut clock = resolve_clock(&sp, engine.config(), qtype)
            .with_context(|| format!("replica {}", r.name))?;
        if let Some(t) = &sp.thermal {
            clock = clock.with_thermal(t.tau, t.floor);
        }
        // Same scheduler seed on every replica: priority draws are
        // identical no matter where a request lands.
        let mut scheduler = sp.scheduler.build(sp.seed);
        let run = SimLoop::new(engine, clock, false)
            .with_pool_blocks(sp.pool_blocks)
            .with_prefix_share(sp.prefix_share)
            .start_routed(requests.clone(), scheduler.as_mut())?;
        actors.push(ReplicaActor::new(r.name.clone(), r.tier, run, scheduler));
    }

    let mut router = policy.build();
    pump(&requests, workload.as_mut(), router.as_mut(), &mut actors)?;
    let offloaded = router.offloaded();
    let partials: Vec<PartialOutput> = actors
        .into_iter()
        .map(|a| a.into_run().finish_routed())
        .collect();

    // Merge: every request retired on exactly one replica (a migrated
    // chat turn leaves no record on its origin — `cancel_park` frees
    // the slot silently).
    let n = requests.len();
    let mut merged: Vec<Option<RequestRecord>> = vec![None; n];
    let mut sequences: Vec<Vec<u32>> = vec![Vec::new(); n];
    for part in &partials {
        for (id, rec) in part.records.iter().enumerate() {
            if let Some(rec) = rec {
                anyhow::ensure!(merged[id].is_none(), "request {id} retired on two replicas");
                merged[id] = Some(rec.clone());
                sequences[id] = part.sequences[id].clone();
            }
        }
    }
    let mut records = Vec::with_capacity(n);
    for (id, rec) in merged.into_iter().enumerate() {
        records.push(rec.ok_or_else(|| anyhow!("request {id} never retired"))?);
    }

    let makespan_secs = partials.iter().fold(0.0f64, |m, q| m.max(q.makespan_secs));
    let output_tokens: usize = records.iter().map(|r| r.output_tokens).sum();
    let shed = records
        .iter()
        .filter(|r| matches!(r.outcome, Outcome::Shed))
        .count();
    let preempted = records
        .iter()
        .filter(|r| matches!(r.outcome, Outcome::Preempted))
        .count();
    let mut reuse = KvReuse::default();
    for part in &partials {
        reuse.reused_turns += part.reuse.reused_turns;
        reuse.reused_tokens += part.reuse.reused_tokens;
    }
    let replicas: Vec<ReplicaStats> = p
        .replicas
        .iter()
        .zip(&partials)
        .map(|(spec, part)| {
            let served = part
                .records
                .iter()
                .flatten()
                .filter(|r| matches!(r.outcome, Outcome::Served))
                .count();
            let queue_depth_mean = if part.step_queue.is_empty() {
                0.0
            } else {
                part.step_queue.iter().sum::<usize>() as f64 / part.step_queue.len() as f64
            };
            let mbu: Vec<f64> = part.step_mbu.iter().copied().filter(|m| *m > 0.0).collect();
            ReplicaStats {
                name: spec.name.clone(),
                routed: part.routed,
                served,
                busy_secs: part.busy_secs,
                utilization: if makespan_secs > 0.0 {
                    part.busy_secs / makespan_secs
                } else {
                    0.0
                },
                queue_depth_mean,
                queue_depth_max: part.step_queue.iter().copied().max().unwrap_or(0),
                mbu_mean: if mbu.is_empty() {
                    None
                } else {
                    Some(Summary::of(&mbu).mean)
                },
                processed_tokens: part.processed_tokens,
            }
        })
        .collect();
    let fleet_mbu = metrics::fleet_mbu(
        &replicas
            .iter()
            .map(|r| (r.processed_tokens, r.mbu_mean))
            .collect::<Vec<_>>(),
    );
    let tokens_fnv = tokens_fnv(&sequences);
    Ok(PolicyReport {
        policy,
        offered: n,
        output_tokens,
        makespan_secs,
        shed,
        preempted,
        offloaded,
        reuse,
        records,
        replicas,
        fleet_mbu,
        tokens_fnv,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serve::SloSpec;
    use crate::model::testutil::random_weights;
    use crate::util::json;

    fn flat(name: &str, tier: Tier, bw: f64) -> ReplicaSpec {
        ReplicaSpec::flat(name, tier, bw, 2e9, QuantType::Q8_0, 2)
    }

    fn small_scenario() -> ScenarioSpec {
        ScenarioSpec {
            arrival_rate: 20.0,
            num_requests: 10,
            seed: 9,
            prompt_len: (2, 4),
            output_len: (2, 4),
            ..ScenarioSpec::default()
        }
    }

    fn run(p: &ClusterParams) -> ClusterReport {
        let mcfg = LlamaConfig::tiny();
        let dense = random_weights(&mcfg, 11);
        run_cluster(&mcfg, &dense, p).unwrap()
    }

    fn assert_conserved(pr: &PolicyReport) {
        assert_eq!(
            pr.served() + pr.shed + pr.preempted,
            pr.offered,
            "{}: served + shed + preempted must equal offered",
            pr.policy.label()
        );
    }

    #[test]
    fn every_policy_conserves_the_offered_trace() {
        let p = ClusterParams {
            scenario: small_scenario(),
            replicas: vec![
                flat("edge0", Tier::Edge, 50e6),
                flat("edge1", Tier::Edge, 100e6),
                flat("cloud0", Tier::Cloud, 200e6),
            ],
            policies: RoutePolicy::ALL.to_vec(),
            threads: 1,
        };
        let rep = run(&p);
        assert_eq!(rep.policies.len(), 4);
        for pr in &rep.policies {
            assert_conserved(pr);
            assert_eq!(pr.shed, 0, "no SLOs, nothing may shed");
            let routed: usize = pr.replicas.iter().map(|r| r.routed).sum();
            assert_eq!(routed, pr.offered, "every request dispatched exactly once");
            assert!(pr.makespan_secs > 0.0);
            assert!(pr.tokens_fnv != 0);
            assert!(pr.fleet_mbu.is_some(), "decode steps happened somewhere");
        }
        // Without chat migrations the decoded tokens depend only on the
        // (identical) prompts, so every policy produces the same trace.
        let fnvs: Vec<u64> = rep.policies.iter().map(|p| p.tokens_fnv).collect();
        assert!(
            fnvs.iter().all(|f| *f == fnvs[0]),
            "non-chat token traces must be policy-invariant: {fnvs:x?}"
        );
    }

    #[test]
    fn cluster_json_is_bitwise_identical_across_threads() {
        let mcfg = LlamaConfig::tiny();
        let dense = random_weights(&mcfg, 11);
        let mut p = ClusterParams {
            scenario: small_scenario(),
            replicas: vec![
                flat("edge0", Tier::Edge, 50e6),
                flat("edge1", Tier::Edge, 120e6),
                flat("cloud0", Tier::Cloud, 300e6),
            ],
            policies: RoutePolicy::ALL.to_vec(),
            threads: 1,
        };
        let baseline = json::to_string_pretty(&run_cluster(&mcfg, &dense, &p).unwrap().to_json());
        for threads in [2, 8] {
            p.threads = threads;
            let rerun = json::to_string_pretty(&run_cluster(&mcfg, &dense, &p).unwrap().to_json());
            assert_eq!(baseline, rerun, "threads={threads} changed cluster.json");
        }
    }

    #[test]
    fn session_affinity_reuses_kv_where_round_robin_cold_starts() {
        let p = ClusterParams {
            scenario: ScenarioSpec {
                workload: "chat".into(),
                clients: Some(3),
                turns: Some((2, 3)),
                num_requests: 9,
                arrival_rate: 20.0,
                seed: 13,
                prompt_len: (2, 4),
                output_len: (2, 4),
                ..ScenarioSpec::default()
            },
            replicas: vec![
                flat("edge0", Tier::Edge, 100e6),
                flat("edge1", Tier::Edge, 100e6),
                flat("edge2", Tier::Edge, 100e6),
            ],
            policies: vec![RoutePolicy::RoundRobin, RoutePolicy::SessionAffinity],
            threads: 1,
        };
        let rep = run(&p);
        let rr = &rep.policies[0];
        let aff = &rep.policies[1];
        assert_conserved(rr);
        assert_conserved(aff);
        assert!(
            aff.reuse.reused_turns > 0,
            "pinned sessions must reuse their parked KV"
        );
        assert!(
            aff.reuse.reused_turns > rr.reuse.reused_turns,
            "affinity ({}) must beat round-robin ({}) on kv reuse",
            aff.reuse.reused_turns,
            rr.reuse.reused_turns
        );
    }

    fn offload_params(ttft: f64, cloud: bool) -> ClusterParams {
        let mut replicas = vec![
            // Slow enough that even the shortest prefill provably
            // misses any realistic deadline (model bytes / 1e3 B/s).
            flat("edge0", Tier::Edge, 1e3),
            flat("edge1", Tier::Edge, 1e3),
        ];
        if cloud {
            replicas.push(ReplicaSpec::flat(
                "cloud0",
                Tier::Cloud,
                1e12,
                1e15,
                QuantType::Q8_0,
                4,
            ));
        }
        ClusterParams {
            scenario: ScenarioSpec {
                workload: "flash-crowd".into(),
                num_requests: 12,
                arrival_rate: 20.0,
                seed: 21,
                prompt_len: (2, 4),
                output_len: (2, 4),
                slo: Some(SloSpec { ttft, tpot: 10.0 }),
                ..ScenarioSpec::default()
            },
            replicas,
            policies: vec![RoutePolicy::DeadlineOffload],
            threads: 1,
        }
    }

    #[test]
    fn offload_fires_only_when_provably_unmeetable() {
        // Loose deadline: the certificate can never prove infeasibility,
        // so nothing spills even though the edge tier is glacial.
        let loose = run(&offload_params(1e9, true));
        assert_eq!(loose.policies[0].offloaded, 0);
        assert_conserved(&loose.policies[0]);
        // Tight deadline: every edge floor exceeds it, everything spills.
        let tight = run(&offload_params(0.05, true));
        assert!(tight.policies[0].offloaded > 0, "certificate must fire");
        assert_conserved(&tight.policies[0]);
    }

    #[test]
    fn offload_improves_flash_crowd_goodput_over_edge_only() {
        let offloaded = run(&offload_params(0.05, true));
        let mut edge_only_params = offload_params(0.05, false);
        edge_only_params.policies = vec![RoutePolicy::LeastQueue];
        let edge_only = run(&edge_only_params);
        let g_off = offloaded.policies[0].goodput().unwrap();
        let g_edge = edge_only.policies[0].goodput().unwrap();
        assert!(
            g_off > g_edge,
            "offload goodput {g_off} must beat edge-only {g_edge}"
        );
    }

    #[test]
    fn heterogeneous_device_replicas_run_and_report() {
        let p = ClusterParams {
            scenario: ScenarioSpec {
                num_requests: 6,
                arrival_rate: 20.0,
                seed: 5,
                prompt_len: (2, 4),
                output_len: (2, 4),
                ..ScenarioSpec::default()
            },
            replicas: vec![
                ReplicaSpec::on_device(
                    "edge0:NanoPI",
                    Tier::Edge,
                    "NanoPI",
                    Accel::CpuBlas,
                    QuantType::Q4_0,
                    2,
                    4,
                ),
                ReplicaSpec::on_device(
                    "cloud0:Macbook",
                    Tier::Cloud,
                    "Macbook",
                    Accel::Gpu,
                    QuantType::Q8_0,
                    2,
                    4,
                ),
            ],
            policies: vec![RoutePolicy::LeastQueue],
            threads: 1,
        };
        let rep = run(&p);
        let pr = &rep.policies[0];
        assert_conserved(pr);
        assert_eq!(pr.replicas.len(), 2);
        let j = rep.to_json();
        let rows = match j.get("replicas") {
            Some(Json::Arr(rows)) => rows,
            other => panic!("replicas must be an array, got {other:?}"),
        };
        assert!(rows[0].get("device").is_some(), "device replicas record their device");
        assert!(rows[0].get("peak_bw").is_none(), "device rows omit the flat rates");
    }

    #[test]
    fn validate_rejects_degenerate_fleets() {
        let mut p = ClusterParams {
            scenario: small_scenario(),
            replicas: vec![flat("a", Tier::Edge, 1e8), flat("a", Tier::Edge, 1e8)],
            policies: vec![RoutePolicy::RoundRobin],
            threads: 1,
        };
        assert!(p.validate().is_err(), "duplicate names");
        p.replicas = vec![flat("a", Tier::Edge, 1e8)];
        p.policies = vec![RoutePolicy::RoundRobin, RoutePolicy::RoundRobin];
        assert!(p.validate().is_err(), "duplicate policies");
        p.policies = vec![RoutePolicy::RoundRobin];
        p.scenario.device = Some(DeviceTarget {
            device: "NanoPI".into(),
            accel: Accel::CpuBlas,
            threads: 4,
        });
        assert!(p.validate().is_err(), "scenario-level device pin");
        p.scenario.device = None;
        assert!(p.validate().is_ok());
    }
}
