//! The fleet sweep (`elib fleet`): one seeded request trace served on
//! every device × accelerator × quant cell of a simulated edge fleet.
//!
//! The paper's core result is comparative — MBU, throughput and latency
//! across three platforms × accelerators × quant formats. The solo grid
//! (`runner`) prices a steady-state decode step per cell; the fleet
//! sweep replays the *same* serving trace (`serve::run_serve`, priced on
//! each cell's [`DeviceClock`](crate::device::DeviceClock)) so the
//! comparison holds *under load*:
//! TTFT includes queueing, TPOT reflects continuous batching, and
//! MBU-under-load is reported against each device's peak bandwidth.
//!
//! Two properties make `fleet.json` CI-worthy:
//!
//! * **capacity admission** — cells whose 7B-scale deployment (param
//!   bytes + per-slot *trace-bounded* paged KV + scratch + runtime
//!   floor) exceeds the device's RAM are rejected up front as
//!   structured `infeasible` results, not panics: deploy feasibility is
//!   itself a benchmark output (RQ2). The paged allocator made the KV
//!   charge token-granular (`serve::paged_context_tokens`), which is
//!   what flips the default grid's q8_0 @ 8-slot cells feasible on
//!   16 GiB devices.
//! * **determinism** — cells fan out over
//!   [`threadpool::parallel_map`](crate::util::threadpool::parallel_map)
//!   in fixed grid order, every cell's trace and clock are pure
//!   functions of the seed and calibration, so the emitted `fleet.json`
//!   is bitwise identical for any `--threads` value (CI `cmp`s a rerun).

use anyhow::{anyhow, Result};

use crate::device::{Accel, Capacity, DeviceSpec};
use crate::gguf::ModelFile;
use crate::graph::KvPoolStats;
use crate::metrics::FleetCellMetrics;
use crate::model::testutil::{build_model_file, DenseWeights};
use crate::model::LlamaConfig;
use crate::quant::QuantType;
use crate::util::json::Json;
use crate::util::threadpool::parallel_map;

use super::runner::backend_for;
use super::serve::{paged_context_tokens, run_serve, DeviceTarget, ServeParams, ServeReport};

/// Inputs of one fleet sweep. The `trace` seeds one request schedule
/// shared by every cell — the whole point: identical load, different
/// hardware.
#[derive(Clone, Debug)]
pub struct FleetParams {
    pub devices: Vec<DeviceSpec>,
    pub accels: Vec<Accel>,
    pub quants: Vec<QuantType>,
    /// Engine slots per cell — also the concurrency the 7B-scale
    /// capacity gate prices (each admitted request owns a full-context
    /// KV allocation).
    pub slots: usize,
    /// Device CPU threads the clock's contention model is evaluated at.
    pub device_threads: usize,
    /// Fleet scheduler fan-out (cells over the shared threadpool).
    /// Result order — and `fleet.json` — is identical for any value.
    pub scheduler_threads: usize,
    /// Base trace (seed, arrivals, lengths). `slots` and `device` are
    /// overwritten per cell.
    pub trace: ServeParams,
}

impl Default for FleetParams {
    fn default() -> Self {
        Self {
            devices: DeviceSpec::paper_devices(),
            accels: vec![Accel::CpuBlas, Accel::Gpu],
            quants: vec![QuantType::Q4_0, QuantType::Q8_0],
            // 8 slots at q8_0 oversubscribed every 16 GiB device under
            // full-context charging; the paged pool's token-granular
            // charge fits the whole default grid — the expanded serving
            // frontier is itself a headline fleet.json result.
            slots: 8,
            device_threads: 4,
            scheduler_threads: 1,
            trace: ServeParams {
                arrival_rate: 2.0,
                num_requests: 48,
                ..ServeParams::default()
            },
        }
    }
}

impl FleetParams {
    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(!self.devices.is_empty(), "fleet needs at least one device");
        anyhow::ensure!(!self.accels.is_empty(), "fleet needs at least one accelerator");
        anyhow::ensure!(!self.quants.is_empty(), "fleet needs at least one quant format");
        anyhow::ensure!(self.slots >= 1, "fleet needs at least one slot per cell");
        anyhow::ensure!(self.device_threads >= 1, "fleet needs at least one device thread");
        Ok(())
    }
}

/// What happened in one cell.
#[derive(Clone, Debug)]
pub enum CellOutcome {
    /// The full serve report (the cell's bench.json-equivalent).
    Served(Box<ServeReport>),
    /// Rejected by the RAM-capacity admission gate — never run.
    Infeasible(Capacity),
}

/// One (device, accel, quant) cell of the sweep.
#[derive(Clone, Debug)]
pub struct FleetCell {
    pub device: String,
    pub platform: String,
    pub accel: Accel,
    /// Framework label per the device's Table-6 column.
    pub framework: String,
    pub quant: QuantType,
    pub capacity: Capacity,
    pub outcome: CellOutcome,
}

impl FleetCell {
    pub fn is_feasible(&self) -> bool {
        matches!(self.outcome, CellOutcome::Served(_))
    }

    /// Flatten into the comparative metrics row (`fleet.json` cell).
    pub fn metrics(&self) -> FleetCellMetrics {
        let accelerator = match self.accel {
            Accel::CpuNone | Accel::CpuBlas => "CPU",
            Accel::Gpu => "GPU",
        };
        let mut m = FleetCellMetrics {
            device: self.device.clone(),
            platform: self.platform.clone(),
            accelerator: accelerator.to_string(),
            framework: self.framework.clone(),
            accel_key: self.accel.key().to_string(),
            quant: self.quant.name().to_string(),
            feasible: self.is_feasible(),
            need_ram_bytes: self.capacity.need_bytes,
            ram_bytes: self.capacity.have_bytes,
            throughput_tok_s: None,
            ttft: None,
            tpot: None,
            queue_wait: None,
            mbu_mean: None,
            mbu_max: None,
            makespan_secs: None,
            output_tokens: None,
            tokens_fnv: None,
            kv_pool_occupancy: None,
            kv_prefix_share_bytes: None,
            goodput: None,
        };
        if let CellOutcome::Served(rep) = &self.outcome {
            let mbu = rep.mbu_summary();
            m.throughput_tok_s = Some(rep.throughput_tok_s());
            // Summaries are over served requests and `None` when a cell
            // served nothing (an all-shed SLO trace) — serialized null.
            m.ttft = rep.ttft_summary();
            m.tpot = rep.tpot_summary();
            m.queue_wait = rep.queue_wait_summary();
            // SLO-attained token fraction; `None` (→ null) without SLOs.
            m.goodput = rep.goodput();
            // `None` (no token-generating steps) stays `None` and
            // serializes as `mbu: null` — the same convention
            // `ServeReport::to_json` uses, so bench.json and fleet.json
            // never disagree about what an absent MBU means.
            m.mbu_mean = mbu.as_ref().map(|s| s.mean);
            m.mbu_max = mbu.as_ref().map(|s| s.max);
            m.makespan_secs = Some(rep.makespan_secs);
            m.output_tokens = Some(rep.output_tokens);
            m.tokens_fnv = Some(format!("{:016x}", rep.tokens_fnv()));
            // Paged-pool footprint of the cell's engine: peak block
            // occupancy and CoW prefix-share savings (both absent on a
            // slot-layout engine — never the fleet default).
            m.kv_pool_occupancy = rep.kv_pool.as_ref().map(KvPoolStats::peak_occupancy);
            m.kv_prefix_share_bytes = rep.kv_pool.as_ref().map(|s| s.shared_bytes);
        }
        m
    }
}

/// Everything one fleet sweep produced.
#[derive(Clone, Debug)]
pub struct FleetReport {
    pub params: FleetParams,
    pub cells: Vec<FleetCell>,
}

impl FleetReport {
    pub fn feasible_cells(&self) -> impl Iterator<Item = &FleetCell> {
        self.cells.iter().filter(|c| c.is_feasible())
    }

    pub fn infeasible_count(&self) -> usize {
        self.cells.iter().filter(|c| !c.is_feasible()).count()
    }

    /// The MBU frontier: per device, the feasible cell with the highest
    /// MBU-under-load — the paper's "which accel × quant actually uses
    /// this device's bandwidth" question, answered under serving load.
    pub fn mbu_frontier(&self) -> Vec<&FleetCell> {
        let mut out: Vec<&FleetCell> = Vec::new();
        for d in &self.params.devices {
            let best = self
                .feasible_cells()
                .filter(|c| c.device == d.name)
                .max_by(|a, b| {
                    let ma = a.metrics().mbu_mean.unwrap_or(0.0);
                    let mb = b.metrics().mbu_mean.unwrap_or(0.0);
                    ma.partial_cmp(&mb).expect("mbu is finite")
                });
            if let Some(c) = best {
                out.push(c);
            }
        }
        out
    }

    /// The deterministic `fleet.json` document.
    pub fn to_json(&self) -> Json {
        let p = &self.params;
        let mut trace = p.trace.clone();
        trace.slots = p.slots;
        trace.device = None; // per-cell, recorded in each cell row
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("scenario", Json::Str("fleet".into())),
            ("trace", trace.to_json()),
            (
                "grid",
                Json::obj(vec![
                    (
                        "devices",
                        Json::Arr(
                            p.devices
                                .iter()
                                .map(|d| Json::Str(d.name.to_string()))
                                .collect(),
                        ),
                    ),
                    (
                        "accels",
                        Json::Arr(p.accels.iter().map(|a| Json::Str(a.key().into())).collect()),
                    ),
                    (
                        "quants",
                        Json::Arr(
                            p.quants
                                .iter()
                                .map(|q| Json::Str(q.name().into()))
                                .collect(),
                        ),
                    ),
                    ("slots", Json::Num(p.slots as f64)),
                    ("device_threads", Json::Num(p.device_threads as f64)),
                ]),
            ),
            (
                "aggregate",
                Json::obj(vec![
                    ("cells", Json::Num(self.cells.len() as f64)),
                    ("infeasible", Json::Num(self.infeasible_count() as f64)),
                ]),
            ),
            (
                "cells",
                Json::Arr(self.cells.iter().map(|c| c.metrics().to_json()).collect()),
            ),
        ])
    }
}

/// Run the fleet sweep: quantize the model once per format, then serve
/// the shared trace on every (device, accel, quant) cell, fanned out
/// over the threadpool in deterministic grid order.
pub fn run_fleet(mcfg: &LlamaConfig, dense: &DenseWeights, p: &FleetParams) -> Result<FleetReport> {
    p.validate()?;
    let models: Vec<(QuantType, ModelFile)> = p
        .quants
        .iter()
        .map(|q| (*q, build_model_file(mcfg, *q, dense)))
        .collect();

    struct CellJob<'a> {
        spec: &'a DeviceSpec,
        accel: Accel,
        quant: QuantType,
        mf: &'a ModelFile,
    }
    let mut jobs = Vec::new();
    for spec in &p.devices {
        for accel in &p.accels {
            for (quant, mf) in &models {
                jobs.push(CellJob {
                    spec,
                    accel: *accel,
                    quant: *quant,
                    mf,
                });
            }
        }
    }

    let outcomes = parallel_map(
        &jobs,
        p.scheduler_threads.max(1),
        |job| -> Result<(Capacity, CellOutcome)> {
            // Token-granular admission: charge the shared trace's worst
            // per-slot context (block-rounded), not the full window —
            // exactly what the cell's paged engine will allocate.
            let cap =
                job.spec
                    .serve_capacity_tokens(job.quant, p.slots, paged_context_tokens(&p.trace));
            if !cap.fits() {
                return Ok((cap, CellOutcome::Infeasible(cap)));
            }
            let mut sp = p.trace.clone();
            sp.slots = p.slots;
            sp.device = Some(DeviceTarget {
                device: job.spec.name.to_string(),
                accel: job.accel,
                threads: p.device_threads,
            });
            let backend = backend_for(job.accel, job.spec);
            run_serve(job.mf, backend, &sp)
                .map(|rep| (cap, CellOutcome::Served(Box::new(rep))))
                .map_err(|e| {
                    anyhow!("{}/{}/{}: {e:#}", job.spec.name, job.accel.key(), job.quant.name())
                })
        },
    );

    let mut cells = Vec::with_capacity(jobs.len());
    for (job, outcome) in jobs.iter().zip(outcomes) {
        let (capacity, outcome) = outcome?;
        let (_, framework) = job.spec.accel_label(job.accel);
        cells.push(FleetCell {
            device: job.spec.name.to_string(),
            platform: job.spec.platform.to_string(),
            accel: job.accel,
            framework: framework.to_string(),
            quant: job.quant,
            capacity,
            outcome,
        });
    }
    Ok(FleetReport {
        params: p.clone(),
        cells,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::random_weights;
    use crate::util::json;

    /// A reduced trace so the full default grid stays fast under test.
    fn small_fleet() -> FleetParams {
        FleetParams {
            trace: ServeParams {
                arrival_rate: 20.0,
                num_requests: 4,
                seed: 5,
                prompt_len: (2, 4),
                output_len: (2, 4),
                ..ServeParams::default()
            },
            ..FleetParams::default()
        }
    }

    /// The acceptance-criteria grid, post-paging: the default axes
    /// cover 3 devices × 2 accels × 2 quants, and the token-granular
    /// capacity charge now admits the *whole* grid — including the
    /// q8_0 @ 8-slot cells that full-context charging rejected on every
    /// 16 GiB device (the frontier-flip regression test).
    #[test]
    fn default_fleet_grid_shape_and_feasibility() {
        let mcfg = LlamaConfig::tiny();
        let dense = random_weights(&mcfg, 11);
        let p = small_fleet();
        let rep = run_fleet(&mcfg, &dense, &p).unwrap();
        assert_eq!(rep.cells.len(), 3 * 2 * 2);
        let devices: std::collections::BTreeSet<&str> =
            rep.cells.iter().map(|c| c.device.as_str()).collect();
        assert_eq!(devices.len(), 3, "all paper devices covered");
        assert_eq!(
            rep.infeasible_count(),
            0,
            "token-granular admission must serve the whole default grid"
        );
        for c in &rep.cells {
            assert!(c.is_feasible(), "{}/{}", c.device, c.quant.name());
            let m = c.metrics();
            // Every served cell reports its paged pool's footprint.
            let occ = m.kv_pool_occupancy.expect("paged cells report occupancy");
            assert!(occ > 0.0 && occ <= 1.0, "occupancy {occ}");
            assert_eq!(m.kv_prefix_share_bytes, Some(0), "sharing is off by default");
            if c.quant == QuantType::Q8_0 {
                // The flip is real: the legacy full-context charge
                // still rejects this exact cell.
                let spec = p.devices.iter().find(|d| d.name == c.device).unwrap();
                assert!(
                    !spec.serve_capacity(QuantType::Q8_0, p.slots).fits(),
                    "{}: full-context charging should reject q8_0 @ 8 slots",
                    c.device
                );
            }
        }
        // Every device has a frontier cell among the feasible ones.
        assert_eq!(rep.mbu_frontier().len(), 3);
    }

    /// The capacity gate still bites: on a shrunk-RAM device the q8_0
    /// column exceeds even the token-granular charge and comes back as
    /// structured infeasible rows, while q4_0 serves.
    #[test]
    fn shrunk_ram_device_rejects_cells_as_structured_rows() {
        let mcfg = LlamaConfig::tiny();
        let dense = random_weights(&mcfg, 17);
        let mut p = small_fleet();
        let mut tight = DeviceSpec::nanopi();
        tight.ram_bytes = 8 << 30; // q4_0 fits this trace, q8_0 cannot
        p.devices = vec![tight];
        let rep = run_fleet(&mcfg, &dense, &p).unwrap();
        assert_eq!(rep.cells.len(), 4);
        assert_eq!(rep.infeasible_count(), 2);
        for c in &rep.cells {
            match c.quant {
                QuantType::Q4_0 => assert!(c.is_feasible(), "q4_0 fits 8 GiB"),
                QuantType::Q8_0 => assert!(!c.is_feasible(), "q8_0 exceeds 8 GiB"),
                _ => {}
            }
            let m = c.metrics();
            assert_eq!(m.kv_pool_occupancy.is_some(), c.is_feasible());
            if let CellOutcome::Infeasible(cap) = &c.outcome {
                assert!(cap.need_bytes > cap.have_bytes);
                assert!(m.throughput_tok_s.is_none());
            }
        }
    }

    /// Fleet determinism: the scheduler fan-out must not change a bit of
    /// fleet.json (the property the CI fleet-smoke job `cmp`s).
    #[test]
    fn fleet_json_is_bitwise_deterministic_across_threads() {
        let mcfg = LlamaConfig::tiny();
        let dense = random_weights(&mcfg, 23);
        let mut p = small_fleet();
        // One device keeps the test quick; determinism is about ordering.
        p.devices = vec![DeviceSpec::nanopi(), DeviceSpec::macbook()];
        p.scheduler_threads = 1;
        let a = json::to_string_pretty(&run_fleet(&mcfg, &dense, &p).unwrap().to_json());
        for threads in [2usize, 8] {
            p.scheduler_threads = threads;
            let b = json::to_string_pretty(&run_fleet(&mcfg, &dense, &p).unwrap().to_json());
            assert_eq!(a, b, "scheduler_threads={threads} changed fleet.json");
        }
    }

    /// The same trace on different hardware: a comparative invariant the
    /// paper's Table 6 rests on — the MacBook GPU cell must out-serve
    /// the NanoPI BLAS cell at equal quant.
    #[test]
    fn fleet_cells_are_comparable_across_devices() {
        let mcfg = LlamaConfig::tiny();
        let dense = random_weights(&mcfg, 31);
        let p = small_fleet();
        let rep = run_fleet(&mcfg, &dense, &p).unwrap();
        let pick = |device: &str, accel: Accel| {
            rep.cells
                .iter()
                .find(|c| c.device == device && c.accel == accel && c.quant == QuantType::Q4_0)
                .unwrap()
                .metrics()
        };
        let nano = pick("NanoPI", Accel::CpuBlas);
        let mac = pick("Macbook", Accel::Gpu);
        assert!(mac.ttft.as_ref().unwrap().mean < nano.ttft.as_ref().unwrap().mean);
        assert!(mac.throughput_tok_s.unwrap() >= nano.throughput_tok_s.unwrap());
        // Same seeded trace: identical shapes → identical output volume.
        assert_eq!(nano.output_tokens, mac.output_tokens);
    }

    #[test]
    fn fleet_rejects_empty_axes() {
        let bad = FleetParams {
            quants: vec![],
            ..FleetParams::default()
        };
        assert!(bad.validate().is_err());
        let bad = FleetParams {
            slots: 0,
            ..FleetParams::default()
        };
        assert!(bad.validate().is_err());
    }
}
