//! Continuous-batching serving simulator (DESIGN.md §5).
//!
//! PR 1's benchmark decodes fixed lockstep batches; real edge serving is
//! requests that *arrive*, *queue*, *join* and *leave* batches. This
//! module is the [`ServeParams`] → `bench.json` front of the pluggable
//! serving API in [`coordinator::sim`](crate::coordinator::sim): the
//! params resolve to a [`Workload`](crate::coordinator::sim::Workload)
//! (`poisson` | `closed` | `chat`) and a
//! [`Scheduler`](crate::coordinator::sim::Scheduler)
//! (`fcfs` | `priority` | `chunked`), and
//! [`SimLoop`](crate::coordinator::sim::SimLoop) — which owns the
//! batched engine, the clock and the event queue — drives the trace.
//! Queued requests are admitted into free [`KvCache`] slots mid-flight
//! (`Engine::reset_slot` claims the slot, zeroing any stale cache),
//! active slots advance at ragged positions (`Engine::forward_spans`),
//! and finished requests retire without disturbing their neighbors.
//! With the default `fcfs` + `poisson` pair the loop reproduces the
//! pre-split monolith **bit for bit** (the golden-reference parity test
//! below), so committed baselines stay valid.
//!
//! Time is a **virtual clock**: each step is priced from the engine's
//! *measured* byte traffic and FLOPs on a roofline
//! (`t = max(bytes/eff_bw, flops/eff_flops)`) — by default the flat
//! `peak_bw`/`peak_flops` pair, or, with [`ServeParams::device`] set, a
//! [`DeviceClock`] derived from the device simulator's calibration
//! (thread contention, per-accel/quant achievable bandwidth — DESIGN.md
//! §2/§5), gated by RAM-capacity admission. Either way the engine really
//! executes every token (logits, KV and token streams are real), while
//! the clock is deterministic, so a seeded run reproduces bit-identical
//! latency percentiles on any machine and any `--threads` value. That
//! determinism is what lets CI compare `bench.json` against a committed
//! baseline with tight tolerance bands, and what makes `elib fleet`'s
//! device × accel × quant cells comparable.
//!
//! [`KvCache`]: crate::graph::KvCache

use anyhow::{anyhow, Result};

use crate::device::{Accel, DeviceClock, DeviceSpec, Thermal};
use crate::gguf::ModelFile;
use crate::graph::{Engine, KvLayout, KvPoolStats, KV_BLOCK_TOKENS};
use crate::kernel::BackendKind;
use crate::metrics::{self, Outcome, RequestRecord, Slo, SloTier, TierAttainment};
use crate::model::{scale, LlamaConfig, ModelWeights};
use crate::quant::QuantType;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats::Summary;

use super::registry;
use super::sim::{KvReuse, Scheduler, SchedulerPolicy, SimLoop, Workload};

/// Salt mixed into the trace seed for the SLO tier stream, so assigning
/// tiers never perturbs the trace RNG — the token trace is identical
/// with and without SLOs, which is what makes goodput comparable across
/// schedulers.
const SLO_TIER_SEED_SALT: u64 = 0x534c_4f5f_5449_4552; // "SLO_TIER"

/// How requests enter the system (the built-in
/// [`Workload`](crate::coordinator::sim::Workload) the params resolve to).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArrivalMode {
    /// Open loop: arrivals are a Poisson process at `arrival_rate` req/s.
    Poisson,
    /// Closed loop: `clients` users, each submitting its next request the
    /// moment the previous one finishes (arrival = completion time).
    ClosedLoop { clients: usize },
    /// Multi-turn chat sessions: `num_requests` *sessions* arrive as a
    /// Poisson process at `arrival_rate`, each with `turns ∈ [lo, hi]`
    /// turns. Follow-up turns reuse their session's KV prefix instead
    /// of re-prefilling (DESIGN.md §5).
    Chat { turns: (usize, usize) },
    /// Open loop with diurnal sine-modulated Poisson arrivals (the rate
    /// swings ±80% around `arrival_rate` over two cycles of the trace).
    Diurnal,
    /// Open loop with a flash-crowd burst: the middle half of the trace
    /// arrives at 8× `arrival_rate`.
    FlashCrowd,
    /// Open loop with heavy-tailed (log-normal) prompt lengths at the
    /// base Poisson rate.
    HeavyTail,
}

impl ArrivalMode {
    pub fn label(&self) -> &'static str {
        match self {
            ArrivalMode::Poisson => "poisson",
            ArrivalMode::ClosedLoop { .. } => "closed",
            ArrivalMode::Chat { .. } => "chat",
            ArrivalMode::Diurnal => "diurnal",
            ArrivalMode::FlashCrowd => "flash-crowd",
            ArrivalMode::HeavyTail => "heavy-tail",
        }
    }

    /// Open-loop modes draw every arrival up front and release nothing
    /// dynamically — the modes SLOs are defined for (a deadline against
    /// a completion-coupled arrival process measures the client, not the
    /// server).
    pub fn is_open_loop(&self) -> bool {
        !matches!(self, ArrivalMode::ClosedLoop { .. } | ArrivalMode::Chat { .. })
    }

    /// Resolve to the built-in workload implementation through the
    /// single [registry](crate::coordinator::registry) table — the mode
    /// label is the registry key, so the accepted names and the
    /// `bench.json` strings can never drift apart.
    fn workload(&self, p: &ServeParams) -> Box<dyn Workload> {
        let entry = registry::workload_entry(self.label())
            .expect("every ArrivalMode label is registered");
        let knobs = registry::WorkloadKnobs {
            rate: p.arrival_rate,
            n: p.num_requests,
            prompt_len: p.prompt_len,
            output_len: p.output_len,
            clients: match *self {
                ArrivalMode::ClosedLoop { clients } => Some(clients),
                _ => None,
            },
            turns: match *self {
                ArrivalMode::Chat { turns } => Some(turns),
                _ => None,
            },
        };
        (entry.build)(&knobs)
    }
}

/// Base TTFT/TPOT deadlines (virtual seconds) for the *interactive*
/// tier; the seeded tier draw relaxes them by
/// [`SloTier::multiplier`] (×1 / ×4 / ×16). Either deadline may be
/// `f64::INFINITY` (that constraint never binds) — infinite deadlines
/// serialize as absent keys, since JSON cannot represent them.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloSpec {
    pub ttft: f64,
    pub tpot: f64,
}

/// Price the serve clock on a simulated edge device instead of the flat
/// roofline: the [`DeviceClock`] is derived from the named
/// [`DeviceSpec`]'s calibration (thread contention, per-accel/quant
/// achievable bandwidth), scaled so tiny-engine steps take the virtual
/// time the 7B deployment would, and the RAM-capacity admission gate
/// applies (DESIGN.md §5).
#[derive(Clone, Debug, PartialEq)]
pub struct DeviceTarget {
    /// Device name (`DeviceSpec::by_name`).
    pub device: String,
    pub accel: Accel,
    /// Device CPU threads the contention model is evaluated at.
    pub threads: usize,
}

/// Inputs of one serve run (`elib serve`). Everything that shapes the
/// trace is here, so (params, model, backend) → bit-identical output.
/// Construct with [`ServeParams::builder`].
///
/// (The `#[deprecated]` `RooflineParams` alias that used to live here —
/// a flat `(peak_bw, peak_flops)` pair collapsed from a device clock —
/// was removed when the builder landed: capture a flat roofline by
/// building with `.peak_bw(..)`/`.peak_flops(..)` and no `.device(..)`.)
#[derive(Clone, Debug)]
pub struct ServeParams {
    /// Mean arrivals per virtual second (Poisson mode).
    pub arrival_rate: f64,
    pub num_requests: usize,
    /// Seeds request shapes, prompt tokens and arrival times.
    pub seed: u64,
    /// Engine sequence slots = max concurrent requests.
    pub slots: usize,
    /// Prompt length range `[lo, hi]`, inclusive.
    pub prompt_len: (usize, usize),
    /// Output length range `[lo, hi]`, inclusive.
    pub output_len: (usize, usize),
    pub mode: ArrivalMode,
    /// Virtual peak memory bandwidth (B/s) for step pricing + MBU. The
    /// default is scaled *down* in proportion to the tiny model standing
    /// in for the paper's 7B deployment (~0.5 MB vs ~3.5 GB of weights),
    /// so a decode step prices at edge-realistic milliseconds and the
    /// default `--arrival-rate 4` actually queues — the regime the RQ2
    /// latency constraint is about.
    pub peak_bw: f64,
    /// Virtual peak compute (FLOP/s) for step pricing, scaled like
    /// `peak_bw`; the defaults keep decode bandwidth-bound (the edge
    /// regime the paper argues), so MBU under load runs high.
    pub peak_flops: f64,
    /// Price the clock on a simulated device instead of the flat
    /// `peak_bw`/`peak_flops` pair. When set, the resolved
    /// [`DeviceClock`] overwrites those two fields in the report's
    /// params (same JSON keys — the bench.json schema is unchanged; a
    /// `device` object is *added*), MBU-under-load is reported against
    /// the device's scaled peak bandwidth, and the RAM-capacity gate
    /// must admit the 7B-scale deployment.
    pub device: Option<DeviceTarget>,
    /// Admission + prefill policy (DESIGN.md §5). `Fcfs` is the PR-2
    /// behavior bit for bit; `Priority` admits by seeded tier;
    /// `Chunked` bounds multi-token prefill spans.
    pub scheduler: SchedulerPolicy,
    /// Keep every sampling event's logits per request (tests only —
    /// not serialized into `bench.json`).
    pub capture_logits: bool,
    /// Cap the paged KV pool at this many blocks: the loop defers
    /// admissions that would oversubscribe it (reported as
    /// `deferred_admissions`). `None` (default) gates on free slots
    /// only — the pre-paged behavior bit for bit.
    pub pool_blocks: Option<usize>,
    /// Fork identical prompt prefixes copy-on-write at admission
    /// instead of re-prefilling them. Changes step timing, never
    /// tokens; off by default so baselines stay bit-identical.
    pub prefix_share: bool,
    /// Prepend this many seeded shared "system prompt" tokens to every
    /// conversation's first prompt (0 = off). With `prefix_share` this
    /// is the workload where copy-on-write sharing pays.
    pub system_prompt: usize,
    /// Attach per-request TTFT/TPOT deadlines (DESIGN.md §5): each
    /// request draws a seeded tier (interactive/standard/batch) that
    /// relaxes these base deadlines ×1/×4/×16, and the report gains
    /// `goodput` + per-tier attainment. `None` (default) = no SLOs, no
    /// new bench.json keys — the committed baseline stays valid.
    /// Open-loop modes only.
    pub slo: Option<SloSpec>,
    /// Thermal throttling: derate `eff_flops` toward `floor` with busy
    /// virtual time constant `tau` (see [`Thermal`]). `None` (default)
    /// prices steps exactly as the un-throttled clock, bit for bit.
    pub thermal: Option<Thermal>,
}

impl Default for ServeParams {
    fn default() -> Self {
        Self {
            arrival_rate: 4.0,
            num_requests: 64,
            seed: 7,
            slots: 4,
            prompt_len: (8, 24),
            output_len: (4, 24),
            mode: ArrivalMode::Poisson,
            peak_bw: 100e6,
            peak_flops: 2e9,
            device: None,
            scheduler: SchedulerPolicy::Fcfs,
            capture_logits: false,
            pool_blocks: None,
            prefix_share: false,
            system_prompt: 0,
            slo: None,
            thermal: None,
        }
    }
}

/// Fluent constructor for [`ServeParams`] — the API every scenario PR
/// plugs into: `ServeParams::builder().workload(..).scheduler(..)`.
#[derive(Clone, Debug, Default)]
pub struct ServeParamsBuilder {
    p: ServeParams,
}

impl ServeParamsBuilder {
    pub fn arrival_rate(mut self, rate: f64) -> Self {
        self.p.arrival_rate = rate;
        self
    }

    pub fn num_requests(mut self, n: usize) -> Self {
        self.p.num_requests = n;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.p.seed = seed;
        self
    }

    pub fn slots(mut self, slots: usize) -> Self {
        self.p.slots = slots;
        self
    }

    pub fn prompt_len(mut self, lo: usize, hi: usize) -> Self {
        self.p.prompt_len = (lo, hi);
        self
    }

    pub fn output_len(mut self, lo: usize, hi: usize) -> Self {
        self.p.output_len = (lo, hi);
        self
    }

    /// The workload identity (`poisson` | `closed` | `chat`).
    pub fn workload(mut self, mode: ArrivalMode) -> Self {
        self.p.mode = mode;
        self
    }

    /// The scheduler identity (`fcfs` | `priority` | `chunked`).
    pub fn scheduler(mut self, scheduler: SchedulerPolicy) -> Self {
        self.p.scheduler = scheduler;
        self
    }

    pub fn peak_bw(mut self, bw: f64) -> Self {
        self.p.peak_bw = bw;
        self
    }

    pub fn peak_flops(mut self, flops: f64) -> Self {
        self.p.peak_flops = flops;
        self
    }

    /// Price the clock on a simulated device instead of the flat pair.
    pub fn device(mut self, target: DeviceTarget) -> Self {
        self.p.device = Some(target);
        self
    }

    pub fn capture_logits(mut self, capture: bool) -> Self {
        self.p.capture_logits = capture;
        self
    }

    /// Cap the paged KV pool (admission gate); `None` = free slots only.
    pub fn pool_blocks(mut self, blocks: Option<usize>) -> Self {
        self.p.pool_blocks = blocks;
        self
    }

    /// Fork identical prompt prefixes copy-on-write at admission.
    pub fn prefix_share(mut self, share: bool) -> Self {
        self.p.prefix_share = share;
        self
    }

    /// Shared seeded system-prompt tokens prepended to first prompts.
    pub fn system_prompt(mut self, tokens: usize) -> Self {
        self.p.system_prompt = tokens;
        self
    }

    /// Attach per-request SLOs: base interactive-tier TTFT/TPOT
    /// deadlines in virtual seconds (either may be `f64::INFINITY`).
    pub fn slo(mut self, ttft: f64, tpot: f64) -> Self {
        self.p.slo = Some(SloSpec { ttft, tpot });
        self
    }

    /// Thermal throttling: derate compute toward `floor` over busy time
    /// constant `tau` virtual seconds.
    pub fn thermal(mut self, tau: f64, floor: f64) -> Self {
        self.p.thermal = Some(Thermal { tau, floor });
        self
    }

    /// Validate and return the params.
    pub fn build(self) -> Result<ServeParams> {
        self.p.validate()?;
        Ok(self.p)
    }
}

impl ServeParams {
    /// Start a builder from the defaults:
    /// `ServeParams::builder().workload(..).scheduler(..).build()`.
    pub fn builder() -> ServeParamsBuilder {
        ServeParamsBuilder::default()
    }

    pub fn validate(&self) -> Result<()> {
        anyhow::ensure!(self.num_requests >= 1, "serve needs at least one request");
        anyhow::ensure!(self.slots >= 1, "serve needs at least one slot");
        anyhow::ensure!(
            self.prompt_len.0 >= 1 && self.prompt_len.0 <= self.prompt_len.1,
            "bad prompt length range {:?}",
            self.prompt_len
        );
        anyhow::ensure!(
            self.output_len.0 >= 1 && self.output_len.0 <= self.output_len.1,
            "bad output length range {:?}",
            self.output_len
        );
        anyhow::ensure!(
            self.peak_bw.is_finite() && self.peak_bw > 0.0,
            "peak_bw must be positive"
        );
        anyhow::ensure!(
            self.peak_flops.is_finite() && self.peak_flops > 0.0,
            "peak_flops must be positive"
        );
        match self.mode {
            ArrivalMode::Poisson => anyhow::ensure!(
                self.arrival_rate.is_finite() && self.arrival_rate > 0.0,
                "arrival rate must be positive"
            ),
            ArrivalMode::ClosedLoop { clients } => {
                anyhow::ensure!(clients >= 1, "closed loop needs at least one client")
            }
            ArrivalMode::Chat { turns } => {
                anyhow::ensure!(
                    self.arrival_rate.is_finite() && self.arrival_rate > 0.0,
                    "arrival rate must be positive"
                );
                anyhow::ensure!(
                    turns.0 >= 1 && turns.0 <= turns.1,
                    "bad chat turn range {turns:?}"
                );
            }
            ArrivalMode::Diurnal | ArrivalMode::FlashCrowd | ArrivalMode::HeavyTail => {
                anyhow::ensure!(
                    self.arrival_rate.is_finite() && self.arrival_rate > 0.0,
                    "arrival rate must be positive"
                )
            }
        }
        self.scheduler.validate()?;
        if let Some(slo) = &self.slo {
            anyhow::ensure!(
                self.mode.is_open_loop(),
                "SLOs need an open-loop workload ({} couples arrivals to completions)",
                self.mode.label()
            );
            anyhow::ensure!(
                !slo.ttft.is_nan() && slo.ttft > 0.0,
                "slo ttft deadline must be positive"
            );
            anyhow::ensure!(
                !slo.tpot.is_nan() && slo.tpot > 0.0,
                "slo tpot deadline must be positive"
            );
        } else {
            anyhow::ensure!(
                self.scheduler != SchedulerPolicy::SloAware,
                "the slo-aware scheduler needs SLOs (set --slo-ttft and/or --slo-tpot)"
            );
        }
        if let Some(t) = &self.thermal {
            anyhow::ensure!(
                t.tau.is_finite() && t.tau > 0.0,
                "thermal tau must be positive"
            );
            anyhow::ensure!(
                t.floor > 0.0 && t.floor <= 1.0,
                "thermal floor must be in (0, 1]"
            );
        }
        anyhow::ensure!(
            self.pool_blocks != Some(0),
            "kv pool budget must be at least one block"
        );
        if let Some(t) = &self.device {
            anyhow::ensure!(!t.device.is_empty(), "device target needs a name");
            anyhow::ensure!(t.threads >= 1, "device target needs at least one thread");
        }
        Ok(())
    }

    pub(crate) fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("arrival_rate", Json::Num(self.arrival_rate)),
            ("num_requests", Json::Num(self.num_requests as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("slots", Json::Num(self.slots as f64)),
            (
                "prompt_len",
                Json::Arr(vec![
                    Json::Num(self.prompt_len.0 as f64),
                    Json::Num(self.prompt_len.1 as f64),
                ]),
            ),
            (
                "output_len",
                Json::Arr(vec![
                    Json::Num(self.output_len.0 as f64),
                    Json::Num(self.output_len.1 as f64),
                ]),
            ),
            ("mode", Json::Str(self.mode.label().into())),
            ("peak_bw", Json::Num(self.peak_bw)),
            ("peak_flops", Json::Num(self.peak_flops)),
        ];
        if let ArrivalMode::ClosedLoop { clients } = self.mode {
            pairs.push(("clients", Json::Num(clients as f64)));
        }
        // Workload/scheduler identity is additive: the default
        // fcfs + poisson run serializes exactly the pre-split schema
        // (absent keys mean fcfs/poisson — `compare_bench` and the
        // committed `ci/bench_baseline.json` rely on that), while chat
        // runs add `turns` and non-FCFS runs add `scheduler` (+
        // `chunk_tokens`), all treated as identity by the comparator.
        if let ArrivalMode::Chat { turns } = self.mode {
            pairs.push((
                "turns",
                Json::Arr(vec![Json::Num(turns.0 as f64), Json::Num(turns.1 as f64)]),
            ));
        }
        match self.scheduler {
            SchedulerPolicy::Fcfs => {}
            SchedulerPolicy::Priority | SchedulerPolicy::SloAware => {
                pairs.push(("scheduler", Json::Str(self.scheduler.label().into())));
            }
            SchedulerPolicy::Chunked { chunk_tokens } => {
                pairs.push(("scheduler", Json::Str(self.scheduler.label().into())));
                pairs.push(("chunk_tokens", Json::Num(chunk_tokens as f64)));
            }
        }
        // SLO + thermal knobs, additive like the rest. Infinite
        // deadlines are absent (JSON has no Infinity); an SLO run with
        // both deadlines infinite still serializes `scheduler`/tier
        // stats, so its identity never collides with a no-SLO run of
        // the same shape in practice.
        if let Some(slo) = &self.slo {
            if slo.ttft.is_finite() {
                pairs.push(("slo_ttft", Json::Num(slo.ttft)));
            }
            if slo.tpot.is_finite() {
                pairs.push(("slo_tpot", Json::Num(slo.tpot)));
            }
        }
        if let Some(t) = &self.thermal {
            pairs.push(("thermal_tau", Json::Num(t.tau)));
            pairs.push(("thermal_floor", Json::Num(t.floor)));
        }
        // Paged-pool knobs, additive like the rest: defaults (no
        // budget, no sharing, no system prompt) serialize nothing, so
        // the pre-paged schema is byte-identical.
        if let Some(blocks) = self.pool_blocks {
            pairs.push(("kv_pool_blocks", Json::Num(blocks as f64)));
        }
        if self.prefix_share {
            pairs.push(("kv_prefix_share", Json::Bool(true)));
        }
        if self.system_prompt > 0 {
            pairs.push(("system_prompt", Json::Num(self.system_prompt as f64)));
        }
        // Additive: flat-roofline runs (device: None) serialize exactly
        // the pre-fleet schema, so old baselines stay comparable.
        if let Some(t) = &self.device {
            pairs.push((
                "device",
                Json::obj(vec![
                    ("name", Json::Str(t.device.clone())),
                    ("accel", Json::Str(t.accel.key().into())),
                    ("threads", Json::Num(t.threads as f64)),
                ]),
            ));
        }
        Json::obj(pairs)
    }
}

/// Everything one serve run produced: per-request records, the full token
/// streams, and per-step load/MBU time series. `to_json` is the
/// `bench.json` schema the regression CI compares.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub params: ServeParams,
    pub backend: String,
    pub quant: String,
    /// Resolved workload identity key (`params.mode.label()`).
    pub workload: String,
    /// Resolved scheduler identity key (`params.scheduler.label()`).
    pub scheduler: String,
    /// Chat-workload KV-prefix reuse accounting (zero otherwise).
    pub reuse: KvReuse,
    /// One record per request, indexed by request id.
    pub records: Vec<RequestRecord>,
    /// Full token stream (prompt + outputs) per request id.
    pub sequences: Vec<Vec<u32>>,
    /// Per request: logits at each sampling event (only when
    /// `capture_logits`; never serialized).
    pub captured_logits: Vec<Vec<Vec<f32>>>,
    /// Virtual clock after each engine step.
    pub step_t: Vec<f64>,
    /// Requests waiting (not yet admitted) at each step.
    pub step_queue: Vec<usize>,
    /// Active slots at each step.
    pub step_active: Vec<usize>,
    /// Batch-aware MBU at each step (0.0 for pure-prefill steps that
    /// generated no token).
    pub step_mbu: Vec<f64>,
    pub output_tokens: usize,
    /// Virtual time of the last completion.
    pub makespan_secs: f64,
    /// Admissions the kv pool block budget deferred (0 without one).
    pub deferred_admissions: usize,
    /// Requests the scheduler shed before admission (0 without SLOs).
    pub shed_requests: usize,
    /// In-flight requests the scheduler preempted (0 without SLOs).
    pub preempted_requests: usize,
    /// Paged-pool counters at the end of the run (`None` on the
    /// slot-layout reference engine).
    pub kv_pool: Option<KvPoolStats>,
}

impl ServeReport {
    /// Records that ran to completion. Latency summaries are defined
    /// over these only — a shed request has no TTFT, and averaging in
    /// its zero-length life would reward shedding with better latency.
    fn served(&self) -> impl Iterator<Item = &RequestRecord> {
        self.records.iter().filter(|r| r.outcome == Outcome::Served)
    }

    /// `None` when no request was served (an all-shed run has no TTFT).
    pub fn ttft_summary(&self) -> Option<Summary> {
        Summary::of_opt(&self.served().map(RequestRecord::ttft).collect::<Vec<_>>())
    }

    /// `None` when no request was served.
    pub fn tpot_summary(&self) -> Option<Summary> {
        Summary::of_opt(&self.served().map(RequestRecord::tpot).collect::<Vec<_>>())
    }

    /// `None` when no request was served.
    pub fn queue_wait_summary(&self) -> Option<Summary> {
        Summary::of_opt(
            &self
                .served()
                .map(RequestRecord::queue_wait)
                .collect::<Vec<_>>(),
        )
    }

    /// SLO-attained token fraction (DESIGN.md §5): attained target
    /// tokens over all target tokens, `None` when the run carried no
    /// SLOs — consumers serialize that as an absent key.
    pub fn goodput(&self) -> Option<f64> {
        metrics::goodput(&self.records)
    }

    /// Per-tier SLO attainment rollup (empty without SLOs).
    pub fn tier_attainment(&self) -> Vec<TierAttainment> {
        metrics::tier_attainment(&self.records)
    }

    /// Aggregate output tokens per virtual second over the whole run.
    pub fn throughput_tok_s(&self) -> f64 {
        if self.makespan_secs <= 0.0 {
            0.0
        } else {
            self.output_tokens as f64 / self.makespan_secs
        }
    }

    /// MBU-under-load over token-generating steps (prefill-only steps are
    /// load, not token production, so they are excluded here and zero in
    /// the series). `None` means the run had no token-generating steps;
    /// consumers serialize that as `null` — never as a fake 0.0 — in
    /// both `bench.json` and `fleet.json`.
    pub fn mbu_summary(&self) -> Option<Summary> {
        let xs: Vec<f64> = self.step_mbu.iter().copied().filter(|m| *m > 0.0).collect();
        if xs.is_empty() {
            None
        } else {
            Some(Summary::of(&xs))
        }
    }

    pub fn queue_depth_mean(&self) -> f64 {
        if self.step_queue.is_empty() {
            0.0
        } else {
            self.step_queue.iter().sum::<usize>() as f64 / self.step_queue.len() as f64
        }
    }

    pub fn queue_depth_max(&self) -> usize {
        self.step_queue.iter().copied().max().unwrap_or(0)
    }

    /// FNV-1a over all token streams — a compact fingerprint the baseline
    /// comparison uses to spot token drift.
    pub fn tokens_fnv(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |b: u8| {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        };
        for seq in &self.sequences {
            for b in (seq.len() as u32).to_le_bytes() {
                mix(b);
            }
            for t in seq {
                for b in t.to_le_bytes() {
                    mix(b);
                }
            }
        }
        h
    }

    /// The `bench.json` document (deterministic: BTreeMap key order,
    /// shortest-round-trip floats, virtual-clock values only).
    pub fn to_json(&self) -> Json {
        // Latency summaries are over served requests; an all-shed run
        // has none, which serializes `null` (same convention as MBU).
        let sum = |s: &Option<Summary>| match s {
            Some(s) => Json::obj(vec![
                ("mean", Json::Num(s.mean)),
                ("p50", Json::Num(s.p50)),
                ("p95", Json::Num(s.p95)),
                ("p99", Json::Num(s.p99)),
                ("max", Json::Num(s.max)),
            ]),
            None => Json::Null,
        };
        let mbu = self.mbu_summary();
        // Chat runs report KV-prefix reuse; the key is additive (absent
        // for poisson/closed, so the pre-split schema is unchanged).
        let mut aggregate = vec![
            ("num_requests", Json::Num(self.records.len() as f64)),
            ("output_tokens", Json::Num(self.output_tokens as f64)),
            ("steps", Json::Num(self.step_t.len() as f64)),
            ("makespan_secs", Json::Num(self.makespan_secs)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s())),
            ("ttft", sum(&self.ttft_summary())),
            ("tpot", sum(&self.tpot_summary())),
            ("queue_wait", sum(&self.queue_wait_summary())),
            ("queue_depth_mean", Json::Num(self.queue_depth_mean())),
            ("queue_depth_max", Json::Num(self.queue_depth_max() as f64)),
            // Empty (no token-generating steps) serializes `null`, not a
            // fake 0.0 — mirrored by fleet.json's cell rows.
            (
                "mbu_mean",
                mbu.as_ref().map_or(Json::Null, |s| Json::Num(s.mean)),
            ),
            (
                "mbu_p50",
                mbu.as_ref().map_or(Json::Null, |s| Json::Num(s.p50)),
            ),
            (
                "mbu_max",
                mbu.as_ref().map_or(Json::Null, |s| Json::Num(s.max)),
            ),
            (
                "tokens_fnv",
                Json::Str(format!("{:016x}", self.tokens_fnv())),
            ),
        ];
        // SLO block, additive: present only when the run carried SLOs,
        // so the committed no-SLO baseline's aggregate is unchanged.
        if self.params.slo.is_some() {
            aggregate.push((
                "goodput",
                self.goodput().map_or(Json::Null, Json::Num),
            ));
            aggregate.push(("shed_requests", Json::Num(self.shed_requests as f64)));
            aggregate.push((
                "preempted_requests",
                Json::Num(self.preempted_requests as f64),
            ));
            aggregate.push((
                "slo_tiers",
                Json::Arr(
                    self.tier_attainment()
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("tier", Json::Str(t.tier.key().into())),
                                ("requests", Json::Num(t.requests as f64)),
                                (
                                    "attained_requests",
                                    Json::Num(t.attained_requests as f64),
                                ),
                                ("target_tokens", Json::Num(t.target_tokens as f64)),
                                ("attained_tokens", Json::Num(t.attained_tokens as f64)),
                                ("token_fraction", Json::Num(t.token_fraction())),
                            ])
                        })
                        .collect(),
                ),
            ));
        }
        if self.workload == "chat" {
            aggregate.push((
                "kv_reuse",
                Json::obj(vec![
                    ("reused_turns", Json::Num(self.reuse.reused_turns as f64)),
                    ("reused_tokens", Json::Num(self.reuse.reused_tokens as f64)),
                ]),
            ));
        }
        // Paged-pool occupancy and prefix-share accounting (absent on
        // the slot-layout reference engine, present on every paged run
        // — the default — so bench.json carries the pool's footprint).
        if let Some(pool) = &self.kv_pool {
            aggregate.push((
                "kv_pool",
                Json::obj(vec![
                    ("block_tokens", Json::Num(pool.block_tokens as f64)),
                    ("blocks_total", Json::Num(pool.blocks_total as f64)),
                    (
                        "peak_blocks_in_use",
                        Json::Num(pool.peak_blocks_in_use as f64),
                    ),
                    ("occupancy_peak", Json::Num(pool.peak_occupancy())),
                    ("cow_copies", Json::Num(pool.cow_copies as f64)),
                    ("prefix_forks", Json::Num(pool.prefix_forks as f64)),
                    ("shared_tokens", Json::Num(pool.shared_tokens as f64)),
                    ("prefix_share_bytes", Json::Num(pool.shared_bytes as f64)),
                    (
                        "deferred_admissions",
                        Json::Num(self.deferred_admissions as f64),
                    ),
                ]),
            ));
        }
        Json::obj(vec![
            ("schema", Json::Num(1.0)),
            ("scenario", Json::Str("serve".into())),
            ("params", self.params.to_json()),
            (
                "model",
                Json::obj(vec![
                    ("quant", Json::Str(self.quant.clone())),
                    // Backend *class* only: the kernel thread count does
                    // not change a single bit of the trace (see the
                    // thread-determinism test), so it must not change
                    // bench.json either.
                    (
                        "backend",
                        Json::Str(
                            self.backend
                                .split('(')
                                .next()
                                .unwrap_or(&self.backend)
                                .to_string(),
                        ),
                    ),
                ]),
            ),
            ("aggregate", Json::obj(aggregate)),
            (
                "requests",
                Json::Arr(self.records.iter().map(RequestRecord::to_json).collect()),
            ),
            (
                "series",
                Json::obj(vec![
                    (
                        "t",
                        Json::Arr(self.step_t.iter().map(|v| Json::Num(*v)).collect()),
                    ),
                    (
                        "queue_depth",
                        Json::Arr(
                            self.step_queue.iter().map(|v| Json::Num(*v as f64)).collect(),
                        ),
                    ),
                    (
                        "active",
                        Json::Arr(
                            self.step_active.iter().map(|v| Json::Num(*v as f64)).collect(),
                        ),
                    ),
                    (
                        "mbu",
                        Json::Arr(self.step_mbu.iter().map(|v| Json::Num(*v)).collect()),
                    ),
                ]),
            ),
        ])
    }
}

/// Resolve the pricing clock for a serve run: the flat
/// `peak_bw`/`peak_flops` roofline by default, or — when
/// [`ServeParams::device`] is set — a [`DeviceClock`] derived from the
/// device's calibration and scaled by `served_model_bytes / 7B_bytes`
/// so tiny-engine steps price at 7B-realistic virtual seconds. Also
/// enforces the RAM-capacity admission gate for device-priced runs.
pub fn resolve_clock(
    p: &ServeParams,
    model_cfg: &LlamaConfig,
    qtype: QuantType,
) -> Result<DeviceClock> {
    let Some(t) = &p.device else {
        return Ok(DeviceClock::flat(p.peak_bw, p.peak_flops));
    };
    let spec = DeviceSpec::by_name(&t.device)
        .ok_or_else(|| anyhow!("unknown device `{}` in serve params", t.device))?;
    // Token-granular admission: a paged pool only holds blocks for
    // positions the trace actually caches, so the 7B-scale RAM charge
    // is this trace's worst per-slot context (block-rounded) — not the
    // full model window. This is what flips previously infeasible
    // high-slot cells feasible on 16 GiB devices.
    let cap = spec.serve_capacity_tokens(qtype, p.slots, paged_context_tokens(p));
    anyhow::ensure!(
        cap.fits(),
        "infeasible: a 7B-scale {} deployment with {} slots needs {} bytes of RAM \
         but {} has {} (drop slots or pick a smaller quant)",
        qtype.name(),
        p.slots,
        cap.need_bytes,
        spec.name,
        cap.have_bytes
    );
    let served = scale::model_file_bytes(model_cfg, qtype) as f64;
    let deployed = scale::model_file_bytes(&LlamaConfig::llama_7b(), qtype) as f64;
    Ok(spec.clock(t.accel, qtype, t.threads).scaled(served / deployed))
}

/// The worst per-slot context this trace can cache, rounded up to the
/// paged allocator's block size — the token count behind the
/// token-granular RAM admission charge
/// ([`DeviceSpec::serve_capacity_tokens`]).
pub fn paged_context_tokens(p: &ServeParams) -> usize {
    let worst = match p.mode {
        ArrivalMode::Chat { turns } => turns.1 * (p.prompt_len.1 + p.output_len.1 + 1),
        _ => p.prompt_len.1 + p.output_len.1,
    } + p.system_prompt;
    worst.div_ceil(KV_BLOCK_TOKENS) * KV_BLOCK_TOKENS
}

/// Decorate a freshly built request set with the params' seeded
/// system-prompt prefix and SLO tiers. Shared by `run_serve_layout` and
/// the cluster runner (which builds the trace once and must apply
/// exactly these decorations before cloning it per replica).
pub(crate) fn decorate_requests(
    requests: &mut [crate::coordinator::sim::Request],
    p: &ServeParams,
    vocab: usize,
) {
    if p.system_prompt > 0 {
        // One shared seeded token run, prepended to every
        // conversation's *first* prompt (follow-up chat turns inherit
        // it through their session's cache). Salted off the trace seed
        // so the workload's own draws are untouched.
        let mut srng = Rng::new(p.seed ^ 0x5157_5F50_524F_4D50);
        let sys: Vec<u32> = (0..p.system_prompt)
            .map(|_| srng.below(vocab as u64) as u32)
            .collect();
        for r in requests.iter_mut() {
            if r.session.as_ref().map_or(true, |s| s.turn == 0) {
                let mut prompt = sys.clone();
                prompt.extend_from_slice(&r.prompt);
                r.prompt = prompt;
            }
        }
    }
    if let Some(spec) = &p.slo {
        // Seeded tier assignment (DESIGN.md §5): a salted side-stream
        // draws each request's tier in id order — 2:3:5
        // interactive:standard:batch, the PriorityTiers split — and the
        // tier multiplier relaxes the base deadlines. The trace RNG is
        // untouched, so the token trace is bit-identical to the no-SLO
        // run and identical across schedulers.
        let mut srng = Rng::new(p.seed ^ SLO_TIER_SEED_SALT);
        for r in requests.iter_mut() {
            let d = srng.below(10);
            let tier = if d < 2 {
                SloTier::Interactive
            } else if d < 5 {
                SloTier::Standard
            } else {
                SloTier::Batch
            };
            r.slo = Some(Slo {
                tier,
                ttft: spec.ttft * tier.multiplier(),
                tpot: spec.tpot * tier.multiplier(),
            });
        }
    }
}

/// Run the serving scenario: resolve the params into a workload and a
/// scheduler, then drive the seeded request trace through [`SimLoop`]
/// (continuous batching over the batched engine) and assemble the full
/// report. Uses the default (paged) KV layout.
pub fn run_serve(mf: &ModelFile, backend: BackendKind, p: &ServeParams) -> Result<ServeReport> {
    run_serve_layout(mf, backend, p, KvLayout::default())
}

/// [`run_serve`] with an explicit KV layout. `KvLayout::Slot` is the
/// retained pre-paged reference: the parity suite runs every scheduler
/// × workload pair through both layouts and demands bitwise-identical
/// traces.
pub fn run_serve_layout(
    mf: &ModelFile,
    backend: BackendKind,
    p: &ServeParams,
    layout: KvLayout,
) -> Result<ServeReport> {
    p.validate()?;
    let weights = ModelWeights::load(mf)?;
    let qtype = weights.qtype;
    let quant = qtype.name().to_string();
    let engine = Engine::new_batched_layout(weights, backend, p.slots, layout);
    let vocab = engine.config().vocab_size;
    let max_seq = engine.config().max_seq_len;
    // A slot's context holds one request's prompt + outputs — or, for
    // chat, a whole session (every turn's bridge + delta + outputs) —
    // plus any shared system prompt on the first turn.
    let worst_context = match p.mode {
        ArrivalMode::Chat { turns } => turns.1 * (p.prompt_len.1 + p.output_len.1 + 1),
        _ => p.prompt_len.1 + p.output_len.1,
    } + p.system_prompt;
    match p.mode {
        ArrivalMode::Chat { turns } => anyhow::ensure!(
            worst_context <= max_seq,
            "a {}-turn chat session of prompt+output ({} + {}) needs up to {worst_context} \
             context tokens, exceeding the window {max_seq}",
            turns.1,
            p.prompt_len.1,
            p.output_len.1
        ),
        _ => anyhow::ensure!(
            worst_context <= max_seq,
            "prompt+output ({} + {}) exceeds the context window {max_seq}",
            p.prompt_len.1,
            p.output_len.1
        ),
    }
    let mut clock = resolve_clock(p, engine.config(), qtype)?;
    if let Some(t) = &p.thermal {
        clock = clock.with_thermal(t.tau, t.floor);
    }
    // The report's params carry the rates actually used for pricing, in
    // the same keys the flat roofline wrote — device runs stay schema-
    // compatible with pre-fleet bench.json consumers. (`peak_flops` is
    // the *cold* rate; thermal derating is a time-varying factor on top,
    // recorded by the `thermal_tau`/`thermal_floor` identity keys.)
    let mut resolved = p.clone();
    resolved.peak_bw = clock.eff_bw;
    resolved.peak_flops = clock.eff_flops;

    // Shapes and arrivals are drawn by the workload from the trace RNG
    // (a pure function of seed + params); the scheduler is resolved
    // from its descriptor, with any priority stream salted off the
    // same seed so the trace itself is scheduler-invariant.
    let mut workload = p.mode.workload(p);
    let mut scheduler: Box<dyn Scheduler> = p.scheduler.build(p.seed);
    let mut rng = Rng::new(p.seed);
    let mut requests = workload.build(&mut rng, vocab);
    decorate_requests(&mut requests, p, vocab);
    let out = SimLoop::new(engine, clock, p.capture_logits)
        .with_pool_blocks(p.pool_blocks)
        .with_prefix_share(p.prefix_share)
        .run(requests, workload.as_mut(), scheduler.as_mut())?;

    Ok(ServeReport {
        params: resolved,
        backend: backend.label(),
        quant,
        workload: p.mode.label().to_string(),
        scheduler: p.scheduler.label().to_string(),
        reuse: out.reuse,
        records: out.records,
        sequences: out.sequences,
        captured_logits: out.captured_logits,
        step_t: out.step_t,
        step_queue: out.step_queue,
        step_active: out.step_active,
        step_mbu: out.step_mbu,
        output_tokens: out.output_tokens,
        makespan_secs: out.makespan_secs,
        deferred_admissions: out.deferred_admissions,
        shed_requests: out.shed_requests,
        preempted_requests: out.preempted_requests,
        kv_pool: out.kv_pool,
    })
}

// ----------------------------------------------------- bench regression

/// Outcome of comparing a `bench.json` against the committed baseline.
#[derive(Clone, Debug, Default)]
pub struct BenchComparison {
    /// Regressions beyond the tolerance band — CI fails on any.
    pub violations: Vec<String>,
    /// Informational: improvements beyond the band, token drift,
    /// bootstrap baselines.
    pub notes: Vec<String>,
}

impl BenchComparison {
    pub fn is_pass(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Which direction of change is a regression for a metric.
enum Better {
    Higher,
    Lower,
}

/// Compare serve `bench.json` documents with relative tolerance bands.
///
/// * a baseline with `"bootstrap": true` accepts anything (it records
///   that no real baseline has been promoted yet);
/// * mismatched run parameters are violations (the comparison would be
///   meaningless);
/// * throughput / TTFT / TPOT / MBU regressions beyond `tol_pct` percent
///   are violations, improvements beyond the band are notes (refresh the
///   baseline);
/// * token-stream drift (count or fingerprint) is a violation: the trace
///   is exact by construction, so drift means the numerics changed.
///
/// A `"tolerance_pct"` field in the baseline overrides `tol_pct`.
pub fn compare_bench(current: &Json, baseline: &Json, tol_pct: f64) -> BenchComparison {
    let mut cmp = BenchComparison::default();
    if baseline.get("bootstrap").and_then(Json::as_bool) == Some(true) {
        cmp.notes.push(
            "baseline is a bootstrap placeholder: recording only, no regression gate; \
             promote a real bench.json to enable it"
                .to_string(),
        );
        return cmp;
    }
    let tol = baseline
        .get("tolerance_pct")
        .and_then(Json::as_f64)
        .unwrap_or(tol_pct)
        .max(0.0)
        / 100.0;

    // Every trace-shaping input must match, or the comparison is
    // meaningless (a changed cost model, length range, quantization or
    // backend moves every number and would read as a huge
    // 'improvement'/'regression').
    //
    // Identity is *derived*: every key either document serializes under
    // `params` or `model` is identity — the union of both documents'
    // key sets, so a key present on only one side still mismatches
    // (`Some(..)` vs `None`), while keys absent from both compare
    // absent == absent. The schema is additive (defaults serialize
    // nothing), so the pre-split `ci/bench_baseline.json` stays valid
    // and new scenario knobs are identity the day they are serialized —
    // no hand-maintained key list to grow out of date (the regression
    // test below pins that the derived set covers the legacy one).
    for section in ["params", "model"] {
        let mut keys: std::collections::BTreeSet<&str> = std::collections::BTreeSet::new();
        for doc in [current, baseline] {
            if let Some(Json::Obj(map)) = doc.get(section) {
                keys.extend(map.keys().map(String::as_str));
            }
        }
        for key in keys {
            let path = [section, key];
            let c = current.at(&path);
            let b = baseline.at(&path);
            if c != b {
                cmp.violations.push(format!(
                    "config mismatch: {} is {c:?} but baseline has {b:?} — not comparable",
                    path.join(".")
                ));
            }
        }
    }
    if !cmp.violations.is_empty() {
        return cmp;
    }

    let metrics: [(&[&str], Better); 8] = [
        (&["aggregate", "throughput_tok_s"], Better::Higher),
        (&["aggregate", "ttft", "p50"], Better::Lower),
        (&["aggregate", "ttft", "p95"], Better::Lower),
        (&["aggregate", "ttft", "p99"], Better::Lower),
        (&["aggregate", "tpot", "p50"], Better::Lower),
        (&["aggregate", "tpot", "p95"], Better::Lower),
        (&["aggregate", "tpot", "p99"], Better::Lower),
        (&["aggregate", "mbu_mean"], Better::Higher),
    ];
    for (path, better) in metrics {
        let name = path.join(".");
        let (Some(c), Some(b)) = (
            current.at(path).and_then(Json::as_f64),
            baseline.at(path).and_then(Json::as_f64),
        ) else {
            cmp.violations
                .push(format!("metric {name} missing from bench.json or baseline"));
            continue;
        };
        let rel = (c - b) / b.abs().max(1e-12);
        let (regressed, improved) = match better {
            Better::Higher => (rel < -tol, rel > tol),
            Better::Lower => (rel > tol, rel < -tol),
        };
        if regressed {
            cmp.violations.push(format!(
                "{name} regressed: {c:.6} vs baseline {b:.6} ({:+.2}% > {:.2}% band)",
                rel * 100.0,
                tol * 100.0
            ));
        } else if improved {
            cmp.notes.push(format!(
                "{name} improved beyond the band: {c:.6} vs baseline {b:.6} \
                 ({:+.2}%) — consider refreshing the baseline",
                rel * 100.0
            ));
        }
    }

    let c_out = current.at(&["aggregate", "output_tokens"]).and_then(Json::as_f64);
    let b_out = baseline.at(&["aggregate", "output_tokens"]).and_then(Json::as_f64);
    if c_out != b_out {
        cmp.violations.push(format!(
            "output token count changed: {c_out:?} vs baseline {b_out:?} \
             (the seeded trace is supposed to be exact)"
        ));
    }
    // Token streams are a pure function of (seed, params, model): the
    // engine is scalar IEEE arithmetic with no reassociation, so the
    // fingerprint must be exact. A drift means the *numerics* changed —
    // the one regression the latency bands cannot see, because the
    // virtual clock prices bytes and FLOPs, not token values.
    let c_fnv = current.at(&["aggregate", "tokens_fnv"]).and_then(Json::as_str);
    let b_fnv = baseline.at(&["aggregate", "tokens_fnv"]).and_then(Json::as_str);
    if c_fnv != b_fnv {
        cmp.violations.push(format!(
            "token streams drifted (fnv {c_fnv:?} vs baseline {b_fnv:?}): engine \
             numerics changed; if intentional, refresh the baseline"
        ));
    }
    cmp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::random_model_file;
    use crate::quant::QuantType;
    use crate::testkit::{check, gen};
    use crate::util::json;

    fn small_params() -> ServeParams {
        ServeParams {
            arrival_rate: 40.0,
            num_requests: 6,
            seed: 11,
            slots: 2,
            prompt_len: (2, 5),
            output_len: (2, 5),
            ..ServeParams::default()
        }
    }

    #[test]
    fn serve_completes_all_requests_with_valid_lifecycle() {
        let mf = random_model_file(QuantType::Q8_0, 21);
        let p = small_params();
        let rep = run_serve(&mf, BackendKind::Naive, &p).unwrap();
        assert_eq!(rep.records.len(), p.num_requests);
        let mut total_out = 0;
        for (rid, r) in rep.records.iter().enumerate() {
            assert_eq!(r.id, rid);
            assert!(r.arrival <= r.admit, "req {rid}: admitted before arrival");
            assert!(r.admit < r.first_token, "req {rid}: first token not after admit");
            assert!(r.first_token <= r.finish, "req {rid}: finish before first token");
            assert_eq!(
                rep.sequences[rid].len(),
                r.prompt_tokens + r.output_tokens,
                "req {rid}: sequence length mismatch"
            );
            assert!(r.ttft() > 0.0 && r.tpot() >= 0.0);
            total_out += r.output_tokens;
        }
        assert_eq!(total_out, rep.output_tokens);
        assert!(rep.throughput_tok_s() > 0.0);
        assert!(rep.makespan_secs > 0.0);
        // Series are per-step and aligned.
        let steps = rep.step_t.len();
        assert!(steps > 0);
        assert_eq!(rep.step_queue.len(), steps);
        assert_eq!(rep.step_active.len(), steps);
        assert_eq!(rep.step_mbu.len(), steps);
        assert!(rep.step_t.windows(2).all(|w| w[0] < w[1]), "clock must advance");
        assert!(rep.step_active.iter().all(|a| (1..=p.slots).contains(a)));
        assert!(rep.mbu_summary().is_some());
    }

    /// Satellite regression for the derived-identity comparator: every
    /// key the retired hand-maintained 23-entry list named is covered
    /// by the serialized-params key union, so `ci/bench_baseline.json`
    /// gates exactly as before (and new scenario knobs are identity the
    /// day they serialize — no manual registration).
    #[test]
    fn derived_bench_identity_covers_the_legacy_key_list() {
        use std::collections::BTreeSet;
        let legacy: [&[&str]; 23] = [
            &["params", "num_requests"],
            &["params", "seed"],
            &["params", "arrival_rate"],
            &["params", "slots"],
            &["params", "mode"],
            &["params", "clients"],
            &["params", "turns"],
            &["params", "prompt_len"],
            &["params", "output_len"],
            &["params", "scheduler"],
            &["params", "chunk_tokens"],
            &["params", "peak_bw"],
            &["params", "peak_flops"],
            &["params", "device"],
            &["params", "kv_pool_blocks"],
            &["params", "kv_prefix_share"],
            &["params", "system_prompt"],
            &["params", "slo_ttft"],
            &["params", "slo_tpot"],
            &["params", "thermal_tau"],
            &["params", "thermal_floor"],
            &["model", "quant"],
            &["model", "backend"],
        ];
        // Two fully-populated variants: `turns` only serializes for
        // chat, `clients` only for closed — together they cover every
        // optional params key the legacy list named.
        let chat = ServeParams {
            mode: ArrivalMode::Chat { turns: (2, 3) },
            scheduler: SchedulerPolicy::Chunked { chunk_tokens: 8 },
            device: Some(DeviceTarget {
                device: "NanoPI".into(),
                accel: Accel::CpuBlas,
                threads: 4,
            }),
            pool_blocks: Some(64),
            prefix_share: true,
            system_prompt: 8,
            thermal: Some(Thermal { tau: 5.0, floor: 0.5 }),
            ..ServeParams::default()
        };
        let slo = ServeParams {
            mode: ArrivalMode::ClosedLoop { clients: 2 },
            slo: Some(SloSpec { ttft: 0.5, tpot: 0.1 }),
            ..ServeParams::default()
        };
        let mut derived: BTreeSet<String> = BTreeSet::new();
        for p in [&chat, &slo] {
            if let Json::Obj(map) = p.to_json() {
                derived.extend(map.keys().map(|k| format!("params.{k}")));
            }
        }
        // The model section always serializes both keys.
        derived.insert("model.quant".into());
        derived.insert("model.backend".into());
        // `slo` only serializes deadlines; an SLO run with the slo-aware
        // scheduler also serializes the scheduler key — covered by chat's
        // chunked scheduler above. Assert coverage of the legacy set.
        for path in legacy {
            assert!(
                derived.contains(&path.join(".")),
                "legacy identity key {} is not derivable from serialized params",
                path.join(".")
            );
        }
        // And the comparator actually flags a key present on one side
        // only (the asymmetry the union guards).
        let a = json::parse(r#"{"params": {"seed": 7, "extra": 1}, "model": {}}"#).unwrap();
        let b = json::parse(r#"{"params": {"seed": 7}, "model": {}}"#).unwrap();
        let cmp = compare_bench(&a, &b, 5.0);
        assert!(
            cmp.violations.iter().any(|v| v.contains("params.extra")),
            "one-sided key must be a config mismatch: {:?}",
            cmp.violations
        );
    }

    #[test]
    fn serve_rerun_is_bitwise_identical() {
        let mf = random_model_file(QuantType::Q4_0, 9);
        let p = small_params();
        let a = run_serve(&mf, BackendKind::Naive, &p).unwrap();
        let b = run_serve(&mf, BackendKind::Naive, &p).unwrap();
        assert_eq!(
            json::to_string_pretty(&a.to_json()),
            json::to_string_pretty(&b.to_json()),
            "same seed must reproduce identical bench.json"
        );
        assert_eq!(a.sequences, b.sequences, "token streams must be identical");
    }

    /// The `--threads` determinism property: the serve trace (token
    /// streams, latency records, series — the whole bench.json) is
    /// bitwise identical for any kernel thread count, because parallel
    /// kernels partition rows without changing per-row arithmetic and
    /// the clock is virtual.
    #[test]
    fn serve_is_bitwise_deterministic_across_thread_counts() {
        let mf = random_model_file(QuantType::Q8_0, 33);
        let p = small_params();
        let base = json::to_string_pretty(
            &run_serve(&mf, BackendKind::Parallel(1), &p).unwrap().to_json(),
        );
        for threads in [2usize, 5] {
            let rep = run_serve(&mf, BackendKind::Parallel(threads), &p).unwrap();
            assert_eq!(
                base,
                json::to_string_pretty(&rep.to_json()),
                "threads={threads} must reproduce the single-thread bench.json bitwise"
            );
        }
    }

    #[test]
    fn closed_loop_bounds_in_flight_requests_and_completes() {
        let mf = random_model_file(QuantType::Q8_0, 5);
        let p = ServeParams {
            mode: ArrivalMode::ClosedLoop { clients: 2 },
            num_requests: 7,
            seed: 3,
            slots: 4,
            prompt_len: (2, 4),
            output_len: (2, 4),
            ..ServeParams::default()
        };
        let rep = run_serve(&mf, BackendKind::Naive, &p).unwrap();
        assert_eq!(rep.records.len(), 7);
        assert!(
            rep.step_active.iter().all(|a| *a <= 2),
            "closed loop with 2 clients must never have >2 in flight"
        );
        // A new request arrives exactly when a previous one finishes.
        for r in &rep.records[2..] {
            assert!(
                rep.records.iter().any(|q| (q.finish - r.arrival).abs() < 1e-12),
                "closed-loop arrival {} not at any completion",
                r.arrival
            );
        }
    }

    /// Continuous batching must not change what any single request
    /// computes: per-request token streams equal a solo single-sequence
    /// run of the same prompt, and the logits at every sampling event
    /// match within 1e-5 (they are in fact bitwise equal on CPU backends;
    /// the tolerance covers gpu-sim rounding).
    #[test]
    fn prop_serve_requests_match_solo_runs() {
        check("serve-vs-solo parity", |rng, _| {
            let q = *rng.choose(&[QuantType::F32, QuantType::Q4_0, QuantType::Q8_0]);
            let backend = *rng.choose(&[
                BackendKind::Naive,
                BackendKind::Parallel(2),
                BackendKind::Gpu(crate::kernel::Precision::Full),
            ]);
            let seed = rng.next_u64();
            let mf = random_model_file(q, seed);
            let mode = if rng.bool(0.5) {
                ArrivalMode::Poisson
            } else {
                ArrivalMode::ClosedLoop {
                    clients: gen::usize_in(rng, 1, 3),
                }
            };
            let p = ServeParams {
                arrival_rate: 1.0 + rng.next_f64() * 60.0,
                num_requests: gen::usize_in(rng, 2, 5),
                seed: rng.next_u64(),
                slots: gen::usize_in(rng, 1, 3),
                prompt_len: (2, 5),
                output_len: (2, 4),
                mode,
                capture_logits: true,
                ..ServeParams::default()
            };
            let rep = run_serve(&mf, backend, &p).map_err(|e| format!("{e:#}"))?;
            for (rid, r) in rep.records.iter().enumerate() {
                let prompt = &rep.sequences[rid][..r.prompt_tokens];
                let mut solo = Engine::new(
                    crate::model::ModelWeights::load(&mf).unwrap(),
                    backend,
                );
                let mut logits = Vec::new();
                for (i, t) in prompt.iter().enumerate() {
                    logits = solo.forward(*t, i).unwrap().to_vec();
                }
                if rep.captured_logits[rid].len() != r.output_tokens {
                    return Err(format!("req {rid}: captured event count mismatch"));
                }
                let mut seq = prompt.to_vec();
                for k in 0..r.output_tokens {
                    let cap = &rep.captured_logits[rid][k];
                    let d = crate::util::stats::max_abs_diff(cap, &logits);
                    if d > 1e-5 {
                        return Err(format!(
                            "req {rid} event {k}: serve logits drift {d} from solo \
                             ({} {:?})",
                            q.name(),
                            backend
                        ));
                    }
                    let next = argmax(&logits);
                    seq.push(next);
                    if k + 1 < r.output_tokens {
                        logits = solo.forward(next, prompt.len() + k).unwrap().to_vec();
                    }
                }
                if seq != rep.sequences[rid] {
                    return Err(format!(
                        "req {rid}: token stream diverged from solo run ({})",
                        q.name()
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn serve_rejects_bad_params() {
        let mf = random_model_file(QuantType::Q8_0, 1);
        let bad = [
            ServeParams {
                num_requests: 0,
                ..ServeParams::default()
            },
            ServeParams {
                slots: 0,
                ..ServeParams::default()
            },
            ServeParams {
                arrival_rate: 0.0,
                ..ServeParams::default()
            },
            ServeParams {
                prompt_len: (3, 2),
                ..ServeParams::default()
            },
            ServeParams {
                output_len: (0, 2),
                ..ServeParams::default()
            },
            ServeParams {
                prompt_len: (200, 200),
                output_len: (200, 200),
                ..ServeParams::default()
            },
            ServeParams {
                mode: ArrivalMode::ClosedLoop { clients: 0 },
                ..ServeParams::default()
            },
            ServeParams {
                mode: ArrivalMode::Chat { turns: (0, 2) },
                ..ServeParams::default()
            },
            ServeParams {
                scheduler: SchedulerPolicy::Chunked { chunk_tokens: 0 },
                ..ServeParams::default()
            },
            // A whole chat session lives in one slot's context window, so
            // the worst case is turns × (prompt + output + bridge).
            ServeParams {
                mode: ArrivalMode::Chat { turns: (4, 4) },
                prompt_len: (40, 40),
                output_len: (40, 40),
                ..ServeParams::default()
            },
        ];
        for p in bad {
            assert!(run_serve(&mf, BackendKind::Naive, &p).is_err(), "{p:?}");
        }
    }

    // ---------------------------------------------- device-priced serve

    fn device_params(device: &str, accel: crate::device::Accel) -> ServeParams {
        ServeParams {
            device: Some(DeviceTarget {
                device: device.to_string(),
                accel,
                threads: 4,
            }),
            ..small_params()
        }
    }

    /// The device clock changes *time*, never *tokens*: a device-priced
    /// run reproduces the flat run's token streams exactly, while its
    /// latencies move and its params JSON gains the `device` object
    /// (and only that — flat runs serialize the pre-fleet schema).
    #[test]
    fn device_pricing_changes_clock_not_tokens() {
        let mf = random_model_file(QuantType::Q4_0, 17);
        let flat = run_serve(&mf, BackendKind::Naive, &small_params()).unwrap();
        let dev = run_serve(
            &mf,
            BackendKind::Naive,
            &device_params("NanoPI", crate::device::Accel::CpuBlas),
        )
        .unwrap();
        assert_eq!(flat.sequences, dev.sequences, "tokens must not depend on the clock");
        assert_eq!(flat.output_tokens, dev.output_tokens);
        assert_ne!(
            flat.makespan_secs, dev.makespan_secs,
            "device pricing must actually move the clock"
        );
        let fj = flat.to_json();
        let dj = dev.to_json();
        assert!(fj.at(&["params", "device"]).is_none(), "flat schema unchanged");
        assert_eq!(
            dj.at(&["params", "device", "name"]).and_then(Json::as_str),
            Some("NanoPI")
        );
        assert_eq!(
            dj.at(&["params", "device", "accel"]).and_then(Json::as_str),
            Some("blas")
        );
        // The resolved rates land in the same keys the flat roofline used.
        let spec = crate::device::DeviceSpec::nanopi();
        let clock = spec.clock(crate::device::Accel::CpuBlas, QuantType::Q4_0, 4);
        let served = crate::model::scale::model_file_bytes(
            &crate::model::LlamaConfig::tiny(),
            QuantType::Q4_0,
        ) as f64;
        let deployed = crate::model::scale::model_file_bytes(
            &crate::model::LlamaConfig::llama_7b(),
            QuantType::Q4_0,
        ) as f64;
        let scale_factor = served / deployed;
        assert_eq!(
            dj.at(&["params", "peak_bw"]).and_then(Json::as_f64),
            Some(clock.eff_bw * scale_factor)
        );
    }

    #[test]
    fn device_serve_enforces_capacity_admission() {
        let mf = random_model_file(QuantType::Q8_0, 8);
        // Token-granular admission: this trace's worst context rounds
        // to a single 16-token block per slot, so q8_0 at 8 slots —
        // infeasible at full-window charging — now fits a 16 GiB
        // device. This is the serving frontier the paged pool unlocks.
        let p8 = ServeParams {
            slots: 8,
            ..device_params("NanoPI", crate::device::Accel::CpuBlas)
        };
        assert!(
            !crate::device::DeviceSpec::nanopi()
                .serve_capacity(QuantType::Q8_0, 8)
                .fits(),
            "full-window charging must still reject this cell"
        );
        assert!(run_serve(&mf, BackendKind::Naive, &p8).is_ok());
        // RAM still gates for real: at 64 slots the per-slot scratch
        // alone oversubscribes 16 GiB, token granularity or not.
        let p64 = ServeParams {
            slots: 64,
            ..device_params("NanoPI", crate::device::Accel::CpuBlas)
        };
        let err = run_serve(&mf, BackendKind::Naive, &p64).unwrap_err();
        assert!(err.to_string().contains("infeasible"), "{err:#}");
        // Unknown devices are errors.
        let mf4 = random_model_file(QuantType::Q4_0, 8);
        let bad = ServeParams {
            device: Some(DeviceTarget {
                device: "Pixel".into(),
                accel: crate::device::Accel::Gpu,
                threads: 4,
            }),
            ..small_params()
        };
        assert!(run_serve(&mf4, BackendKind::Naive, &bad).is_err());
    }

    /// Cross-device ordering under the same trace: the MacBook GPU clock
    /// beats the NanoPI BLAS clock on both roofline axes, so the whole
    /// run — makespan and mean TTFT — must be faster (the fleet
    /// comparison the paper's Table 6 makes, under load).
    #[test]
    fn faster_device_serves_the_same_trace_faster() {
        let mf = random_model_file(QuantType::Q4_0, 29);
        let nano = run_serve(
            &mf,
            BackendKind::Naive,
            &device_params("NanoPI", crate::device::Accel::CpuBlas),
        )
        .unwrap();
        let mac = run_serve(
            &mf,
            BackendKind::Naive,
            &device_params("Macbook", crate::device::Accel::Gpu),
        )
        .unwrap();
        assert!(mac.makespan_secs < nano.makespan_secs);
        assert!(mac.ttft_summary().unwrap().mean < nano.ttft_summary().unwrap().mean);
        // MBU under load is a *fraction* of peak on a device clock.
        for rep in [&nano, &mac] {
            let m = rep.mbu_summary().expect("token-generating steps exist");
            assert!(m.mean > 0.0 && m.mean.is_finite());
        }
    }

    // ------------------------------------------ trait-split parity (golden)

    /// The pre-refactor `run_serve` monolith, kept **verbatim** as a
    /// golden reference: the tentpole's acceptance criterion is that
    /// `Fcfs` + `PoissonOpen`/`ClosedLoop` through [`SimLoop`] reproduce
    /// this loop's bench.json bit for bit, forever.
    mod golden {
        use super::*;
        use crate::gguf::ModelFile;
        use crate::graph::sampler::argmax;
        use crate::graph::Engine;
        use crate::kernel::BackendKind;
        use crate::metrics::{self, RequestRecord};
        use crate::model::ModelWeights;
        use crate::util::rng::Rng;
        use std::collections::VecDeque;

        struct Req {
            prompt: Vec<u32>,
            target_out: usize,
        }

        struct InFlight {
            rid: usize,
            fed: usize,
            admit: f64,
            first_token: Option<f64>,
        }

        fn exp_sample(rng: &mut Rng, rate: f64) -> f64 {
            -(1.0 - rng.next_f64()).ln() / rate
        }

        pub fn run_serve_reference(
            mf: &ModelFile,
            backend: BackendKind,
            p: &ServeParams,
        ) -> Result<ServeReport> {
            p.validate()?;
            let weights = ModelWeights::load(mf)?;
            let qtype = weights.qtype;
            let quant = qtype.name().to_string();
            let param_bytes = weights.bytes_per_token();
            let mut engine = Engine::new_batched(weights, backend, p.slots);
            let vocab = engine.config().vocab_size;
            let clock = resolve_clock(p, engine.config(), qtype)?;
            let mut resolved = p.clone();
            resolved.peak_bw = clock.eff_bw;
            resolved.peak_flops = clock.eff_flops;

            let n = p.num_requests;
            let mut rng = Rng::new(p.seed);
            let reqs: Vec<Req> = (0..n)
                .map(|_| {
                    let plen =
                        rng.range_u64(p.prompt_len.0 as u64, p.prompt_len.1 as u64 + 1) as usize;
                    let target_out =
                        rng.range_u64(p.output_len.0 as u64, p.output_len.1 as u64 + 1) as usize;
                    Req {
                        prompt: (0..plen).map(|_| rng.below(vocab as u64) as u32).collect(),
                        target_out,
                    }
                })
                .collect();
            let mut arrived_at = vec![0.0f64; n];
            let mut submitted = 0usize;
            let mut queue: VecDeque<usize> = VecDeque::new();
            match p.mode {
                ArrivalMode::Poisson => {
                    let mut t = 0.0;
                    for a in arrived_at.iter_mut() {
                        t += exp_sample(&mut rng, p.arrival_rate);
                        *a = t;
                    }
                    submitted = n;
                }
                ArrivalMode::ClosedLoop { clients } => {
                    while submitted < clients.min(n) {
                        arrived_at[submitted] = 0.0;
                        queue.push_back(submitted);
                        submitted += 1;
                    }
                }
                ArrivalMode::Chat { .. } => {
                    unreachable!("the golden reference predates the chat workload")
                }
            }

            let mut now = 0.0f64;
            let mut next_arrival = 0usize;
            let mut active: Vec<Option<InFlight>> = (0..p.slots).map(|_| None).collect();
            let mut records: Vec<Option<RequestRecord>> = vec![None; n];
            let mut sequences: Vec<Vec<u32>> = vec![Vec::new(); n];
            let mut captured: Vec<Vec<Vec<f32>>> = vec![Vec::new(); n];
            let (mut step_t, mut step_queue, mut step_active, mut step_mbu) =
                (Vec::new(), Vec::new(), Vec::new(), Vec::new());
            let mut completed = 0usize;
            let mut output_tokens = 0usize;
            let mut makespan = 0.0f64;
            let step_limit = n * (p.prompt_len.1 + p.output_len.1) + 16;

            let mut slots_vec: Vec<usize> = Vec::with_capacity(p.slots);
            let mut toks: Vec<u32> = Vec::with_capacity(p.slots);
            while completed < n {
                anyhow::ensure!(step_t.len() <= step_limit, "reference loop exceeded its bound");
                if p.mode == ArrivalMode::Poisson {
                    while next_arrival < n && arrived_at[next_arrival] <= now {
                        queue.push_back(next_arrival);
                        next_arrival += 1;
                    }
                }
                for (slot, state) in active.iter_mut().enumerate() {
                    if state.is_none() {
                        if let Some(rid) = queue.pop_front() {
                            engine.reset_slot(slot);
                            sequences[rid] = reqs[rid].prompt.clone();
                            *state = Some(InFlight {
                                rid,
                                fed: 0,
                                admit: now,
                                first_token: None,
                            });
                        }
                    }
                }
                if active.iter().all(Option::is_none) {
                    anyhow::ensure!(
                        p.mode == ArrivalMode::Poisson && next_arrival < n,
                        "reference loop stalled"
                    );
                    now = arrived_at[next_arrival];
                    continue;
                }

                slots_vec.clear();
                toks.clear();
                for (slot, state) in active.iter().enumerate() {
                    if let Some(a) = state {
                        slots_vec.push(slot);
                        toks.push(sequences[a.rid][a.fed]);
                    }
                }
                let logits = engine.forward_slots(&slots_vec, &toks)?.to_vec();
                let traffic = engine.traffic_for_slots(&slots_vec);
                let flops = engine.flops_for_slots(&slots_vec);
                let step_secs = clock.step_secs(traffic.total(), flops);
                now += step_secs;

                let mut generated = 0usize;
                for (i, &slot) in slots_vec.iter().enumerate() {
                    let a = active[slot].as_mut().expect("active slot vanished");
                    a.fed += 1;
                    let rid = a.rid;
                    let plen = reqs[rid].prompt.len();
                    if a.fed < plen {
                        continue;
                    }
                    let lg = &logits[i * vocab..(i + 1) * vocab];
                    if p.capture_logits {
                        captured[rid].push(lg.to_vec());
                    }
                    sequences[rid].push(argmax(lg));
                    generated += 1;
                    output_tokens += 1;
                    if a.first_token.is_none() {
                        a.first_token = Some(now);
                    }
                    if sequences[rid].len() - plen >= reqs[rid].target_out {
                        records[rid] = Some(RequestRecord {
                            id: rid,
                            arrival: arrived_at[rid],
                            admit: a.admit,
                            first_token: a.first_token.expect("no first token"),
                            finish: now,
                            prompt_tokens: plen,
                            output_tokens: reqs[rid].target_out,
                            slo: None,
                            outcome: Outcome::Served,
                            target_tokens: reqs[rid].target_out,
                        });
                        active[slot] = None;
                        engine.reset_slot(slot);
                        completed += 1;
                        makespan = now;
                        if let ArrivalMode::ClosedLoop { .. } = p.mode {
                            if submitted < n {
                                arrived_at[submitted] = now;
                                queue.push_back(submitted);
                                submitted += 1;
                            }
                        }
                    }
                }
                if p.mode == ArrivalMode::Poisson {
                    while next_arrival < n && arrived_at[next_arrival] <= now {
                        queue.push_back(next_arrival);
                        next_arrival += 1;
                    }
                }
                step_t.push(now);
                step_queue.push(queue.len());
                step_active.push(slots_vec.len());
                step_mbu.push(if generated > 0 {
                    metrics::mbu(
                        param_bytes,
                        traffic.kv_read_bytes,
                        step_secs / generated as f64,
                        clock.peak_bw,
                    )
                } else {
                    0.0
                });
            }

            Ok(ServeReport {
                params: resolved,
                backend: backend.label(),
                quant,
                workload: p.mode.label().to_string(),
                scheduler: SchedulerPolicy::Fcfs.label().to_string(),
                reuse: KvReuse::default(),
                records: records
                    .into_iter()
                    .map(|r| r.expect("request completed without a record"))
                    .collect(),
                sequences,
                captured_logits: captured,
                step_t,
                step_queue,
                step_active,
                step_mbu,
                output_tokens,
                makespan_secs: makespan,
                deferred_admissions: 0,
                shed_requests: 0,
                preempted_requests: 0,
                // The reference loop drives the same paged engine
                // through the same op sequence, so its pool counters
                // must agree with SimLoop's bit for bit.
                kv_pool: engine.kv_pool_stats(),
            })
        }
    }

    /// THE tentpole acceptance test: `Fcfs` + `PoissonOpen` (and the
    /// closed loop) through [`SimLoop`] reproduce the pre-refactor
    /// monolith's bench.json **bitwise** on seeded synthetic traces —
    /// same tokens, same virtual clock, same serialized bytes.
    #[test]
    fn sim_loop_reproduces_pre_refactor_bench_json_bitwise() {
        let cases: [(QuantType, u64, ServeParams); 3] = [
            // A shrunk copy of the CI bench-smoke trace shape.
            (
                QuantType::Q4_0,
                0x5EED,
                ServeParams {
                    arrival_rate: 4.0,
                    num_requests: 16,
                    seed: 7,
                    slots: 4,
                    ..ServeParams::default()
                },
            ),
            (QuantType::Q8_0, 21, small_params()),
            (
                QuantType::Q4_0,
                9,
                ServeParams {
                    mode: ArrivalMode::ClosedLoop { clients: 2 },
                    num_requests: 7,
                    seed: 3,
                    slots: 3,
                    prompt_len: (2, 5),
                    output_len: (2, 5),
                    ..ServeParams::default()
                },
            ),
        ];
        for (q, model_seed, p) in cases {
            let mf = random_model_file(q, model_seed);
            let new = run_serve(&mf, BackendKind::Naive, &p).unwrap();
            let old = golden::run_serve_reference(&mf, BackendKind::Naive, &p).unwrap();
            assert_eq!(
                json::to_string_pretty(&new.to_json()),
                json::to_string_pretty(&old.to_json()),
                "{} mode={}: the trait split must not move a single bit of bench.json",
                q.name(),
                p.mode.label()
            );
            assert_eq!(new.sequences, old.sequences);
            assert_eq!(new.step_t, old.step_t, "virtual clocks must agree exactly");
        }
    }

    // ------------------------------------- paged-vs-slot layout parity

    /// The paged allocator is a *layout*, not a numerics change: across
    /// every scheduler × workload pair, the paged run (the default)
    /// reproduces the retained slot-layout reference bitwise — tokens,
    /// request records, the virtual clock, the whole series — and the
    /// logits at every sampling event agree within 1e-5 (bitwise on
    /// this CPU backend; the band covers gpu-sim rounding).
    #[test]
    fn paged_layout_matches_slot_reference_across_schedulers_and_workloads() {
        let mf = random_model_file(QuantType::Q8_0, 47);
        let combos: [(SchedulerPolicy, ArrivalMode); 6] = [
            (SchedulerPolicy::Fcfs, ArrivalMode::Poisson),
            (SchedulerPolicy::Priority, ArrivalMode::Poisson),
            (
                SchedulerPolicy::Chunked { chunk_tokens: 3 },
                ArrivalMode::Poisson,
            ),
            (SchedulerPolicy::Fcfs, ArrivalMode::Chat { turns: (2, 3) }),
            (SchedulerPolicy::Priority, ArrivalMode::Chat { turns: (2, 2) }),
            (
                SchedulerPolicy::Chunked { chunk_tokens: 4 },
                ArrivalMode::Chat { turns: (2, 3) },
            ),
        ];
        for (scheduler, mode) in combos {
            let p = ServeParams {
                mode,
                scheduler,
                capture_logits: true,
                arrival_rate: 25.0,
                num_requests: 4,
                seed: 13,
                slots: 2,
                prompt_len: (2, 5),
                output_len: (2, 4),
                ..ServeParams::default()
            };
            let ctx = format!("{}/{}", p.scheduler.label(), p.mode.label());
            let paged = run_serve(&mf, BackendKind::Naive, &p).unwrap();
            let slotted =
                run_serve_layout(&mf, BackendKind::Naive, &p, KvLayout::Slot).unwrap();
            assert!(paged.kv_pool.is_some() && slotted.kv_pool.is_none());
            assert_eq!(paged.sequences, slotted.sequences, "{ctx}: tokens");
            assert_eq!(paged.records, slotted.records, "{ctx}: records");
            assert_eq!(paged.step_t, slotted.step_t, "{ctx}: virtual clock");
            assert_eq!(paged.step_queue, slotted.step_queue, "{ctx}: queue series");
            assert_eq!(paged.step_active, slotted.step_active, "{ctx}: active series");
            assert_eq!(paged.step_mbu, slotted.step_mbu, "{ctx}: mbu series");
            assert_eq!(paged.reuse, slotted.reuse, "{ctx}: chat kv reuse");
            for (rid, (a, b)) in paged
                .captured_logits
                .iter()
                .zip(&slotted.captured_logits)
                .enumerate()
            {
                assert_eq!(a.len(), b.len(), "{ctx} req {rid}: event count");
                for (k, (la, lb)) in a.iter().zip(b).enumerate() {
                    let d = crate::util::stats::max_abs_diff(la, lb);
                    assert!(d <= 1e-5, "{ctx} req {rid} event {k}: logits drift {d}");
                }
            }
            // The slot reference is itself thread-invariant, so the
            // paged default's thread determinism (tested above) carries
            // the equivalence to every --threads value.
            let threaded =
                run_serve_layout(&mf, BackendKind::Parallel(3), &p, KvLayout::Slot).unwrap();
            assert_eq!(threaded.sequences, paged.sequences, "{ctx}: threads=3 tokens");
            assert_eq!(threaded.step_t, paged.step_t, "{ctx}: threads=3 clock");
        }
    }

    /// Pool occupancy surfaces in bench.json (and only for paged runs).
    #[test]
    fn bench_json_reports_pool_occupancy_for_paged_runs() {
        let mf = random_model_file(QuantType::Q8_0, 21);
        let rep = run_serve(&mf, BackendKind::Naive, &small_params()).unwrap();
        let j = rep.to_json();
        let pool = rep.kv_pool.unwrap();
        assert!(pool.blocks_total >= 1 && pool.peak_blocks_in_use >= 1);
        assert_eq!(
            j.at(&["aggregate", "kv_pool", "blocks_total"]).and_then(Json::as_f64),
            Some(pool.blocks_total as f64)
        );
        let occ = j
            .at(&["aggregate", "kv_pool", "occupancy_peak"])
            .and_then(Json::as_f64)
            .unwrap();
        assert!(occ > 0.0 && occ <= 1.0, "occupancy {occ}");
        assert_eq!(
            j.at(&["aggregate", "kv_pool", "deferred_admissions"]).and_then(Json::as_f64),
            Some(0.0)
        );
        // Defaults stay schema-identical: no pool params serialized.
        assert!(j.at(&["params", "kv_pool_blocks"]).is_none());
        assert!(j.at(&["params", "kv_prefix_share"]).is_none());
        assert!(j.at(&["params", "system_prompt"]).is_none());
        let slotted = run_serve_layout(
            &mf,
            BackendKind::Naive,
            &small_params(),
            KvLayout::Slot,
        )
        .unwrap();
        assert!(slotted.to_json().at(&["aggregate", "kv_pool"]).is_none());
    }

    /// A shared system prompt + copy-on-write prefix sharing end to
    /// end: tokens identical to the unshared run, the forks/CoW/shared
    /// bytes all reported, and the pool params self-describe in the
    /// JSON identity (so shared and unshared runs never silently
    /// compare).
    #[test]
    fn system_prompt_prefix_sharing_saves_prefill_without_token_drift() {
        let mf = random_model_file(QuantType::Q8_0, 21);
        let base = ServeParams {
            system_prompt: 24,
            ..small_params()
        };
        let plain = run_serve(&mf, BackendKind::Naive, &base).unwrap();
        let shared = run_serve(
            &mf,
            BackendKind::Naive,
            &ServeParams {
                prefix_share: true,
                ..base.clone()
            },
        )
        .unwrap();
        assert_eq!(plain.sequences, shared.sequences, "sharing must not change tokens");
        assert_eq!(plain.output_tokens, shared.output_tokens);
        let pool = shared.kv_pool.unwrap();
        assert!(pool.prefix_forks >= 1, "identical system prompts must fork");
        assert!(pool.shared_tokens >= 1 && pool.shared_bytes > 0);
        assert!(pool.cow_copies >= 1, "divergence past the prefix must copy");
        // Sharing skips prefill work: fewer engine steps end to end.
        assert!(
            shared.step_t.len() < plain.step_t.len(),
            "forked prefixes must save steps: {} vs {}",
            shared.step_t.len(),
            plain.step_t.len()
        );
        let j = shared.to_json();
        assert_eq!(j.at(&["params", "kv_prefix_share"]).and_then(Json::as_bool), Some(true));
        assert_eq!(j.at(&["params", "system_prompt"]).and_then(Json::as_f64), Some(24.0));
        assert!(
            j.at(&["aggregate", "kv_pool", "prefix_share_bytes"])
                .and_then(Json::as_f64)
                .unwrap()
                > 0.0
        );
        let cmp = compare_bench(&j, &plain.to_json(), 5.0);
        assert!(
            cmp.violations.iter().any(|v| v.contains("kv_prefix_share")),
            "shared vs unshared runs must not silently compare: {:?}",
            cmp.violations
        );
    }

    /// A pool budget below the engine's slot count serializes service
    /// through `elib serve`'s front door: deferrals surface in
    /// bench.json and the budget joins the params identity.
    #[test]
    fn pool_budget_flows_through_serve_params() {
        let mf = random_model_file(QuantType::Q8_0, 21);
        let p = ServeParams {
            pool_blocks: Some(1),
            // Arrival gaps far below a step's virtual cost, so the
            // trace genuinely contends for the single block.
            arrival_rate: 1000.0,
            ..small_params()
        };
        let rep = run_serve(&mf, BackendKind::Naive, &p).unwrap();
        assert_eq!(rep.records.len(), p.num_requests);
        assert!(rep.deferred_admissions > 0, "slots=2 under a 1-block budget");
        assert!(rep.step_active.iter().all(|&a| a <= 1));
        let j = rep.to_json();
        assert_eq!(j.at(&["params", "kv_pool_blocks"]).and_then(Json::as_f64), Some(1.0));
        assert_eq!(
            j.at(&["aggregate", "kv_pool", "deferred_admissions"]).and_then(Json::as_f64),
            Some(rep.deferred_admissions as f64)
        );
        // Budget 0 is a params error; a budget on the slot layout is a
        // layout error.
        assert!(ServeParams::builder().pool_blocks(Some(0)).build().is_err());
        let err = run_serve_layout(&mf, BackendKind::Naive, &p, KvLayout::Slot).unwrap_err();
        assert!(err.to_string().contains("paged KV layout"), "{err:#}");
    }

    // ---------------------------------------- schedulers and workloads

    #[test]
    fn builder_constructs_and_validates() {
        let p = ServeParams::builder()
            .arrival_rate(8.0)
            .num_requests(5)
            .seed(3)
            .slots(2)
            .prompt_len(2, 4)
            .output_len(2, 3)
            .workload(ArrivalMode::ClosedLoop { clients: 2 })
            .scheduler(SchedulerPolicy::Chunked { chunk_tokens: 8 })
            .peak_bw(50e6)
            .build()
            .unwrap();
        assert_eq!(p.num_requests, 5);
        assert_eq!(p.mode, ArrivalMode::ClosedLoop { clients: 2 });
        assert_eq!(p.scheduler, SchedulerPolicy::Chunked { chunk_tokens: 8 });
        assert_eq!(p.peak_bw, 50e6);
        assert_eq!(
            ServeParams::builder().build().unwrap().scheduler,
            SchedulerPolicy::Fcfs,
            "defaults are the pre-split identity"
        );
        assert!(ServeParams::builder().slots(0).build().is_err());
        assert!(ServeParams::builder()
            .scheduler(SchedulerPolicy::Chunked { chunk_tokens: 0 })
            .build()
            .is_err());
        assert!(ServeParams::builder()
            .workload(ArrivalMode::Chat { turns: (3, 2) })
            .build()
            .is_err());
        assert!(ServeParams::builder()
            .workload(ArrivalMode::Chat { turns: (0, 2) })
            .build()
            .is_err());
    }

    /// Schedulers are timing policies, not numerics: on one seeded
    /// long-prompt trace, chunked prefill reproduces FCFS's token
    /// streams exactly while collapsing prefill into bounded spans —
    /// fewer steps, earlier first tokens, shorter queues, faster
    /// makespan (the weight stream is charged per step, so chunking is
    /// what lets long prompts stop monopolizing it).
    #[test]
    fn chunked_prefill_serves_the_same_trace_faster_than_fcfs() {
        let mf = random_model_file(QuantType::Q4_0, 41);
        let base = ServeParams {
            arrival_rate: 30.0,
            num_requests: 8,
            seed: 13,
            slots: 3,
            prompt_len: (40, 56),
            output_len: (3, 6),
            ..ServeParams::default()
        };
        let fcfs = run_serve(&mf, BackendKind::Naive, &base).unwrap();
        let chunked = run_serve(
            &mf,
            BackendKind::Naive,
            &ServeParams {
                scheduler: SchedulerPolicy::Chunked { chunk_tokens: 32 },
                ..base.clone()
            },
        )
        .unwrap();
        assert_eq!(fcfs.sequences, chunked.sequences, "same trace, same tokens");
        assert_eq!(fcfs.output_tokens, chunked.output_tokens);
        assert!(
            chunked.step_t.len() < fcfs.step_t.len(),
            "prefill must collapse into ⌈prompt/chunk⌉ spans: {} vs {} steps",
            chunked.step_t.len(),
            fcfs.step_t.len()
        );
        assert!(chunked.makespan_secs < fcfs.makespan_secs);
        assert!(chunked.throughput_tok_s() > fcfs.throughput_tok_s());
        assert!(
            chunked.ttft_summary().unwrap().p95 < fcfs.ttft_summary().unwrap().p95,
            "bounded chunks must reach first tokens sooner under load"
        );
        assert!(
            chunked.queue_wait_summary().unwrap().mean < fcfs.queue_wait_summary().unwrap().mean
        );
        // Identity: the chunked run self-describes, the fcfs run keeps
        // the pre-split schema, and the two never silently compare.
        let cj = chunked.to_json();
        assert_eq!(cj.at(&["params", "scheduler"]).and_then(Json::as_str), Some("chunked"));
        assert_eq!(cj.at(&["params", "chunk_tokens"]).and_then(Json::as_f64), Some(32.0));
        let fj = fcfs.to_json();
        assert!(fj.at(&["params", "scheduler"]).is_none());
        let cmp = compare_bench(&cj, &fj, 5.0);
        assert!(
            cmp.violations.iter().any(|v| v.contains("scheduler")),
            "{:?}",
            cmp.violations
        );
    }

    /// Priority tiers change *who waits*, never *what is computed*: the
    /// token trace matches FCFS exactly, and under contention tier-0
    /// requests see shorter queue waits than best-effort tier-2.
    #[test]
    fn priority_tiers_cut_urgent_queue_waits_on_the_same_trace() {
        use crate::coordinator::sim::{PriorityTiers, Request, Scheduler as _};
        let n = 24;
        let tiers_of = |seed: u64| -> Vec<u8> {
            let mut dummies: Vec<Request> = (0..n)
                .map(|id| Request {
                    id,
                    arrival: None,
                    prompt: vec![0],
                    target_out: 1,
                    priority: 0,
                    session: None,
                    slo: None,
                })
                .collect();
            PriorityTiers::new(seed).assign_priorities(&mut dummies);
            dummies.into_iter().map(|r| r.priority).collect()
        };
        // Pick (deterministically) a trace seed whose tier assignment
        // populates both the urgent and the best-effort tier.
        let seed = (5u64..64)
            .find(|&s| {
                let t = tiers_of(s);
                t.iter().any(|p| *p == 0) && t.iter().any(|p| *p == 2)
            })
            .expect("some seed below 64 populates tiers 0 and 2");
        let mf = random_model_file(QuantType::Q4_0, 23);
        let base = ServeParams {
            // Arrivals at ~2× the two slots' service capacity, so the
            // queue is deep and admission order dominates waiting.
            arrival_rate: 120.0,
            num_requests: n,
            seed,
            slots: 2,
            prompt_len: (4, 8),
            output_len: (2, 4),
            ..ServeParams::default()
        };
        let fcfs = run_serve(&mf, BackendKind::Naive, &base).unwrap();
        let prio = run_serve(
            &mf,
            BackendKind::Naive,
            &ServeParams {
                scheduler: SchedulerPolicy::Priority,
                ..base.clone()
            },
        )
        .unwrap();
        assert_eq!(fcfs.sequences, prio.sequences, "tiers must not change the trace");
        let dummies: Vec<u8> = tiers_of(seed);
        let dummies: Vec<Request> = dummies
            .into_iter()
            .enumerate()
            .map(|(id, priority)| Request {
                id,
                arrival: None,
                prompt: vec![0],
                target_out: 1,
                priority,
                session: None,
                slo: None,
            })
            .collect();
        let wait_of = |tier: u8| {
            let xs: Vec<f64> = prio
                .records
                .iter()
                .zip(&dummies)
                .filter(|(_, d)| d.priority == tier)
                .map(|(r, _)| r.queue_wait())
                .collect();
            assert!(!xs.is_empty(), "tier {tier} unpopulated at n=24");
            xs.iter().sum::<f64>() / xs.len() as f64
        };
        assert!(
            wait_of(0) < wait_of(2),
            "urgent tier must wait less than best-effort: {} vs {}",
            wait_of(0),
            wait_of(2)
        );
        assert_eq!(
            prio.to_json().at(&["params", "scheduler"]).and_then(Json::as_str),
            Some("priority")
        );
    }

    // ------------------------------------------------- chat sessions

    /// The chat workload end to end: follow-up turns inherit their
    /// session's slot, the reused prefix is **never re-fed** (turn 2
    /// prices zero prefill for it — its recorded prompt is just bridge
    /// + delta), the reuse savings are reported, and every sampling
    /// event still matches a solo engine fed the full flattened
    /// conversation.
    #[test]
    fn chat_sessions_reuse_kv_prefixes_and_match_solo_replay() {
        use crate::graph::sampler::argmax;
        let mf = random_model_file(QuantType::Q8_0, 31);
        let p = ServeParams {
            arrival_rate: 20.0,
            num_requests: 4, // sessions
            seed: 9,
            slots: 2,
            prompt_len: (3, 6),
            output_len: (2, 4),
            mode: ArrivalMode::Chat { turns: (2, 3) },
            capture_logits: true,
            ..ServeParams::default()
        };
        let rep = run_serve(&mf, BackendKind::Naive, &p).unwrap();
        assert_eq!(rep.workload, "chat");
        assert!(rep.records.len() >= 8, "4 sessions × ≥2 turns");
        // Rebuild the trace the workload drew (same seed, same order).
        let mut wl = p.mode.workload(&p);
        let requests = wl.build(&mut Rng::new(p.seed), 256);
        assert_eq!(requests.len(), rep.records.len());
        let follow_ups: Vec<usize> = requests
            .iter()
            .filter(|r| r.session.unwrap().turn > 0)
            .map(|r| r.id)
            .collect();
        assert!(!follow_ups.is_empty());
        // Zero prefix re-prefill: a follow-up turn's recorded prompt is
        // bridge + delta only, and the reported savings are exactly the
        // prefix lengths it skipped (prev turn's cache: feed + out - 1,
        // compounding across the session).
        let mut expected_reuse = 0usize;
        for &rid in &follow_ups {
            assert_eq!(
                rep.records[rid].prompt_tokens,
                requests[rid].prompt.len() + 1,
                "turn {rid} must prefill only its delta (+bridge)"
            );
            let mut prefix = 0usize;
            let session = requests[rid].session.unwrap().session;
            for r in &rep.records[..rid] {
                if requests[r.id].session.unwrap().session == session {
                    prefix += r.prompt_tokens + r.output_tokens - 1;
                }
            }
            expected_reuse += prefix;
        }
        assert_eq!(rep.reuse.reused_turns, follow_ups.len());
        assert_eq!(rep.reuse.reused_tokens, expected_reuse);
        assert!(rep.reuse.reused_tokens > 0);
        // bench.json self-describes the workload and the savings.
        let j = rep.to_json();
        assert_eq!(j.at(&["params", "mode"]).and_then(Json::as_str), Some("chat"));
        assert!(j.at(&["params", "turns"]).is_some());
        assert_eq!(
            j.at(&["aggregate", "kv_reuse", "reused_tokens"]).and_then(Json::as_f64),
            Some(expected_reuse as f64)
        );
        // Correctness of the reuse: replay each session through a solo
        // engine over the full flattened conversation; every captured
        // sampling event must match.
        let sessions: std::collections::BTreeSet<usize> =
            requests.iter().map(|r| r.session.unwrap().session).collect();
        for s in sessions {
            let turn_ids: Vec<usize> = requests
                .iter()
                .filter(|r| r.session.unwrap().session == s)
                .map(|r| r.id)
                .collect();
            let mut solo = Engine::new(ModelWeights::load(&mf).unwrap(), BackendKind::Naive);
            let mut pos = 0usize;
            for &rid in &turn_ids {
                let seq = &rep.sequences[rid];
                let feed = rep.records[rid].prompt_tokens;
                assert_eq!(seq.len(), feed + rep.records[rid].output_tokens);
                for i in 0..seq.len() - 1 {
                    let logits = solo.forward(seq[i], pos).unwrap().to_vec();
                    pos += 1;
                    if i + 1 >= feed {
                        let cap = &rep.captured_logits[rid][i + 1 - feed];
                        let d = crate::util::stats::max_abs_diff(cap, &logits);
                        assert!(
                            d <= 1e-5,
                            "session {s} turn {rid} event {}: reuse drifted {d} from solo",
                            i + 1 - feed
                        );
                        assert_eq!(seq[i + 1], argmax(&logits), "token stream diverged");
                    }
                }
            }
        }
    }

    #[test]
    fn empty_mbu_serializes_null_not_zero() {
        // A report with no token-generating steps must write
        // `mbu_*: null` (fleet.json mirrors this per cell) — a fake 0.0
        // would read as "zero utilization", which is a different claim.
        let rep = ServeReport {
            params: ServeParams::default(),
            backend: "cpu".into(),
            quant: "q4_0".into(),
            workload: "poisson".into(),
            scheduler: "fcfs".into(),
            reuse: KvReuse::default(),
            records: vec![RequestRecord {
                id: 0,
                arrival: 0.0,
                admit: 0.0,
                first_token: 1.0,
                finish: 1.0,
                prompt_tokens: 1,
                output_tokens: 1,
                slo: None,
                outcome: Outcome::Served,
                target_tokens: 1,
            }],
            sequences: vec![vec![1, 2]],
            captured_logits: vec![Vec::new()],
            step_t: vec![1.0],
            step_queue: vec![0],
            step_active: vec![1],
            step_mbu: vec![0.0],
            output_tokens: 1,
            makespan_secs: 1.0,
            deferred_admissions: 0,
            shed_requests: 0,
            preempted_requests: 0,
            kv_pool: None,
        };
        assert!(rep.mbu_summary().is_none());
        let j = rep.to_json();
        assert_eq!(j.at(&["aggregate", "mbu_mean"]), Some(&Json::Null));
        assert_eq!(j.at(&["aggregate", "mbu_p50"]), Some(&Json::Null));
        assert_eq!(j.at(&["aggregate", "mbu_max"]), Some(&Json::Null));
    }

    // ------------------------------------------------- bench comparison

    fn bench_doc(tput: f64, ttft_p95: f64, out_tokens: f64, fnv: &str) -> Json {
        json::parse(&format!(
            r#"{{
                "params": {{"num_requests": 64, "seed": 7, "arrival_rate": 4, "slots": 4}},
                "aggregate": {{
                    "throughput_tok_s": {tput},
                    "ttft": {{"p50": 0.1, "p95": {ttft_p95}, "p99": 0.4}},
                    "tpot": {{"p50": 0.01, "p95": 0.02, "p99": 0.03}},
                    "mbu_mean": 1.5,
                    "output_tokens": {out_tokens},
                    "tokens_fnv": "{fnv}"
                }}
            }}"#
        ))
        .unwrap()
    }

    #[test]
    fn bench_check_passes_within_band_and_fails_regressions() {
        let base = bench_doc(100.0, 0.2, 900.0, "abc");
        // Within 5%: pass.
        let ok = bench_doc(97.0, 0.205, 900.0, "abc");
        let cmp = compare_bench(&ok, &base, 5.0);
        assert!(cmp.is_pass(), "{:?}", cmp.violations);
        // Throughput down 10%: violation.
        let slow = bench_doc(90.0, 0.2, 900.0, "abc");
        let cmp = compare_bench(&slow, &base, 5.0);
        assert!(!cmp.is_pass());
        assert!(cmp.violations[0].contains("throughput"));
        // TTFT p95 up 50%: violation.
        let laggy = bench_doc(100.0, 0.3, 900.0, "abc");
        assert!(!compare_bench(&laggy, &base, 5.0).is_pass());
        // Improvement beyond the band: pass, with a note.
        let fast = bench_doc(120.0, 0.1, 900.0, "abc");
        let cmp = compare_bench(&fast, &base, 5.0);
        assert!(cmp.is_pass());
        assert!(cmp.notes.iter().any(|n| n.contains("improved")));
    }

    #[test]
    fn bench_check_flags_token_drift_and_param_mismatch() {
        let base = bench_doc(100.0, 0.2, 900.0, "abc");
        // Token count change is a violation.
        let fewer = bench_doc(100.0, 0.2, 890.0, "abc");
        assert!(compare_bench(&fewer, &base, 5.0)
            .violations
            .iter()
            .any(|v| v.contains("output token count")));
        // Same counts, different fnv: numerics changed — a violation (the
        // latency bands cannot see this class of regression).
        let drift = bench_doc(100.0, 0.2, 900.0, "def");
        let cmp = compare_bench(&drift, &base, 5.0);
        assert!(!cmp.is_pass());
        assert!(cmp.violations.iter().any(|n| n.contains("drifted")));
        // Param mismatch is a violation regardless of metrics.
        let mut other = bench_doc(100.0, 0.2, 900.0, "abc");
        if let Some(Json::Obj(params)) = match &mut other {
            Json::Obj(m) => m.get_mut("params"),
            _ => None,
        } {
            params.insert("seed".into(), Json::Num(8.0));
        }
        assert!(!compare_bench(&other, &base, 5.0).is_pass());
    }

    #[test]
    fn bench_check_flags_device_identity_mismatch() {
        // A device-priced bench.json must not silently compare against a
        // flat-roofline baseline: the clocks are different instruments.
        let base = bench_doc(100.0, 0.2, 900.0, "abc");
        let mut dev = bench_doc(100.0, 0.2, 900.0, "abc");
        if let Some(Json::Obj(params)) = match &mut dev {
            Json::Obj(m) => m.get_mut("params"),
            _ => None,
        } {
            params.insert(
                "device".into(),
                Json::obj(vec![
                    ("name", Json::Str("NanoPI".into())),
                    ("accel", Json::Str("blas".into())),
                    ("threads", Json::Num(4.0)),
                ]),
            );
        }
        let cmp = compare_bench(&dev, &base, 5.0);
        assert!(!cmp.is_pass());
        assert!(cmp.violations.iter().any(|v| v.contains("device")));
    }

    #[test]
    fn bench_check_accepts_bootstrap_baseline() {
        let cur = bench_doc(100.0, 0.2, 900.0, "abc");
        let boot = json::parse(r#"{"bootstrap": true, "note": "no toolchain yet"}"#).unwrap();
        let cmp = compare_bench(&cur, &boot, 5.0);
        assert!(cmp.is_pass());
        assert!(cmp.notes.iter().any(|n| n.contains("bootstrap")));
    }

    #[test]
    fn bench_check_respects_baseline_tolerance_override() {
        let mut base = bench_doc(100.0, 0.2, 900.0, "abc");
        if let Json::Obj(m) = &mut base {
            m.insert("tolerance_pct".into(), Json::Num(20.0));
        }
        // 10% down would fail the 5% default, but the baseline allows 20%.
        let slow = bench_doc(90.0, 0.2, 900.0, "abc");
        assert!(compare_bench(&slow, &base, 5.0).is_pass());
    }

    #[test]
    fn bench_json_has_the_fields_ci_compares() {
        let mf = random_model_file(QuantType::Q8_0, 2);
        let p = ServeParams {
            num_requests: 3,
            prompt_len: (2, 3),
            output_len: (2, 3),
            arrival_rate: 30.0,
            ..ServeParams::default()
        };
        let rep = run_serve(&mf, BackendKind::Naive, &p).unwrap();
        let j = rep.to_json();
        for path in [
            vec!["aggregate", "throughput_tok_s"],
            vec!["aggregate", "ttft", "p50"],
            vec!["aggregate", "ttft", "p95"],
            vec!["aggregate", "ttft", "p99"],
            vec!["aggregate", "tpot", "p95"],
            vec!["aggregate", "mbu_mean"],
            vec!["aggregate", "tokens_fnv"],
            vec!["params", "seed"],
            vec!["series", "queue_depth"],
        ] {
            assert!(j.at(&path).is_some(), "bench.json missing {path:?}");
        }
        // And the self-comparison passes trivially.
        assert!(compare_bench(&j, &j, 5.0).is_pass());
    }

    // ------------------------------------------------- SLOs and goodput

    /// Flash-crowd overload shared by the SLO tests: two slots, arrivals
    /// at well past service capacity in the middle half of the trace.
    fn slo_params(seed: u64, scheduler: SchedulerPolicy, slo: SloSpec) -> ServeParams {
        ServeParams {
            mode: ArrivalMode::FlashCrowd,
            arrival_rate: 60.0,
            num_requests: 16,
            seed,
            slots: 2,
            prompt_len: (2, 5),
            output_len: (2, 5),
            scheduler,
            slo: Some(slo),
            ..ServeParams::default()
        }
    }

    #[test]
    fn slo_params_validate_and_serialize_additively() {
        // Happy path through the builder.
        let p = ServeParams::builder()
            .workload(ArrivalMode::FlashCrowd)
            .scheduler(SchedulerPolicy::SloAware)
            .slo(0.5, 0.1)
            .thermal(5.0, 0.5)
            .build()
            .unwrap();
        assert_eq!(p.slo, Some(SloSpec { ttft: 0.5, tpot: 0.1 }));
        // SLOs are open-loop-only: a closed loop couples arrivals to
        // completions, so a deadline would measure the client.
        for mode in [
            ArrivalMode::ClosedLoop { clients: 2 },
            ArrivalMode::Chat { turns: (1, 2) },
        ] {
            let err = ServeParams {
                mode,
                slo: Some(SloSpec { ttft: 0.5, tpot: 0.1 }),
                ..ServeParams::default()
            }
            .validate()
            .unwrap_err();
            assert!(err.to_string().contains("open-loop"), "{err}");
        }
        // The slo-aware scheduler is meaningless without SLOs.
        let err = ServeParams {
            scheduler: SchedulerPolicy::SloAware,
            ..ServeParams::default()
        }
        .validate()
        .unwrap_err();
        assert!(err.to_string().contains("slo-aware"), "{err}");
        // Deadlines must be positive; thermal knobs bounded.
        for bad in [
            ServeParams {
                slo: Some(SloSpec { ttft: 0.0, tpot: 0.1 }),
                ..ServeParams::default()
            },
            ServeParams {
                slo: Some(SloSpec { ttft: 0.5, tpot: -1.0 }),
                ..ServeParams::default()
            },
            ServeParams {
                thermal: Some(Thermal { tau: 0.0, floor: 0.5 }),
                ..ServeParams::default()
            },
            ServeParams {
                thermal: Some(Thermal { tau: 5.0, floor: 0.0 }),
                ..ServeParams::default()
            },
            ServeParams {
                thermal: Some(Thermal { tau: 5.0, floor: 1.5 }),
                ..ServeParams::default()
            },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should not validate");
        }
        // Additive serialization: the default run writes none of the new
        // keys (the committed baseline stays comparable) …
        let plain = ServeParams::default().to_json();
        for key in ["slo_ttft", "slo_tpot", "thermal_tau", "thermal_floor"] {
            assert!(plain.get(key).is_none(), "{key} must be absent by default");
        }
        // … an SLO run writes the finite deadlines, and an infinite
        // deadline is *absent* (JSON cannot represent Infinity).
        let j = ServeParams {
            slo: Some(SloSpec { ttft: 0.5, tpot: f64::INFINITY }),
            thermal: Some(Thermal { tau: 5.0, floor: 0.5 }),
            scheduler: SchedulerPolicy::SloAware,
            ..ServeParams::default()
        }
        .to_json();
        assert_eq!(j.get("slo_ttft").and_then(Json::as_f64), Some(0.5));
        assert!(j.get("slo_tpot").is_none(), "infinite deadline must be absent");
        assert_eq!(j.get("thermal_tau").and_then(Json::as_f64), Some(5.0));
        assert_eq!(j.get("thermal_floor").and_then(Json::as_f64), Some(0.5));
        assert_eq!(j.get("scheduler").and_then(Json::as_str), Some("slo-aware"));
    }

    /// Goodput is the SLO-attained token fraction: 1.0 exactly when every
    /// deadline is infinite, within [0, 1] when deadlines bind, and the
    /// key (plus shed/preempt counters and the tier rollup) appears in
    /// bench.json only for SLO runs.
    #[test]
    fn goodput_is_bounded_and_unity_with_infinite_deadlines() {
        let mf = random_model_file(QuantType::Q4_0, 17);
        let infinite = SloSpec { ttft: f64::INFINITY, tpot: f64::INFINITY };
        let rep = run_serve(
            &mf,
            BackendKind::Naive,
            &slo_params(11, SchedulerPolicy::SloAware, infinite),
        )
        .unwrap();
        assert_eq!(rep.goodput(), Some(1.0), "no deadline can be missed");
        assert_eq!(rep.shed_requests + rep.preempted_requests, 0);
        let tight = SloSpec { ttft: 0.06, tpot: 0.05 };
        let rep = run_serve(
            &mf,
            BackendKind::Naive,
            &slo_params(11, SchedulerPolicy::SloAware, tight),
        )
        .unwrap();
        let g = rep.goodput().expect("SLO run must report goodput");
        assert!((0.0..=1.0).contains(&g), "goodput {g} out of bounds");
        let j = rep.to_json();
        assert_eq!(j.at(&["aggregate", "goodput"]).and_then(Json::as_f64), Some(g));
        assert_eq!(
            j.at(&["aggregate", "shed_requests"]).and_then(Json::as_f64),
            Some(rep.shed_requests as f64)
        );
        assert!(j.at(&["aggregate", "slo_tiers"]).is_some());
        // No-SLO runs keep the aggregate schema unchanged.
        let plain = run_serve(&mf, BackendKind::Naive, &small_params())
            .unwrap()
            .to_json();
        for key in ["goodput", "shed_requests", "preempted_requests", "slo_tiers"] {
            assert!(
                plain.at(&["aggregate", key]).is_none(),
                "{key} must be absent without SLOs"
            );
        }
    }

    /// Shed/preempt accounting conserves the offered trace: every one of
    /// the `num_requests` offered requests retires exactly once, as
    /// served, shed or preempted — never silently dropped.
    #[test]
    fn slo_accounting_conserves_offered_requests() {
        let mf = random_model_file(QuantType::Q4_0, 17);
        let rep = run_serve(
            &mf,
            BackendKind::Naive,
            &slo_params(11, SchedulerPolicy::SloAware, SloSpec { ttft: 0.02, tpot: 0.02 }),
        )
        .unwrap();
        assert_eq!(rep.records.len(), rep.params.num_requests);
        let served = rep
            .records
            .iter()
            .filter(|r| r.outcome == Outcome::Served)
            .count();
        let shed = rep.records.iter().filter(|r| r.outcome == Outcome::Shed).count();
        let pre = rep
            .records
            .iter()
            .filter(|r| r.outcome == Outcome::Preempted)
            .count();
        assert_eq!(shed, rep.shed_requests);
        assert_eq!(pre, rep.preempted_requests);
        assert_eq!(
            served + shed + pre,
            rep.params.num_requests,
            "admitted + shed + preempted must cover the offered trace"
        );
        for r in rep.records.iter().filter(|r| r.outcome == Outcome::Shed) {
            assert_eq!(r.output_tokens, 0, "shed requests produce nothing");
            assert!(!r.attained(), "a shed request never attains its SLO");
        }
    }

    /// THE SLO acceptance test (ISSUE 7): under a flash-crowd burst with
    /// deadlines attached, the slo-aware scheduler's goodput strictly
    /// beats FCFS on the same seeded trace — shedding doomed requests
    /// and running EDF admission converts wasted work into attained
    /// tokens. Seed chosen by the deterministic search pattern the
    /// priority test uses.
    #[test]
    fn slo_aware_beats_fcfs_on_goodput_under_flash_crowd() {
        let mf = random_model_file(QuantType::Q4_0, 17);
        let slo = SloSpec { ttft: 0.06, tpot: 0.05 };
        let goodputs = |seed: u64| {
            let fcfs = run_serve(
                &mf,
                BackendKind::Naive,
                &slo_params(seed, SchedulerPolicy::Fcfs, slo),
            )
            .unwrap();
            let aware = run_serve(
                &mf,
                BackendKind::Naive,
                &slo_params(seed, SchedulerPolicy::SloAware, slo),
            )
            .unwrap();
            // FCFS never sheds or preempts, SLOs or not.
            assert_eq!(fcfs.shed_requests + fcfs.preempted_requests, 0);
            (fcfs.goodput().unwrap(), aware.goodput().unwrap())
        };
        let seed = (5u64..40)
            .find(|&s| {
                let (f, a) = goodputs(s);
                a > f
            })
            .expect("some seed below 40 separates slo-aware from fcfs on goodput");
        let (f, a) = goodputs(seed);
        assert!(
            a > f,
            "slo-aware goodput {a} must strictly beat fcfs {f} (seed {seed})"
        );
    }

    /// The `--threads` determinism property extends to the full SLO
    /// machinery: shedding, preemption, EDF admission and thermal
    /// pricing are pure functions of the virtual clock, so the SLO
    /// bench.json is bitwise identical for any kernel thread count.
    #[test]
    fn slo_serve_is_bitwise_deterministic_across_thread_counts() {
        let mf = random_model_file(QuantType::Q8_0, 33);
        let mut p = slo_params(9, SchedulerPolicy::SloAware, SloSpec { ttft: 0.06, tpot: 0.05 });
        p.thermal = Some(Thermal { tau: 0.5, floor: 0.6 });
        let base = json::to_string_pretty(
            &run_serve(&mf, BackendKind::Parallel(1), &p).unwrap().to_json(),
        );
        for threads in [2usize, 5] {
            let rep = run_serve(&mf, BackendKind::Parallel(threads), &p).unwrap();
            assert_eq!(
                base,
                json::to_string_pretty(&rep.to_json()),
                "threads={threads} must reproduce the single-thread SLO bench.json bitwise"
            );
        }
    }

    /// Thermal throttling stretches the virtual clock without touching a
    /// single token: same trace, strictly longer makespan once the
    /// compute-bound derate bites, and the thermal knobs are identity
    /// keys (a throttled run never silently compares to a cold one).
    #[test]
    fn thermal_throttling_stretches_the_same_trace() {
        let mf = random_model_file(QuantType::Q8_0, 21);
        // Compute-bound roofline (bandwidth effectively free), so the
        // eff_flops derate is what prices every step.
        let base = ServeParams {
            peak_bw: 1e15,
            peak_flops: 2e9,
            ..small_params()
        };
        let cold = run_serve(&mf, BackendKind::Naive, &base).unwrap();
        let hot = run_serve(
            &mf,
            BackendKind::Naive,
            &ServeParams {
                thermal: Some(Thermal { tau: 0.001, floor: 0.5 }),
                ..base.clone()
            },
        )
        .unwrap();
        assert_eq!(cold.sequences, hot.sequences, "throttling must not change tokens");
        assert!(
            hot.makespan_secs > cold.makespan_secs,
            "derated compute must stretch the run: {} vs {}",
            hot.makespan_secs,
            cold.makespan_secs
        );
        let cmp = compare_bench(&hot.to_json(), &cold.to_json(), 5.0);
        assert!(
            cmp.violations.iter().any(|v| v.contains("thermal")),
            "thermal identity must not silently compare: {:?}",
            cmp.violations
        );
    }
}
