//! The unified, serializable scenario description (`ScenarioSpec`) —
//! the api_redesign entry point every serving surface consumes.
//!
//! `serve`, `fleet` and `cluster` used to each re-plumb workload and
//! scheduler names, SLO knobs and KV settings from their own flag or
//! config grammar into [`ServeParams`]. `ScenarioSpec` is the one
//! stringly-but-validated description of *what to run*: workload and
//! scheduler are registry names (see
//! [`registry`](crate::coordinator::registry)), every knob is optional
//! with the serve defaults, and [`ScenarioSpec::resolve`] turns it into
//! a validated [`ServeParams`] — the *resolved view* the simulator
//! actually executes. The JSON grammar (`from_json`/`to_json`) is the
//! config file's `serve` section, reused verbatim by `cluster.json`'s
//! embedded `spec` object.

use anyhow::{anyhow, Result};

use crate::device::Thermal;
use crate::util::json::Json;

use super::registry;
use super::serve::{ArrivalMode, DeviceTarget, ServeParams, SloSpec};
use super::sim::{SchedulerPolicy, Workload};

/// A serializable serving scenario: workload + scheduler + SLOs +
/// device/KV knobs, with registry names instead of enum variants.
/// Construct programmatically, from JSON (`from_json`), or from an
/// existing [`ServeParams`] (`from_params`); run via `resolve()`.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioSpec {
    /// Workload registry name (`poisson | closed | chat | ...`).
    pub workload: String,
    pub arrival_rate: f64,
    pub num_requests: usize,
    pub seed: u64,
    pub slots: usize,
    pub prompt_len: (usize, usize),
    pub output_len: (usize, usize),
    /// Closed-loop client count; `None` = knob not set (the registry
    /// default applies, and non-closed workloads reject `Some`).
    pub clients: Option<usize>,
    /// Chat turns range; `None` = knob not set.
    pub turns: Option<(usize, usize)>,
    /// Scheduler registry name (`fcfs | priority | chunked | slo-aware`).
    pub scheduler: String,
    /// Chunked-prefill span; `None` = knob not set (default 32 when the
    /// chunked scheduler is selected; other schedulers reject `Some`).
    pub chunk_tokens: Option<usize>,
    pub slo: Option<SloSpec>,
    pub thermal: Option<Thermal>,
    pub pool_blocks: Option<usize>,
    pub prefix_share: bool,
    pub system_prompt: usize,
    pub peak_bw: f64,
    pub peak_flops: f64,
    pub device: Option<DeviceTarget>,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        Self::from_params(&ServeParams::default())
    }
}

/// Default chunked-prefill span when the knob is unset (the config
/// grammar's historical default).
pub const DEFAULT_CHUNK_TOKENS: usize = 32;

impl ScenarioSpec {
    /// Project an already-resolved [`ServeParams`] back into its spec —
    /// the inverse of [`resolve`](Self::resolve) (up to default knobs).
    pub fn from_params(p: &ServeParams) -> Self {
        let (clients, turns) = match p.mode {
            ArrivalMode::ClosedLoop { clients } => (Some(clients), None),
            ArrivalMode::Chat { turns } => (None, Some(turns)),
            _ => (None, None),
        };
        let chunk_tokens = match p.scheduler {
            SchedulerPolicy::Chunked { chunk_tokens } => Some(chunk_tokens),
            _ => None,
        };
        Self {
            workload: p.mode.label().to_string(),
            arrival_rate: p.arrival_rate,
            num_requests: p.num_requests,
            seed: p.seed,
            slots: p.slots,
            prompt_len: p.prompt_len,
            output_len: p.output_len,
            clients,
            turns,
            scheduler: p.scheduler.label().to_string(),
            chunk_tokens,
            slo: p.slo,
            thermal: p.thermal,
            pool_blocks: p.pool_blocks,
            prefix_share: p.prefix_share,
            system_prompt: p.system_prompt,
            peak_bw: p.peak_bw,
            peak_flops: p.peak_flops,
            device: p.device.clone(),
        }
    }

    /// Parse the config-file `serve` section grammar (also embedded as
    /// `cluster.json`'s `spec` object). Key-applicability cross-checks
    /// (`clients` without `closed`, `chunk_tokens` without `chunked`,
    /// a `system_prompt` nobody shares, a thermal floor without a time
    /// constant) are enforced here, where key *presence* is visible.
    pub fn from_json(s: &Json) -> Result<Self> {
        let mut spec = ScenarioSpec::default();
        let num = |k: &str, d: f64| s.get(k).and_then(Json::as_f64).unwrap_or(d);
        spec.arrival_rate = num("arrival_rate", spec.arrival_rate);
        spec.num_requests = num("num_requests", spec.num_requests as f64) as usize;
        spec.seed = num("seed", spec.seed as f64) as u64;
        spec.slots = num("slots", spec.slots as f64) as usize;
        spec.prompt_len = parse_len_range(s, "prompt_len", spec.prompt_len)?;
        spec.output_len = parse_len_range(s, "output_len", spec.output_len)?;
        spec.peak_bw = num("peak_bw", spec.peak_bw);
        spec.peak_flops = num("peak_flops", spec.peak_flops);
        if let Some(m) = s.get("mode") {
            let name = m
                .as_str()
                .ok_or_else(|| anyhow!("serve.mode must be a string, got {m:?}"))?;
            let entry = registry::workload_entry(name).ok_or_else(|| {
                anyhow!("bad serve mode `{name}` ({})", registry::workload_names())
            })?;
            spec.workload = entry.name.to_string();
        }
        if let Some(v) = s.get("clients") {
            let entry = registry::workload_entry(&spec.workload).expect("default is registered");
            if !entry.accepts_clients {
                return Err(anyhow!(
                    "serve.clients only applies to mode \"closed\" (open-loop and chat \
                     workloads have no clients)"
                ));
            }
            spec.clients = Some(
                v.as_f64()
                    .ok_or_else(|| anyhow!("serve.clients must be a number, got {v:?}"))?
                    as usize,
            );
        }
        if s.get("turns").is_some() {
            let entry = registry::workload_entry(&spec.workload).expect("default is registered");
            if !entry.accepts_turns {
                return Err(anyhow!(
                    "serve.turns only applies to mode \"chat\" (single-turn workloads have no turns)"
                ));
            }
            spec.turns = Some(parse_len_range(s, "turns", registry::DEFAULT_TURNS)?);
        }
        if let Some(v) = s.get("scheduler") {
            let name = v
                .as_str()
                .ok_or_else(|| anyhow!("serve.scheduler must be a string, got {v:?}"))?;
            let entry = registry::scheduler_entry(name).ok_or_else(|| {
                anyhow!("bad serve scheduler `{name}` ({})", registry::scheduler_names())
            })?;
            spec.scheduler = entry.name.to_string();
        }
        if let Some(v) = s.get("chunk_tokens") {
            let entry = registry::scheduler_entry(&spec.scheduler).expect("default is registered");
            if !entry.accepts_chunk {
                return Err(anyhow!(
                    "serve.chunk_tokens only applies to scheduler \"chunked\""
                ));
            }
            spec.chunk_tokens = Some(
                v.as_f64()
                    .ok_or_else(|| anyhow!("serve.chunk_tokens must be a number, got {v:?}"))?
                    as usize,
            );
        }
        if let Some(v) = s.get("pool_blocks") {
            spec.pool_blocks = Some(
                v.as_f64()
                    .filter(|b| *b >= 1.0 && b.fract() == 0.0)
                    .map(|b| b as usize)
                    .ok_or_else(|| {
                        anyhow!("serve.pool_blocks must be a whole number >= 1, got {v:?}")
                    })?,
            );
        }
        if let Some(v) = s.get("prefix_share") {
            spec.prefix_share = v
                .as_bool()
                .ok_or_else(|| anyhow!("serve.prefix_share must be a bool, got {v:?}"))?;
        }
        spec.system_prompt = num("system_prompt", spec.system_prompt as f64) as usize;
        if spec.system_prompt > 0 && !spec.prefix_share {
            return Err(anyhow!(
                "serve.system_prompt only pays off with serve.prefix_share enabled \
                 (a shared prefix nobody shares just burns prefill)"
            ));
        }
        // SLO deadlines: either key enables SLOs; the other defaults
        // to ∞ (that constraint never binds). Cross-checks (open-loop
        // only, slo-aware needs SLOs, positive values) live in
        // `ServeParams::validate`.
        let slo_ttft = s.get("slo_ttft").map(|v| {
            v.as_f64()
                .ok_or_else(|| anyhow!("serve.slo_ttft must be a number, got {v:?}"))
        });
        let slo_tpot = s.get("slo_tpot").map(|v| {
            v.as_f64()
                .ok_or_else(|| anyhow!("serve.slo_tpot must be a number, got {v:?}"))
        });
        if slo_ttft.is_some() || slo_tpot.is_some() {
            spec.slo = Some(SloSpec {
                ttft: slo_ttft.transpose()?.unwrap_or(f64::INFINITY),
                tpot: slo_tpot.transpose()?.unwrap_or(f64::INFINITY),
            });
        }
        // Thermal throttling: `thermal_tau` enables it, the floor
        // defaults to 0.5 (half the cold compute rate, sustained).
        let thermal_floor = s.get("thermal_floor").map(|v| {
            v.as_f64()
                .ok_or_else(|| anyhow!("serve.thermal_floor must be a number, got {v:?}"))
        });
        match s.get("thermal_tau") {
            Some(v) => {
                let tau = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("serve.thermal_tau must be a number, got {v:?}"))?;
                spec.thermal = Some(Thermal {
                    tau,
                    floor: thermal_floor.transpose()?.unwrap_or(0.5),
                });
            }
            None => {
                if thermal_floor.is_some() {
                    return Err(anyhow!(
                        "serve.thermal_floor needs serve.thermal_tau (a floor without a \
                         time constant throttles nothing)"
                    ));
                }
            }
        }
        if let Some(d) = s.get("device") {
            let name = d
                .at(&["name"])
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("serve.device needs a string `name`, got {d:?}"))?;
            let accel = d
                .at(&["accel"])
                .and_then(Json::as_str)
                .map_or(Ok(crate::device::Accel::CpuBlas), |a| {
                    crate::device::Accel::parse(a)
                        .ok_or_else(|| anyhow!("bad serve.device accel `{a}` (none | blas | gpu)"))
                })?;
            let threads = d.at(&["threads"]).and_then(Json::as_f64).unwrap_or(4.0) as usize;
            spec.device = Some(DeviceTarget {
                device: name.to_string(),
                accel,
                threads,
            });
        }
        Ok(spec)
    }

    /// Serialize in the same grammar `from_json` reads — the config
    /// `serve` section, additive like [`ServeParams`]'s bench.json
    /// params (defaults and unset knobs emit nothing).
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("arrival_rate", Json::Num(self.arrival_rate)),
            ("num_requests", Json::Num(self.num_requests as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("slots", Json::Num(self.slots as f64)),
            (
                "prompt_len",
                Json::Arr(vec![
                    Json::Num(self.prompt_len.0 as f64),
                    Json::Num(self.prompt_len.1 as f64),
                ]),
            ),
            (
                "output_len",
                Json::Arr(vec![
                    Json::Num(self.output_len.0 as f64),
                    Json::Num(self.output_len.1 as f64),
                ]),
            ),
            ("mode", Json::Str(self.workload.clone())),
            ("peak_bw", Json::Num(self.peak_bw)),
            ("peak_flops", Json::Num(self.peak_flops)),
        ];
        if let Some(c) = self.clients {
            pairs.push(("clients", Json::Num(c as f64)));
        }
        if let Some(t) = self.turns {
            pairs.push((
                "turns",
                Json::Arr(vec![Json::Num(t.0 as f64), Json::Num(t.1 as f64)]),
            ));
        }
        if self.scheduler != "fcfs" {
            pairs.push(("scheduler", Json::Str(self.scheduler.clone())));
        }
        if let Some(c) = self.chunk_tokens {
            pairs.push(("chunk_tokens", Json::Num(c as f64)));
        }
        if let Some(slo) = &self.slo {
            if slo.ttft.is_finite() {
                pairs.push(("slo_ttft", Json::Num(slo.ttft)));
            }
            if slo.tpot.is_finite() {
                pairs.push(("slo_tpot", Json::Num(slo.tpot)));
            }
        }
        if let Some(t) = &self.thermal {
            pairs.push(("thermal_tau", Json::Num(t.tau)));
            pairs.push(("thermal_floor", Json::Num(t.floor)));
        }
        if let Some(b) = self.pool_blocks {
            pairs.push(("pool_blocks", Json::Num(b as f64)));
        }
        if self.prefix_share {
            pairs.push(("prefix_share", Json::Bool(true)));
        }
        if self.system_prompt > 0 {
            pairs.push(("system_prompt", Json::Num(self.system_prompt as f64)));
        }
        if let Some(t) = &self.device {
            pairs.push((
                "device",
                Json::obj(vec![
                    ("name", Json::Str(t.device.clone())),
                    ("accel", Json::Str(t.accel.key().into())),
                    ("threads", Json::Num(t.threads as f64)),
                ]),
            ));
        }
        Json::obj(pairs)
    }

    /// Resolve into the validated [`ServeParams`] view the simulator
    /// runs: registry lookups for both names, knob-applicability
    /// checks, then `ServeParams::validate`.
    pub fn resolve(&self) -> Result<ServeParams> {
        let wentry = registry::workload_entry(self.workload.trim()).ok_or_else(|| {
            anyhow!(
                "bad serve mode `{}` ({})",
                self.workload,
                registry::workload_names()
            )
        })?;
        anyhow::ensure!(
            wentry.accepts_clients || self.clients.is_none(),
            "serve.clients only applies to mode \"closed\" (open-loop and chat \
             workloads have no clients)"
        );
        anyhow::ensure!(
            wentry.accepts_turns || self.turns.is_none(),
            "serve.turns only applies to mode \"chat\" (single-turn workloads have no turns)"
        );
        let mode = match wentry.name {
            "closed" => ArrivalMode::ClosedLoop {
                clients: self.clients.unwrap_or(registry::DEFAULT_CLIENTS),
            },
            "chat" => ArrivalMode::Chat {
                turns: self.turns.unwrap_or(registry::DEFAULT_TURNS),
            },
            "diurnal" => ArrivalMode::Diurnal,
            "flash-crowd" => ArrivalMode::FlashCrowd,
            "heavy-tail" => ArrivalMode::HeavyTail,
            _ => ArrivalMode::Poisson,
        };
        let sentry = registry::scheduler_entry(self.scheduler.trim()).ok_or_else(|| {
            anyhow!(
                "bad serve scheduler `{}` ({})",
                self.scheduler,
                registry::scheduler_names()
            )
        })?;
        anyhow::ensure!(
            sentry.accepts_chunk || self.chunk_tokens.is_none(),
            "serve.chunk_tokens only applies to scheduler \"chunked\""
        );
        let scheduler = SchedulerPolicy::parse(
            sentry.name,
            self.chunk_tokens.unwrap_or(DEFAULT_CHUNK_TOKENS),
        )
        .expect("registry names parse");
        let p = ServeParams {
            arrival_rate: self.arrival_rate,
            num_requests: self.num_requests,
            seed: self.seed,
            slots: self.slots,
            prompt_len: self.prompt_len,
            output_len: self.output_len,
            mode,
            peak_bw: self.peak_bw,
            peak_flops: self.peak_flops,
            device: self.device.clone(),
            scheduler,
            capture_logits: false,
            pool_blocks: self.pool_blocks,
            prefix_share: self.prefix_share,
            system_prompt: self.system_prompt,
            slo: self.slo,
            thermal: self.thermal,
        };
        p.validate()?;
        Ok(p)
    }

    /// Build the scenario's workload through the registry — the cluster
    /// runner builds the traffic stream once, globally, from here.
    pub fn build_workload(&self) -> Result<Box<dyn Workload>> {
        let entry = registry::workload_entry(self.workload.trim()).ok_or_else(|| {
            anyhow!(
                "bad serve mode `{}` ({})",
                self.workload,
                registry::workload_names()
            )
        })?;
        let knobs = registry::WorkloadKnobs {
            rate: self.arrival_rate,
            n: self.num_requests,
            prompt_len: self.prompt_len,
            output_len: self.output_len,
            clients: self.clients,
            turns: self.turns,
        };
        Ok((entry.build)(&knobs))
    }
}

/// Parse a `[lo, hi]` length range from a spec object field.
fn parse_len_range(obj: &Json, key: &str, default: (usize, usize)) -> Result<(usize, usize)> {
    match obj.get(key) {
        None => Ok(default),
        Some(Json::Arr(a)) if a.len() == 2 => {
            let get = |i: usize| -> Result<usize> {
                a[i].as_f64()
                    .filter(|v| *v >= 1.0 && v.fract() == 0.0)
                    .map(|v| v as usize)
                    .ok_or_else(|| anyhow!("bad {key} entry {:?}", a[i]))
            };
            Ok((get(0)?, get(1)?))
        }
        Some(other) => Err(anyhow!("{key} must be a [lo, hi] pair, got {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    #[test]
    fn default_spec_resolves_to_default_serve_params() {
        let p = ScenarioSpec::default().resolve().unwrap();
        let d = ServeParams::default();
        assert_eq!(p.arrival_rate, d.arrival_rate);
        assert_eq!(p.num_requests, d.num_requests);
        assert_eq!(p.seed, d.seed);
        assert_eq!(p.slots, d.slots);
        assert_eq!(p.mode, d.mode);
        assert_eq!(p.scheduler, d.scheduler);
        assert_eq!(p.prompt_len, d.prompt_len);
        assert_eq!(p.peak_bw, d.peak_bw);
    }

    #[test]
    fn spec_round_trips_through_json() {
        let spec = ScenarioSpec {
            workload: "chat".into(),
            turns: Some((2, 4)),
            scheduler: "chunked".into(),
            chunk_tokens: Some(16),
            pool_blocks: Some(48),
            prefix_share: true,
            system_prompt: 8,
            ..ScenarioSpec::default()
        };
        let j = spec.to_json();
        let back = ScenarioSpec::from_json(&j).unwrap();
        assert_eq!(spec, back, "to_json/from_json must round-trip");
        // And an SLO + thermal spec round-trips too.
        let spec = ScenarioSpec {
            workload: "flash-crowd".into(),
            scheduler: "slo-aware".into(),
            slo: Some(SloSpec { ttft: 0.5, tpot: 0.1 }),
            thermal: Some(Thermal { tau: 5.0, floor: 0.6 }),
            ..ScenarioSpec::default()
        };
        assert_eq!(spec, ScenarioSpec::from_json(&spec.to_json()).unwrap());
    }

    #[test]
    fn resolve_rejects_inapplicable_knobs_and_unknown_names() {
        let bad = ScenarioSpec {
            workload: "warp".into(),
            ..ScenarioSpec::default()
        };
        assert!(bad.resolve().is_err());
        let bad = ScenarioSpec {
            clients: Some(3),
            ..ScenarioSpec::default()
        };
        assert!(bad.resolve().is_err(), "clients without closed mode");
        let bad = ScenarioSpec {
            turns: Some((2, 3)),
            ..ScenarioSpec::default()
        };
        assert!(bad.resolve().is_err(), "turns without chat mode");
        let bad = ScenarioSpec {
            chunk_tokens: Some(8),
            ..ScenarioSpec::default()
        };
        assert!(bad.resolve().is_err(), "chunk_tokens without chunked");
        let bad = ScenarioSpec {
            scheduler: "slo-aware".into(),
            ..ScenarioSpec::default()
        };
        assert!(bad.resolve().is_err(), "slo-aware without SLOs");
    }

    #[test]
    fn spec_workload_builds_through_the_registry() {
        let spec = ScenarioSpec {
            workload: "heavy-tail".into(),
            ..ScenarioSpec::default()
        };
        assert_eq!(spec.build_workload().unwrap().label(), "heavy-tail");
        let bad = ScenarioSpec {
            workload: "warp".into(),
            ..ScenarioSpec::default()
        };
        assert!(bad.build_workload().is_err());
    }

    #[test]
    fn from_params_projects_the_resolved_view_back() {
        let p = ServeParams {
            mode: ArrivalMode::Chat { turns: (2, 5) },
            scheduler: SchedulerPolicy::Chunked { chunk_tokens: 24 },
            ..ServeParams::default()
        };
        let spec = ScenarioSpec::from_params(&p);
        assert_eq!(spec.workload, "chat");
        assert_eq!(spec.turns, Some((2, 5)));
        assert_eq!(spec.scheduler, "chunked");
        assert_eq!(spec.chunk_tokens, Some(24));
        let r = spec.resolve().unwrap();
        assert_eq!(r.mode, p.mode);
        assert_eq!(r.scheduler, p.scheduler);
    }

    #[test]
    fn json_grammar_matches_the_config_serve_section() {
        let s = json::parse(
            r#"{"mode": "closed", "clients": 3, "arrival_rate": 8.5, "num_requests": 32}"#,
        )
        .unwrap();
        let p = ScenarioSpec::from_json(&s).unwrap().resolve().unwrap();
        assert_eq!(p.mode, ArrivalMode::ClosedLoop { clients: 3 });
        assert_eq!(p.arrival_rate, 8.5);
        assert_eq!(p.num_requests, 32);
        for bad in [
            r#"{"mode": "warp"}"#,
            r#"{"mode": ["closed"]}"#,
            r#"{"clients": 8}"#,
            r#"{"turns": [2, 3]}"#,
            r#"{"scheduler": "sjf"}"#,
            r#"{"scheduler": ["fcfs"]}"#,
            r#"{"chunk_tokens": 8}"#,
            r#"{"pool_blocks": 0}"#,
            r#"{"prefix_share": "yes"}"#,
            r#"{"system_prompt": 16}"#,
            r#"{"thermal_floor": 0.5}"#,
            r#"{"slo_ttft": "fast"}"#,
        ] {
            let j = json::parse(bad).unwrap();
            assert!(ScenarioSpec::from_json(&j).is_err(), "must reject {bad}");
        }
    }
}
