//! L3 coordinator: the ELIB benchmarking program (paper §4, Algorithm 1).
//!
//! `Elib` wires the pieces: configuration ([`config`]), the automatic
//! quantization flow ([`flow`]), the deploy/measure/metrics loop
//! ([`runner`]) and report persistence. The CLI (`rust/src/main.rs`) and
//! the examples drive this type.

pub mod cluster;
pub mod config;
pub mod fleet;
pub mod flow;
pub mod registry;
pub mod runner;
pub mod scenario;
pub mod serve;
pub mod sim;

pub use cluster::{run_cluster, ClusterParams, ClusterReport, ReplicaSpec, RoutePolicy, Tier};
pub use config::{BenchParams, ElibConfig};
pub use fleet::{run_fleet, CellOutcome, FleetCell, FleetParams, FleetReport};
pub use flow::{quantization_flow, QuantizedModel};
pub use runner::{HostMeasurement, RunReport, SkipReason};
pub use scenario::ScenarioSpec;
pub use serve::{
    compare_bench, run_serve, ArrivalMode, BenchComparison, DeviceTarget, ServeParams,
    ServeParamsBuilder, ServeReport, SloSpec,
};
pub use sim::{Scheduler, SchedulerPolicy, SimLoop, Workload};

use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// The top-level benchmarking system.
pub struct Elib {
    pub config: ElibConfig,
    log_quiet: bool,
}

impl Elib {
    pub fn new(config: ElibConfig) -> Self {
        Self {
            config,
            log_quiet: false,
        }
    }

    pub fn quiet(mut self) -> Self {
        self.log_quiet = true;
        self
    }

    fn log(&self, msg: &str) {
        if !self.log_quiet {
            println!("{msg}");
        }
    }

    /// Algorithm 1 Ln. 2: produce every quantized model from the original.
    pub fn quantization_flow(&self) -> Result<Vec<QuantizedModel>> {
        let original = self
            .config
            .artifacts_dir
            .join("tiny_llama_f32.eguf");
        let (cfg, dense) = flow::load_original(&original)?;
        let models = flow::quantization_flow(
            &cfg,
            &dense,
            &self.config.quant_schemes,
            &self.config.out_dir,
        )?;
        for m in &models {
            self.log(&format!(
                "[flow] {}: {} bytes, max rel rmse {:.4}",
                m.qtype.name(),
                m.file_bytes,
                m.max_rel_rmse
            ));
        }
        let report = flow::flow_report(&models);
        std::fs::write(
            self.config.out_dir.join("quantization_flow.json"),
            json::to_string_pretty(&report),
        )?;
        Ok(models)
    }

    /// Full Algorithm-1 run: flow + grid + persisted report. Returns the
    /// report and the path of the JSON it was saved to.
    pub fn run(&self) -> Result<(RunReport, PathBuf)> {
        std::fs::create_dir_all(&self.config.out_dir)?;
        let models = self.quantization_flow()?;
        let quiet = self.log_quiet;
        let mut log = |m: &str| {
            if !quiet {
                println!("{m}");
            }
        };
        let report = runner::run(&self.config, &models, &mut log)?;
        let path = self.config.out_dir.join("run_report.json");
        std::fs::write(&path, json::to_string_pretty(&report_json(&report)))
            .with_context(|| format!("write {}", path.display()))?;
        Ok((report, path))
    }
}

/// Serialize a run report.
pub fn report_json(r: &RunReport) -> Json {
    Json::obj(vec![
        (
            "records",
            Json::Arr(r.records.iter().map(|m| m.to_json()).collect()),
        ),
        (
            "skipped",
            Json::Arr(
                r.skipped
                    .iter()
                    .map(|(c, why)| {
                        Json::obj(vec![
                            ("cell", Json::Str(c.clone())),
                            ("reason", Json::Str(why.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "host",
            Json::Arr(
                r.host
                    .iter()
                    .map(|h| {
                        Json::obj(vec![
                            ("qtype", Json::Str(h.qtype.name().into())),
                            ("backend", Json::Str(h.backend.clone())),
                            ("batch", Json::Num(h.batch as f64)),
                            ("throughput_tok_s", Json::Num(h.throughput_tok_s)),
                            ("tpot_secs", Json::Num(h.tpot_secs)),
                            ("prefill_secs", Json::Num(h.prefill_secs)),
                            ("bytes_per_token", Json::Num(h.bytes_per_token as f64)),
                            ("param_bytes", Json::Num(h.param_bytes as f64)),
                            ("kv_bytes", Json::Num(h.kv_bytes as f64)),
                            ("host_mbu", Json::Num(h.host_mbu)),
                            ("ppl", Json::Num(h.ppl)),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}
