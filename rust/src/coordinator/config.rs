//! ELIB configuration (Algorithm 1 inputs): original model, quantization
//! schemes, prompt/benchmark/device parameters. Loadable from a JSON
//! config file so deployments are reproducible.

use std::path::{Path, PathBuf};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::device::{Accel, DeviceSpec};
use crate::quant::QuantType;
use crate::util::json::{self, Json};

use super::fleet::FleetParams;
use super::scenario::ScenarioSpec;
use super::serve::ServeParams;

/// `benchmark_params` of Algorithm 1.
#[derive(Clone, Debug)]
pub struct BenchParams {
    /// Benchmark iterations (paper ran 100; default kept small so the
    /// full grid regenerates quickly — raise via config/CLI).
    pub iterations: usize,
    /// Concurrent sequences for the simulated workload (MBU eq. 3).
    pub batch_size: usize,
    /// Batch sizes the *host* engine sweeps (`--batch-sizes 1,2,4,8`):
    /// each (quant, backend) host measurement runs once per entry on the
    /// batched engine. Default `[1]` keeps the seed behavior.
    pub batch_sizes: Vec<usize>,
    /// Worker threads of the benchmark scheduler: host measurements and
    /// device-grid cells fan out over the shared threadpool. Results are
    /// collected in deterministic grid order regardless of this value.
    /// Defaults to 1 (the sequential seed path) because concurrent host
    /// jobs contend for cores and would pollute the wall-clock
    /// throughput/TPOT numbers; raise it (`--threads`) when grid
    /// turnaround matters more than timing fidelity.
    pub scheduler_threads: usize,
    /// Prompt length driving TTFT.
    pub prompt_tokens: usize,
    /// Tokens generated per measurement run.
    pub gen_tokens: usize,
    /// Held-out corpus tokens used for the accuracy (perplexity) metric.
    pub ppl_tokens: usize,
    /// Simulated context length when pricing the 7B workload.
    pub context_len: usize,
    /// Per-cell inference timeout (Algorithm 1 Ln. 11 error handling).
    pub timeout: Duration,
    /// Assumed peak memory bandwidth of the *host* running the native
    /// engine, for host-side MBU accounting (B/s).
    pub host_peak_bw: f64,
}

impl Default for BenchParams {
    fn default() -> Self {
        Self {
            iterations: 1,
            batch_size: 1,
            batch_sizes: vec![1],
            scheduler_threads: 1,
            prompt_tokens: 32,
            gen_tokens: 32,
            ppl_tokens: 384,
            context_len: 128,
            timeout: Duration::from_secs(120),
            host_peak_bw: 20e9,
        }
    }
}

/// Network-front defaults of `elib daemon` (DESIGN.md §10). The sim
/// side of the daemon — slots, seed, scheduler, KV pool, device clock —
/// reuses the `serve` section; this holds only the wall-clock knobs.
#[derive(Clone, Debug)]
pub struct DaemonConfig {
    /// Bind address (default loopback; `0.0.0.0` exposes the daemon).
    pub host: String,
    /// TCP port (0 = ephemeral).
    pub port: u16,
    /// Connection-handling worker threads.
    pub workers: usize,
    /// Requests allowed to wait for a slot before arrivals get 429.
    pub queue_depth: usize,
    /// Lifetime request budget (placeholder ring size).
    pub max_requests: usize,
    /// Virtual seconds per wall second (1.0 = real time).
    pub pace: f64,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            host: "127.0.0.1".into(),
            port: 8080,
            workers: 4,
            queue_depth: 8,
            max_requests: 4096,
            pace: 1.0,
        }
    }
}

/// Top-level ELIB configuration.
#[derive(Clone, Debug)]
pub struct ElibConfig {
    /// Directory with `make artifacts` outputs (original model + corpus).
    pub artifacts_dir: PathBuf,
    /// Where quantized models and reports are written.
    pub out_dir: PathBuf,
    /// `quantization_params`: which schemes the flow produces.
    pub quant_schemes: Vec<QuantType>,
    /// `device_params`: which simulated devices to benchmark.
    pub devices: Vec<DeviceSpec>,
    pub bench: BenchParams,
    /// The `serve` scenario (continuous-batching serving simulator).
    pub serve: ServeParams,
    /// The `fleet` sweep (device-aware serving across the grid).
    pub fleet: FleetParams,
    /// The `daemon` network front (wall-clock serving over the sim).
    pub daemon: DaemonConfig,
}

impl Default for ElibConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("target/elib-out"),
            quant_schemes: QuantType::PAPER_SET.to_vec(),
            devices: DeviceSpec::paper_devices(),
            bench: BenchParams::default(),
            serve: ServeParams::default(),
            fleet: FleetParams::default(),
            daemon: DaemonConfig::default(),
        }
    }
}

impl ElibConfig {
    /// Parse from a JSON config file. All fields optional; unknown device
    /// names are an error.
    pub fn from_json_str(text: &str) -> Result<Self> {
        let j = json::parse(text).map_err(|e| anyhow!("config: {e}"))?;
        let mut cfg = ElibConfig::default();
        if let Some(s) = j.get("artifacts_dir").and_then(Json::as_str) {
            cfg.artifacts_dir = PathBuf::from(s);
        }
        if let Some(s) = j.get("out_dir").and_then(Json::as_str) {
            cfg.out_dir = PathBuf::from(s);
        }
        if let Some(arr) = j.get("quant_schemes").and_then(Json::as_arr) {
            cfg.quant_schemes = arr
                .iter()
                .map(|q| {
                    q.as_str()
                        .and_then(QuantType::parse)
                        .ok_or_else(|| anyhow!("bad quant scheme {q:?}"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(arr) = j.get("devices").and_then(Json::as_arr) {
            cfg.devices = arr
                .iter()
                .map(|d| {
                    d.as_str()
                        .and_then(DeviceSpec::by_name)
                        .ok_or_else(|| anyhow!("unknown device {d:?}"))
                })
                .collect::<Result<_>>()?;
        }
        if let Some(b) = j.get("bench") {
            let mut bp = BenchParams::default();
            let num = |k: &str, d: f64| b.get(k).and_then(Json::as_f64).unwrap_or(d);
            bp.iterations = num("iterations", bp.iterations as f64) as usize;
            bp.batch_size = num("batch_size", bp.batch_size as f64) as usize;
            if let Some(arr) = b.get("batch_sizes").and_then(Json::as_arr) {
                bp.batch_sizes = arr
                    .iter()
                    .map(|x| {
                        x.as_f64()
                            .filter(|v| *v >= 1.0 && v.fract() == 0.0)
                            .map(|v| v as usize)
                            .ok_or_else(|| anyhow!("bad batch size {x:?}"))
                    })
                    .collect::<Result<_>>()?;
            }
            bp.scheduler_threads = num("scheduler_threads", bp.scheduler_threads as f64) as usize;
            bp.prompt_tokens = num("prompt_tokens", bp.prompt_tokens as f64) as usize;
            bp.gen_tokens = num("gen_tokens", bp.gen_tokens as f64) as usize;
            bp.ppl_tokens = num("ppl_tokens", bp.ppl_tokens as f64) as usize;
            bp.context_len = num("context_len", bp.context_len as f64) as usize;
            bp.timeout = Duration::from_secs_f64(num("timeout_secs", 120.0));
            bp.host_peak_bw = num("host_peak_bw", bp.host_peak_bw);
            cfg.bench = bp;
        }
        if let Some(s) = j.get("serve") {
            // The serve-section grammar lives in `ScenarioSpec` now (the
            // unified spec `serve`, `fleet` and `cluster` all consume);
            // the config keeps only the *resolved view*. Same keys, same
            // cross-checks, same errors — the tests below pin them.
            cfg.serve = ScenarioSpec::from_json(s)?.resolve()?;
        }
        if let Some(f) = j.get("fleet") {
            let mut fp = FleetParams::default();
            let num = |k: &str, d: f64| f.get(k).and_then(Json::as_f64).unwrap_or(d);
            if let Some(arr) = f.get("devices").and_then(Json::as_arr) {
                fp.devices = arr
                    .iter()
                    .map(|d| {
                        d.as_str()
                            .and_then(DeviceSpec::by_name)
                            .ok_or_else(|| anyhow!("unknown fleet device {d:?}"))
                    })
                    .collect::<Result<_>>()?;
            }
            if let Some(arr) = f.get("accels").and_then(Json::as_arr) {
                fp.accels = arr
                    .iter()
                    .map(|a| {
                        a.as_str()
                            .and_then(Accel::parse)
                            .ok_or_else(|| anyhow!("bad fleet accel {a:?} (none | blas | gpu)"))
                    })
                    .collect::<Result<_>>()?;
            }
            if let Some(arr) = f.get("quants").and_then(Json::as_arr) {
                fp.quants = arr
                    .iter()
                    .map(|q| {
                        q.as_str()
                            .and_then(QuantType::parse)
                            .ok_or_else(|| anyhow!("bad fleet quant {q:?}"))
                    })
                    .collect::<Result<_>>()?;
            }
            fp.slots = num("slots", fp.slots as f64) as usize;
            fp.device_threads = num("device_threads", fp.device_threads as f64) as usize;
            fp.trace.arrival_rate = num("arrival_rate", fp.trace.arrival_rate);
            fp.trace.num_requests = num("num_requests", fp.trace.num_requests as f64) as usize;
            fp.trace.seed = num("seed", fp.trace.seed as f64) as u64;
            fp.trace.prompt_len = parse_len_range(f, "prompt_len", fp.trace.prompt_len)?;
            fp.trace.output_len = parse_len_range(f, "output_len", fp.trace.output_len)?;
            fp.validate()?;
            fp.trace.validate()?;
            cfg.fleet = fp;
        }
        if let Some(d) = j.get("daemon") {
            let mut dc = DaemonConfig::default();
            if let Some(s) = d.get("host").and_then(Json::as_str) {
                anyhow::ensure!(!s.is_empty(), "daemon host must not be empty");
                dc.host = s.to_string();
            }
            let int = |k: &str, default: usize| -> Result<usize> {
                match d.get(k) {
                    None => Ok(default),
                    Some(v) => v
                        .as_f64()
                        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
                        .map(|x| x as usize)
                        .ok_or_else(|| anyhow!("bad daemon {k} {v:?}")),
                }
            };
            let port = int("port", dc.port as usize)?;
            anyhow::ensure!(port <= u16::MAX as usize, "daemon port {port} out of range");
            dc.port = port as u16;
            dc.workers = int("workers", dc.workers)?;
            anyhow::ensure!(dc.workers >= 1, "daemon workers must be at least 1");
            dc.queue_depth = int("queue_depth", dc.queue_depth)?;
            dc.max_requests = int("max_requests", dc.max_requests)?;
            anyhow::ensure!(dc.max_requests >= 1, "daemon max_requests must be at least 1");
            if let Some(v) = d.get("pace") {
                dc.pace = v
                    .as_f64()
                    .filter(|x| x.is_finite() && *x > 0.0)
                    .ok_or_else(|| anyhow!("daemon pace must be a positive, finite rate"))?;
            }
            cfg.daemon = dc;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("read config {}: {e}", path.display()))?;
        Self::from_json_str(&text)
    }
}

/// Parse a `[lo, hi]` length range from a config object field.
fn parse_len_range(obj: &Json, key: &str, default: (usize, usize)) -> Result<(usize, usize)> {
    match obj.get(key) {
        None => Ok(default),
        Some(Json::Arr(a)) if a.len() == 2 => {
            let get = |i: usize| -> Result<usize> {
                a[i].as_f64()
                    .filter(|v| *v >= 1.0 && v.fract() == 0.0)
                    .map(|v| v as usize)
                    .ok_or_else(|| anyhow!("bad {key} entry {:?}", a[i]))
            };
            Ok((get(0)?, get(1)?))
        }
        Some(other) => Err(anyhow!("{key} must be a [lo, hi] pair, got {other:?}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::serve::ArrivalMode;
    use crate::coordinator::sim::SchedulerPolicy;

    #[test]
    fn default_covers_paper_grid() {
        let c = ElibConfig::default();
        assert_eq!(c.quant_schemes.len(), 5);
        assert_eq!(c.devices.len(), 3);
    }

    #[test]
    fn json_overrides() {
        let c = ElibConfig::from_json_str(
            r#"{
                "quant_schemes": ["q4_0", "q8_0"],
                "devices": ["Macbook"],
                "bench": {"iterations": 3, "gen_tokens": 8, "timeout_secs": 5}
            }"#,
        )
        .unwrap();
        assert_eq!(c.quant_schemes, vec![QuantType::Q4_0, QuantType::Q8_0]);
        assert_eq!(c.devices.len(), 1);
        assert_eq!(c.bench.iterations, 3);
        assert_eq!(c.bench.timeout, Duration::from_secs(5));
    }

    #[test]
    fn batch_sizes_and_threads_parse() {
        let c = ElibConfig::from_json_str(
            r#"{"bench": {"batch_sizes": [1, 2, 4, 8], "scheduler_threads": 6}}"#,
        )
        .unwrap();
        assert_eq!(c.bench.batch_sizes, vec![1, 2, 4, 8]);
        assert_eq!(c.bench.scheduler_threads, 6);
        // Defaults reproduce the single-batch, sequential seed behavior
        // (concurrency would pollute wall-clock measurements).
        assert_eq!(ElibConfig::default().bench.batch_sizes, vec![1]);
        assert_eq!(ElibConfig::default().bench.scheduler_threads, 1);
        // Zero or fractional batches are config errors, not later panics.
        assert!(ElibConfig::from_json_str(r#"{"bench": {"batch_sizes": [0]}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"bench": {"batch_sizes": [2.7]}}"#).is_err());
    }

    #[test]
    fn fleet_section_parses_and_validates() {
        let c = ElibConfig::from_json_str(
            r#"{"fleet": {
                "devices": ["NanoPI", "Macbook"], "accels": ["blas", "gpu"],
                "quants": ["q4_0", "q5_1"], "slots": 6, "device_threads": 8,
                "arrival_rate": 3.5, "num_requests": 24, "seed": 13,
                "prompt_len": [4, 8], "output_len": [2, 6]
            }}"#,
        )
        .unwrap();
        assert_eq!(c.fleet.devices.len(), 2);
        assert_eq!(c.fleet.accels, vec![Accel::CpuBlas, Accel::Gpu]);
        assert_eq!(c.fleet.quants, vec![QuantType::Q4_0, QuantType::Q5_1]);
        assert_eq!(c.fleet.slots, 6);
        assert_eq!(c.fleet.device_threads, 8);
        assert_eq!(c.fleet.trace.arrival_rate, 3.5);
        assert_eq!(c.fleet.trace.num_requests, 24);
        assert_eq!(c.fleet.trace.seed, 13);
        assert_eq!(c.fleet.trace.prompt_len, (4, 8));
        // Defaults: the acceptance grid (3 devices × 2 accels × 2 quants).
        let d = ElibConfig::default();
        assert_eq!(d.fleet.devices.len(), 3);
        assert_eq!(d.fleet.accels.len(), 2);
        assert_eq!(d.fleet.quants.len(), 2);
        assert_eq!(d.fleet.slots, 8);
        // Bad values are config errors, not later panics.
        assert!(ElibConfig::from_json_str(r#"{"fleet": {"accels": ["warp"]}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"fleet": {"devices": ["Pixel"]}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"fleet": {"quants": []}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"fleet": {"slots": 0}}"#).is_err());
    }

    #[test]
    fn daemon_section_parses_and_validates() {
        let c = ElibConfig::from_json_str(
            r#"{"daemon": {
                "host": "0.0.0.0", "port": 9090, "workers": 2,
                "queue_depth": 16, "max_requests": 128, "pace": 0.5
            }}"#,
        )
        .unwrap();
        assert_eq!(c.daemon.host, "0.0.0.0");
        assert_eq!(c.daemon.port, 9090);
        assert_eq!(c.daemon.workers, 2);
        assert_eq!(c.daemon.queue_depth, 16);
        assert_eq!(c.daemon.max_requests, 128);
        assert_eq!(c.daemon.pace, 0.5);
        // Defaults: loopback, real-time pace.
        let d = ElibConfig::default().daemon;
        assert_eq!((d.host.as_str(), d.port, d.pace), ("127.0.0.1", 8080, 1.0));
        assert_eq!((d.workers, d.queue_depth, d.max_requests), (4, 8, 4096));
        // Bad values are config errors, not later panics.
        assert!(ElibConfig::from_json_str(r#"{"daemon": {"port": 70000}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"daemon": {"port": 1.5}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"daemon": {"workers": 0}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"daemon": {"max_requests": 0}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"daemon": {"pace": 0}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"daemon": {"pace": "fast"}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"daemon": {"host": ""}}"#).is_err());
    }

    #[test]
    fn rejects_unknown_scheme_or_device() {
        assert!(ElibConfig::from_json_str(r#"{"quant_schemes":["q2_k"]}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"devices":["Pixel"]}"#).is_err());
    }

    #[test]
    fn serve_section_parses_and_validates() {
        let c = ElibConfig::from_json_str(
            r#"{"serve": {
                "arrival_rate": 8.5, "num_requests": 32, "seed": 99, "slots": 6,
                "prompt_len": [4, 10], "output_len": [2, 8],
                "mode": "closed", "clients": 3
            }}"#,
        )
        .unwrap();
        assert_eq!(c.serve.arrival_rate, 8.5);
        assert_eq!(c.serve.num_requests, 32);
        assert_eq!(c.serve.seed, 99);
        assert_eq!(c.serve.slots, 6);
        assert_eq!(c.serve.prompt_len, (4, 10));
        assert_eq!(c.serve.output_len, (2, 8));
        assert_eq!(c.serve.mode, ArrivalMode::ClosedLoop { clients: 3 });
        // Defaults when the section is absent.
        let d = ElibConfig::default();
        assert_eq!(d.serve.num_requests, 64);
        assert_eq!(d.serve.mode, ArrivalMode::Poisson);
        // Bad values are config errors, not later panics.
        assert!(ElibConfig::from_json_str(r#"{"serve": {"mode": "warp"}}"#).is_err());
        assert!(
            ElibConfig::from_json_str(r#"{"serve": {"mode": ["closed"]}}"#).is_err(),
            "non-string mode must not silently become poisson"
        );
        assert!(
            ElibConfig::from_json_str(r#"{"serve": {"clients": 8}}"#).is_err(),
            "clients without closed mode must be rejected, as on the CLI"
        );
        assert!(ElibConfig::from_json_str(r#"{"serve": {"prompt_len": [0, 4]}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"serve": {"prompt_len": [9, 4]}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"serve": {"num_requests": 0}}"#).is_err());
    }

    #[test]
    fn serve_scheduler_and_chat_keys_parse_and_validate() {
        let c = ElibConfig::from_json_str(
            r#"{"serve": {"scheduler": "chunked", "chunk_tokens": 16}}"#,
        )
        .unwrap();
        assert_eq!(c.serve.scheduler, SchedulerPolicy::Chunked { chunk_tokens: 16 });
        let c = ElibConfig::from_json_str(r#"{"serve": {"scheduler": "priority"}}"#).unwrap();
        assert_eq!(c.serve.scheduler, SchedulerPolicy::Priority);
        let c = ElibConfig::from_json_str(
            r#"{"serve": {"mode": "chat", "turns": [2, 4], "arrival_rate": 2.0}}"#,
        )
        .unwrap();
        assert_eq!(c.serve.mode, ArrivalMode::Chat { turns: (2, 4) });
        // Defaults: fcfs scheduler, chunked gets 32 tokens, chat 2-3 turns.
        assert_eq!(ElibConfig::default().serve.scheduler, SchedulerPolicy::Fcfs);
        let c = ElibConfig::from_json_str(r#"{"serve": {"scheduler": "chunked"}}"#).unwrap();
        assert_eq!(c.serve.scheduler, SchedulerPolicy::Chunked { chunk_tokens: 32 });
        let c = ElibConfig::from_json_str(r#"{"serve": {"mode": "chat"}}"#).unwrap();
        assert_eq!(c.serve.mode, ArrivalMode::Chat { turns: (2, 3) });
        // Bad values are config errors, not later panics.
        assert!(ElibConfig::from_json_str(r#"{"serve": {"scheduler": "sjf"}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"serve": {"scheduler": ["fcfs"]}}"#).is_err());
        assert!(
            ElibConfig::from_json_str(r#"{"serve": {"chunk_tokens": 8}}"#).is_err(),
            "chunk_tokens without the chunked scheduler must be rejected"
        );
        assert!(
            ElibConfig::from_json_str(r#"{"serve": {"scheduler": "chunked", "chunk_tokens": 0}}"#)
                .is_err()
        );
        assert!(
            ElibConfig::from_json_str(r#"{"serve": {"turns": [2, 3]}}"#).is_err(),
            "turns without chat mode must be rejected"
        );
        assert!(
            ElibConfig::from_json_str(r#"{"serve": {"mode": "chat", "clients": 8}}"#).is_err(),
            "clients with chat mode must be rejected, not silently ignored"
        );
        assert!(
            ElibConfig::from_json_str(r#"{"serve": {"mode": "chat", "turns": [4, 2]}}"#).is_err()
        );
    }

    #[test]
    fn serve_slo_and_thermal_keys_parse_and_validate() {
        use crate::coordinator::SloSpec;
        let c = ElibConfig::from_json_str(
            r#"{"serve": {
                "mode": "flash-crowd", "scheduler": "slo-aware",
                "slo_ttft": 0.5, "slo_tpot": 0.1,
                "thermal_tau": 5.0, "thermal_floor": 0.6
            }}"#,
        )
        .unwrap();
        assert_eq!(c.serve.mode, ArrivalMode::FlashCrowd);
        assert_eq!(c.serve.scheduler, SchedulerPolicy::SloAware);
        assert_eq!(c.serve.slo, Some(SloSpec { ttft: 0.5, tpot: 0.1 }));
        let t = c.serve.thermal.unwrap();
        assert_eq!((t.tau, t.floor), (5.0, 0.6));
        // Either deadline alone enables SLOs; the other never binds.
        let c = ElibConfig::from_json_str(r#"{"serve": {"slo_ttft": 0.5}}"#).unwrap();
        assert_eq!(c.serve.slo, Some(SloSpec { ttft: 0.5, tpot: f64::INFINITY }));
        // The remaining hostile modes parse too.
        for mode in ["diurnal", "heavy-tail"] {
            let c =
                ElibConfig::from_json_str(&format!(r#"{{"serve": {{"mode": "{mode}"}}}}"#))
                    .unwrap();
            assert_eq!(c.serve.mode.label(), mode);
        }
        // The floor alone throttles nothing — reject it.
        let c = ElibConfig::from_json_str(r#"{"serve": {"thermal_tau": 2.0}}"#).unwrap();
        assert_eq!(c.serve.thermal.map(|t| t.floor), Some(0.5));
        assert!(ElibConfig::from_json_str(r#"{"serve": {"thermal_floor": 0.5}}"#).is_err());
        // Cross-checks surface as config errors, not later panics.
        assert!(
            ElibConfig::from_json_str(r#"{"serve": {"scheduler": "slo-aware"}}"#).is_err(),
            "slo-aware without SLOs must be rejected"
        );
        assert!(
            ElibConfig::from_json_str(
                r#"{"serve": {"mode": "closed", "slo_ttft": 0.5}}"#
            )
            .is_err(),
            "SLOs on a closed loop must be rejected"
        );
        assert!(ElibConfig::from_json_str(r#"{"serve": {"slo_ttft": "fast"}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"serve": {"slo_ttft": -1.0}}"#).is_err());
        assert!(
            ElibConfig::from_json_str(r#"{"serve": {"thermal_tau": 2.0, "thermal_floor": 0.0}}"#)
                .is_err()
        );
    }

    #[test]
    fn serve_paged_kv_keys_parse_and_validate() {
        let c = ElibConfig::from_json_str(
            r#"{"serve": {"pool_blocks": 48, "prefix_share": true, "system_prompt": 24}}"#,
        )
        .unwrap();
        assert_eq!(c.serve.pool_blocks, Some(48));
        assert!(c.serve.prefix_share);
        assert_eq!(c.serve.system_prompt, 24);
        // Defaults: unbounded pool, no sharing, no system prompt.
        let d = ElibConfig::default();
        assert_eq!(d.serve.pool_blocks, None);
        assert!(!d.serve.prefix_share);
        assert_eq!(d.serve.system_prompt, 0);
        // Prefix sharing alone is fine (it forks identical trace prompts).
        assert!(ElibConfig::from_json_str(r#"{"serve": {"prefix_share": true}}"#).is_ok());
        // Bad values are config errors, not later panics.
        assert!(ElibConfig::from_json_str(r#"{"serve": {"pool_blocks": 0}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"serve": {"pool_blocks": 2.5}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"serve": {"pool_blocks": "big"}}"#).is_err());
        assert!(ElibConfig::from_json_str(r#"{"serve": {"prefix_share": "yes"}}"#).is_err());
        assert!(
            ElibConfig::from_json_str(r#"{"serve": {"system_prompt": 16}}"#).is_err(),
            "a system prompt nobody shares must be rejected, as on the CLI"
        );
    }
}
