//! The single workload/scheduler registry (DESIGN.md §5).
//!
//! Before this module the `"poisson" | "closed" | "chat" | ...` and
//! `"fcfs" | "priority" | "chunked" | "slo-aware"` name matches were
//! duplicated across `serve.rs`, `scheduler.rs`, `config.rs` and
//! `main.rs` — adding a workload meant finding every match arm. Now one
//! table maps each stable name (the string that appears in `bench.json`
//! and in `--workload` / `--scheduler` flags — unchanged by this
//! refactor) to its builder plus the knobs it accepts, and every
//! consumer (`SchedulerPolicy::parse`, `ArrivalMode::workload`,
//! `ElibConfig`, `ScenarioSpec`, `--compare-schedulers`) resolves
//! through it.

use super::sim::{
    ChatSessions, ChunkedPrefill, ClosedLoop, DiurnalPoisson, Fcfs, FlashCrowd, HeavyTail,
    PoissonOpen, PriorityTiers, Scheduler, SloAware, Workload,
};

/// Everything a workload builder may consume. Callers fill the knobs
/// they have; builders read only the ones their entry declares
/// (`accepts_clients` / `accepts_turns`), falling back to the serve
/// defaults for the rest.
#[derive(Clone, Copy, Debug)]
pub struct WorkloadKnobs {
    /// Arrival rate in req/s (chat: session rate). Ignored by `closed`.
    pub rate: f64,
    /// Request count (chat: session count).
    pub n: usize,
    pub prompt_len: (usize, usize),
    pub output_len: (usize, usize),
    /// Closed-loop concurrency; only read when `accepts_clients`.
    pub clients: Option<usize>,
    /// Chat turns-per-session range; only read when `accepts_turns`.
    pub turns: Option<(usize, usize)>,
}

/// Default closed-loop client count when the knob is unset.
pub const DEFAULT_CLIENTS: usize = 4;
/// Default chat turns-per-session range when the knob is unset.
pub const DEFAULT_TURNS: (usize, usize) = (2, 3);

/// One registered workload: the stable name plus what it accepts and
/// how to build it.
pub struct WorkloadEntry {
    /// The `bench.json` / `--workload` identity string.
    pub name: &'static str,
    /// Whether `--clients` applies (closed loop only).
    pub accepts_clients: bool,
    /// Whether `--turns` applies (chat only).
    pub accepts_turns: bool,
    /// Open-loop workloads decouple arrivals from completions — the
    /// property SLO validation requires.
    pub open_loop: bool,
    pub build: fn(&WorkloadKnobs) -> Box<dyn Workload>,
}

/// The registry: every serving workload, in CLI-documentation order.
pub const WORKLOADS: &[WorkloadEntry] = &[
    WorkloadEntry {
        name: "poisson",
        accepts_clients: false,
        accepts_turns: false,
        open_loop: true,
        build: |k| {
            Box::new(PoissonOpen {
                rate: k.rate,
                n: k.n,
                prompt_len: k.prompt_len,
                output_len: k.output_len,
            })
        },
    },
    WorkloadEntry {
        name: "closed",
        accepts_clients: true,
        accepts_turns: false,
        open_loop: false,
        build: |k| {
            Box::new(ClosedLoop::new(
                k.clients.unwrap_or(DEFAULT_CLIENTS),
                k.n,
                k.prompt_len,
                k.output_len,
            ))
        },
    },
    WorkloadEntry {
        name: "chat",
        accepts_clients: false,
        accepts_turns: true,
        open_loop: false,
        build: |k| {
            Box::new(ChatSessions::new(
                k.rate,
                k.n,
                k.turns.unwrap_or(DEFAULT_TURNS),
                k.prompt_len,
                k.output_len,
            ))
        },
    },
    WorkloadEntry {
        name: "diurnal",
        accepts_clients: false,
        accepts_turns: false,
        open_loop: true,
        build: |k| {
            Box::new(DiurnalPoisson {
                rate: k.rate,
                n: k.n,
                prompt_len: k.prompt_len,
                output_len: k.output_len,
            })
        },
    },
    WorkloadEntry {
        name: "flash-crowd",
        accepts_clients: false,
        accepts_turns: false,
        open_loop: true,
        build: |k| {
            Box::new(FlashCrowd {
                rate: k.rate,
                n: k.n,
                prompt_len: k.prompt_len,
                output_len: k.output_len,
            })
        },
    },
    WorkloadEntry {
        name: "heavy-tail",
        accepts_clients: false,
        accepts_turns: false,
        open_loop: true,
        build: |k| {
            Box::new(HeavyTail {
                rate: k.rate,
                n: k.n,
                prompt_len: k.prompt_len,
                output_len: k.output_len,
            })
        },
    },
];

/// Look up a workload by its stable name (exact match — callers
/// normalize case/whitespace if their input grammar allows it).
pub fn workload_entry(name: &str) -> Option<&'static WorkloadEntry> {
    WORKLOADS.iter().find(|e| e.name == name)
}

/// `" | "`-joined workload names, for error messages.
pub fn workload_names() -> String {
    WORKLOADS
        .iter()
        .map(|e| e.name)
        .collect::<Vec<_>>()
        .join(" | ")
}

/// One registered scheduler: the stable name, its knob constraints, and
/// how to build it.
pub struct SchedulerEntry {
    /// The `bench.json` / `--scheduler` identity string.
    pub name: &'static str,
    /// Whether the policy requires SLOs to be configured.
    pub needs_slo: bool,
    /// Whether `--chunk-tokens` applies.
    pub accepts_chunk: bool,
    /// Build the scheduler. `seed` feeds policies with their own seeded
    /// stream (priority tiers); `chunk` is the chunked-prefill span.
    /// SLO-aware policies capture the deadline table themselves in
    /// [`Scheduler::assign_priorities`].
    pub build: fn(seed: u64, chunk: usize) -> Box<dyn Scheduler>,
}

/// The registry: every admission/prefill policy, in CLI order.
pub const SCHEDULERS: &[SchedulerEntry] = &[
    SchedulerEntry {
        name: "fcfs",
        needs_slo: false,
        accepts_chunk: false,
        build: |_, _| Box::new(Fcfs),
    },
    SchedulerEntry {
        name: "priority",
        needs_slo: false,
        accepts_chunk: false,
        build: |seed, _| Box::new(PriorityTiers::new(seed)),
    },
    SchedulerEntry {
        name: "chunked",
        needs_slo: false,
        accepts_chunk: true,
        build: |_, chunk| Box::new(ChunkedPrefill::new(chunk)),
    },
    SchedulerEntry {
        name: "slo-aware",
        needs_slo: true,
        accepts_chunk: false,
        build: |_, _| Box::new(SloAware::new()),
    },
];

/// Look up a scheduler by its stable name (exact match).
pub fn scheduler_entry(name: &str) -> Option<&'static SchedulerEntry> {
    SCHEDULERS.iter().find(|e| e.name == name)
}

/// `" | "`-joined scheduler names, for error messages.
pub fn scheduler_names() -> String {
    SCHEDULERS
        .iter()
        .map(|e| e.name)
        .collect::<Vec<_>>()
        .join(" | ")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn registry_names_match_the_documented_cli_grammar() {
        assert_eq!(
            workload_names(),
            "poisson | closed | chat | diurnal | flash-crowd | heavy-tail"
        );
        assert_eq!(scheduler_names(), "fcfs | priority | chunked | slo-aware");
    }

    #[test]
    fn every_workload_entry_builds_a_workload_with_its_own_name() {
        let knobs = WorkloadKnobs {
            rate: 4.0,
            n: 8,
            prompt_len: (2, 4),
            output_len: (1, 3),
            clients: Some(2),
            turns: Some((2, 3)),
        };
        for e in WORKLOADS {
            let mut w = (e.build)(&knobs);
            assert_eq!(w.label(), e.name);
            let reqs = w.build(&mut Rng::new(7), 256);
            assert!(!reqs.is_empty(), "{} built an empty trace", e.name);
            for (i, r) in reqs.iter().enumerate() {
                assert_eq!(r.id, i, "{} ids must be dense", e.name);
            }
        }
    }

    #[test]
    fn every_scheduler_entry_builds_a_scheduler_with_its_own_name() {
        for e in SCHEDULERS {
            let s = (e.build)(11, 16);
            assert_eq!(s.label(), e.name);
        }
    }

    #[test]
    fn unknown_names_resolve_to_none() {
        assert!(workload_entry("bursty").is_none());
        assert!(scheduler_entry("lifo").is_none());
        assert!(workload_entry("Poisson").is_none(), "lookups are exact");
    }
}
