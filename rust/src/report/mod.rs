//! Report generation: renders every table and figure of the paper's
//! evaluation from a [`RunReport`] (ASCII for the terminal, CSV series
//! for plotting), plus the §5.2 summary ratios the paper quotes in prose.

use crate::coordinator::{ClusterReport, FleetReport, HostMeasurement, RunReport, ServeReport};
use crate::device::DeviceSpec;
use crate::metrics::MetricsRecord;
use crate::model::scale;
use crate::quant::QuantType;
use crate::util::table::{f1, f2, f3, human_bytes, Table};

/// Table 1: device hardware specs.
pub fn table1() -> Table {
    let mut t = Table::new(&[
        "Platform", "Device", "CPU", "RAM", "BW", "GPU", "OS", "Frameworks",
    ])
    .left_cols(8)
    .title("Table 1: target edge devices");
    for d in DeviceSpec::paper_devices() {
        t.row(vec![
            d.platform.into(),
            d.name.into(),
            format!("{}+{} cores", d.big_cores, d.little_cores),
            human_bytes(d.ram_bytes),
            format!("{:.0}GB/s", d.mem_bw / 1e9),
            format!("{:.0} GFLOPS", d.gpu_gflops),
            d.os.into(),
            format!("{} / {}", d.framework_cpu_blas, d.framework_gpu),
        ]);
    }
    t
}

/// Table 3: LLaMA family storage, original vs INT4.
pub fn table3() -> Table {
    let mut t = Table::new(&["Parameters", "Original size", "Quantized size (INT4)"])
        .left_cols(1)
        .title("Table 3: storage of LLaMA models");
    let rows = scale::table3();
    for pair in rows.chunks(2) {
        t.row(vec![
            pair[0].model.to_string(),
            human_bytes(pair[0].file_bytes),
            human_bytes(pair[1].file_bytes),
        ]);
    }
    t
}

/// Table 5: the benchmark quantization formats on 7B.
pub fn table5() -> Table {
    let mut t = Table::new(&[
        "Quant", "bits/w (nominal)", "bits/w (actual)", "Model size", "Max RAM",
    ])
    .left_cols(1)
    .title("Table 5: quantized models for benchmarking (virtual LLaMA-7B)");
    for r in scale::table5() {
        t.row(vec![
            r.qtype.name().to_string(),
            f1(r.qtype.nominal_bits_per_weight()),
            f1(r.qtype.bits_per_weight()),
            human_bytes(r.file_bytes),
            human_bytes(r.max_ram_bytes),
        ]);
    }
    t
}

/// Table 6: the full benchmark grid.
pub fn table6(records: &[MetricsRecord]) -> Table {
    let mut t = Table::new(&[
        "Quant", "Platform", "OS", "Accel", "Framework", "FLOPS t4 (G)",
        "FLOPS t8 (G)", "Tput (tok/s)", "TTLM (s)", "TTFT (s)", "MBU", "PPL",
    ])
    .left_cols(5)
    .title("Table 6: benchmark results (simulated devices, 7B workload; ppl from the real tiny model)");
    for r in records {
        t.row(vec![
            r.qtype.name().to_string(),
            r.device.clone(),
            r.os.clone(),
            r.accelerator.clone(),
            r.framework.clone(),
            f2(r.flops_t4_giga),
            f2(r.flops_t8_giga),
            f2(r.throughput_tok_s),
            f2(r.ttlm_secs),
            f2(r.ttft_secs),
            f2(r.mbu),
            f2(r.ppl),
        ]);
    }
    t
}

fn find<'a>(
    records: &'a [MetricsRecord],
    device: &str,
    accel: &str,
    framework_contains: Option<&str>,
    q: QuantType,
) -> Option<&'a MetricsRecord> {
    records.iter().find(|r| {
        r.device == device
            && r.accelerator == accel
            && r.qtype == q
            && framework_contains.map_or(true, |f| r.framework.contains(f))
    })
}

/// Figure 3a: FLOPS, accelerated vs non-accelerated per platform/quant.
pub fn fig3a(records: &[MetricsRecord]) -> Table {
    let mut t = Table::new(&["Quant", "Device", "CPU none (G)", "CPU accel (G)", "GPU (G)"])
        .left_cols(2)
        .title("Figure 3a: FLOPS by accelerator (4 threads)");
    for q in QuantType::PAPER_SET {
        for d in ["NanoPI", "Xiaomi", "Macbook"] {
            let none = find(records, d, "CPU", Some("None"), q);
            let blas = find(records, d, "CPU", None, q)
                .filter(|r| r.framework != "None")
                .or_else(|| {
                    records.iter().find(|r| {
                        r.device == d && r.accelerator == "CPU" && r.framework != "None" && r.qtype == q
                    })
                });
            let gpu = find(records, d, "GPU", None, q);
            if let (Some(n), Some(b), Some(g)) = (none, blas, gpu) {
                t.row(vec![
                    q.name().into(),
                    d.into(),
                    f2(n.flops_t4_giga),
                    f2(b.flops_t4_giga),
                    f2(g.flops_t4_giga),
                ]);
            }
        }
    }
    t
}

/// Figure 3b: FLOPS at 4 vs 8 threads.
pub fn fig3b(records: &[MetricsRecord]) -> Table {
    let mut t = Table::new(&["Quant", "Device", "Accel", "t4 (G)", "t8 (G)", "t4/t8"])
        .left_cols(3)
        .title("Figure 3b: FLOPS, 4 threads vs 8 threads");
    for r in records {
        if r.accelerator == "GPU" {
            continue;
        }
        t.row(vec![
            r.qtype.name().into(),
            r.device.clone(),
            r.framework.clone(),
            f2(r.flops_t4_giga),
            f2(r.flops_t8_giga),
            f2(r.flops_t4_giga / r.flops_t8_giga.max(1e-9)),
        ]);
    }
    t
}

/// Figure 4: throughput.
pub fn fig4(records: &[MetricsRecord]) -> Table {
    let mut t = Table::new(&["Quant", "Device", "Accel/Framework", "tok/s"])
        .left_cols(3)
        .title("Figure 4: inference throughput");
    for r in records {
        t.row(vec![
            r.qtype.name().into(),
            r.device.clone(),
            format!("{}/{}", r.accelerator, r.framework),
            f2(r.throughput_tok_s),
        ]);
    }
    t
}

/// Figure 5a/5b: latency (TTLM, TTFT).
pub fn fig5(records: &[MetricsRecord]) -> (Table, Table) {
    let mut a = Table::new(&["Quant", "Device", "Accel", "TTLM (s)"])
        .left_cols(3)
        .title("Figure 5a: time to load model");
    let mut b = Table::new(&["Quant", "Device", "Accel", "TTFT (s)"])
        .left_cols(3)
        .title("Figure 5b: time to first token");
    for r in records {
        a.row(vec![
            r.qtype.name().into(),
            r.device.clone(),
            r.accelerator.clone(),
            f2(r.ttlm_secs),
        ]);
        b.row(vec![
            r.qtype.name().into(),
            r.device.clone(),
            r.accelerator.clone(),
            f2(r.ttft_secs),
        ]);
    }
    (a, b)
}

/// Figure 6: accuracy (perplexity).
pub fn fig6(records: &[MetricsRecord]) -> Table {
    let mut t = Table::new(&["Quant", "Device", "Accel/Framework", "PPL"])
        .left_cols(3)
        .title("Figure 6: inference accuracy (perplexity)");
    for r in records {
        t.row(vec![
            r.qtype.name().into(),
            r.device.clone(),
            format!("{}/{}", r.accelerator, r.framework),
            f2(r.ppl),
        ]);
    }
    t
}

/// Batch sweep: measured host-engine effect of decoding B sequences per
/// weight pass (`--batch-sizes`). Bytes/token falls and batch-aware MBU
/// rises with batch — the paper's central batching effect, measured on
/// the real engine rather than priced on the simulator.
pub fn batch_sweep(host: &[HostMeasurement]) -> Table {
    let mut t = Table::new(&[
        "Quant", "Backend", "Batch", "agg tok/s", "bytes/token", "MBU(host)", "PPL",
    ])
    .left_cols(2)
    .title("Batch sweep: measured weight-stream amortization (host engine)");
    for h in host {
        t.row(vec![
            h.qtype.name().into(),
            h.backend.clone(),
            h.batch.to_string(),
            f2(h.throughput_tok_s),
            human_bytes(h.bytes_per_token),
            f2(h.host_mbu),
            f2(h.ppl),
        ]);
    }
    t
}

/// Serve scenario (DESIGN.md §5): latency percentiles and load metrics
/// of one continuous-batching serving run, rendered for the terminal.
pub fn serve_section(rep: &ServeReport) -> String {
    let p = &rep.params;
    let mut t = Table::new(&["Latency", "mean (ms)", "p50 (ms)", "p95 (ms)", "p99 (ms)", "max (ms)"])
        .left_cols(1)
        .title("Serve scenario: per-request latency under continuous batching");
    for (name, s) in [
        ("TTFT", rep.ttft_summary()),
        ("TPOT", rep.tpot_summary()),
        ("queue wait", rep.queue_wait_summary()),
    ] {
        // Summaries cover served requests only; a run that shed
        // everything has no latency to report.
        match s {
            Some(s) => t.row(vec![
                name.to_string(),
                f2(s.mean * 1e3),
                f2(s.p50 * 1e3),
                f2(s.p95 * 1e3),
                f2(s.p99 * 1e3),
                f2(s.max * 1e3),
            ]),
            None => t.row(vec![
                name.to_string(),
                "—".into(),
                "—".into(),
                "—".into(),
                "—".into(),
                "—".into(),
            ]),
        }
    }
    let mut s = t.render();
    let mode = match p.mode {
        crate::coordinator::ArrivalMode::Poisson => {
            format!("poisson @ {:.2} req/s", p.arrival_rate)
        }
        crate::coordinator::ArrivalMode::ClosedLoop { clients } => {
            format!("closed loop, {clients} clients")
        }
        crate::coordinator::ArrivalMode::Chat { turns } => {
            format!(
                "chat sessions @ {:.2}/s, {}-{} turns",
                p.arrival_rate, turns.0, turns.1
            )
        }
        crate::coordinator::ArrivalMode::Diurnal => {
            format!("diurnal poisson @ {:.2} req/s mean", p.arrival_rate)
        }
        crate::coordinator::ArrivalMode::FlashCrowd => {
            format!("flash crowd @ {:.2} req/s base", p.arrival_rate)
        }
        crate::coordinator::ArrivalMode::HeavyTail => {
            format!("heavy-tail prompts @ {:.2} req/s", p.arrival_rate)
        }
    };
    s.push_str(&format!(
        "\n  {} requests ({mode}), {} scheduler, {} slots, seed {}, {} [{}]\n",
        rep.records.len(),
        rep.scheduler,
        p.slots,
        p.seed,
        rep.quant,
        rep.backend
    ));
    if rep.workload == "chat" {
        s.push_str(&format!(
            "  KV-prefix reuse: {} follow-up turns reused {} cached tokens \
             (zero re-prefill for reused prefixes)\n",
            rep.reuse.reused_turns, rep.reuse.reused_tokens
        ));
    }
    if let Some(pool) = &rep.kv_pool {
        s.push_str(&format!(
            "  KV pool: peak {}/{} blocks ({} occupancy, {} tokens/block), \
             {} CoW copies, {} prefix forks sharing {}",
            pool.peak_blocks_in_use,
            pool.blocks_total,
            f3(pool.peak_occupancy()),
            pool.block_tokens,
            pool.cow_copies,
            pool.prefix_forks,
            human_bytes(pool.shared_bytes),
        ));
        if rep.deferred_admissions > 0 {
            s.push_str(&format!(
                ", {} deferred admission(s)",
                rep.deferred_admissions
            ));
        }
        s.push('\n');
    }
    s.push_str(&format!(
        "  makespan {:.3} s (virtual), {} output tokens, throughput {} tok/s, {} engine steps\n",
        rep.makespan_secs,
        rep.output_tokens,
        f2(rep.throughput_tok_s()),
        rep.step_t.len()
    ));
    s.push_str(&format!(
        "  queue depth mean {} max {}; ",
        f2(rep.queue_depth_mean()),
        rep.queue_depth_max()
    ));
    match rep.mbu_summary() {
        Some(m) => s.push_str(&format!(
            "MBU under load mean {} p50 {} max {}\n",
            f3(m.mean),
            f3(m.p50),
            f3(m.max)
        )),
        None => s.push_str("MBU under load: no token-generating steps\n"),
    }
    if rep.params.slo.is_some() {
        s.push_str(&format!(
            "  SLO goodput {} ({} shed, {} preempted)\n",
            rep.goodput().map_or_else(|| "—".into(), f3),
            rep.shed_requests,
            rep.preempted_requests,
        ));
        for tier in rep.tier_attainment() {
            s.push_str(&format!(
                "    {}: {}/{} requests in SLO, token fraction {}\n",
                tier.tier.key(),
                tier.attained_requests,
                tier.requests,
                f3(tier.token_fraction()),
            ));
        }
    }
    s
}

/// Wall-clock daemon (DESIGN.md §10): the virtual-clock record of the
/// drained run next to the measured wall-clock counters — the
/// predicted-vs-measured comparison is the daemon's whole point.
pub fn daemon_section(rep: &ServeReport, stats: &crate::daemon::DaemonStats) -> String {
    let mut t = Table::new(&["Latency", "mean (ms)", "p50 (ms)", "p95 (ms)", "p99 (ms)", "max (ms)"])
        .left_cols(1)
        .title("Daemon: predicted (virtual clock) vs measured (wall clock) latency");
    let dash = || "—".to_string();
    let mut push = |name: &str, s: &Option<crate::util::stats::Summary>| match s {
        Some(s) => t.row(vec![
            name.to_string(),
            f2(s.mean * 1e3),
            f2(s.p50 * 1e3),
            f2(s.p95 * 1e3),
            f2(s.p99 * 1e3),
            f2(s.max * 1e3),
        ]),
        None => t.row(vec![name.to_string(), dash(), dash(), dash(), dash(), dash()]),
    };
    push("TTFT predicted", &rep.ttft_summary());
    push("TTFT measured", &stats.measured_ttft);
    push("TPOT predicted", &rep.tpot_summary());
    push("TPOT measured", &stats.measured_tpot);
    let mut s = t.render();
    s.push_str(&format!(
        "\n  {} offered = {} served + {} shed ({} rejected at the door), \
         uptime {:.1} s wall, pace {}x\n",
        stats.offered, stats.served, stats.shed, stats.rejected, stats.uptime_secs, stats.pace
    ));
    s.push_str(&format!(
        "  {} output tokens over {} engine steps, makespan {:.3} s (virtual)\n",
        rep.output_tokens,
        rep.step_t.len(),
        rep.makespan_secs
    ));
    // The cross-check rescales predicted MBU by the predicted/measured
    // TPOT ratio: ~1:1 with predicted MBU means the byte/FLOP ledger's
    // step pricing matches what the wall clock saw at this pace.
    match (stats.mbu_cross_check, rep.mbu_summary()) {
        (Some(x), Some(m)) => s.push_str(&format!(
            "  MBU predicted mean {} — measured cross-check {} (ratio {})\n",
            f3(m.mean),
            f3(x),
            f3(x / m.mean)
        )),
        (Some(x), None) => s.push_str(&format!("  MBU measured cross-check {}\n", f3(x))),
        (None, Some(m)) => s.push_str(&format!(
            "  MBU predicted mean {} (no measured cross-check: nothing multi-token served)\n",
            f3(m.mean)
        )),
        (None, None) => s.push_str("  MBU: no token-generating steps\n"),
    }
    s
}

/// Per-scheduler comparison (DESIGN.md §5): the same seeded trace served
/// under different admission/prefill policies, one row per run. Token
/// streams are scheduler-invariant, so every delta in this table is a
/// pure policy effect — which is the point of the Workload/Scheduler
/// split (`elib serve --compare-schedulers` prints it).
pub fn scheduler_comparison(reports: &[ServeReport]) -> String {
    let mut t = Table::new(&[
        "Scheduler", "tok/s", "makespan (s)", "TTFT p50 (ms)", "TTFT p95 (ms)",
        "TPOT p50 (ms)", "TPOT p95 (ms)", "wait p95 (ms)", "goodput", "steps",
    ])
    .left_cols(1)
    .title("Scheduler comparison: one seeded trace, different admission/prefill policies");
    let ms = |s: Option<f64>| s.map_or_else(|| "—".into(), |v| f2(v * 1e3));
    for rep in reports {
        let (ttft, tpot, wait) = (
            rep.ttft_summary(),
            rep.tpot_summary(),
            rep.queue_wait_summary(),
        );
        t.row(vec![
            rep.scheduler.clone(),
            f2(rep.throughput_tok_s()),
            f3(rep.makespan_secs),
            ms(ttft.as_ref().map(|s| s.p50)),
            ms(ttft.as_ref().map(|s| s.p95)),
            ms(tpot.as_ref().map(|s| s.p50)),
            ms(tpot.as_ref().map(|s| s.p95)),
            ms(wait.as_ref().map(|s| s.p95)),
            rep.goodput().map_or_else(|| "—".into(), f3),
            rep.step_t.len().to_string(),
        ]);
    }
    let mut s = t.render();
    if let Some(first) = reports.first() {
        s.push_str(&format!(
            "  {} requests, seed {}, {} workload — token streams identical across rows\n",
            first.records.len(),
            first.params.seed,
            first.workload
        ));
    }
    // Under SLOs the slo-aware policy may shed or preempt, so rows can
    // serve different subsets of the trace; call the winner by goodput.
    let mut best: Option<(&ServeReport, f64)> = None;
    for rep in reports {
        if let Some(g) = rep.goodput() {
            if best.map_or(true, |(_, bg)| g > bg) {
                best = Some((rep, g));
            }
        }
    }
    if let Some((rep, g)) = best {
        s.push_str(&format!(
            "  goodput winner: {} ({})\n",
            rep.scheduler,
            f3(g)
        ));
    }
    s
}

/// SLO grid (DESIGN.md §5): scheduler × workload goodput under hostile
/// traffic. One row per run; the per-workload goodput winner is named
/// below the table (ties break to the first row, so the output is
/// deterministic for a fixed run order).
pub fn slo_section(reports: &[ServeReport]) -> String {
    let mut t = Table::new(&[
        "Workload", "Scheduler", "goodput", "served", "shed", "preempted",
        "TTFT p95 (ms)", "tok/s",
    ])
    .left_cols(2)
    .title("SLO attainment grid: goodput per scheduler under hostile traffic");
    for rep in reports {
        let served = rep
            .records
            .len()
            .saturating_sub(rep.shed_requests + rep.preempted_requests);
        t.row(vec![
            rep.workload.clone(),
            rep.scheduler.clone(),
            rep.goodput().map_or_else(|| "—".into(), f3),
            served.to_string(),
            rep.shed_requests.to_string(),
            rep.preempted_requests.to_string(),
            rep.ttft_summary()
                .map_or_else(|| "—".into(), |s| f2(s.p95 * 1e3)),
            f2(rep.throughput_tok_s()),
        ]);
    }
    let mut s = t.render();
    let mut workloads: Vec<&str> = Vec::new();
    for rep in reports {
        if !workloads.contains(&rep.workload.as_str()) {
            workloads.push(&rep.workload);
        }
    }
    for w in workloads {
        let mut best: Option<(&ServeReport, f64)> = None;
        for rep in reports.iter().filter(|r| r.workload == w) {
            if let Some(g) = rep.goodput() {
                if best.map_or(true, |(_, bg)| g > bg) {
                    best = Some((rep, g));
                }
            }
        }
        if let Some((rep, g)) = best {
            s.push_str(&format!(
                "  {w}: goodput winner {} ({})\n",
                rep.scheduler,
                f3(g)
            ));
        }
    }
    s
}

/// Fleet sweep (DESIGN.md §5): the comparative device × accel × quant
/// serving table — latency percentiles, throughput and MBU-under-load
/// per cell, capacity-rejected cells rendered as `infeasible`, and the
/// per-device MBU frontier (`*` rows) called out below the table.
pub fn fleet_section(rep: &FleetReport) -> String {
    let frontier: Vec<(String, String, String)> = rep
        .mbu_frontier()
        .iter()
        .map(|c| (c.device.clone(), c.accel.key().to_string(), c.quant.name().to_string()))
        .collect();
    let mut t = Table::new(&[
        "Device", "Accel", "Framework", "Quant", "Status", "tok/s", "TTFT p50 (s)",
        "TTFT p95 (s)", "TTFT p99 (s)", "TPOT p50 (ms)", "MBU(load)", "",
    ])
    .left_cols(5)
    .title("Fleet sweep: one seeded trace served per device × accel × quant");
    for c in &rep.cells {
        let m = c.metrics();
        let is_frontier = frontier.iter().any(|(d, a, q)| {
            *d == m.device && *a == m.accel_key && *q == m.quant
        });
        let row = if let (Some(tput), Some(ttft), Some(tpot)) =
            (m.throughput_tok_s, m.ttft.as_ref(), m.tpot.as_ref())
        {
            vec![
                m.device.clone(),
                m.accel_key.clone(),
                m.framework.clone(),
                m.quant.clone(),
                "ok".into(),
                f2(tput),
                f2(ttft.p50),
                f2(ttft.p95),
                f2(ttft.p99),
                f2(tpot.p50 * 1e3),
                m.mbu_mean.map_or_else(|| "-".into(), f3),
                if is_frontier { "*".into() } else { String::new() },
            ]
        } else {
            vec![
                m.device.clone(),
                m.accel_key.clone(),
                m.framework.clone(),
                m.quant.clone(),
                "infeasible".into(),
                format!(
                    "need {} > ram {}",
                    human_bytes(m.need_ram_bytes),
                    human_bytes(m.ram_bytes)
                ),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
                String::new(),
            ]
        };
        t.row(row);
    }
    let mut s = t.render();
    s.push_str(&format!(
        "  {} cells ({} infeasible), {} slots, seed {}, {} requests per cell\n",
        rep.cells.len(),
        rep.infeasible_count(),
        rep.params.slots,
        rep.params.trace.seed,
        rep.params.trace.num_requests,
    ));
    s.push_str("  MBU frontier (*): ");
    if frontier.is_empty() {
        s.push_str("none (no feasible cells)\n");
    } else {
        let items: Vec<String> = frontier
            .iter()
            .map(|(d, a, q)| format!("{d}: {a}/{q}"))
            .collect();
        s.push_str(&items.join(", "));
        s.push('\n');
    }
    s
}

/// Cluster policy comparison (`elib cluster`): one seeded trace offered
/// to every routing policy over the same heterogeneous fleet, so the
/// rows differ by routing and nothing else. Below the table: per-replica
/// utilization per policy, and the winner line — by goodput when the
/// scenario carries SLOs, by throughput otherwise (ties break to the
/// first row, so the output is deterministic for a fixed policy order).
pub fn cluster_section(rep: &ClusterReport) -> String {
    let chat = rep.params.scenario.workload == "chat";
    let has_slo = rep.params.scenario.slo.is_some();
    let mut t = Table::new(&[
        "Policy", "goodput", "tok/s", "TTFT p50 (ms)", "TTFT p95 (ms)", "TTFT p99 (ms)",
        "TPOT p50 (ms)", "fleet MBU", "kv reuse", "offload", "shed",
    ])
    .left_cols(1)
    .title("Cluster routing comparison: one seeded trace, different routers, same fleet");
    let ms = |s: Option<f64>| s.map_or_else(|| "—".into(), |v| f2(v * 1e3));
    for pr in &rep.policies {
        let (ttft, tpot) = (pr.ttft_summary(), pr.tpot_summary());
        t.row(vec![
            pr.policy.label().to_string(),
            pr.goodput().map_or_else(|| "—".into(), f3),
            f2(pr.throughput_tok_s()),
            ms(ttft.as_ref().map(|s| s.p50)),
            ms(ttft.as_ref().map(|s| s.p95)),
            ms(ttft.as_ref().map(|s| s.p99)),
            ms(tpot.as_ref().map(|s| s.p50)),
            pr.fleet_mbu.map_or_else(|| "—".into(), f3),
            if chat {
                pr.reuse.reused_turns.to_string()
            } else {
                "—".into()
            },
            pr.offloaded.to_string(),
            pr.shed.to_string(),
        ]);
    }
    let mut s = t.render();
    s.push_str(&format!(
        "  {} requests, seed {}, {} workload, {} replicas — identical offered trace per row\n",
        rep.params.scenario.num_requests,
        rep.params.scenario.seed,
        rep.params.scenario.workload,
        rep.params.replicas.len(),
    ));
    for pr in &rep.policies {
        let util: Vec<String> = pr
            .replicas
            .iter()
            .map(|r| format!("{} {} ({} reqs)", r.name, f3(r.utilization), r.routed))
            .collect();
        s.push_str(&format!("  {}: utilization {}\n", pr.policy.label(), util.join(", ")));
    }
    // Winner: goodput under SLOs (what the scenario optimizes for),
    // throughput otherwise. First-max keeps ties deterministic.
    if has_slo {
        let mut best: Option<(&str, f64)> = None;
        for pr in &rep.policies {
            if let Some(g) = pr.goodput() {
                if best.map_or(true, |(_, bg)| g > bg) {
                    best = Some((pr.policy.label(), g));
                }
            }
        }
        if let Some((name, g)) = best {
            s.push_str(&format!("  goodput winner: {} ({})\n", name, f3(g)));
        }
    } else {
        let mut best: Option<(&str, f64)> = None;
        for pr in &rep.policies {
            let tput = pr.throughput_tok_s();
            if best.map_or(true, |(_, bt)| tput > bt) {
                best = Some((pr.policy.label(), tput));
            }
        }
        if let Some((name, tput)) = best {
            s.push_str(&format!("  throughput winner: {} ({} tok/s)\n", name, f2(tput)));
        }
    }
    s
}

/// The §5.2 prose ratios: q4_0-vs-q8_0 throughput per device (CPU-accel &
/// GPU) and mean GPU/CPU speedup per device.
#[derive(Clone, Debug)]
pub struct SummaryRatios {
    pub device: String,
    pub q4_vs_q8_cpu: f64,
    pub q4_vs_q8_gpu: f64,
    pub gpu_vs_cpu_mean: f64,
}

pub fn summary_ratios(records: &[MetricsRecord]) -> Vec<SummaryRatios> {
    let mut out = Vec::new();
    for d in ["NanoPI", "Xiaomi", "Macbook"] {
        let get = |accel: &str, q: QuantType| -> Option<f64> {
            records
                .iter()
                .find(|r| {
                    r.device == d
                        && r.accelerator == accel
                        && r.qtype == q
                        && (accel == "GPU" || r.framework != "None")
                })
                .map(|r| r.throughput_tok_s)
        };
        let (Some(c4), Some(c8), Some(g4), Some(g8)) = (
            get("CPU", QuantType::Q4_0),
            get("CPU", QuantType::Q8_0),
            get("GPU", QuantType::Q4_0),
            get("GPU", QuantType::Q8_0),
        ) else {
            continue;
        };
        let mut gpu_cpu = Vec::new();
        for q in QuantType::PAPER_SET {
            if let (Some(c), Some(g)) = (get("CPU", q), get("GPU", q)) {
                gpu_cpu.push(g / c);
            }
        }
        out.push(SummaryRatios {
            device: d.to_string(),
            q4_vs_q8_cpu: c4 / c8,
            q4_vs_q8_gpu: g4 / g8,
            gpu_vs_cpu_mean: crate::util::stats::mean(&gpu_cpu),
        });
    }
    out
}

/// Render everything into one text report (used by `elib report` and the
/// bench binaries).
pub fn full_report(report: &RunReport) -> String {
    let mut s = String::new();
    s.push_str(&table1().render());
    s.push('\n');
    s.push_str(&table3().render());
    s.push('\n');
    s.push_str(&table5().render());
    s.push('\n');
    s.push_str(&table6(&report.records).render());
    s.push('\n');
    s.push_str(&fig3a(&report.records).render());
    s.push('\n');
    s.push_str(&fig3b(&report.records).render());
    s.push('\n');
    s.push_str(&fig4(&report.records).render());
    let (a, b) = fig5(&report.records);
    s.push('\n');
    s.push_str(&a.render());
    s.push('\n');
    s.push_str(&b.render());
    s.push('\n');
    s.push_str(&fig6(&report.records).render());
    if !report.host.is_empty() {
        s.push('\n');
        s.push_str(&batch_sweep(&report.host).render());
    }
    s.push_str("\nSummary ratios (paper §5.2):\n");
    for r in summary_ratios(&report.records) {
        s.push_str(&format!(
            "  {}: q4_0/q8_0 throughput cpu {:.2}x gpu {:.2}x; mean gpu/cpu {:.2}x\n",
            r.device, r.q4_vs_q8_cpu, r.q4_vs_q8_gpu, r.gpu_vs_cpu_mean
        ));
    }
    if !report.skipped.is_empty() {
        s.push_str("\nSkipped cells:\n");
        for (cell, why) in &report.skipped {
            s.push_str(&format!("  {cell}: {why}\n"));
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_record(device: &str, accel: &str, fw: &str, q: QuantType, tput: f64) -> MetricsRecord {
        MetricsRecord {
            device: device.into(),
            os: "OS".into(),
            accelerator: accel.into(),
            framework: fw.into(),
            qtype: q,
            flops_t4_giga: 50.0,
            flops_t8_giga: 40.0,
            throughput_tok_s: tput,
            ttlm_secs: 10.0,
            ttft_secs: 1.0,
            mbu: 0.5,
            ppl: 6.5,
        }
    }

    #[test]
    fn static_tables_render() {
        assert!(table1().render().contains("NanoPI"));
        assert!(table3().render().contains("65B"));
        assert!(table5().render().contains("q4_0"));
    }

    #[test]
    fn table6_rows_match_records() {
        let rs = vec![
            fake_record("NanoPI", "CPU", "None", QuantType::Q4_0, 2.5),
            fake_record("NanoPI", "GPU", "CLBlast&OpenCL", QuantType::Q4_0, 4.0),
        ];
        let t = table6(&rs);
        assert_eq!(t.n_rows(), 2);
        assert!(t.render().contains("CLBlast"));
    }

    #[test]
    fn summary_ratios_computed() {
        let mut rs = Vec::new();
        for (q, c, g) in [
            (QuantType::Q4_0, 4.0, 8.0),
            (QuantType::Q8_0, 2.0, 3.0),
        ] {
            rs.push(fake_record("NanoPI", "CPU", "OpenBLAS", q, c));
            rs.push(fake_record("NanoPI", "GPU", "CLBlast&OpenCL", q, g));
        }
        let s = summary_ratios(&rs);
        assert_eq!(s.len(), 1);
        assert!((s[0].q4_vs_q8_cpu - 2.0).abs() < 1e-9);
        assert!((s[0].q4_vs_q8_gpu - 8.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn batch_sweep_renders_one_row_per_measurement() {
        use crate::kernel::BackendKind;
        let host: Vec<HostMeasurement> = [1usize, 4]
            .iter()
            .map(|b| HostMeasurement {
                qtype: QuantType::Q4_0,
                backend_kind: BackendKind::Naive,
                backend: "cpu/none".into(),
                batch: *b,
                throughput_tok_s: 10.0 * *b as f64,
                tpot_secs: 0.01,
                prefill_secs: 0.1,
                bytes_per_token: 1_000_000 / *b as u64,
                param_bytes: 1_000_000,
                kv_bytes: 10_000 * *b as u64,
                host_mbu: 0.1 * *b as f64,
                ppl: 6.5,
            })
            .collect();
        let t = batch_sweep(&host);
        assert_eq!(t.n_rows(), 2);
        let text = t.render();
        assert!(text.contains("Batch sweep"));
        assert!(text.contains("cpu/none"));
    }

    #[test]
    fn serve_section_renders_percentiles_and_load() {
        use crate::coordinator::{run_serve, ServeParams};
        use crate::kernel::BackendKind;
        let mf = crate::model::testutil::random_model_file(QuantType::Q8_0, 4);
        let p = ServeParams {
            num_requests: 3,
            prompt_len: (2, 3),
            output_len: (2, 3),
            arrival_rate: 20.0,
            ..ServeParams::default()
        };
        let rep = run_serve(&mf, BackendKind::Naive, &p).unwrap();
        let s = serve_section(&rep);
        assert!(s.contains("TTFT"), "{s}");
        assert!(s.contains("TPOT"));
        assert!(s.contains("p95 (ms)"));
        assert!(s.contains("3 requests"));
        assert!(s.contains("MBU under load"));
        assert!(s.contains("KV pool: peak "), "paged pool line:\n{s}");
        assert!(s.contains("tokens/block"), "{s}");
    }

    #[test]
    fn serve_section_reports_scheduler_and_chat_reuse() {
        use crate::coordinator::{run_serve, ArrivalMode, ServeParams};
        use crate::kernel::BackendKind;
        let mf = crate::model::testutil::random_model_file(QuantType::Q8_0, 14);
        let p = ServeParams {
            num_requests: 2, // sessions
            prompt_len: (2, 3),
            output_len: (2, 3),
            arrival_rate: 20.0,
            mode: ArrivalMode::Chat { turns: (2, 2) },
            ..ServeParams::default()
        };
        let rep = run_serve(&mf, BackendKind::Naive, &p).unwrap();
        let s = serve_section(&rep);
        assert!(s.contains("fcfs scheduler"), "{s}");
        assert!(s.contains("chat sessions @"), "{s}");
        assert!(s.contains("KV-prefix reuse"), "{s}");
    }

    #[test]
    fn scheduler_comparison_renders_one_row_per_policy() {
        use crate::coordinator::{run_serve, ServeParams, SchedulerPolicy};
        use crate::kernel::BackendKind;
        let mf = crate::model::testutil::random_model_file(QuantType::Q4_0, 6);
        let base = ServeParams {
            num_requests: 3,
            prompt_len: (4, 6),
            output_len: (2, 3),
            arrival_rate: 30.0,
            ..ServeParams::default()
        };
        let reports: Vec<_> = [
            SchedulerPolicy::Fcfs,
            SchedulerPolicy::Priority,
            SchedulerPolicy::Chunked { chunk_tokens: 4 },
        ]
        .into_iter()
        .map(|scheduler| {
            run_serve(&mf, BackendKind::Naive, &ServeParams { scheduler, ..base.clone() })
                .unwrap()
        })
        .collect();
        let s = scheduler_comparison(&reports);
        assert!(s.contains("Scheduler comparison"), "{s}");
        for name in ["fcfs", "priority", "chunked"] {
            assert!(s.contains(name), "missing {name} row:\n{s}");
        }
        assert!(s.contains("token streams identical"), "{s}");
    }

    #[test]
    fn fleet_section_renders_ok_and_infeasible_rows() {
        use crate::coordinator::{run_fleet, FleetParams, ServeParams};
        use crate::model::testutil::random_weights;
        use crate::model::LlamaConfig;
        let mcfg = LlamaConfig::tiny();
        let dense = random_weights(&mcfg, 7);
        // Token-granular admission serves the whole default grid, so an
        // infeasible row needs a shrunk-RAM device: 8 GiB fits the q4_0
        // trace footprint but not q8_0 — both row kinds render.
        let mut tight = crate::device::DeviceSpec::nanopi();
        tight.ram_bytes = 8 << 30;
        let p = FleetParams {
            devices: vec![tight],
            trace: ServeParams {
                arrival_rate: 20.0,
                num_requests: 3,
                prompt_len: (2, 3),
                output_len: (2, 3),
                ..ServeParams::default()
            },
            ..FleetParams::default()
        };
        let rep = run_fleet(&mcfg, &dense, &p).unwrap();
        let s = fleet_section(&rep);
        assert!(s.contains("Fleet sweep"), "{s}");
        assert!(s.contains("infeasible"), "q8_0 overflows the 8 GiB device:\n{s}");
        assert!(s.contains("need "), "infeasible rows show the capacity evidence:\n{s}");
        assert!(s.contains("TTFT p95"), "{s}");
        assert!(s.contains("MBU frontier (*): NanoPI"), "{s}");
    }

    #[test]
    fn cluster_section_compares_policies_and_names_a_winner() {
        use crate::coordinator::cluster::{run_cluster, ClusterParams, ReplicaSpec, RoutePolicy, Tier};
        use crate::coordinator::ScenarioSpec;
        use crate::model::testutil::random_weights;
        use crate::model::LlamaConfig;
        let mcfg = LlamaConfig::tiny();
        let dense = random_weights(&mcfg, 7);
        let p = ClusterParams {
            scenario: ScenarioSpec {
                arrival_rate: 20.0,
                num_requests: 6,
                seed: 3,
                prompt_len: (2, 3),
                output_len: (2, 3),
                ..ScenarioSpec::default()
            },
            replicas: vec![
                ReplicaSpec::flat("edge0", Tier::Edge, 80e6, 2e9, QuantType::Q8_0, 2),
                ReplicaSpec::flat("cloud0", Tier::Cloud, 200e6, 2e9, QuantType::Q8_0, 2),
            ],
            policies: vec![RoutePolicy::RoundRobin, RoutePolicy::LeastQueue],
            threads: 1,
        };
        let rep = run_cluster(&mcfg, &dense, &p).unwrap();
        let s = cluster_section(&rep);
        assert!(s.contains("Cluster routing comparison"), "{s}");
        assert!(s.contains("round-robin"), "{s}");
        assert!(s.contains("least-queue"), "{s}");
        assert!(s.contains("fleet MBU"), "{s}");
        assert!(s.contains("utilization"), "{s}");
        assert!(
            s.contains("throughput winner:"),
            "no SLOs -> throughput winner line:\n{s}"
        );
    }

    #[test]
    fn slo_section_names_a_goodput_winner_per_workload() {
        use crate::coordinator::{run_serve, ArrivalMode, ServeParams, SchedulerPolicy, SloSpec};
        use crate::kernel::BackendKind;
        let mf = crate::model::testutil::random_model_file(QuantType::Q4_0, 6);
        let mut reports = Vec::new();
        for mode in [ArrivalMode::Poisson, ArrivalMode::FlashCrowd] {
            for scheduler in [SchedulerPolicy::Fcfs, SchedulerPolicy::SloAware] {
                let p = ServeParams {
                    num_requests: 6,
                    prompt_len: (2, 4),
                    output_len: (2, 4),
                    arrival_rate: 40.0,
                    slots: 2,
                    mode,
                    scheduler,
                    slo: Some(SloSpec {
                        ttft: 0.08,
                        tpot: 0.06,
                    }),
                    ..ServeParams::default()
                };
                reports.push(run_serve(&mf, BackendKind::Naive, &p).unwrap());
            }
        }
        let s = slo_section(&reports);
        assert!(s.contains("SLO attainment grid"), "{s}");
        assert!(s.contains("slo-aware"), "{s}");
        assert!(s.contains("poisson: goodput winner "), "{s}");
        assert!(s.contains("flash-crowd: goodput winner "), "{s}");

        // The per-run serve section carries the goodput + tier rollup.
        let one = serve_section(&reports[3]);
        assert!(one.contains("SLO goodput "), "{one}");
        assert!(one.contains("interactive: "), "{one}");
        assert!(one.contains("flash crowd @"), "{one}");
    }

    #[test]
    fn scheduler_comparison_shows_goodput_column_under_slos() {
        use crate::coordinator::{run_serve, ArrivalMode, ServeParams, SchedulerPolicy, SloSpec};
        use crate::kernel::BackendKind;
        let mf = crate::model::testutil::random_model_file(QuantType::Q4_0, 6);
        let base = ServeParams {
            num_requests: 4,
            prompt_len: (2, 4),
            output_len: (2, 4),
            arrival_rate: 40.0,
            slots: 2,
            mode: ArrivalMode::FlashCrowd,
            slo: Some(SloSpec {
                ttft: 0.08,
                tpot: 0.06,
            }),
            ..ServeParams::default()
        };
        let reports: Vec<_> = [SchedulerPolicy::Fcfs, SchedulerPolicy::SloAware]
            .into_iter()
            .map(|scheduler| {
                run_serve(&mf, BackendKind::Naive, &ServeParams { scheduler, ..base.clone() })
                    .unwrap()
            })
            .collect();
        let s = scheduler_comparison(&reports);
        assert!(s.contains("goodput"), "{s}");
        assert!(s.contains("goodput winner: "), "{s}");
        // Without SLOs the column renders a dash and no winner is named.
        let plain = run_serve(
            &mf,
            BackendKind::Naive,
            &ServeParams {
                mode: ArrivalMode::Poisson,
                slo: None,
                ..base.clone()
            },
        )
        .unwrap();
        let s = scheduler_comparison(std::slice::from_ref(&plain));
        assert!(s.contains("—"), "{s}");
        assert!(!s.contains("goodput winner"), "{s}");
    }

    #[test]
    fn figures_skip_gpu_in_3b() {
        let rs = vec![
            fake_record("NanoPI", "CPU", "None", QuantType::Q4_0, 1.0),
            fake_record("NanoPI", "GPU", "CLBlast&OpenCL", QuantType::Q4_0, 1.0),
        ];
        assert_eq!(fig3b(&rs).n_rows(), 1);
    }
}
