//! # ELIB — Edge LLM Inference Benchmarking
//!
//! A reproduction of *"Inference performance evaluation for LLMs on edge
//! devices with a novel benchmarking framework and metric"* (Chen et al.,
//! cs.PF 2025): the ELIB benchmarking system, the Model–Graph–Kernel
//! inference runtime it measures, the GGML-style quantization flow, the
//! edge-device simulator standing in for the paper's NanoPI / Xiaomi /
//! MacBook testbed, and the MBU (Model Bandwidth Utilization) metric.
//!
//! Architecture (three layers, python never on the benchmark path):
//!
//! * **L3 (this crate)** — coordinator: quantization flow, deployment,
//!   Algorithm-1 benchmark loop, metrics + report generation, plus the
//!   native Model–Graph–Kernel engine and the device simulator.
//! * **L2/L1 (python/compile)** — tiny-LLaMA JAX model and Pallas kernels,
//!   AOT-lowered once to HLO text in `artifacts/`.
//! * **runtime** — PJRT CPU client (xla crate) that loads and executes the
//!   lowered artifacts from rust.

pub mod testkit;
pub mod util;

pub mod gguf;
pub mod quant;
pub mod tensor;
pub mod graph;
pub mod kernel;
pub mod model;
pub mod device;
pub mod metrics;
pub mod coordinator;
pub mod report;
pub mod runtime;
