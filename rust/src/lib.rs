//! # ELIB — Edge LLM Inference Benchmarking
//!
//! A reproduction of *"Inference performance evaluation for LLMs on edge
//! devices with a novel benchmarking framework and metric"* (Chen et al.,
//! cs.PF 2025): the ELIB benchmarking system, the Model–Graph–Kernel
//! inference runtime it measures, the GGML-style quantization flow, the
//! edge-device simulator standing in for the paper's NanoPI / Xiaomi /
//! MacBook testbed, and the MBU (Model Bandwidth Utilization) metric.
//!
//! Architecture (three layers, python never on the benchmark path):
//!
//! * **L3 (this crate)** — coordinator: quantization flow, deployment,
//!   Algorithm-1 benchmark loop, metrics + report generation, plus the
//!   native Model–Graph–Kernel engine and the device simulator.
//! * **L2/L1 (python/compile)** — tiny-LLaMA JAX model and Pallas kernels,
//!   AOT-lowered once to HLO text in `artifacts/`.
//! * **runtime** — PJRT CPU client (xla crate) that loads and executes the
//!   lowered artifacts from rust.
//!
//! Batched + concurrent execution (DESIGN.md §3–§5):
//!
//! * **Batched decode** — [`graph::Engine::new_batched`] pre-allocates
//!   `[batch × dim]` scratch and a slot-addressed [`graph::KvCache`];
//!   [`graph::Engine::forward_batch`] advances `B` sequences per weight
//!   pass, so the traffic ledger charges the weight stream once per step
//!   while KV traffic scales per slot — measured bytes/token falls and
//!   the paper's batch-aware MBU (eq. 1–3) rises with batch. Per-slot
//!   numerics are bitwise identical to independent single-sequence
//!   engines (property-tested). [`graph::generate_batch`] is the driver.
//! * **Concurrent scheduler** — [`coordinator::runner::run`] fans host
//!   measurements (quant × backend × `--batch-sizes`) and device-grid
//!   cells out over [`util::threadpool`], committing results in
//!   deterministic grid order: any thread count reproduces the
//!   sequential run exactly.
//! * **Batch-sweep report** — [`report::batch_sweep`] renders the
//!   measured amortization per (quant, backend, batch).
//! * **Serving scenario** — [`coordinator::serve::run_serve`] (CLI:
//!   `elib serve --arrival-rate 4 --num-requests 64 --seed 7`) replaces
//!   the lockstep sweep with continuous batching behind the pluggable
//!   [`coordinator::sim`] API: a
//!   [`Workload`](coordinator::sim::Workload) (seeded Poisson open
//!   loop, closed loop, or multi-turn `chat` sessions whose follow-up
//!   turns reuse their slot's KV prefix) and a
//!   [`Scheduler`](coordinator::sim::Scheduler) (`fcfs`, `priority`
//!   tiers, or `chunked` prefill spans) plug into
//!   [`SimLoop`](coordinator::sim::SimLoop), which owns the engine,
//!   clock and event queue ([`graph::Engine::forward_spans`] /
//!   [`graph::Engine::reset_slot`] / [`graph::Engine::truncate_slot`]).
//!   A virtual roofline clock prices each step from measured traffic,
//!   and per-request TTFT/TPOT records roll up into p50/p95/p99 plus
//!   queue-depth and MBU-under-load series. `bench.json` is
//!   bit-reproducible from the seed — identical to the pre-split
//!   monolith for the default `fcfs`+`poisson` pair — and carries
//!   workload/scheduler identity keys; `elib bench-check` gates CI
//!   against a committed baseline with tolerance bands (and
//!   `--write-baseline` promotes a run into the committed reference).
//! * **Wall-clock daemon** — [`daemon::spawn`] (CLI: `elib daemon`)
//!   puts a dependency-free HTTP/1.1 front (OpenAI-style
//!   `POST /v1/completions`, unary or SSE streaming, `GET /metrics`
//!   JSON lines, a self-contained HTML dashboard at `GET /`) over the
//!   routed [`coordinator::sim::SimLoop`]: live prompts are swapped
//!   into pre-allocated placeholder requests, a [`daemon::Pacer`]
//!   ticks the virtual clock at wall speed, and each response reports
//!   *measured* wall TTFT/TPOT next to the ledger's *predicted* values
//!   (the live MBU cross-check). Graceful shutdown drains in-flight
//!   decodes, sheds the queue with structured 503s, and writes
//!   `daemon.json` in the `bench.json` schema (DESIGN.md §10).
//! * **Fleet sweep** — [`coordinator::fleet::run_fleet`] (CLI:
//!   `elib fleet --synthetic`) serves the *same* seeded trace on every
//!   device × accelerator × quant cell: each cell's clock is a
//!   [`device::DeviceClock`] derived from [`device::DeviceSpec`]
//!   calibration (thread contention, per-accel/quant achievable
//!   bandwidth), RAM-capacity admission rejects oversubscribed cells as
//!   structured `infeasible` results, and the comparative `fleet.json`
//!   (+ [`report::fleet_section`] MBU-frontier table) is bitwise
//!   deterministic across `--threads`.

// The decode and serve loops index several parallel scratch buffers per
// sequence slot; an index-free style would obscure the stripe/slot
// arithmetic the engine is built around. Measurement plumbing passes
// explicit scalar knobs for the same reason.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]

pub mod testkit;
pub mod util;

pub mod gguf;
pub mod quant;
pub mod tensor;
pub mod graph;
pub mod kernel;
pub mod model;
pub mod device;
pub mod metrics;
pub mod coordinator;
pub mod daemon;
pub mod report;
pub mod runtime;

pub mod analysis;
