//! The lint rule set (DESIGN.md §11).
//!
//! Two families. *Zone rules* run per line over the stripped code of a
//! scanned file, keyed by the file's [`Zone`]; *drift rules* (see
//! [`super::drift`]) compare docs against code. Both emit the same
//! [`Finding`] shape. Every rule here must be demonstrated by a fixture
//! in `rust/tests/lint_fixtures/` — a rule that cannot fire is a rule
//! that silently rots.
//!
//! Escapes: `// elib-lint: allow(<rule>, reason = "...")` suppresses
//! exactly that rule on the line it governs and is counted as an
//! [`Allow`]. A pragma with an unknown rule name or a missing reason is
//! itself a finding (`bad-pragma`) and suppresses nothing.

use super::scan::ScannedFile;
use super::zones::Zone;

/// Every rule the pass knows, in report order. Drift rules are listed
/// too: pragma validation and the fixture-coverage check need the full
/// universe.
pub const RULES: &[&str] = &[
    "hash-collections",
    "wall-clock",
    "raw-thread-spawn",
    "unordered-reduction",
    "request-path-unwrap",
    "bad-pragma",
    "design-ref",
    "metrics-doc-key",
    "registry-names",
    "bench-identity",
];

/// Is `rule` a known rule name?
pub fn known_rule(rule: &str) -> bool {
    RULES.contains(&rule)
}

/// One lint finding: `file:line rule message`.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    /// 1-indexed.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// One counted `allow` escape.
#[derive(Clone, Debug)]
pub struct Allow {
    pub file: String,
    /// 1-indexed line of the pragma comment.
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

/// Run the zone rules (plus pragma validation, which applies in every
/// zone) over one scanned file.
pub fn check_file(f: &ScannedFile, zone: Zone) -> (Vec<Finding>, Vec<Allow>) {
    let mut findings = Vec::new();
    let mut allows = Vec::new();
    for (idx, line) in f.lines.iter().enumerate() {
        let lineno = idx + 1;
        // Pragma hygiene first — it applies even inside test regions
        // and unzoned files, and invalid pragmas must not suppress.
        let mut live_allows: Vec<&str> = Vec::new();
        for p in &line.pragmas {
            if p.rule.is_empty() {
                findings.push(Finding {
                    file: f.rel.clone(),
                    line: p.line,
                    rule: "bad-pragma",
                    message: "malformed pragma: expected \
                              `elib-lint: allow(<rule>, reason = \"...\")`"
                        .into(),
                });
            } else if !known_rule(&p.rule) {
                findings.push(Finding {
                    file: f.rel.clone(),
                    line: p.line,
                    rule: "bad-pragma",
                    message: format!(
                        "pragma names unknown rule `{}` (known: {})",
                        p.rule,
                        RULES.join(", ")
                    ),
                });
            } else if p.reason.is_none() {
                findings.push(Finding {
                    file: f.rel.clone(),
                    line: p.line,
                    rule: "bad-pragma",
                    message: format!(
                        "pragma for `{}` has no reason — escapes must say why",
                        p.rule
                    ),
                });
            } else {
                live_allows.push(p.rule.as_str());
                allows.push(Allow {
                    file: f.rel.clone(),
                    line: p.line,
                    rule: p.rule.clone(),
                    reason: p.reason.clone().expect("checked above"),
                });
            }
        }
        if line.in_test {
            // Test modules may clock, spawn and unwrap freely.
            continue;
        }
        let code = line.code.as_str();
        let mut emit = |rule: &'static str, message: String| {
            if !live_allows.contains(&rule) {
                findings.push(Finding { file: f.rel.clone(), line: lineno, rule, message });
            }
        };
        match zone {
            Zone::Deterministic => {
                for tok in ["HashMap", "HashSet", "RandomState"] {
                    if code.contains(tok) {
                        emit(
                            "hash-collections",
                            format!(
                                "`{tok}` in a deterministic zone: hash iteration order is \
                                 unstable across builds — use BTreeMap/BTreeSet"
                            ),
                        );
                    }
                }
                for tok in ["Instant::now", "SystemTime"] {
                    if code.contains(tok) {
                        emit(
                            "wall-clock",
                            format!(
                                "`{tok}` in a deterministic zone: priced time must come \
                                 from the virtual clock, never the host"
                            ),
                        );
                    }
                }
                if code.contains("thread::spawn") {
                    emit(
                        "raw-thread-spawn",
                        "raw `thread::spawn` in a deterministic zone: fan out through \
                         `util::threadpool` so completion order cannot leak into results"
                            .into(),
                    );
                }
                let lower = code.to_ascii_lowercase();
                if (code.contains(".values()") || code.contains(".keys()"))
                    && [".sum(", ".fold(", ".product("].iter().any(|r| code.contains(r))
                    && lower.contains("hash")
                {
                    emit(
                        "unordered-reduction",
                        "float reduction over a hash container's iteration order: \
                         the result depends on bucket layout — reduce over a BTree \
                         or sort first"
                            .into(),
                    );
                }
            }
            Zone::WallClock => {
                if code.contains(".unwrap()") || code.contains(".expect(") {
                    emit(
                        "request-path-unwrap",
                        "`unwrap()`/`expect()` on a daemon request path: a panicking \
                         worker kills live connections — return a structured 4xx/5xx \
                         instead"
                            .into(),
                    );
                }
            }
            Zone::Unzoned => {}
        }
    }
    (findings, allows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::scan_str;

    fn det(src: &str) -> (Vec<Finding>, Vec<Allow>) {
        check_file(&scan_str("rust/src/graph/mod.rs", src), Zone::Deterministic)
    }

    fn wall(src: &str) -> (Vec<Finding>, Vec<Allow>) {
        check_file(&scan_str("rust/src/daemon/server.rs", src), Zone::WallClock)
    }

    #[test]
    fn deterministic_zone_rules_fire() {
        let (f, _) = det("use std::collections::HashMap;\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "hash-collections");
        let (f, _) = det("let t0 = Instant::now();\n");
        assert_eq!(f[0].rule, "wall-clock");
        let (f, _) = det("std::thread::spawn(move || {});\n");
        assert_eq!(f[0].rule, "raw-thread-spawn");
        let (f, _) = det("let s: f64 = hash_weights.values().sum();\n");
        assert_eq!(f[0].rule, "unordered-reduction");
    }

    #[test]
    fn btree_reductions_do_not_fire() {
        let (f, _) = det("let s: f64 = by_name.values().sum();\n");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn wallclock_zone_allows_clocks_but_not_unwraps() {
        let (f, _) = wall("let t = Instant::now();\nstd::thread::spawn(|| {});\n");
        assert!(f.is_empty(), "{f:?}");
        let (f, _) = wall("let v = res.unwrap();\n");
        assert_eq!(f[0].rule, "request-path-unwrap");
        let (f, _) = wall("let g = hub.lock().expect(\"hub lock\");\n");
        assert_eq!(f[0].rule, "request-path-unwrap");
    }

    #[test]
    fn unwrap_or_else_recovery_is_not_an_unwrap() {
        let (f, _) = wall("let g = hub.lock().unwrap_or_else(|e| e.into_inner());\n");
        assert!(f.is_empty(), "{f:?}");
        let (f, _) = wall("let first = t.first_token_wall.unwrap_or(now_wall);\n");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn prose_and_strings_never_fire() {
        let (f, _) = det("// HashMap and Instant::now discussed in prose\nlet m = \"HashMap\";\n");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn test_regions_are_exempt() {
        let (f, _) = wall("#[cfg(test)]\nmod tests {\n  fn t() { x.unwrap(); }\n}\n");
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn pragma_suppresses_exactly_its_rule() {
        let src = "use std::collections::HashMap; \
                   // elib-lint: allow(hash-collections, reason = \"ordered rebuild below\")\n";
        let (f, a) = det(src);
        assert!(f.is_empty(), "{f:?}");
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].rule, "hash-collections");
        // The wrong rule name suppresses nothing.
        let src = "let t = Instant::now(); \
                   // elib-lint: allow(hash-collections, reason = \"mismatched\")\n";
        let (f, a) = det(src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "wall-clock");
        assert_eq!(a.len(), 1, "the mismatched allow is still counted");
    }

    #[test]
    fn leading_pragma_round_trip() {
        let src = "// elib-lint: allow(wall-clock, reason = \"host measurement path\")\n\
                   let t0 = Instant::now();\nlet t1 = Instant::now();\n";
        let (f, a) = det(src);
        // Only the governed line is suppressed; line 3 still fires.
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].line, 3);
        assert_eq!(a.len(), 1);
    }

    #[test]
    fn unknown_rule_and_missing_reason_are_findings() {
        let (f, a) = det("let x = 1; // elib-lint: allow(no-such-rule, reason = \"eh\")\n");
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, "bad-pragma");
        assert!(f[0].message.contains("no-such-rule"));
        assert!(a.is_empty());
        let (f, _) = det("let t = Instant::now(); // elib-lint: allow(wall-clock)\n");
        // Reasonless pragma: bad-pragma AND the hazard still fires.
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().any(|x| x.rule == "bad-pragma"));
        assert!(f.iter().any(|x| x.rule == "wall-clock"));
    }
}
