//! The determinism-zone map (DESIGN.md §11).
//!
//! ELIB's headline claim is that bench.json / fleet.json / cluster.json
//! / daemon.json are bit-for-bit reproducible across machines and
//! `--threads` values. That property is only as strong as the code that
//! computes them: one `HashMap` iteration feeding a float reduction, or
//! one wall-clock read leaking into a priced quantity, silently breaks
//! it on a different allocator, a different std version, or a different
//! machine. The zone map declares which modules carry that burden.
//!
//! Zones are assigned by the first path component under `rust/src/`:
//!
//! | zone          | modules                                                  |
//! |---------------|----------------------------------------------------------|
//! | deterministic | coordinator, graph, device, metrics, quant, kernel       |
//! | wall-clock    | daemon                                                   |
//! | unzoned       | everything else (util, model, gguf, report, analysis, …) |
//!
//! *Deterministic* modules feed the reproducible artifacts: no
//! order-unstable hash collections, no wall-clock reads, no raw thread
//! spawns (the shared `util::threadpool` is the sanctioned fan-out).
//! The *wall-clock* zone is the daemon — `Instant::now` and raw spawns
//! are its job, but `unwrap()`/`expect()` on a request path is not: a
//! panicking worker kills live connections. Unzoned modules are
//! substrate; only the pragma grammar is enforced there.

use std::path::Path;

/// What a module is allowed to do (DESIGN.md §11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Zone {
    /// Feeds the bit-for-bit artifacts: hash collections, wall clocks
    /// and raw thread spawns are findings.
    Deterministic,
    /// The daemon: wall time is fine, panicking on a request path is
    /// not.
    WallClock,
    /// Substrate and tooling: only pragma hygiene is checked.
    Unzoned,
}

impl Zone {
    /// Human label used in findings and reports.
    pub fn label(self) -> &'static str {
        match self {
            Zone::Deterministic => "deterministic",
            Zone::WallClock => "wall-clock",
            Zone::Unzoned => "unzoned",
        }
    }
}

/// Top-level `rust/src/` modules in the deterministic zone.
pub const DETERMINISTIC_MODULES: &[&str] =
    &["coordinator", "graph", "device", "metrics", "quant", "kernel"];

/// Top-level `rust/src/` modules in the wall-clock zone.
pub const WALLCLOCK_MODULES: &[&str] = &["daemon"];

/// Zone of a source file, keyed by its path relative to the repo root
/// (e.g. `rust/src/coordinator/serve.rs`). Paths outside `rust/src/`
/// are unzoned.
pub fn zone_of(rel: &str) -> Zone {
    let path = Path::new(rel);
    let mut comps = path.components().map(|c| c.as_os_str().to_string_lossy());
    // Accept both `rust/src/<mod>/…` (repo-relative) and `<mod>/…`
    // (already src-relative), so callers can hand in either.
    let mut first = match comps.next() {
        Some(c) => c.to_string(),
        None => return Zone::Unzoned,
    };
    if first == "rust" {
        match comps.next() {
            Some(c) if c == "src" => {}
            _ => return Zone::Unzoned,
        }
        first = match comps.next() {
            Some(c) => c.to_string(),
            None => return Zone::Unzoned,
        };
    } else if first == "src" {
        first = match comps.next() {
            Some(c) => c.to_string(),
            None => return Zone::Unzoned,
        };
    }
    // `rust/src/graph.rs` and `rust/src/graph/mod.rs` are the same
    // module as far as the zone map cares.
    let module = first.trim_end_matches(".rs");
    if DETERMINISTIC_MODULES.contains(&module) {
        Zone::Deterministic
    } else if WALLCLOCK_MODULES.contains(&module) {
        Zone::WallClock
    } else {
        Zone::Unzoned
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repo_relative_paths_resolve() {
        assert_eq!(zone_of("rust/src/coordinator/serve.rs"), Zone::Deterministic);
        assert_eq!(zone_of("rust/src/graph/mod.rs"), Zone::Deterministic);
        assert_eq!(zone_of("rust/src/daemon/server.rs"), Zone::WallClock);
        assert_eq!(zone_of("rust/src/util/threadpool.rs"), Zone::Unzoned);
        assert_eq!(zone_of("rust/src/analysis/scan.rs"), Zone::Unzoned);
        assert_eq!(zone_of("rust/src/main.rs"), Zone::Unzoned);
    }

    #[test]
    fn src_relative_and_bare_paths_resolve() {
        assert_eq!(zone_of("src/kernel/backends.rs"), Zone::Deterministic);
        assert_eq!(zone_of("metrics/mod.rs"), Zone::Deterministic);
        assert_eq!(zone_of("daemon/http.rs"), Zone::WallClock);
    }

    #[test]
    fn single_file_modules_resolve() {
        assert_eq!(zone_of("rust/src/metrics.rs"), Zone::Deterministic);
        assert_eq!(zone_of("rust/src/report.rs"), Zone::Unzoned);
    }

    #[test]
    fn outside_the_tree_is_unzoned() {
        assert_eq!(zone_of("examples/quickstart.rs"), Zone::Unzoned);
        assert_eq!(zone_of("rust/tests/integration.rs"), Zone::Unzoned);
        assert_eq!(zone_of(""), Zone::Unzoned);
    }
}
