//! Rendering for lint results: the human `file:line rule message`
//! stream and the machine-readable `lint.json` (DESIGN.md §11).

use crate::util::json::Json;

use super::rules::{Allow, Finding};

/// Human-readable report. Findings first (one per line, in scan order),
/// then the counted allow escapes, then a one-line summary.
pub fn render_text(findings: &[Finding], allows: &[Allow]) -> String {
    let mut out = String::new();
    for f in findings {
        out.push_str(&format!("{}:{} {} {}\n", f.file, f.line, f.rule, f.message));
    }
    if !allows.is_empty() {
        if !findings.is_empty() {
            out.push('\n');
        }
        out.push_str(&format!("{} allow escape(s):\n", allows.len()));
        for a in allows {
            out.push_str(&format!(
                "{}:{} allow({}): {}\n",
                a.file, a.line, a.rule, a.reason
            ));
        }
    }
    if !out.is_empty() {
        out.push('\n');
    }
    out.push_str(&format!(
        "lint: {} finding(s), {} allow escape(s)\n",
        findings.len(),
        allows.len()
    ));
    out
}

/// `lint.json` payload.
pub fn to_json(findings: &[Finding], allows: &[Allow]) -> Json {
    let fs: Vec<Json> = findings
        .iter()
        .map(|f| {
            Json::obj(vec![
                ("file", Json::Str(f.file.clone())),
                ("line", Json::Num(f.line as f64)),
                ("rule", Json::Str(f.rule.to_string())),
                ("message", Json::Str(f.message.clone())),
            ])
        })
        .collect();
    let als: Vec<Json> = allows
        .iter()
        .map(|a| {
            Json::obj(vec![
                ("file", Json::Str(a.file.clone())),
                ("line", Json::Num(a.line as f64)),
                ("rule", Json::Str(a.rule.clone())),
                ("reason", Json::Str(a.reason.clone())),
            ])
        })
        .collect();
    Json::obj(vec![
        ("findings", Json::Arr(fs)),
        ("allows", Json::Arr(als)),
        (
            "counts",
            Json::obj(vec![
                ("findings", Json::Num(findings.len() as f64)),
                ("allows", Json::Num(allows.len() as f64)),
            ]),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (Vec<Finding>, Vec<Allow>) {
        (
            vec![Finding {
                file: "rust/src/graph/mod.rs".into(),
                line: 7,
                rule: "wall-clock",
                message: "host clock in a deterministic zone".into(),
            }],
            vec![Allow {
                file: "rust/src/coordinator/runner.rs".into(),
                line: 3,
                rule: "raw-thread-spawn".into(),
                reason: "watchdog".into(),
            }],
        )
    }

    #[test]
    fn text_report_shape() {
        let (f, a) = sample();
        let txt = render_text(&f, &a);
        assert!(txt.contains("rust/src/graph/mod.rs:7 wall-clock"));
        assert!(txt.contains("1 allow escape(s):"));
        assert!(txt.contains("allow(raw-thread-spawn): watchdog"));
        assert!(txt.ends_with("lint: 1 finding(s), 1 allow escape(s)\n"));
    }

    #[test]
    fn json_report_counts() {
        let (f, a) = sample();
        let j = crate::util::json::to_string_pretty(&to_json(&f, &a));
        assert!(j.contains("\"findings\""));
        assert!(j.contains("\"wall-clock\""));
        assert!(j.contains("\"watchdog\""));
    }
}
