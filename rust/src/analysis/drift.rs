//! Doc/code drift checks (DESIGN.md §11).
//!
//! Zone rules police single lines; drift rules police *contracts
//! between files* — the ones that have rotted three PRs in a row:
//!
//! - `design-ref`: every `DESIGN.md` section reference in a doc comment
//!   or markdown file must resolve to a real `## §N` heading.
//! - `metrics-doc-key`: every JSON key documented in `docs/METRICS.md`
//!   must appear, quoted, in some serializing source line.
//! - `registry-names`: workload/scheduler pipe-lists in README/docs
//!   must be subsets of `coordinator/registry.rs`, and every registered
//!   name must be documented in at least one such list.
//! - `bench-identity`: the `compare_bench` identity keys — i.e. the
//!   keys `ServeParams::to_json` emits — must stay derivable from
//!   `ScenarioSpec::to_json` (modulo the documented alias pairs), so a
//!   new knob cannot silently escape scenario identity.
//!
//! All checks work on raw text: markdown has no lexer, and for Rust
//! sources only the comment tail of each line is searched for section
//! references, so string literals never produce phantom refs.

use std::collections::BTreeSet;

use super::rules::Finding;

/// One input document: repo-relative path plus contents.
#[derive(Clone, Debug)]
pub struct DocFile {
    pub rel: String,
    pub text: String,
}

impl DocFile {
    pub fn new(rel: impl Into<String>, text: impl Into<String>) -> Self {
        DocFile { rel: rel.into(), text: text.into() }
    }
}

/// Everything the drift checks read. The fixture runner substitutes
/// deliberately-bad files here; the real runner loads the tree.
#[derive(Clone, Debug)]
pub struct DriftInputs {
    pub design_md: DocFile,
    pub metrics_md: DocFile,
    pub registry_rs: DocFile,
    pub serve_rs: DocFile,
    pub scenario_rs: DocFile,
    /// Markdown checked for section refs and registry pipe-lists
    /// (README.md plus docs/*.md, including METRICS.md).
    pub docs: Vec<DocFile>,
    /// Rust sources: comment tails are checked for section refs, and
    /// the concatenation is the haystack for `metrics-doc-key`.
    pub sources: Vec<DocFile>,
}

/// Alias pairs between `ServeParams::to_json` keys and their
/// `ScenarioSpec::to_json` spellings.
const IDENTITY_ALIASES: &[(&str, &str)] =
    &[("kv_pool_blocks", "pool_blocks"), ("kv_prefix_share", "prefix_share")];

/// Anchor for the serve-side identity serializer.
const SERVE_ANCHOR: &str = "pub(crate) fn to_json";
/// Anchor for the scenario-side identity serializer.
const SCENARIO_ANCHOR: &str = "pub fn to_json";

/// Run all four drift checks.
pub fn check_drift(inp: &DriftInputs) -> Vec<Finding> {
    let mut out = Vec::new();
    check_design_refs(inp, &mut out);
    check_metrics_keys(inp, &mut out);
    check_registry_names(inp, &mut out);
    check_bench_identity(inp, &mut out);
    out
}

/// `## §N` headings present in DESIGN.md.
fn design_sections(design: &str) -> BTreeSet<u64> {
    let mut set = BTreeSet::new();
    for line in design.lines() {
        if let Some(rest) = line.strip_prefix("## §") {
            let digits: String = rest.chars().take_while(|c| c.is_ascii_digit()).collect();
            if let Ok(n) = digits.parse() {
                set.insert(n);
            }
        }
    }
    set
}

fn check_design_refs(inp: &DriftInputs, out: &mut Vec<Finding>) {
    let sections = design_sections(&inp.design_md.text);
    // METRICS.md is conventionally also in `docs`, so it is not added
    // here — that would double-report its refs.
    let mut files: Vec<&DocFile> = vec![&inp.design_md];
    files.extend(inp.docs.iter());
    files.extend(inp.sources.iter());
    for f in files {
        let is_rs = f.rel.ends_with(".rs");
        for (idx, line) in f.text.lines().enumerate() {
            // In Rust sources only comments may carry doc references;
            // skipping the code part keeps string literals (like this
            // checker's own needle) out of scope.
            let hay = if is_rs {
                match line.find("//") {
                    Some(p) => &line[p..],
                    None => continue,
                }
            } else {
                line
            };
            let needle = "DESIGN.md §";
            let mut rest = hay;
            while let Some(p) = rest.find(needle) {
                let after = &rest[p + needle.len()..];
                let digits: String =
                    after.chars().take_while(|c| c.is_ascii_digit()).collect();
                if !digits.is_empty() {
                    let n: u64 = digits.parse().unwrap_or(u64::MAX);
                    if !sections.contains(&n) {
                        let have: Vec<String> =
                            sections.iter().map(|s| format!("§{s}")).collect();
                        out.push(Finding {
                            file: f.rel.clone(),
                            line: idx + 1,
                            rule: "design-ref",
                            message: format!(
                                "reference to DESIGN.md §{digits} does not resolve \
                                 to a heading (have {})",
                                have.join(", ")
                            ),
                        });
                    }
                }
                rest = after;
            }
        }
    }
}

/// A documented JSON key: starts lowercase, then lowercase / digit /
/// underscore. `report::daemon_section`-style code refs contain `:` and
/// never match.
fn is_json_key(s: &str) -> bool {
    let mut ch = s.chars();
    matches!(ch.next(), Some(c) if c.is_ascii_lowercase())
        && ch.all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

fn check_metrics_keys(inp: &DriftInputs, out: &mut Vec<Finding>) {
    // Haystack: every Rust source the run scanned (plus the identity
    // serializers, which may or may not be in that list).
    let mut hay = String::new();
    for s in inp
        .sources
        .iter()
        .chain([&inp.serve_rs, &inp.scenario_rs, &inp.registry_rs])
    {
        hay.push_str(&s.text);
        hay.push('\n');
    }
    let mut in_json_para = false;
    for (idx, line) in inp.metrics_md.text.lines().enumerate() {
        let t = line.trim();
        if t.is_empty() {
            in_json_para = false;
            continue;
        }
        if t.starts_with("JSON:") {
            in_json_para = true;
        }
        if !in_json_para {
            continue;
        }
        // Backtick spans: odd-numbered fragments after splitting.
        for (k, frag) in line.split('`').enumerate() {
            if k % 2 == 1 && is_json_key(frag) && !hay.contains(&format!("\"{frag}\"")) {
                out.push(Finding {
                    file: inp.metrics_md.rel.clone(),
                    line: idx + 1,
                    rule: "metrics-doc-key",
                    message: format!(
                        "documented JSON key `{frag}` is not serialized by any \
                         source line (no quoted \"{frag}\" anywhere)"
                    ),
                });
            }
        }
    }
}

/// `name: "x"` entries of one `pub const NAME` table, with line numbers.
fn registry_entries(text: &str, const_name: &str) -> Vec<(usize, String)> {
    let marker = format!("pub const {const_name}");
    let mut out = Vec::new();
    let mut started = false;
    for (idx, line) in text.lines().enumerate() {
        if !started {
            started = line.contains(&marker);
            continue;
        }
        if line.trim_start().starts_with("];") || line.contains("pub const ") {
            break;
        }
        if let Some(p) = line.find("name: \"") {
            let rest = &line[p + "name: \"".len()..];
            if let Some(q) = rest.find('"') {
                out.push((idx + 1, rest[..q].to_string()));
            }
        }
    }
    out
}

/// Pipe-lists following `flag` in a doc, e.g. `--workload a|b|c`.
fn doc_flag_lists(doc: &DocFile, flag: &str) -> Vec<(usize, Vec<String>)> {
    let strip = |s: &str| s.trim_matches(|c: char| "`*,.()<>[]".contains(c)).to_string();
    let mut out = Vec::new();
    for (idx, line) in doc.text.lines().enumerate() {
        let mut rest = line;
        while let Some(p) = rest.find(flag) {
            let after = &rest[p + flag.len()..];
            let tok = after.split_whitespace().next().unwrap_or("");
            let tok = strip(tok);
            if tok.contains('|') {
                let names: Vec<String> =
                    tok.split('|').map(|s| strip(s)).filter(|s| !s.is_empty()).collect();
                if !names.is_empty() {
                    out.push((idx + 1, names));
                }
            }
            rest = after;
        }
    }
    out
}

fn check_registry_names(inp: &DriftInputs, out: &mut Vec<Finding>) {
    for (const_name, flag, kind) in [
        ("WORKLOADS", "--workload ", "workload"),
        ("SCHEDULERS", "--scheduler ", "scheduler"),
    ] {
        let entries = registry_entries(&inp.registry_rs.text, const_name);
        if entries.is_empty() {
            out.push(Finding {
                file: inp.registry_rs.rel.clone(),
                line: 1,
                rule: "registry-names",
                message: format!(
                    "cannot find any `name: \"…\"` entries under `pub const \
                     {const_name}` — the registry drift check has no anchor"
                ),
            });
            continue;
        }
        let known: BTreeSet<&str> = entries.iter().map(|(_, n)| n.as_str()).collect();
        let mut documented: BTreeSet<String> = BTreeSet::new();
        let mut any_list = false;
        for doc in &inp.docs {
            for (line, names) in doc_flag_lists(doc, flag) {
                any_list = true;
                for name in names {
                    if !known.contains(name.as_str()) {
                        let have: Vec<&str> = known.iter().copied().collect();
                        out.push(Finding {
                            file: doc.rel.clone(),
                            line,
                            rule: "registry-names",
                            message: format!(
                                "documented {kind} `{name}` is not in \
                                 coordinator/registry.rs (known: {})",
                                have.join(", ")
                            ),
                        });
                    } else {
                        documented.insert(name);
                    }
                }
            }
        }
        // Coverage only makes sense once at least one pipe-list exists
        // for this flag — a docs set that never enumerates schedulers
        // is not claiming to.
        if any_list {
            for (line, name) in &entries {
                if !documented.contains(name) {
                    out.push(Finding {
                        file: inp.registry_rs.rel.clone(),
                        line: *line,
                        rule: "registry-names",
                        message: format!(
                            "registered {kind} `{name}` appears in no documented \
                             {}-list — docs and registry have drifted",
                            flag.trim()
                        ),
                    });
                }
            }
        }
    }
}

/// Body of the first function at/after `anchor`, plus the anchor's
/// 1-indexed line.
fn fn_body<'a>(text: &'a str, anchor: &str) -> Option<(usize, &'a str)> {
    let start = text.find(anchor)?;
    let open = text[start..].find('{')? + start;
    let mut depth = 0i64;
    for (off, c) in text[open..].char_indices() {
        match c {
            '{' => depth += 1,
            '}' => {
                depth -= 1;
                if depth == 0 {
                    let line = text[..start].matches('\n').count() + 1;
                    return Some((line, &text[open..=open + off]));
                }
            }
            _ => {}
        }
    }
    None
}

/// `("key",` identifiers inside a `to_json` body.
fn json_keys(body: &str) -> BTreeSet<String> {
    let mut keys = BTreeSet::new();
    let mut rest = body;
    while let Some(p) = rest.find("(\"") {
        let after = &rest[p + 2..];
        let ident: String = after
            .chars()
            .take_while(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || *c == '_')
            .collect();
        if !ident.is_empty() && after[ident.len()..].starts_with("\",") {
            keys.insert(ident);
        }
        rest = after;
    }
    keys
}

fn check_bench_identity(inp: &DriftInputs, out: &mut Vec<Finding>) {
    let serve = fn_body(&inp.serve_rs.text, SERVE_ANCHOR);
    let scenario = fn_body(&inp.scenario_rs.text, SCENARIO_ANCHOR);
    let mut anchored = true;
    for (body, file, anchor) in
        [(&serve, &inp.serve_rs.rel, SERVE_ANCHOR), (&scenario, &inp.scenario_rs.rel, SCENARIO_ANCHOR)]
    {
        if body.is_none() {
            anchored = false;
            out.push(Finding {
                file: file.clone(),
                line: 1,
                rule: "bench-identity",
                message: format!(
                    "cannot find `{anchor}` — the identity-key drift check has \
                     no serializer to compare"
                ),
            });
        }
    }
    if !anchored {
        return;
    }
    let (_, serve_body) = serve.expect("anchored above");
    let (_, scenario_body) = scenario.expect("anchored above");
    let serve_keys = json_keys(serve_body);
    let scenario_keys = json_keys(scenario_body);
    for key in &serve_keys {
        let want = IDENTITY_ALIASES
            .iter()
            .find(|(from, _)| from == key)
            .map(|(_, to)| *to)
            .unwrap_or(key.as_str());
        if !scenario_keys.contains(want) {
            // Anchor the finding at the key's own line in serve.rs.
            let needle = format!("(\"{key}\",");
            let line = inp
                .serve_rs
                .text
                .lines()
                .position(|l| l.contains(&needle))
                .map(|i| i + 1)
                .unwrap_or(1);
            out.push(Finding {
                file: inp.serve_rs.rel.clone(),
                line,
                rule: "bench-identity",
                message: format!(
                    "ServeParams::to_json emits `{key}` but ScenarioSpec::to_json \
                     has no `{want}` — compare_bench identity keys are no longer \
                     derivable from scenario serialization"
                ),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> DriftInputs {
        DriftInputs {
            design_md: DocFile::new(
                "DESIGN.md",
                "# d\n\n## §1 One\n\nbody\n\n## §2 Two\n\nbody\n",
            ),
            metrics_md: DocFile::new("docs/METRICS.md", "# m\n"),
            registry_rs: DocFile::new(
                "rust/src/coordinator/registry.rs",
                "pub const WORKLOADS: &[W] = &[\n    W { name: \"poisson\" },\n    \
                 W { name: \"closed\" },\n];\npub const SCHEDULERS: &[S] = &[\n    \
                 S { name: \"fcfs\" },\n];\n",
            ),
            serve_rs: DocFile::new(
                "rust/src/coordinator/serve.rs",
                "impl ServeParams {\n    pub(crate) fn to_json(&self) -> Json {\n        \
                 Json::obj(vec![(\"seed\", j(1)), (\"kv_pool_blocks\", j(2))])\n    }\n}\n",
            ),
            scenario_rs: DocFile::new(
                "rust/src/coordinator/scenario.rs",
                "impl ScenarioSpec {\n    pub fn to_json(&self) -> Json {\n        \
                 Json::obj(vec![(\"seed\", j(1)), (\"pool_blocks\", j(2))])\n    }\n}\n",
            ),
            docs: vec![],
            sources: vec![],
        }
    }

    #[test]
    fn clean_inputs_have_no_findings() {
        assert!(check_drift(&base()).is_empty());
    }

    #[test]
    fn stale_design_ref_fires_and_valid_ref_does_not() {
        let mut inp = base();
        inp.sources.push(DocFile::new(
            "rust/src/graph/mod.rs",
            format!("// see DESIGN.md §{} for details\nfn f() {{}}\n", 99),
        ));
        inp.docs.push(DocFile::new(
            "README.md",
            format!("Valid: DESIGN.md §{}.\nStale: DESIGN.md §{}.\n", 2, 7),
        ));
        let f = check_drift(&inp);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "design-ref"));
        assert!(f.iter().any(|x| x.file == "rust/src/graph/mod.rs" && x.line == 1));
        assert!(f.iter().any(|x| x.file == "README.md" && x.line == 2));
    }

    #[test]
    fn refs_in_rust_string_literals_are_ignored() {
        let mut inp = base();
        inp.sources.push(DocFile::new(
            "rust/src/analysis/drift.rs",
            format!("let needle = \"DESIGN.md \u{a7}{}\";\n", 42),
        ));
        assert!(check_drift(&inp).is_empty());
    }

    #[test]
    fn undocumented_metrics_key_fires() {
        let mut inp = base();
        inp.metrics_md.text = "intro\n\nJSON: each run carries `seed` and \
                               `no_such_key_xyz` per record.\n\nprose with `other`\n"
            .into();
        inp.sources.push(DocFile::new("rust/src/report.rs", "let k = \"seed\";\n".into()));
        let f = check_drift(&inp);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "metrics-doc-key");
        assert!(f[0].message.contains("no_such_key_xyz"));
        assert_eq!(f[0].line, 3);
    }

    #[test]
    fn registry_subset_and_coverage() {
        let mut inp = base();
        inp.docs.push(DocFile::new(
            "README.md",
            "Run with `--workload bursty|poisson` to pick arrivals.\n",
        ));
        let f = check_drift(&inp);
        // `bursty` unknown + `closed` uncovered; no scheduler pipe-list
        // exists, so scheduler coverage stays silent.
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f.iter().all(|x| x.rule == "registry-names"));
        assert!(f.iter().any(|x| x.message.contains("bursty") && x.file == "README.md"));
        assert!(f
            .iter()
            .any(|x| x.message.contains("closed") && x.file.ends_with("registry.rs")));
    }

    #[test]
    fn full_pipe_lists_are_clean() {
        let mut inp = base();
        inp.docs.push(DocFile::new(
            "README.md",
            "`--workload poisson|closed` and `--scheduler fcfs` (no list).\n",
        ));
        assert!(check_drift(&inp).is_empty());
    }

    #[test]
    fn identity_key_drift_fires_with_alias_awareness() {
        let mut inp = base();
        inp.serve_rs.text = inp
            .serve_rs
            .text
            .replace("(\"seed\", j(1))", "(\"seed\", j(1)), (\"brand_new_knob\", j(3))");
        let f = check_drift(&inp);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "bench-identity");
        assert!(f[0].message.contains("brand_new_knob"));
        // kv_pool_blocks → pool_blocks aliasing kept the clean key quiet.
    }

    #[test]
    fn missing_anchor_is_a_finding() {
        let mut inp = base();
        inp.serve_rs.text = "fn nothing_here() {}\n".into();
        let f = check_drift(&inp);
        assert_eq!(f.len(), 1, "{f:?}");
        assert_eq!(f[0].rule, "bench-identity");
        assert!(f[0].message.contains("no serializer"));
    }
}
