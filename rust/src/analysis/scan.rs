//! Line/token-level source scanning for the lint pass (DESIGN.md §11).
//!
//! No `syn`, no regex crate — the scanner is a small character automaton
//! in the spirit of the crate's hand-rolled JSON and HTTP layers. It
//! produces, per line:
//!
//! - `code`: the line with comments and string/char literal *contents*
//!   blanked out, so rule matching never fires on prose or payloads;
//! - `in_test`: whether the line sits at or below the file's
//!   `#[cfg(test)]` marker (test modules are conventionally last in
//!   this repo, so the region runs to end of file);
//! - the `// elib-lint: allow(<rule>, reason = "...")` pragmas that
//!   govern the line. The pragma must be the whole comment (the marker
//!   opens it). A pragma on its own comment line governs the next line
//!   that carries code; a trailing pragma governs its own line.
//!
//! The automaton understands line comments, nested block comments,
//! string/char/byte literals with escapes, and raw strings (`r"…"`,
//! `r#"…"#`, `br#"…"#`); lifetimes (`'a`) are not mistaken for char
//! literals.

/// One `// elib-lint: allow(rule, reason = "…")` escape, parsed but not
/// yet validated — `rules` decides whether the rule name is known and
/// the reason is present (a bad pragma is itself a finding).
#[derive(Clone, Debug, PartialEq)]
pub struct Pragma {
    /// 1-indexed line the pragma comment sits on.
    pub line: usize,
    /// The rule name inside `allow(...)`; empty when the pragma is
    /// syntactically malformed.
    pub rule: String,
    /// The quoted reason, when present.
    pub reason: Option<String>,
}

/// One scanned source line.
#[derive(Clone, Debug)]
pub struct ScanLine {
    /// Comment- and literal-stripped text (literal contents become
    /// spaces, delimiters survive).
    pub code: String,
    /// The raw line, for drift checks that read doc comments.
    pub raw: String,
    /// True at and below the first `#[cfg(test)]`.
    pub in_test: bool,
    /// Pragmas governing this line (own trailing pragma plus any
    /// pragma-only comment lines immediately above).
    pub pragmas: Vec<Pragma>,
}

/// A scanned file: path relative to the repo root plus its lines.
#[derive(Clone, Debug)]
pub struct ScannedFile {
    pub rel: String,
    pub lines: Vec<ScanLine>,
}

/// Lexer state carried across characters (and lines — strings and block
/// comments span them).
enum St {
    Code,
    Block(u32),
    Str,
    StrEscape,
    RawStr(u8),
    Char,
    CharEscape,
}

/// Scan a source text. `rel` is kept verbatim for findings.
pub fn scan_str(rel: &str, text: &str) -> ScannedFile {
    // Pass 1: strip. Walk the whole text so multi-line literals and
    // block comments carry state across newlines; collect the comment
    // text per line for pragma parsing.
    let mut stripped = String::with_capacity(text.len());
    let mut comments: Vec<String> = vec![String::new()];
    let mut st = St::Code;
    let chars: Vec<char> = text.chars().collect();
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            // Newline terminates line comments; everything else
            // carries over.
            stripped.push('\n');
            comments.push(String::new());
            if matches!(st, St::StrEscape) {
                // `\` at end of line is the string-continuation escape:
                // the newline is the escaped character, the string goes
                // on below it.
                st = St::Str;
            }
            i += 1;
            continue;
        }
        match st {
            St::Code => match c {
                '/' if chars.get(i + 1) == Some(&'/') => {
                    // Line comment: capture its text for pragmas, emit
                    // nothing.
                    let mut j = i + 2;
                    let buf = comments.last_mut().expect("comment buffer");
                    while j < chars.len() && chars[j] != '\n' {
                        buf.push(chars[j]);
                        j += 1;
                    }
                    i = j;
                }
                '/' if chars.get(i + 1) == Some(&'*') => {
                    st = St::Block(1);
                    i += 2;
                }
                '"' => {
                    stripped.push('"');
                    st = St::Str;
                    i += 1;
                }
                'r' if raw_string_hashes(&chars, i).is_some() => {
                    let h = raw_string_hashes(&chars, i).expect("checked");
                    // Skip `r##…#"`, emit a placeholder delimiter.
                    stripped.push('"');
                    i += 2 + h as usize;
                    st = St::RawStr(h);
                }
                'b' if chars.get(i + 1) == Some(&'"') => {
                    stripped.push('"');
                    st = St::Str;
                    i += 2;
                }
                'b' if chars.get(i + 1) == Some(&'r')
                    && raw_string_hashes(&chars, i + 1).is_some() =>
                {
                    let h = raw_string_hashes(&chars, i + 1).expect("checked");
                    stripped.push('"');
                    i += 3 + h as usize;
                    st = St::RawStr(h);
                }
                'b' if chars.get(i + 1) == Some(&'\'') => {
                    stripped.push('\'');
                    st = St::Char;
                    i += 2;
                }
                '\'' => {
                    // Char literal or lifetime: `'x'` / `'\n'` are
                    // literals; `'a` (no closing quote nearby) is a
                    // lifetime and stays code.
                    if chars.get(i + 1) == Some(&'\\') || chars.get(i + 2) == Some(&'\'') {
                        stripped.push('\'');
                        st = St::Char;
                    } else {
                        stripped.push('\'');
                    }
                    i += 1;
                }
                _ => {
                    stripped.push(c);
                    i += 1;
                }
            },
            St::Block(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    st = St::Block(depth + 1);
                    i += 2;
                } else {
                    i += 1;
                }
            }
            St::Str => match c {
                '\\' => {
                    st = St::StrEscape;
                    stripped.push(' ');
                    i += 1;
                }
                '"' => {
                    st = St::Code;
                    stripped.push('"');
                    i += 1;
                }
                _ => {
                    stripped.push(' ');
                    i += 1;
                }
            },
            St::StrEscape => {
                stripped.push(' ');
                st = St::Str;
                i += 1;
            }
            St::RawStr(h) => {
                if c == '"' && closes_raw(&chars, i, h) {
                    stripped.push('"');
                    st = St::Code;
                    i += 1 + h as usize;
                } else {
                    stripped.push(' ');
                    i += 1;
                }
            }
            St::Char => match c {
                '\\' => {
                    st = St::CharEscape;
                    stripped.push(' ');
                    i += 1;
                }
                '\'' => {
                    st = St::Code;
                    stripped.push('\'');
                    i += 1;
                }
                _ => {
                    stripped.push(' ');
                    i += 1;
                }
            },
            St::CharEscape => {
                stripped.push(' ');
                st = St::Char;
                i += 1;
            }
        }
    }

    // Pass 2: assemble lines, attach pragmas, mark the test region.
    let raw_lines: Vec<&str> = text.split('\n').collect();
    let code_lines: Vec<&str> = stripped.split('\n').collect();
    let mut lines = Vec::with_capacity(raw_lines.len());
    let mut pending: Vec<Pragma> = Vec::new();
    let mut in_test = false;
    for (idx, raw) in raw_lines.iter().enumerate() {
        let code = code_lines.get(idx).copied().unwrap_or("").to_string();
        let comment = comments.get(idx).map(String::as_str).unwrap_or("");
        if code.contains("#[cfg(test)]") {
            in_test = true;
        }
        let own = parse_pragma(comment, idx + 1);
        let has_code = !code.trim().is_empty();
        let mut pragmas = Vec::new();
        if has_code {
            pragmas.append(&mut pending);
            pragmas.extend(own.clone());
        } else {
            pending.extend(own.clone());
        }
        lines.push(ScanLine { code, raw: (*raw).to_string(), in_test, pragmas });
    }
    // A pragma trailing the file with nothing to govern still needs
    // validation: hang it on the last line.
    if !pending.is_empty() {
        if let Some(last) = lines.last_mut() {
            last.pragmas.append(&mut pending);
        }
    }
    ScannedFile { rel: rel.to_string(), lines }
}

/// Scan a file on disk; `rel` is the repo-relative path for findings.
pub fn scan_file(rel: &str, path: &std::path::Path) -> anyhow::Result<ScannedFile> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("lint cannot read {}: {e}", path.display()))?;
    Ok(scan_str(rel, &text))
}

/// If `chars[at..]` starts a raw string (`r"`, `r#"`, `r##"` …), the
/// number of hashes; else None.
fn raw_string_hashes(chars: &[char], at: usize) -> Option<u8> {
    debug_assert_eq!(chars.get(at), Some(&'r'));
    let mut h = 0u8;
    let mut j = at + 1;
    while chars.get(j) == Some(&'#') {
        h = h.saturating_add(1);
        j += 1;
    }
    (chars.get(j) == Some(&'"')).then_some(h)
}

/// Does the `"` at `chars[at]` close a raw string with `h` hashes?
fn closes_raw(chars: &[char], at: usize, h: u8) -> bool {
    (1..=h as usize).all(|k| chars.get(at + k) == Some(&'#'))
}

/// Parse `elib-lint: allow(rule, reason = "…")` out of one comment's
/// text. The marker must open the comment (`// elib-lint: …`) — a
/// comment that merely *mentions* the pragma grammar, like this doc
/// comment, is prose, not a pragma. A marker-opening comment that does
/// not parse cleanly comes back with an empty rule name, which `rules`
/// reports as a bad pragma — a typo must never silently suppress
/// anything.
fn parse_pragma(comment: &str, line: usize) -> Option<Pragma> {
    let rest = comment.trim_start().strip_prefix("elib-lint:")?;
    let rest = rest.trim_start();
    let malformed = Some(Pragma { line, rule: String::new(), reason: None });
    let Some(body) = rest.strip_prefix("allow(") else {
        return malformed;
    };
    let Some(close) = body.rfind(')') else {
        return malformed;
    };
    let inner = &body[..close];
    let (rule_part, reason_part) = match inner.split_once(',') {
        Some((r, rest)) => (r.trim(), Some(rest.trim())),
        None => (inner.trim(), None),
    };
    if rule_part.is_empty()
        || !rule_part.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-')
    {
        return malformed;
    }
    let reason = match reason_part {
        None => None,
        Some(r) => {
            let Some(eq) = r.strip_prefix("reason") else {
                return malformed;
            };
            let Some(q) = eq.trim_start().strip_prefix('=') else {
                return malformed;
            };
            let q = q.trim();
            let Some(q) = q.strip_prefix('"').and_then(|s| s.strip_suffix('"')) else {
                return malformed;
            };
            if q.trim().is_empty() {
                None
            } else {
                Some(q.to_string())
            }
        }
    };
    Some(Pragma { line, rule: rule_part.to_string(), reason })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        scan_str("t.rs", src).lines.into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strings_and_comments_are_blanked() {
        let c = code_of("let x = \"HashMap\"; // Instant::now in prose\nuse std::a;");
        assert!(!c[0].contains("HashMap"), "{:?}", c[0]);
        assert!(!c[0].contains("Instant"), "{:?}", c[0]);
        assert!(c[1].contains("use std::a;"));
    }

    #[test]
    fn raw_and_byte_strings_are_blanked() {
        let c = code_of("let a = r#\"thread::spawn\"#; let b = b\"SystemTime\";");
        assert!(!c[0].contains("spawn"));
        assert!(!c[0].contains("SystemTime"));
        // Code around the literals survives.
        assert!(c[0].contains("let a ="));
        assert!(c[0].contains("let b ="));
    }

    #[test]
    fn multiline_strings_keep_state() {
        let c = code_of("let m = \"line one \\\n   HashMap line two\";\nlet ok = 1;");
        assert!(!c[1].contains("HashMap"), "{:?}", c[1]);
        assert!(c[2].contains("let ok"));
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let c = code_of("fn f<'a>(x: &'a str) -> &'a str { x } // HashMap");
        assert!(c[0].contains("fn f<'a>"));
        assert!(!c[0].contains("HashMap"));
    }

    #[test]
    fn char_literals_are_blanked() {
        let c = code_of("let q = 'H'; let e = '\\n'; let code = 1;");
        assert!(c[0].contains("let code = 1;"));
        assert!(!c[0].contains('H'), "{:?}", c[0]);
    }

    #[test]
    fn nested_block_comments_strip() {
        let c = code_of("/* outer /* Instant::now */ still comment */ let x = 2;");
        assert!(!c[0].contains("Instant"));
        assert!(c[0].contains("let x = 2;"));
    }

    #[test]
    fn test_region_runs_to_eof() {
        let f = scan_str("t.rs", "fn a() {}\n#[cfg(test)]\nmod tests {\n fn b() {}\n}\n");
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test);
        assert!(f.lines[3].in_test);
    }

    #[test]
    fn trailing_pragma_governs_its_line() {
        let f = scan_str(
            "t.rs",
            "use x::HashMap; // elib-lint: allow(hash-collections, reason = \"why\")\n",
        );
        let p = &f.lines[0].pragmas;
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].rule, "hash-collections");
        assert_eq!(p[0].reason.as_deref(), Some("why"));
    }

    #[test]
    fn leading_pragma_governs_next_code_line() {
        let f = scan_str(
            "t.rs",
            "// elib-lint: allow(wall-clock, reason = \"measured path\")\n\nlet t = 1;\n",
        );
        assert!(f.lines[0].pragmas.is_empty());
        assert_eq!(f.lines[2].pragmas.len(), 1);
        assert_eq!(f.lines[2].pragmas[0].rule, "wall-clock");
        assert_eq!(f.lines[2].pragmas[0].line, 1);
    }

    #[test]
    fn malformed_pragmas_surface_with_empty_rule() {
        let f = scan_str("t.rs", "let x = 1; // elib-lint: allow(\n");
        assert_eq!(f.lines[0].pragmas[0].rule, "");
        let f = scan_str("t.rs", "let x = 1; // elib-lint: deny(foo)\n");
        assert_eq!(f.lines[0].pragmas[0].rule, "");
    }

    #[test]
    fn missing_reason_parses_as_none() {
        let f = scan_str("t.rs", "let x = 1; // elib-lint: allow(wall-clock)\n");
        assert_eq!(f.lines[0].pragmas[0].rule, "wall-clock");
        assert_eq!(f.lines[0].pragmas[0].reason, None);
        let f = scan_str("t.rs", "let x = 1; // elib-lint: allow(wall-clock, reason = \"\")\n");
        assert_eq!(f.lines[0].pragmas[0].reason, None);
    }
}
