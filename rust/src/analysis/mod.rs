//! `elib lint` — the repo-specific static-analysis pass (DESIGN.md §11).
//!
//! A dependency-free, line/token-level analyzer over the repo's own
//! sources and docs, in the spirit of the crate's hand-rolled JSON and
//! HTTP layers. Two rule families:
//!
//! - **determinism-zone lints** ([`zones`], [`rules`]): the modules
//!   that feed the bit-for-bit artifacts must not use hash collections,
//!   wall clocks or raw thread spawns; the daemon must not panic on
//!   request paths.
//! - **drift checks** ([`drift`]): section refs, documented JSON keys,
//!   registry names and `compare_bench` identity keys must match the
//!   code they describe.
//!
//! [`run_lint`] walks the real tree and must return zero findings at
//! merge; [`run_fixture_lint`] runs the deliberately-bad corpus under
//! `rust/tests/lint_fixtures/` and must demonstrate every rule firing.

pub mod drift;
pub mod reportfmt;
pub mod rules;
pub mod scan;
pub mod zones;

use std::collections::BTreeSet;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use drift::{check_drift, DocFile, DriftInputs};
use rules::{check_file, Allow, Finding};
use zones::{zone_of, Zone};

/// The result of one lint run.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub allows: Vec<Allow>,
}

impl LintReport {
    /// Process exit code: nonzero on any finding.
    pub fn exit_code(&self) -> i32 {
        if self.findings.is_empty() {
            0
        } else {
            1
        }
    }

    /// Distinct rules that produced at least one finding.
    pub fn rules_fired(&self) -> BTreeSet<&'static str> {
        self.findings.iter().map(|f| f.rule).collect()
    }
}

/// Walk upward from `start` to the repo root: the first directory
/// holding both `rust/src` and `DESIGN.md`.
pub fn find_root(start: &Path) -> Option<PathBuf> {
    let mut cur = Some(start.to_path_buf());
    while let Some(dir) = cur {
        if dir.join("rust").join("src").is_dir() && dir.join("DESIGN.md").is_file() {
            return Some(dir);
        }
        cur = dir.parent().map(Path::to_path_buf);
    }
    None
}

/// Recursively collect files with extension `ext` under `dir`, sorted
/// for deterministic report order.
fn walk_ext(dir: &Path, ext: &str, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("lint cannot read dir {}", dir.display()))?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            walk_ext(&p, ext, out)?;
        } else if p.extension().and_then(|e| e.to_str()) == Some(ext) {
            out.push(p);
        }
    }
    Ok(())
}

/// Repo-relative path with `/` separators, for findings.
fn rel_of(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

/// Read one repo file into a [`DocFile`].
fn read_doc(root: &Path, rel: &str) -> Result<DocFile> {
    let text = std::fs::read_to_string(root.join(rel))
        .map_err(|e| anyhow!("lint cannot read {rel}: {e}"))?;
    Ok(DocFile::new(rel, text))
}

/// Scan one source file: zone rules into `findings`/`allows`, raw text
/// into `sources` for the drift haystack.
fn lint_source(
    root: &Path,
    path: &Path,
    zone: Zone,
    findings: &mut Vec<Finding>,
    allows: &mut Vec<Allow>,
    sources: &mut Vec<DocFile>,
) -> Result<()> {
    let rel = rel_of(root, path);
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow!("lint cannot read {rel}: {e}"))?;
    let scanned = scan::scan_str(&rel, &text);
    let (mut f, mut a) = check_file(&scanned, zone);
    findings.append(&mut f);
    allows.append(&mut a);
    sources.push(DocFile::new(rel, text));
    Ok(())
}

/// Lint the real tree rooted at `root`: every `rust/src/**/*.rs` under
/// its mapped zone, plus the four drift contracts over
/// README.md / DESIGN.md / docs/*.md.
pub fn run_lint(root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    walk_ext(&root.join("rust").join("src"), "rs", &mut files)?;
    let mut findings = Vec::new();
    let mut allows = Vec::new();
    let mut sources = Vec::new();
    for path in &files {
        let zone = zone_of(&rel_of(root, path));
        lint_source(root, path, zone, &mut findings, &mut allows, &mut sources)?;
    }
    let mut docs = vec![read_doc(root, "README.md")?];
    let docs_dir = root.join("docs");
    if docs_dir.is_dir() {
        let mut md = Vec::new();
        walk_ext(&docs_dir, "md", &mut md)?;
        for p in &md {
            docs.push(read_doc(root, &rel_of(root, p))?);
        }
    }
    let inputs = DriftInputs {
        design_md: read_doc(root, "DESIGN.md")?,
        metrics_md: read_doc(root, "docs/METRICS.md")?,
        registry_rs: read_doc(root, "rust/src/coordinator/registry.rs")?,
        serve_rs: read_doc(root, "rust/src/coordinator/serve.rs")?,
        scenario_rs: read_doc(root, "rust/src/coordinator/scenario.rs")?,
        docs,
        sources,
    };
    findings.extend(check_drift(&inputs));
    Ok(LintReport { findings, allows })
}

/// Lint the deliberately-bad fixture corpus under
/// `rust/tests/lint_fixtures/`. Zone is forced by subdirectory
/// (`deterministic/`, `wallclock/`); the `docs/` fixtures substitute
/// the drift inputs they are designed to break, with the real
/// DESIGN.md and registry as the reference side. Expected to exit
/// nonzero with every rule firing.
pub fn run_fixture_lint(root: &Path) -> Result<LintReport> {
    let fx = root.join("rust").join("tests").join("lint_fixtures");
    let mut findings = Vec::new();
    let mut allows = Vec::new();
    let mut sources = Vec::new();
    for (sub, zone) in
        [("deterministic", Zone::Deterministic), ("wallclock", Zone::WallClock)]
    {
        let mut files = Vec::new();
        walk_ext(&fx.join(sub), "rs", &mut files)?;
        for path in &files {
            lint_source(root, path, zone, &mut findings, &mut allows, &mut sources)?;
        }
    }
    let fixture_doc =
        |name: &str| read_doc(root, &format!("rust/tests/lint_fixtures/docs/{name}"));
    let inputs = DriftInputs {
        design_md: read_doc(root, "DESIGN.md")?,
        metrics_md: fixture_doc("metrics_bad.md")?,
        registry_rs: read_doc(root, "rust/src/coordinator/registry.rs")?,
        serve_rs: fixture_doc("serve_params_bad.rs")?,
        scenario_rs: fixture_doc("scenario_spec.rs")?,
        docs: vec![fixture_doc("readme_bad.md")?],
        sources: vec![fixture_doc("design_ref.rs")?],
    };
    findings.extend(check_drift(&inputs));
    Ok(LintReport { findings, allows })
}
