//! Benchmarking metrics (paper §4.2): FLOPS, throughput, latency
//! (TTLM/TTFT/TPOT), accuracy (perplexity) and the paper's novel MBU
//! (Model Bandwidth Utilization) metric, eqs. 1–3.

use crate::model::{scale, LlamaConfig};
use crate::quant::QuantType;

/// MBU, paper eq. 1–2:
///
///   achieved_bw = (param_bytes + kv_cache_bytes) / TPOT
///   MBU         = achieved_bw / peak_bw
///
/// `tpot_secs` is seconds per generated token; `peak_bw` in bytes/sec.
/// The metric is batch-aware through both terms: the eq.-3 KV size scales
/// in B, and TPOT is per *generated* token while the parameter bytes are
/// streamed once per batched step — so a batched decoder's weight reuse
/// counts as effective bandwidth and MBU rises with batch (and may exceed
/// 1.0; the paper's framing for why batching is the lever on edge
/// devices, not a physical >100% bus utilization).
pub fn mbu(param_bytes: u64, kv_cache_bytes: u64, tpot_secs: f64, peak_bw: f64) -> f64 {
    if tpot_secs <= 0.0 || peak_bw <= 0.0 {
        return 0.0;
    }
    let achieved = (param_bytes + kv_cache_bytes) as f64 / tpot_secs;
    achieved / peak_bw
}

/// KV-cache size, paper eq. 3 (delegates to the model-layer formula so
/// there is exactly one implementation).
pub fn kv_cache_size(
    config: &LlamaConfig,
    batch: usize,
    seq: usize,
    data_byte: u64,
) -> u64 {
    scale::kv_cache_bytes(config, batch, seq, data_byte)
}

/// Perplexity: exp of mean NLL (paper §4.2.4).
pub fn perplexity(nll_sum: f64, token_count: usize) -> f64 {
    if token_count == 0 {
        return f64::INFINITY;
    }
    (nll_sum / token_count as f64).exp()
}

/// Throughput in tokens/s from total decode time.
pub fn throughput(generated_tokens: usize, decode_secs: f64) -> f64 {
    if decode_secs <= 0.0 {
        0.0
    } else {
        generated_tokens as f64 / decode_secs
    }
}

/// TPOT is the inverse of throughput (paper §4.2.5).
pub fn tpot(throughput_tok_s: f64) -> f64 {
    if throughput_tok_s <= 0.0 {
        f64::INFINITY
    } else {
        1.0 / throughput_tok_s
    }
}

/// Total latency constraint of RQ2: TTFT + TPOT·N ≤ budget.
pub fn total_latency(ttft_secs: f64, tpot_secs: f64, n_output_tokens: usize) -> f64 {
    ttft_secs + tpot_secs * n_output_tokens as f64
}

/// Service-level tier a request's deadlines were drawn for (DESIGN.md §5
/// "SLOs, goodput, and SLO-aware scheduling"). Tiers are assigned by a
/// seeded side-stream salted off the trace seed, so the token trace is
/// SLO-invariant and tier membership is deterministic per (seed, id).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloTier {
    /// Latency-critical (chat-style): the base deadlines, unrelaxed.
    Interactive,
    /// Default traffic: base deadlines × 4.
    Standard,
    /// Best-effort background: base deadlines × 16.
    Batch,
}

impl SloTier {
    pub const ALL: [SloTier; 3] = [SloTier::Interactive, SloTier::Standard, SloTier::Batch];

    pub fn key(&self) -> &'static str {
        match self {
            SloTier::Interactive => "interactive",
            SloTier::Standard => "standard",
            SloTier::Batch => "batch",
        }
    }

    /// Deadline relaxation relative to the interactive base.
    pub fn multiplier(&self) -> f64 {
        match self {
            SloTier::Interactive => 1.0,
            SloTier::Standard => 4.0,
            SloTier::Batch => 16.0,
        }
    }
}

/// One request's service-level objective: a TTFT deadline measured from
/// *arrival* (queueing included — what the user waits for) and a TPOT
/// deadline per decoded token after the first. Either may be
/// `f64::INFINITY` (never serialized; absent JSON keys mean "no bound").
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Slo {
    pub tier: SloTier,
    /// TTFT deadline in virtual seconds from arrival.
    pub ttft: f64,
    /// TPOT deadline in virtual seconds per decoded token.
    pub tpot: f64,
}

/// How a request left the system. `Served` ran to its target length;
/// `Shed` was rejected before admission (its TTFT deadline was already
/// unmeetable); `Preempted` was evicted mid-decode to free paged-KV
/// blocks after its TPOT deadline became unmeetable. Shed and preempted
/// requests are never silently dropped — they keep their record and are
/// counted in the aggregate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Outcome {
    #[default]
    Served,
    Shed,
    Preempted,
}

impl Outcome {
    pub fn key(&self) -> &'static str {
        match self {
            Outcome::Served => "served",
            Outcome::Shed => "shed",
            Outcome::Preempted => "preempted",
        }
    }
}

/// Per-request latency record of the serving scenario (DESIGN.md §5).
/// All times are on the serve loop's deterministic virtual clock, in
/// seconds since the run started. The lifecycle is
/// `arrival ≤ admit ≤ first_token ≤ finish`:
/// queueing wait is `admit - arrival`, TTFT spans queueing + prefill
/// (`first_token - arrival`, the latency a user of a loaded system sees),
/// and TPOT is the steady decode interval after the first token.
#[derive(Clone, Debug, PartialEq)]
pub struct RequestRecord {
    pub id: usize,
    pub arrival: f64,
    pub admit: f64,
    pub first_token: f64,
    pub finish: f64,
    pub prompt_tokens: usize,
    pub output_tokens: usize,
    /// The request's deadlines, when the run assigned SLOs (`None` keeps
    /// the pre-SLO record — and its JSON — byte-identical).
    pub slo: Option<Slo>,
    /// How the request left the system (default `Served`).
    pub outcome: Outcome,
    /// Tokens the request *asked* for — the goodput denominator. Equals
    /// `output_tokens` for served requests; larger for shed/preempted
    /// ones (which deliver fewer than requested).
    pub target_tokens: usize,
}

impl RequestRecord {
    /// Time the request waited in the queue before a slot freed up.
    pub fn queue_wait(&self) -> f64 {
        self.admit - self.arrival
    }

    /// Time to first token, measured from *arrival* (so it includes the
    /// queueing delay — the RQ2 budget is about what the user waits for).
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    /// Seconds per output token over the decode phase after the first
    /// token (0 for single-token outputs).
    pub fn tpot(&self) -> f64 {
        if self.output_tokens <= 1 {
            0.0
        } else {
            (self.finish - self.first_token) / (self.output_tokens - 1) as f64
        }
    }

    /// Did this request meet its SLO? Requests with no SLO attain
    /// trivially; shed/preempted requests never attain (they did not
    /// deliver what was asked).
    pub fn attained(&self) -> bool {
        if self.outcome != Outcome::Served {
            return false;
        }
        match self.slo {
            None => true,
            Some(slo) => self.ttft() <= slo.ttft && self.tpot() <= slo.tpot,
        }
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut pairs = vec![
            ("id", Json::Num(self.id as f64)),
            ("arrival", Json::Num(self.arrival)),
            ("admit", Json::Num(self.admit)),
            ("first_token", Json::Num(self.first_token)),
            ("finish", Json::Num(self.finish)),
            ("prompt_tokens", Json::Num(self.prompt_tokens as f64)),
            ("output_tokens", Json::Num(self.output_tokens as f64)),
            ("queue_wait_secs", Json::Num(self.queue_wait())),
            ("ttft_secs", Json::Num(self.ttft())),
            ("tpot_secs", Json::Num(self.tpot())),
        ];
        // SLO keys are strictly additive: a no-SLO served record — every
        // record before this PR — serializes byte-identically to the
        // pre-SLO schema. Infinite deadlines stay absent (JSON has no
        // Infinity; absent means "no bound").
        if let Some(slo) = self.slo {
            pairs.push(("slo_tier", Json::Str(slo.tier.key().into())));
            if slo.ttft.is_finite() {
                pairs.push(("slo_ttft_secs", Json::Num(slo.ttft)));
            }
            if slo.tpot.is_finite() {
                pairs.push(("slo_tpot_secs", Json::Num(slo.tpot)));
            }
            pairs.push(("slo_attained", Json::Bool(self.attained())));
        }
        if self.outcome != Outcome::Served {
            pairs.push(("outcome", Json::Str(self.outcome.key().into())));
        }
        if self.target_tokens != self.output_tokens {
            pairs.push(("target_tokens", Json::Num(self.target_tokens as f64)));
        }
        Json::obj(pairs)
    }
}

/// Goodput: the fraction of *requested* tokens delivered within SLO —
/// Σ output_tokens over SLO-attained requests / Σ target_tokens over all
/// requests. `None` when no record carries an SLO (the metric is
/// undefined, and absent keys keep pre-SLO bench.json valid); always in
/// `[0, 1]` otherwise. This is the number the scheduler comparison
/// decides on: a scheduler that sheds a doomed request early loses its
/// tokens from the numerator but frees capacity that keeps *other*
/// requests inside their deadlines.
pub fn goodput(records: &[RequestRecord]) -> Option<f64> {
    if records.iter().all(|r| r.slo.is_none()) {
        return None;
    }
    let target: usize = records.iter().map(|r| r.target_tokens).sum();
    if target == 0 {
        return Some(1.0);
    }
    let attained: usize = records
        .iter()
        .filter(|r| r.attained())
        .map(|r| r.output_tokens)
        .sum();
    Some(attained as f64 / target as f64)
}

/// The daemon's live MBU cross-check (DESIGN.md §10): rescale a
/// model-*predicted* MBU by the ratio of predicted to *measured* TPOT.
/// Both MBU terms price the same bytes over the same peak bandwidth, so
/// the bytes cancel and
///
///   measured_mbu = predicted_mbu · predicted_tpot / measured_tpot
///
/// holds exactly — a wall-clock daemon that decodes slower than the
/// roofline predicted reports proportionally lower achieved bandwidth
/// utilization, without re-measuring byte traffic on the hot path.
/// `None` when either TPOT is non-positive or non-finite (nothing
/// decoded yet, or the measurement clock has not advanced).
pub fn mbu_cross_check(
    predicted_tpot: f64,
    measured_tpot: f64,
    predicted_mbu: f64,
) -> Option<f64> {
    if !(predicted_tpot > 0.0) || !(measured_tpot > 0.0) {
        return None;
    }
    if !predicted_tpot.is_finite() || !measured_tpot.is_finite() {
        return None;
    }
    Some(predicted_mbu * predicted_tpot / measured_tpot)
}

/// Per-tier SLO attainment: request and token counts per populated tier.
#[derive(Clone, Debug, PartialEq)]
pub struct TierAttainment {
    pub tier: SloTier,
    pub requests: usize,
    pub attained_requests: usize,
    pub target_tokens: usize,
    pub attained_tokens: usize,
}

impl TierAttainment {
    /// Token-level attainment fraction within the tier.
    pub fn token_fraction(&self) -> f64 {
        if self.target_tokens == 0 {
            1.0
        } else {
            self.attained_tokens as f64 / self.target_tokens as f64
        }
    }
}

/// Per-tier attainment rollup, tiers in `SloTier::ALL` order, unpopulated
/// tiers omitted. Empty when no record carries an SLO.
pub fn tier_attainment(records: &[RequestRecord]) -> Vec<TierAttainment> {
    SloTier::ALL
        .iter()
        .filter_map(|&tier| {
            let mut a = TierAttainment {
                tier,
                requests: 0,
                attained_requests: 0,
                target_tokens: 0,
                attained_tokens: 0,
            };
            for r in records {
                if r.slo.map(|s| s.tier) != Some(tier) {
                    continue;
                }
                a.requests += 1;
                a.target_tokens += r.target_tokens;
                if r.attained() {
                    a.attained_requests += 1;
                    a.attained_tokens += r.output_tokens;
                }
            }
            (a.requests > 0).then_some(a)
        })
        .collect()
}

/// Fleet-wide MBU: the traffic-weighted mean of per-replica
/// MBU-under-load, one `(processed_tokens, mbu_mean)` pair per replica.
/// Weighting by processed tokens makes the rollup answer "how well did
/// the *traffic* use the fleet's bandwidth" — an idle replica cannot
/// dilute it, and a replica that carried most of the load dominates it.
/// Replicas with no token-generating steps (`mbu_mean == None`) carry
/// no weight; `None` when no replica generated tokens — serialized as
/// `null`, never a fake 0.0 (the bench.json / fleet.json convention).
pub fn fleet_mbu(cells: &[(usize, Option<f64>)]) -> Option<f64> {
    let mut weight = 0.0;
    let mut acc = 0.0;
    for &(tokens, mbu) in cells {
        if let Some(m) = mbu {
            weight += tokens as f64;
            acc += tokens as f64 * m;
        }
    }
    if weight > 0.0 {
        Some(acc / weight)
    } else {
        None
    }
}

/// One fleet-sweep cell's comparative serving metrics: what the shared
/// request trace cost on one (device, accelerator, quant) combination,
/// or why the combination was never run (`feasible == false` — the
/// RAM-capacity admission gate rejected the 7B-scale deployment).
/// Latency summaries are `None` exactly when infeasible.
#[derive(Clone, Debug)]
pub struct FleetCellMetrics {
    pub device: String,
    pub platform: String,
    /// "CPU" / "GPU" (Table-6 accelerator column).
    pub accelerator: String,
    /// Framework label ("None" / "OpenBLAS" / "Metal" / ...).
    pub framework: String,
    /// Stable accel key ("none" / "blas" / "gpu") for machine readers.
    pub accel_key: String,
    pub quant: String,
    pub feasible: bool,
    /// 7B-scale deployment footprint the admission gate priced.
    pub need_ram_bytes: u64,
    pub ram_bytes: u64,
    pub throughput_tok_s: Option<f64>,
    pub ttft: Option<crate::util::stats::Summary>,
    pub tpot: Option<crate::util::stats::Summary>,
    pub queue_wait: Option<crate::util::stats::Summary>,
    pub mbu_mean: Option<f64>,
    pub mbu_max: Option<f64>,
    pub makespan_secs: Option<f64>,
    pub output_tokens: Option<usize>,
    /// Token-stream fingerprint (fleet.json determinism is `cmp`-checked
    /// in CI, and this pins the numerics per cell).
    pub tokens_fnv: Option<String>,
    /// Peak paged-KV pool occupancy (peak blocks in use / blocks total),
    /// `None` for infeasible cells or slot-layout runs.
    pub kv_pool_occupancy: Option<f64>,
    /// Bytes of KV writes avoided by copy-on-write prefix sharing.
    pub kv_prefix_share_bytes: Option<u64>,
    /// SLO-attained token fraction, `None` when the trace carries no
    /// SLOs (or the cell is infeasible) — serialized as `null`, never a
    /// fake 0.0, mirroring the MBU convention.
    pub goodput: Option<f64>,
}

impl FleetCellMetrics {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let sum = |s: &crate::util::stats::Summary| {
            Json::obj(vec![
                ("mean", Json::Num(s.mean)),
                ("p50", Json::Num(s.p50)),
                ("p95", Json::Num(s.p95)),
                ("p99", Json::Num(s.p99)),
                ("max", Json::Num(s.max)),
            ])
        };
        let mut pairs = vec![
            ("device", Json::Str(self.device.clone())),
            ("platform", Json::Str(self.platform.clone())),
            ("accelerator", Json::Str(self.accelerator.clone())),
            ("framework", Json::Str(self.framework.clone())),
            ("accel", Json::Str(self.accel_key.clone())),
            ("quant", Json::Str(self.quant.clone())),
            ("feasible", Json::Bool(self.feasible)),
            ("need_ram_bytes", Json::Num(self.need_ram_bytes as f64)),
            ("ram_bytes", Json::Num(self.ram_bytes as f64)),
            // MBU is always present: `null` for infeasible cells and for
            // served cells with no token-generating steps — the same
            // convention as bench.json's aggregate, never a fake 0.0.
            ("mbu_mean", self.mbu_mean.map_or(Json::Null, Json::Num)),
            ("mbu_max", self.mbu_max.map_or(Json::Null, Json::Num)),
            // Paged-KV pool footprint: `null` when the cell never ran
            // (infeasible) — same convention as MBU.
            (
                "kv_pool_occupancy",
                self.kv_pool_occupancy.map_or(Json::Null, Json::Num),
            ),
            (
                "kv_prefix_share_bytes",
                self.kv_prefix_share_bytes
                    .map_or(Json::Null, |b| Json::Num(b as f64)),
            ),
            // Goodput: `null` for infeasible cells and for traces with no
            // SLOs — the same never-a-fake-0.0 convention as MBU.
            ("goodput", self.goodput.map_or(Json::Null, Json::Num)),
        ];
        if let (Some(tput), Some(ttft), Some(tpot), Some(wait)) = (
            self.throughput_tok_s,
            self.ttft.as_ref(),
            self.tpot.as_ref(),
            self.queue_wait.as_ref(),
        ) {
            pairs.push(("throughput_tok_s", Json::Num(tput)));
            pairs.push(("ttft", sum(ttft)));
            pairs.push(("tpot", sum(tpot)));
            pairs.push(("queue_wait", sum(wait)));
            pairs.push((
                "makespan_secs",
                Json::Num(self.makespan_secs.unwrap_or(0.0)),
            ));
            pairs.push((
                "output_tokens",
                Json::Num(self.output_tokens.unwrap_or(0) as f64),
            ));
            if let Some(fnv) = &self.tokens_fnv {
                pairs.push(("tokens_fnv", Json::Str(fnv.clone())));
            }
        }
        crate::util::json::Json::obj(pairs)
    }
}

/// One complete Table-6 row worth of measurements.
#[derive(Clone, Debug)]
pub struct MetricsRecord {
    pub device: String,
    pub os: String,
    pub accelerator: String,
    pub framework: String,
    pub qtype: QuantType,
    pub flops_t4_giga: f64,
    pub flops_t8_giga: f64,
    pub throughput_tok_s: f64,
    pub ttlm_secs: f64,
    pub ttft_secs: f64,
    pub mbu: f64,
    pub ppl: f64,
}

impl MetricsRecord {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("device", Json::Str(self.device.clone())),
            ("os", Json::Str(self.os.clone())),
            ("accelerator", Json::Str(self.accelerator.clone())),
            ("framework", Json::Str(self.framework.clone())),
            ("quant", Json::Str(self.qtype.name().into())),
            ("flops_t4_giga", Json::Num(self.flops_t4_giga)),
            ("flops_t8_giga", Json::Num(self.flops_t8_giga)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s)),
            ("ttlm_secs", Json::Num(self.ttlm_secs)),
            ("ttft_secs", Json::Num(self.ttft_secs)),
            ("mbu", Json::Num(self.mbu)),
            ("ppl", Json::Num(self.ppl)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_mbu_weights_by_traffic_and_ignores_idle_replicas() {
        // 100 tokens at MBU 0.8 and 300 tokens at MBU 0.4:
        // (100·0.8 + 300·0.4) / 400 = 0.5.
        let m = fleet_mbu(&[(100, Some(0.8)), (300, Some(0.4))]).unwrap();
        assert!((m - 0.5).abs() < 1e-12, "{m}");
        // An idle replica (no token-generating steps) carries no weight.
        let m = fleet_mbu(&[(100, Some(0.8)), (0, None), (999, None)]).unwrap();
        assert!((m - 0.8).abs() < 1e-12, "{m}");
        // No replica generated tokens: None, never a fake 0.0.
        assert_eq!(fleet_mbu(&[(5, None)]), None);
        assert_eq!(fleet_mbu(&[]), None);
    }

    #[test]
    fn mbu_definition() {
        // 4 GB of params+kv per token at 100 ms/token = 40 GB/s achieved;
        // on a 50 GB/s device that's MBU = 0.8.
        let m = mbu(4_000_000_000, 0, 0.1, 50_000_000_000.0);
        assert!((m - 0.8).abs() < 1e-9);
    }

    #[test]
    fn mbu_paper_example_shape() {
        // Paper's motivating shape: faster TPOT on the same model -> higher
        // MBU; bigger model at the same TPOT -> higher MBU.
        let base = mbu(3_500_000_000, 0, 0.5, 34e9);
        assert!(mbu(3_500_000_000, 0, 0.25, 34e9) > base);
        assert!(mbu(6_700_000_000, 0, 0.5, 34e9) > base);
    }

    #[test]
    fn mbu_guards_degenerate_inputs() {
        assert_eq!(mbu(1, 1, 0.0, 1.0), 0.0);
        assert_eq!(mbu(1, 1, 1.0, 0.0), 0.0);
    }

    #[test]
    fn mbu_cross_check_rescales_by_the_tpot_ratio() {
        // A daemon that decodes exactly at the predicted rate reports
        // the predicted MBU; one decoding 2x slower reports half.
        let same = mbu_cross_check(0.1, 0.1, 0.8).unwrap();
        assert!((same - 0.8).abs() < 1e-12);
        let slow = mbu_cross_check(0.1, 0.2, 0.8).unwrap();
        assert!((slow - 0.4).abs() < 1e-12);
        // Equivalence with re-deriving MBU from bytes: same bytes, the
        // measured TPOT substituted — the bytes cancel in the ratio.
        let predicted = mbu(4_000_000_000, 0, 0.1, 50e9);
        let direct = mbu(4_000_000_000, 0, 0.25, 50e9);
        let scaled = mbu_cross_check(0.1, 0.25, predicted).unwrap();
        assert!((scaled - direct).abs() < 1e-12);
        // Degenerate measurements stay None, never fake zeros.
        assert_eq!(mbu_cross_check(0.0, 0.1, 0.8), None);
        assert_eq!(mbu_cross_check(0.1, 0.0, 0.8), None);
        assert_eq!(mbu_cross_check(f64::INFINITY, 0.1, 0.8), None);
        assert_eq!(mbu_cross_check(0.1, f64::NAN, 0.8), None);
    }

    #[test]
    fn perplexity_uniform_256() {
        // Mean NLL of ln(256) => ppl 256.
        let nll = (256f64).ln() * 10.0;
        assert!((perplexity(nll, 10) - 256.0).abs() < 1e-6);
        assert_eq!(perplexity(1.0, 0), f64::INFINITY);
    }

    #[test]
    fn tpot_is_inverse_throughput() {
        let thr = throughput(20, 4.0); // 5 tok/s
        assert!((thr - 5.0).abs() < 1e-12);
        assert!((tpot(thr) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn total_latency_rq2() {
        // TTFT 2s + 100 tokens at 50ms = 7s.
        assert!((total_latency(2.0, 0.05, 100) - 7.0).abs() < 1e-9);
    }

    /// A served no-SLO record: the fields this PR added must all stay
    /// out of the JSON (byte-compatibility with pre-SLO bench.json).
    fn served(id: usize, arrival: f64, first_token: f64, finish: f64, out: usize) -> RequestRecord {
        RequestRecord {
            id,
            arrival,
            admit: arrival,
            first_token,
            finish,
            prompt_tokens: 2,
            output_tokens: out,
            slo: None,
            outcome: Outcome::Served,
            target_tokens: out,
        }
    }

    #[test]
    fn request_record_latencies() {
        let r = RequestRecord {
            id: 3,
            arrival: 1.0,
            admit: 1.5,
            first_token: 2.0,
            finish: 4.0,
            prompt_tokens: 8,
            output_tokens: 5,
            slo: None,
            outcome: Outcome::Served,
            target_tokens: 5,
        };
        assert!((r.queue_wait() - 0.5).abs() < 1e-12);
        assert!((r.ttft() - 1.0).abs() < 1e-12, "ttft counts from arrival");
        assert!((r.tpot() - 0.5).abs() < 1e-12, "4 intervals over 2s");
        let j = r.to_json();
        assert_eq!(j.get("ttft_secs").and_then(|v| v.as_f64()), Some(1.0));
        assert_eq!(j.get("output_tokens").and_then(|v| v.as_f64()), Some(5.0));
        // No SLO, served, full delivery: the additive keys stay absent.
        for absent in ["slo_tier", "slo_ttft_secs", "slo_attained", "outcome", "target_tokens"] {
            assert!(j.get(absent).is_none(), "{absent} must be absent");
        }
    }

    /// The DESIGN.md §5 worked example, computed by hand: three requests
    /// with SLOs — one attained, one served-but-late, one shed — give
    /// goodput 8/20 = 0.40.
    #[test]
    fn goodput_worked_example_from_design_md() {
        let slo = |tier: SloTier, ttft: f64, tpot: f64| Some(Slo { tier, ttft, tpot });
        let records = vec![
            // A: interactive, ttft 0.8 ≤ 1.0, tpot 0.05 ≤ 0.1 → attained, 8 tokens.
            RequestRecord {
                slo: slo(SloTier::Interactive, 1.0, 0.1),
                ..served(0, 0.0, 0.8, 1.15, 8)
            },
            // B: interactive, served but ttft 1.5 > 1.0 → missed, 6 tokens lost.
            RequestRecord {
                slo: slo(SloTier::Interactive, 1.0, 0.1),
                ..served(1, 0.0, 1.5, 1.75, 6)
            },
            // C: standard, shed before admission → 0 of its 6 tokens.
            RequestRecord {
                slo: slo(SloTier::Standard, 4.0, 0.4),
                outcome: Outcome::Shed,
                output_tokens: 0,
                target_tokens: 6,
                ..served(2, 0.0, 5.0, 5.0, 0)
            },
        ];
        let g = goodput(&records).unwrap();
        assert!((g - 8.0 / 20.0).abs() < 1e-12, "goodput {g}");
        let tiers = tier_attainment(&records);
        assert_eq!(tiers.len(), 2);
        assert_eq!(tiers[0].tier, SloTier::Interactive);
        assert_eq!((tiers[0].requests, tiers[0].attained_requests), (2, 1));
        assert_eq!((tiers[0].target_tokens, tiers[0].attained_tokens), (14, 8));
        assert_eq!(tiers[1].tier, SloTier::Standard);
        assert_eq!((tiers[1].requests, tiers[1].attained_requests), (1, 0));
        assert!((tiers[1].token_fraction() - 0.0).abs() < 1e-12);
    }

    #[test]
    fn goodput_bounds_and_infinite_deadlines() {
        // No SLOs anywhere: undefined.
        assert_eq!(goodput(&[served(0, 0.0, 1.0, 2.0, 4)]), None);
        assert_eq!(goodput(&[]), None);
        // All deadlines infinite and everything served: exactly 1.0.
        let inf = Some(Slo {
            tier: SloTier::Batch,
            ttft: f64::INFINITY,
            tpot: f64::INFINITY,
        });
        let relaxed: Vec<RequestRecord> = (0..5)
            .map(|i| RequestRecord {
                slo: inf,
                ..served(i, i as f64, i as f64 + 100.0, i as f64 + 200.0, 3)
            })
            .collect();
        assert_eq!(goodput(&relaxed), Some(1.0));
        // Infinite deadlines serialize as absent keys (JSON has no inf),
        // but the tier and the attainment verdict still appear.
        let j = relaxed[0].to_json();
        assert!(j.get("slo_ttft_secs").is_none());
        assert!(j.get("slo_tpot_secs").is_none());
        assert_eq!(j.get("slo_tier").and_then(|v| v.as_str()), Some("batch"));
        assert_eq!(j.get("slo_attained").and_then(|v| v.as_bool()), Some(true));
        // Everything shed: exactly 0.0; still within [0,1].
        let all_shed: Vec<RequestRecord> = (0..3)
            .map(|i| RequestRecord {
                slo: Some(Slo { tier: SloTier::Interactive, ttft: 0.1, tpot: 0.1 }),
                outcome: Outcome::Shed,
                output_tokens: 0,
                target_tokens: 4,
                ..served(i, 0.0, 1.0, 1.0, 0)
            })
            .collect();
        assert_eq!(goodput(&all_shed), Some(0.0));
        let j = all_shed[0].to_json();
        assert_eq!(j.get("outcome").and_then(|v| v.as_str()), Some("shed"));
        assert_eq!(j.get("target_tokens").and_then(|v| v.as_f64()), Some(4.0));
        assert_eq!(j.get("slo_attained").and_then(|v| v.as_bool()), Some(false));
    }

    #[test]
    fn tier_multipliers_relax_monotonically() {
        assert_eq!(SloTier::Interactive.multiplier(), 1.0);
        assert!(SloTier::Standard.multiplier() > SloTier::Interactive.multiplier());
        assert!(SloTier::Batch.multiplier() > SloTier::Standard.multiplier());
    }

    #[test]
    fn fleet_cell_json_shape_tracks_feasibility() {
        use crate::util::stats::Summary;
        let s = Summary::of(&[0.1, 0.2, 0.3]);
        let mut cell = FleetCellMetrics {
            device: "NanoPI".into(),
            platform: "IoT".into(),
            accelerator: "CPU".into(),
            framework: "OpenBLAS".into(),
            accel_key: "blas".into(),
            quant: "q4_0".into(),
            feasible: true,
            need_ram_bytes: 10,
            ram_bytes: 20,
            throughput_tok_s: Some(12.5),
            ttft: Some(s.clone()),
            tpot: Some(s.clone()),
            queue_wait: Some(s),
            mbu_mean: Some(0.6),
            mbu_max: Some(0.9),
            makespan_secs: Some(3.0),
            output_tokens: Some(100),
            tokens_fnv: Some("abc".into()),
            kv_pool_occupancy: Some(0.75),
            kv_prefix_share_bytes: Some(4096),
            goodput: Some(0.875),
        };
        let j = cell.to_json();
        let p95 = j.at(&["ttft", "p95"]).and_then(|v| v.as_f64()).unwrap();
        assert!((p95 - 0.29).abs() < 1e-12, "{p95}");
        assert_eq!(j.get("feasible").and_then(|v| v.as_bool()), Some(true));
        assert!(j.get("tokens_fnv").is_some());
        assert_eq!(j.get("mbu_mean").and_then(|v| v.as_f64()), Some(0.6));
        assert_eq!(
            j.get("kv_pool_occupancy").and_then(|v| v.as_f64()),
            Some(0.75)
        );
        assert_eq!(
            j.get("kv_prefix_share_bytes").and_then(|v| v.as_f64()),
            Some(4096.0)
        );
        assert_eq!(j.get("goodput").and_then(|v| v.as_f64()), Some(0.875));
        // Infeasible cells carry the capacity evidence plus a `null` MBU
        // (same convention as bench.json — never a fake 0.0).
        cell.feasible = false;
        cell.throughput_tok_s = None;
        cell.mbu_mean = None;
        cell.mbu_max = None;
        cell.kv_pool_occupancy = None;
        cell.kv_prefix_share_bytes = None;
        cell.goodput = None;
        let j = cell.to_json();
        assert!(j.get("ttft").is_none());
        assert!(j.get("throughput_tok_s").is_none());
        assert_eq!(j.get("mbu_mean"), Some(&crate::util::json::Json::Null));
        assert_eq!(j.get("mbu_max"), Some(&crate::util::json::Json::Null));
        assert_eq!(
            j.get("kv_pool_occupancy"),
            Some(&crate::util::json::Json::Null)
        );
        assert_eq!(j.get("goodput"), Some(&crate::util::json::Json::Null));
        assert_eq!(j.get("need_ram_bytes").and_then(|v| v.as_f64()), Some(10.0));
    }

    #[test]
    fn request_record_single_token_tpot_is_zero() {
        let r = RequestRecord {
            id: 0,
            arrival: 0.0,
            admit: 0.0,
            first_token: 1.0,
            finish: 1.0,
            prompt_tokens: 2,
            output_tokens: 1,
            slo: None,
            outcome: Outcome::Served,
            target_tokens: 1,
        };
        assert_eq!(r.tpot(), 0.0);
    }
}
