//! Property-based testing kit (no proptest offline).
//!
//! A deliberately small subset of proptest's model: seeded generators, a
//! configurable case count, and first-failure reporting with the seed so a
//! failure reproduces with `ELIB_PROP_SEED=<seed>`. Used across the quant,
//! coordinator and metrics modules for invariant testing.

use crate::util::rng::Rng;

/// Number of cases per property (override with ELIB_PROP_CASES).
pub fn default_cases() -> usize {
    std::env::var("ELIB_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("ELIB_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xE11B)
}

/// Run `prop(rng, case_index)`; panics with the reproducing seed on failure.
pub fn check<F>(name: &str, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let cases = default_cases();
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, case) {
            panic!(
                "property `{name}` failed at case {case}/{cases}: {msg}\n\
                 reproduce with ELIB_PROP_SEED={seed0} ELIB_PROP_CASES={cases}"
            );
        }
    }
}

/// Generators.
pub mod gen {
    use crate::util::rng::Rng;

    /// Vec of f32 with magnitudes spanning subnormal-ish to large, plus
    /// occasional exact zeros — the distribution quantizers hate most.
    pub fn f32_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len)
            .map(|_| {
                if rng.bool(0.05) {
                    0.0
                } else {
                    let mag = 10f32.powf(rng.range_f32(-4.0, 3.0));
                    let sign = if rng.bool(0.5) { 1.0 } else { -1.0 };
                    sign * mag * rng.range_f32(0.5, 1.0)
                }
            })
            .collect()
    }

    /// Well-behaved activations (unit-ish scale, as produced by rmsnorm).
    pub fn activations(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal()).collect()
    }

    /// A length that is a multiple of `m`, in [m, max].
    pub fn multiple_of(rng: &mut Rng, m: usize, max: usize) -> usize {
        let k = rng.range_u64(1, (max / m) as u64 + 1) as usize;
        k * m
    }

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        rng.range_u64(lo as u64, hi as u64 + 1) as usize
    }

    /// Index into `weights`, drawn proportionally to the weight values;
    /// zero-weight arms are never picked.
    pub fn weighted(rng: &mut Rng, weights: &[u32]) -> usize {
        let total: u64 = weights.iter().map(|&w| w as u64).sum();
        assert!(total > 0, "weighted choice needs a positive total weight");
        let mut roll = rng.below(total);
        for (i, &w) in weights.iter().enumerate() {
            if roll < w as u64 {
                return i;
            }
            roll -= w as u64;
        }
        unreachable!("roll below total always lands in an arm")
    }

    /// A seeded op sequence for stateful-API property tests: `n` ops,
    /// each arm `i` of `weights` picked with probability
    /// `weights[i]/Σweights`, materialized by `make(rng, arm)` (which
    /// draws the arm's operands from the same stream). This is the core
    /// the paged-KV allocator suite drives its op enum through; any
    /// stateful API with an oracle can reuse it.
    pub fn op_sequence<T>(
        rng: &mut Rng,
        n: usize,
        weights: &[u32],
        mut make: impl FnMut(&mut Rng, usize) -> T,
    ) -> Vec<T> {
        (0..n)
            .map(|_| {
                let arm = weighted(rng, weights);
                make(rng, arm)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", |rng, _| {
            let x = rng.next_f32();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("x out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn check_reports_failures() {
        check("always-fails", |_, _| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..100 {
            let m = gen::multiple_of(&mut rng, 32, 512);
            assert!(m % 32 == 0 && (32..=512).contains(&m));
            let u = gen::usize_in(&mut rng, 3, 9);
            assert!((3..=9).contains(&u));
        }
    }

    #[test]
    fn weighted_choice_skips_zero_arms_and_hits_positive_ones() {
        let mut rng = crate::util::rng::Rng::new(2);
        let mut hits = [0usize; 4];
        for _ in 0..400 {
            hits[gen::weighted(&mut rng, &[3, 0, 1, 0])] += 1;
        }
        assert_eq!(hits[1], 0, "zero-weight arm picked");
        assert_eq!(hits[3], 0, "zero-weight arm picked");
        assert!(hits[0] > hits[2], "3:1 weights should order the counts");
        assert!(hits[2] > 0, "positive arm never picked");
    }

    #[test]
    fn op_sequence_is_deterministic_per_seed() {
        let run = || {
            let mut rng = crate::util::rng::Rng::new(77);
            gen::op_sequence(&mut rng, 50, &[2, 1], |rng, arm| {
                (arm, gen::usize_in(rng, 0, 9))
            })
        };
        let a = run();
        assert_eq!(a.len(), 50);
        assert_eq!(a, run(), "same seed must replay the same ops");
        assert!(a.iter().any(|&(arm, _)| arm == 1));
    }
}
