//! Backend dispatch with naive fallback (paper §4.1: "when optimized
//! kernels are not available, the system will directly fall back to
//! running on the naive kernel").

use std::sync::Arc;

use crate::quant::QTensor;

use super::backends::{GpuBackend, NaiveBackend, ParallelBackend, Precision};
use super::{Kernels, Op};

/// Which backend a deployment requests (maps to Table 6's
/// Accelerator/Framework columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// CPU, no acceleration framework.
    Naive,
    /// CPU + BLAS-like acceleration with `n` threads.
    Parallel(usize),
    /// Hybrid GPU offload; `Precision::DegradedF16` models the OpenCL path.
    Gpu(Precision),
}

impl BackendKind {
    pub fn label(&self) -> String {
        match self {
            BackendKind::Naive => "cpu/none".into(),
            BackendKind::Parallel(n) => format!("cpu/blas(t{n})"),
            BackendKind::Gpu(Precision::Full) => "gpu/full".into(),
            BackendKind::Gpu(Precision::DegradedF16) => "gpu/opencl".into(),
        }
    }
}

/// Routes ops to the preferred backend, falling back to naive when the
/// preferred backend does not support an op. Also counts fallbacks so
/// tests and reports can observe routing.
pub struct Dispatcher {
    preferred: Arc<dyn Kernels>,
    naive: NaiveBackend,
    fallbacks: std::sync::atomic::AtomicU64,
    kind: BackendKind,
}

impl Dispatcher {
    pub fn new(kind: BackendKind) -> Self {
        let preferred: Arc<dyn Kernels> = match kind {
            BackendKind::Naive => Arc::new(NaiveBackend),
            BackendKind::Parallel(n) => Arc::new(ParallelBackend::new(n)),
            BackendKind::Gpu(p) => Arc::new(GpuBackend::new(8, p)),
        };
        Self {
            preferred,
            naive: NaiveBackend,
            fallbacks: std::sync::atomic::AtomicU64::new(0),
            kind,
        }
    }

    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    pub fn backend_name(&self) -> &'static str {
        self.preferred.name()
    }

    pub fn fallback_count(&self) -> u64 {
        self.fallbacks.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn pick(&self, op: Op) -> &dyn Kernels {
        if self.preferred.supports(op) {
            self.preferred.as_ref()
        } else {
            self.fallbacks
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            &self.naive
        }
    }

    pub fn qmatvec(&self, w: &QTensor, x: &[f32], out: &mut [f32]) {
        self.pick(Op::QMatVec).qmatvec(w, x, out)
    }

    pub fn rmsnorm(&self, x: &mut [f32], weight: &[f32], eps: f32) {
        self.pick(Op::RmsNorm).rmsnorm(x, weight, eps)
    }

    pub fn softmax(&self, x: &mut [f32]) {
        self.pick(Op::Softmax).softmax(x)
    }

    pub fn rope(&self, x: &mut [f32], pos: usize, theta: f32) {
        self.pick(Op::Rope).rope(x, pos, theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantType;
    use crate::util::rng::Rng;

    #[test]
    fn parallel_falls_back_for_rmsnorm() {
        let d = Dispatcher::new(BackendKind::Parallel(2));
        let mut x = vec![2.0f32; 8];
        let w = vec![1.0f32; 8];
        assert_eq!(d.fallback_count(), 0);
        d.rmsnorm(&mut x, &w, 1e-5);
        assert_eq!(d.fallback_count(), 1, "rmsnorm should fall back to naive");
    }

    #[test]
    fn qmatvec_no_fallback_on_parallel() {
        let mut rng = Rng::new(2);
        let w = QTensor::quantize(QuantType::Q8_0, &rng.normal_vec(32 * 4, 0.1), 4, 32);
        let x = rng.normal_vec(32, 1.0);
        let mut out = vec![0f32; 4];
        let d = Dispatcher::new(BackendKind::Parallel(2));
        d.qmatvec(&w, &x, &mut out);
        assert_eq!(d.fallback_count(), 0);
    }

    #[test]
    fn all_kinds_produce_same_qmatvec_except_degraded() {
        let mut rng = Rng::new(3);
        let w = QTensor::quantize(QuantType::Q5_1, &rng.normal_vec(64 * 16, 0.1), 16, 64);
        let x = rng.normal_vec(64, 1.0);
        let mut base = vec![0f32; 16];
        Dispatcher::new(BackendKind::Naive).qmatvec(&w, &x, &mut base);
        for kind in [
            BackendKind::Parallel(3),
            BackendKind::Gpu(Precision::Full),
        ] {
            let mut out = vec![0f32; 16];
            Dispatcher::new(kind).qmatvec(&w, &x, &mut out);
            assert!(
                crate::util::stats::max_abs_diff(&base, &out) < 1e-6,
                "{:?}",
                kind
            );
        }
        let mut out = vec![0f32; 16];
        Dispatcher::new(BackendKind::Gpu(Precision::DegradedF16)).qmatvec(&w, &x, &mut out);
        assert!(crate::util::stats::max_abs_diff(&base, &out) > 0.0);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(BackendKind::Naive.label(), "cpu/none");
        assert_eq!(BackendKind::Parallel(4).label(), "cpu/blas(t4)");
        assert_eq!(BackendKind::Gpu(Precision::DegradedF16).label(), "gpu/opencl");
    }
}
