//! Backend dispatch with naive fallback (paper §4.1: "when optimized
//! kernels are not available, the system will directly fall back to
//! running on the naive kernel").

use std::sync::Arc;

use crate::quant::QTensor;

use super::backends::{GpuBackend, NaiveBackend, ParallelBackend, Precision};
use super::{Kernels, Op};

/// Which backend a deployment requests (maps to Table 6's
/// Accelerator/Framework columns).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// CPU, no acceleration framework.
    Naive,
    /// CPU + BLAS-like acceleration with `n` threads.
    Parallel(usize),
    /// Hybrid GPU offload; `Precision::DegradedF16` models the OpenCL path.
    Gpu(Precision),
}

impl BackendKind {
    pub fn label(&self) -> String {
        match self {
            BackendKind::Naive => "cpu/none".into(),
            BackendKind::Parallel(n) => format!("cpu/blas(t{n})"),
            BackendKind::Gpu(Precision::Full) => "gpu/full".into(),
            BackendKind::Gpu(Precision::DegradedF16) => "gpu/opencl".into(),
        }
    }
}

/// Routes ops to the preferred backend, falling back to naive when the
/// preferred backend does not support an op. Also counts fallbacks so
/// tests and reports can observe routing.
pub struct Dispatcher {
    preferred: Arc<dyn Kernels>,
    naive: NaiveBackend,
    fallbacks: std::sync::atomic::AtomicU64,
    kind: BackendKind,
}

impl Dispatcher {
    pub fn new(kind: BackendKind) -> Self {
        let preferred: Arc<dyn Kernels> = match kind {
            BackendKind::Naive => Arc::new(NaiveBackend),
            BackendKind::Parallel(n) => Arc::new(ParallelBackend::new(n)),
            BackendKind::Gpu(p) => Arc::new(GpuBackend::new(8, p)),
        };
        Self {
            preferred,
            naive: NaiveBackend,
            fallbacks: std::sync::atomic::AtomicU64::new(0),
            kind,
        }
    }

    pub fn kind(&self) -> BackendKind {
        self.kind
    }

    pub fn backend_name(&self) -> &'static str {
        self.preferred.name()
    }

    pub fn fallback_count(&self) -> u64 {
        self.fallbacks.load(std::sync::atomic::Ordering::Relaxed)
    }

    fn pick(&self, op: Op) -> &dyn Kernels {
        if self.preferred.supports(op) {
            self.preferred.as_ref()
        } else {
            self.fallbacks
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            &self.naive
        }
    }

    pub fn qmatvec(&self, w: &QTensor, x: &[f32], out: &mut [f32]) {
        self.pick(Op::QMatVec).qmatvec(w, x, out)
    }

    /// Batched mat-vec: `xs` holds `batch` activation vectors of `w.cols`
    /// back to back, `out` receives `batch` result vectors of `w.rows`.
    /// Each slot runs the exact same backend kernel as the single-sequence
    /// path (bitwise parity with `batch` independent `qmatvec` calls); the
    /// weight matrix is routed through once per step, which is what the
    /// engine's traffic ledger charges for.
    pub fn qmatvec_batch(&self, w: &QTensor, xs: &[f32], out: &mut [f32], batch: usize) {
        assert_eq!(xs.len(), w.cols * batch, "qmatvec_batch xs len");
        assert_eq!(out.len(), w.rows * batch, "qmatvec_batch out len");
        let k = self.pick(Op::QMatVec);
        for s in 0..batch {
            k.qmatvec(
                w,
                &xs[s * w.cols..(s + 1) * w.cols],
                &mut out[s * w.rows..(s + 1) * w.rows],
            );
        }
    }

    pub fn rmsnorm(&self, x: &mut [f32], weight: &[f32], eps: f32) {
        self.pick(Op::RmsNorm).rmsnorm(x, weight, eps)
    }

    pub fn softmax(&self, x: &mut [f32]) {
        self.pick(Op::Softmax).softmax(x)
    }

    pub fn rope(&self, x: &mut [f32], pos: usize, theta: f32) {
        self.pick(Op::Rope).rope(x, pos, theta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantType;
    use crate::util::rng::Rng;

    #[test]
    fn parallel_falls_back_for_rmsnorm() {
        let d = Dispatcher::new(BackendKind::Parallel(2));
        let mut x = vec![2.0f32; 8];
        let w = vec![1.0f32; 8];
        assert_eq!(d.fallback_count(), 0);
        d.rmsnorm(&mut x, &w, 1e-5);
        assert_eq!(d.fallback_count(), 1, "rmsnorm should fall back to naive");
    }

    #[test]
    fn qmatvec_no_fallback_on_parallel() {
        let mut rng = Rng::new(2);
        let w = QTensor::quantize(QuantType::Q8_0, &rng.normal_vec(32 * 4, 0.1), 4, 32);
        let x = rng.normal_vec(32, 1.0);
        let mut out = vec![0f32; 4];
        let d = Dispatcher::new(BackendKind::Parallel(2));
        d.qmatvec(&w, &x, &mut out);
        assert_eq!(d.fallback_count(), 0);
    }

    #[test]
    fn all_kinds_produce_same_qmatvec_except_degraded() {
        let mut rng = Rng::new(3);
        let w = QTensor::quantize(QuantType::Q5_1, &rng.normal_vec(64 * 16, 0.1), 16, 64);
        let x = rng.normal_vec(64, 1.0);
        let mut base = vec![0f32; 16];
        Dispatcher::new(BackendKind::Naive).qmatvec(&w, &x, &mut base);
        for kind in [
            BackendKind::Parallel(3),
            BackendKind::Gpu(Precision::Full),
        ] {
            let mut out = vec![0f32; 16];
            Dispatcher::new(kind).qmatvec(&w, &x, &mut out);
            assert!(
                crate::util::stats::max_abs_diff(&base, &out) < 1e-6,
                "{:?}",
                kind
            );
        }
        let mut out = vec![0f32; 16];
        Dispatcher::new(BackendKind::Gpu(Precision::DegradedF16)).qmatvec(&w, &x, &mut out);
        assert!(crate::util::stats::max_abs_diff(&base, &out) > 0.0);
    }

    #[test]
    fn qmatvec_batch_matches_per_slot_calls() {
        let mut rng = Rng::new(5);
        let w = QTensor::quantize(QuantType::Q4_0, &rng.normal_vec(32 * 8, 0.1), 8, 32);
        let xs: Vec<f32> = rng.normal_vec(32 * 3, 1.0);
        let d = Dispatcher::new(BackendKind::Naive);
        let mut batched = vec![0f32; 8 * 3];
        d.qmatvec_batch(&w, &xs, &mut batched, 3);
        for s in 0..3 {
            let mut single = vec![0f32; 8];
            d.qmatvec(&w, &xs[s * 32..(s + 1) * 32], &mut single);
            assert_eq!(&batched[s * 8..(s + 1) * 8], &single[..], "slot {s}");
        }
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(BackendKind::Naive.label(), "cpu/none");
        assert_eq!(BackendKind::Parallel(4).label(), "cpu/blas(t4)");
        assert_eq!(BackendKind::Gpu(Precision::DegradedF16).label(), "gpu/opencl");
    }
}
