//! Kernel layer of the Model–Graph–Kernel runtime (paper Fig 2).
//!
//! "The kernel layer provides kernel computing code optimized for
//! different edge platform backends … When optimized kernels are not
//! available, the system will directly fall back to running on the naive
//! kernel."
//!
//! Three backends mirror the paper's accelerator axis:
//!
//! * [`NaiveBackend`]   — scalar single-thread loops (the "None" rows of
//!   Table 6);
//! * [`ParallelBackend`] — multi-threaded, cache-blocked kernels over a
//!   worker pool (the OpenBLAS / Apple Accelerate analogue);
//! * [`GpuBackend`]      — the hybrid-compute analogue (OpenCL / Metal):
//!   widest parallelism, plus an optional *degraded-precision* mode that
//!   reproduces the paper's OpenCL accuracy pathology (Fig 6) by rounding
//!   block partial sums through f16, as mixed CPU/GPU precision did on
//!   Mali/Adreno.
//!
//! [`Dispatcher`] routes each op to the configured backend and falls back
//! to naive for unsupported ops.

pub mod backends;
pub mod dispatch;

pub use backends::{GpuBackend, NaiveBackend, ParallelBackend, Precision};
pub use dispatch::{BackendKind, Dispatcher};

use crate::quant::QTensor;

/// Operations the graph layer needs from a backend.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    QMatVec,
    RmsNorm,
    Softmax,
    Rope,
}

/// A compute backend. All methods operate on caller-provided buffers; the
/// graph layer owns all allocation (hot loop stays allocation-free).
pub trait Kernels: Send + Sync {
    fn name(&self) -> &'static str;

    /// Which ops this backend implements natively (others fall back).
    fn supports(&self, op: Op) -> bool;

    /// `out[r] = dot(W.row(r), x)` for every row. The central decode op:
    /// streams the packed weight matrix once, so its byte traffic is
    /// `W.n_bytes()` — the quantity MBU measures.
    fn qmatvec(&self, w: &QTensor, x: &[f32], out: &mut [f32]);

    /// x := x / rms(x) * weight
    fn rmsnorm(&self, x: &mut [f32], weight: &[f32], eps: f32);

    /// In-place numerically-stable softmax.
    fn softmax(&self, x: &mut [f32]);

    /// Rotary position embedding over interleaved head dims.
    /// `x` is one head's (d_head) slice; standard LLaMA half-rotation.
    fn rope(&self, x: &mut [f32], pos: usize, theta: f32) {
        rope_reference(x, pos, theta);
    }
}

/// Reference RoPE shared by all backends (LLaMA convention: rotate pairs
/// `(x[i], x[i+d/2])` by pos·theta^(-2i/d)).
pub fn rope_reference(x: &mut [f32], pos: usize, theta: f32) {
    let d = x.len();
    let half = d / 2;
    for i in 0..half {
        let freq = theta.powf(-2.0 * i as f32 / d as f32);
        let angle = pos as f32 * freq;
        let (sin, cos) = angle.sin_cos();
        let a = x[i];
        let b = x[i + half];
        x[i] = a * cos - b * sin;
        x[i + half] = a * sin + b * cos;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rope_pos0_is_identity() {
        let mut x = vec![0.3f32, -0.5, 0.9, 0.1];
        let orig = x.clone();
        rope_reference(&mut x, 0, 10000.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn rope_preserves_norm() {
        let mut x = vec![0.3f32, -0.5, 0.9, 0.1, 0.2, -0.8];
        let n0: f32 = x.iter().map(|v| v * v).sum();
        rope_reference(&mut x, 17, 10000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-5);
    }

    #[test]
    fn rope_is_position_dependent() {
        let base = vec![1.0f32, 0.0, 0.0, 0.0];
        let mut a = base.clone();
        let mut b = base.clone();
        rope_reference(&mut a, 1, 10000.0);
        rope_reference(&mut b, 2, 10000.0);
        assert_ne!(a, b);
    }
}
