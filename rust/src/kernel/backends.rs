//! Backend implementations: naive scalar, parallel (BLAS analogue) and
//! gpu-sim (OpenCL/Metal analogue, with an optional degraded-precision
//! mode reproducing the paper's Fig-6 accuracy pathology).

use std::sync::Mutex;

use crate::quant::act::{quantize_activations, ActBlock};
use crate::quant::dot::vec_dot;
use crate::quant::{QTensor, QK};
use crate::tensor;
use crate::util::half::round_f16;
use crate::util::threadpool::ThreadPool;

use super::{Kernels, Op};

// ---------------------------------------------------------------- naive

/// Scalar, single-threaded kernels — the fallback target.
pub struct NaiveBackend;

impl Kernels for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn supports(&self, _op: Op) -> bool {
        true // naive implements everything, by definition of "fallback"
    }

    fn qmatvec(&self, w: &QTensor, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), w.cols, "qmatvec x len");
        assert_eq!(out.len(), w.rows, "qmatvec out len");
        if w.qtype.is_quantized() {
            let act = quantize_activations(x);
            for r in 0..w.rows {
                out[r] = vec_dot(w.qtype, w.row(r), &act);
            }
        } else {
            // f32/f16 rows: plain dot against x.
            let mut wrow = vec![0f32; w.cols];
            for r in 0..w.rows {
                crate::quant::blocks::dequantize_row(w.qtype, w.row(r), &mut wrow);
                out[r] = wrow.iter().zip(x).map(|(a, b)| a * b).sum();
            }
        }
    }

    fn rmsnorm(&self, x: &mut [f32], weight: &[f32], eps: f32) {
        rmsnorm_scalar(x, weight, eps);
    }

    fn softmax(&self, x: &mut [f32]) {
        tensor::softmax_inplace(x);
    }
}

pub(crate) fn rmsnorm_scalar(x: &mut [f32], weight: &[f32], eps: f32) {
    assert_eq!(x.len(), weight.len());
    let ss: f32 = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let inv = 1.0 / (ss + eps).sqrt();
    for (v, w) in x.iter_mut().zip(weight) {
        *v = *v * inv * w;
    }
}

// ------------------------------------------------------------- parallel

/// Multi-threaded kernels over a persistent worker pool — the OpenBLAS /
/// Apple Accelerate analogue. Output rows are partitioned across threads;
/// each thread runs the same quantized dot kernels as naive.
pub struct ParallelBackend {
    pool: Mutex<ThreadPool>,
    n_threads: usize,
}

impl ParallelBackend {
    pub fn new(n_threads: usize) -> Self {
        Self {
            pool: Mutex::new(ThreadPool::new(n_threads)),
            n_threads: n_threads.max(1),
        }
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    fn par_qmatvec(&self, w: &QTensor, act: &ActVec, out: &mut [f32]) {
        let rows = w.rows;
        let n = self.n_threads.min(rows.max(1));
        let chunk = rows.div_ceil(n);
        struct SendPtr(*mut f32);
        unsafe impl Send for SendPtr {}
        unsafe impl Sync for SendPtr {}
        let out_ptr = SendPtr(out.as_mut_ptr());
        let pool = self.pool.lock().unwrap();
        std::thread::scope(|_| {
            // Fan out over the persistent pool (avoids per-call spawn).
            let wref = &*w;
            let actref = &*act;
            let out_ptr = &out_ptr;
            unsafe {
                fanout(&pool, n, |t| {
                    let r0 = t * chunk;
                    let r1 = ((t + 1) * chunk).min(rows);
                    for r in r0..r1 {
                        let v = match actref {
                            ActVec::Quant(a) => vec_dot(wref.qtype, wref.row(r), a),
                            ActVec::Dense(x) => {
                                let mut wrow = vec![0f32; wref.cols];
                                crate::quant::blocks::dequantize_row(
                                    wref.qtype,
                                    wref.row(r),
                                    &mut wrow,
                                );
                                wrow.iter().zip(x.iter()).map(|(a, b)| a * b).sum()
                            }
                        };
                        *out_ptr.0.add(r) = v;
                    }
                });
            }
        });
    }
}

enum ActVec<'a> {
    Quant(Vec<ActBlock>),
    Dense(&'a [f32]),
}

/// Run `f(0..n)` as n jobs on the pool and wait.
///
/// SAFETY: caller guarantees the closures write disjoint memory AND that
/// `f` outlives the `pool.wait()` barrier below (it does: we block until
/// every job completed before returning). The pointer is laundered
/// through `usize` + a monomorphized trampoline so the 'static bound on
/// `ThreadPool::execute` is satisfied without requiring `F: 'static`.
unsafe fn fanout<F: Fn(usize) + Sync>(pool: &ThreadPool, n: usize, f: F) {
    fn trampoline<F: Fn(usize)>(ptr: usize, t: usize) {
        unsafe { (*(ptr as *const F))(t) }
    }
    let f_addr = &f as *const F as usize;
    let tramp: fn(usize, usize) = trampoline::<F>;
    for t in 0..n {
        pool.execute(move || tramp(f_addr, t));
    }
    pool.wait();
}

impl Kernels for ParallelBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn supports(&self, op: Op) -> bool {
        // rope is left to the shared reference impl; rmsnorm/softmax are
        // bandwidth-trivial so the parallel backend doesn't specialize them
        // (they fall back to naive via the dispatcher).
        matches!(op, Op::QMatVec)
    }

    fn qmatvec(&self, w: &QTensor, x: &[f32], out: &mut [f32]) {
        assert_eq!(x.len(), w.cols);
        assert_eq!(out.len(), w.rows);
        // Perf (EXPERIMENTS.md §Perf L3-1): fan-out costs ~8µs of pool
        // wake/barrier latency; below this work threshold a single
        // thread wins, so route small mat-vecs to the scalar path.
        const PAR_THRESHOLD: usize = 1 << 17;
        if self.n_threads == 1 || w.rows * w.cols < PAR_THRESHOLD {
            return NaiveBackend.qmatvec(w, x, out);
        }
        let act = if w.qtype.is_quantized() {
            ActVec::Quant(quantize_activations(x))
        } else {
            ActVec::Dense(x)
        };
        self.par_qmatvec(w, &act, out);
    }

    fn rmsnorm(&self, x: &mut [f32], weight: &[f32], eps: f32) {
        rmsnorm_scalar(x, weight, eps);
    }

    fn softmax(&self, x: &mut [f32]) {
        tensor::softmax_inplace(x);
    }
}

// ------------------------------------------------------------------ gpu

/// Numerical fidelity of the simulated GPU path.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Precision {
    /// Metal-like: results match CPU (paper: MacBook GPU ppl == CPU ppl).
    Full,
    /// OpenCL-on-Mali/Adreno-like: block partial sums round through f16,
    /// modeling the mixed-precision accumulation the paper blames for the
    /// ~10× perplexity blow-up (Fig 6, §5.2.4).
    DegradedF16,
}

/// The hybrid-computing backend analogue. Numerically it is the parallel
/// backend with a configurable accumulation fidelity; *timing* of a real
/// edge GPU is the device simulator's job, not this backend's.
pub struct GpuBackend {
    inner: ParallelBackend,
    pub precision: Precision,
}

impl GpuBackend {
    pub fn new(n_lanes: usize, precision: Precision) -> Self {
        Self {
            inner: ParallelBackend::new(n_lanes),
            precision,
        }
    }
}

impl Kernels for GpuBackend {
    fn name(&self) -> &'static str {
        match self.precision {
            Precision::Full => "gpu",
            Precision::DegradedF16 => "gpu-degraded",
        }
    }

    fn supports(&self, op: Op) -> bool {
        matches!(op, Op::QMatVec | Op::Softmax)
    }

    fn qmatvec(&self, w: &QTensor, x: &[f32], out: &mut [f32]) {
        match self.precision {
            Precision::Full => self.inner.qmatvec(w, x, out),
            Precision::DegradedF16 => {
                // Quantize activations through f16 first (device-side
                // upload truncation), dot per block, round each block's
                // partial accumulation to f16 — the error mechanism of a
                //16-bit accumulator pipeline.
                assert_eq!(x.len(), w.cols);
                assert_eq!(out.len(), w.rows);
                let x16: Vec<f32> = x.iter().map(|v| round_f16(*v)).collect();
                let act = quantize_activations(&x16);
                for r in 0..w.rows {
                    let row = w.row(r);
                    // bytes per 32-weight activation block (f32/f16 store
                    // one weight per "block", quantized formats 32).
                    let bb = w.qtype.row_bytes(QK);
                    let mut acc = 0f32;
                    for (bi, a) in act.iter().enumerate() {
                        let one = vec_dot(
                            w.qtype,
                            &row[bi * bb..(bi + 1) * bb],
                            std::slice::from_ref(a),
                        );
                        acc = round_f16(acc + round_f16(one));
                    }
                    out[r] = acc;
                }
            }
        }
    }

    fn rmsnorm(&self, x: &mut [f32], weight: &[f32], eps: f32) {
        rmsnorm_scalar(x, weight, eps);
    }

    fn softmax(&self, x: &mut [f32]) {
        tensor::softmax_inplace(x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantType;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;

    fn mk_weights(rng: &mut Rng, rows: usize, cols: usize, q: QuantType) -> QTensor {
        let src = rng.normal_vec(rows * cols, 0.08);
        QTensor::quantize(q, &src, rows, cols)
    }

    #[test]
    fn parallel_matches_naive() {
        let mut rng = Rng::new(21);
        let w = mk_weights(&mut rng, 96, QK * 4, QuantType::Q4_0);
        let x = rng.normal_vec(QK * 4, 1.0);
        let mut o1 = vec![0f32; 96];
        let mut o2 = vec![0f32; 96];
        NaiveBackend.qmatvec(&w, &x, &mut o1);
        ParallelBackend::new(4).qmatvec(&w, &x, &mut o2);
        assert!(max_abs_diff(&o1, &o2) < 1e-6, "{}", max_abs_diff(&o1, &o2));
    }

    #[test]
    fn parallel_matches_naive_f32_weights() {
        let mut rng = Rng::new(23);
        let w = mk_weights(&mut rng, 33, QK * 2, QuantType::F32);
        let x = rng.normal_vec(QK * 2, 1.0);
        let mut o1 = vec![0f32; 33];
        let mut o2 = vec![0f32; 33];
        NaiveBackend.qmatvec(&w, &x, &mut o1);
        ParallelBackend::new(3).qmatvec(&w, &x, &mut o2);
        assert!(max_abs_diff(&o1, &o2) < 1e-5);
    }

    #[test]
    fn gpu_full_matches_naive() {
        let mut rng = Rng::new(22);
        let w = mk_weights(&mut rng, 64, QK * 2, QuantType::Q8_0);
        let x = rng.normal_vec(QK * 2, 1.0);
        let mut o1 = vec![0f32; 64];
        let mut o2 = vec![0f32; 64];
        NaiveBackend.qmatvec(&w, &x, &mut o1);
        GpuBackend::new(8, Precision::Full).qmatvec(&w, &x, &mut o2);
        assert!(max_abs_diff(&o1, &o2) < 1e-6);
    }

    #[test]
    fn gpu_degraded_differs_but_is_bounded() {
        let mut rng = Rng::new(29);
        let w = mk_weights(&mut rng, 64, QK * 8, QuantType::Q4_0);
        let x = rng.normal_vec(QK * 8, 1.0);
        let mut full = vec![0f32; 64];
        let mut degr = vec![0f32; 64];
        NaiveBackend.qmatvec(&w, &x, &mut full);
        GpuBackend::new(8, Precision::DegradedF16).qmatvec(&w, &x, &mut degr);
        let d = max_abs_diff(&full, &degr);
        assert!(d > 0.0, "degraded mode must perturb results");
        // Still the same computation, not garbage.
        let scale = full.iter().fold(0f32, |a, v| a.max(v.abs()));
        assert!(d < scale, "degradation too large: {d} vs scale {scale}");
    }

    #[test]
    fn rmsnorm_unit_output_scale() {
        let mut x = vec![3.0f32; 16];
        let w = vec![1.0f32; 16];
        rmsnorm_scalar(&mut x, &w, 1e-5);
        for v in &x {
            assert!((v - 1.0).abs() < 1e-3);
        }
    }

    #[test]
    fn qmatvec_rejects_bad_shapes() {
        let mut rng = Rng::new(1);
        let w = mk_weights(&mut rng, 4, QK, QuantType::Q8_0);
        let x = vec![0f32; QK];
        let mut out = vec![0f32; 3]; // wrong
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            NaiveBackend.qmatvec(&w, &x, &mut out)
        }));
        assert!(res.is_err());
    }
}
