//! Pre-allocated KV cache (paper §4.1: "KV cache storage optimization
//! creates an optimized KV cache with pre-allocated memory, updating only
//! new tokens each time instead of loading all tokens").
//!
//! All layers' K/V live in two flat buffers allocated once at engine
//! construction; `append` writes one position, attention reads slices
//! in-place — the decode loop never allocates.

use crate::model::LlamaConfig;

/// Flat pre-allocated KV storage, f32 (data_byte = 4 in MBU eq. 3).
#[derive(Clone, Debug)]
pub struct KvCache {
    pub n_layers: usize,
    pub kv_dim: usize,
    pub max_seq: usize,
    /// layout: [layer][pos][kv_dim]
    k: Vec<f32>,
    v: Vec<f32>,
    len: usize,
}

impl KvCache {
    pub fn new(config: &LlamaConfig) -> Self {
        let kv_dim = config.n_kv_heads * config.head_dim();
        let cap = config.n_layers * config.max_seq_len * kv_dim;
        Self {
            n_layers: config.n_layers,
            kv_dim,
            max_seq: config.max_seq_len,
            k: vec![0.0; cap],
            v: vec![0.0; cap],
            len: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn reset(&mut self) {
        self.len = 0;
    }

    #[inline]
    fn off(&self, layer: usize, pos: usize) -> usize {
        (layer * self.max_seq + pos) * self.kv_dim
    }

    /// Write K/V for `pos` in `layer`. Positions must be appended in
    /// order; `advance` is called once per token after all layers wrote.
    pub fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.max_seq, "kv cache overflow: pos {pos} >= {}", self.max_seq);
        assert_eq!(k.len(), self.kv_dim);
        assert_eq!(v.len(), self.kv_dim);
        let o = self.off(layer, pos);
        self.k[o..o + self.kv_dim].copy_from_slice(k);
        self.v[o..o + self.kv_dim].copy_from_slice(v);
    }

    /// Mark one more position valid (after all layers wrote it).
    pub fn advance(&mut self, pos: usize) {
        debug_assert!(pos >= self.len);
        self.len = pos + 1;
    }

    pub fn k_at(&self, layer: usize, pos: usize) -> &[f32] {
        let o = self.off(layer, pos);
        &self.k[o..o + self.kv_dim]
    }

    pub fn v_at(&self, layer: usize, pos: usize) -> &[f32] {
        let o = self.off(layer, pos);
        &self.v[o..o + self.kv_dim]
    }

    /// Bytes currently occupied by valid entries (both K and V).
    pub fn bytes_in_use(&self) -> u64 {
        (self.n_layers * self.len * self.kv_dim * 4 * 2) as u64
    }

    /// Bytes *read* by one decode step: attention scans all cached
    /// positions in every layer (K for scores + V for mixing).
    pub fn bytes_read_per_step(&self) -> u64 {
        self.bytes_in_use()
    }

    /// Total pre-allocated capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        (self.k.len() * 4 * 2) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LlamaConfig {
        LlamaConfig::tiny()
    }

    #[test]
    fn write_read_roundtrip() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let dim = kv.kv_dim;
        let kvec: Vec<f32> = (0..dim).map(|i| i as f32).collect();
        let vvec: Vec<f32> = (0..dim).map(|i| -(i as f32)).collect();
        kv.write(2, 0, &kvec, &vvec);
        kv.advance(0);
        assert_eq!(kv.k_at(2, 0), &kvec[..]);
        assert_eq!(kv.v_at(2, 0), &vvec[..]);
        assert_eq!(kv.len(), 1);
    }

    #[test]
    fn bytes_track_eq3_with_f32() {
        // eq 3 with data_byte=4: len · head_dim · n_layers · n_kv_heads · 4 · 2
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let z = vec![0f32; kv.kv_dim];
        for pos in 0..5 {
            for l in 0..c.n_layers {
                kv.write(l, pos, &z, &z);
            }
            kv.advance(pos);
        }
        let expect = 5 * c.head_dim() * c.n_layers * c.n_kv_heads * 4 * 2;
        assert_eq!(kv.bytes_in_use(), expect as u64);
    }

    #[test]
    fn reset_clears_len_not_capacity() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let z = vec![0f32; kv.kv_dim];
        kv.write(0, 0, &z, &z);
        kv.advance(0);
        let cap = kv.capacity_bytes();
        kv.reset();
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.capacity_bytes(), cap);
    }

    #[test]
    #[should_panic(expected = "kv cache overflow")]
    fn overflow_panics() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let z = vec![0f32; kv.kv_dim];
        kv.write(0, c.max_seq_len, &z, &z);
    }
}
