//! Pre-allocated KV cache (paper §4.1: "KV cache storage optimization
//! creates an optimized KV cache with pre-allocated memory, updating only
//! new tokens each time instead of loading all tokens").
//!
//! All layers' K/V live in two flat buffers allocated once at engine
//! construction; `write` stores one position, attention reads slices
//! in-place — the decode loop never allocates.
//!
//! The cache holds `batch` independent sequence *slots* (paper eq. 3 is
//! batch-aware: KV size scales linearly in the batch dimension). Slot 0
//! keeps the original single-sequence API (`write`/`advance`/`k_at`/
//! `v_at`) so batch-1 callers are unchanged; the batched engine addresses
//! slots explicitly via the `*_slot` variants. Slots advance
//! independently, so sequences of different lengths can share one cache.

use crate::model::LlamaConfig;

/// Flat pre-allocated KV storage, f32 (data_byte = 4 in MBU eq. 3).
#[derive(Clone, Debug)]
pub struct KvCache {
    pub n_layers: usize,
    pub kv_dim: usize,
    pub max_seq: usize,
    /// Number of independent sequence slots.
    pub batch: usize,
    /// layout: `[layer][slot][pos][kv_dim]`
    k: Vec<f32>,
    v: Vec<f32>,
    /// Valid positions per slot.
    lens: Vec<usize>,
}

impl KvCache {
    pub fn new(config: &LlamaConfig) -> Self {
        Self::new_batched(config, 1)
    }

    /// Cache with `batch` independent sequence slots.
    pub fn new_batched(config: &LlamaConfig, batch: usize) -> Self {
        assert!(batch >= 1, "kv cache needs at least one slot");
        let kv_dim = config.n_kv_heads * config.head_dim();
        let cap = config.n_layers * batch * config.max_seq_len * kv_dim;
        Self {
            n_layers: config.n_layers,
            kv_dim,
            max_seq: config.max_seq_len,
            batch,
            k: vec![0.0; cap],
            v: vec![0.0; cap],
            lens: vec![0; batch],
        }
    }

    /// Slot-0 length (the single-sequence view).
    pub fn len(&self) -> usize {
        self.lens[0]
    }

    /// Valid positions in `slot`.
    pub fn slot_len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|l| *l == 0)
    }

    pub fn reset(&mut self) {
        for l in &mut self.lens {
            *l = 0;
        }
    }

    /// Release one slot: zero its valid length so a retired sequence's
    /// stale cache can never leak into a newly admitted request, while
    /// every other slot keeps decoding undisturbed. This is the
    /// claim/release primitive of the continuous-batching serve loop
    /// (DESIGN.md §5): `release` and `claim` are both a `reset_slot`.
    pub fn reset_slot(&mut self, slot: usize) {
        assert!(slot < self.batch, "kv cache slot {slot} >= batch {}", self.batch);
        self.lens[slot] = 0;
    }

    /// Pin one slot's valid length to exactly `len` (shrink-only): the
    /// prefix-reuse primitive of the chat-session workload (DESIGN.md
    /// §5). A follow-up turn that inherits its session's slot truncates
    /// to the prefix it is allowed to attend over, so any KV written
    /// past the handed-off prefix can never leak into the new turn.
    /// `reset_slot` is `truncate_slot(slot, 0)`.
    pub fn truncate_slot(&mut self, slot: usize, len: usize) {
        assert!(slot < self.batch, "kv cache slot {slot} >= batch {}", self.batch);
        assert!(
            len <= self.lens[slot],
            "kv truncate cannot extend: slot {slot} has {} valid positions, asked for {len}",
            self.lens[slot]
        );
        self.lens[slot] = len;
    }

    #[inline]
    fn off(&self, layer: usize, slot: usize, pos: usize) -> usize {
        debug_assert!(slot < self.batch, "kv cache slot {slot} >= batch {}", self.batch);
        debug_assert!(pos < self.max_seq);
        ((layer * self.batch + slot) * self.max_seq + pos) * self.kv_dim
    }

    /// Write K/V for `pos` in `layer`, slot 0. Positions must be appended
    /// in order; `advance` is called once per token after all layers wrote.
    pub fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.write_slot(layer, 0, pos, k, v);
    }

    /// Write K/V for `pos` in `layer` of sequence `slot`.
    pub fn write_slot(&mut self, layer: usize, slot: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.max_seq, "kv cache overflow: pos {pos} >= {}", self.max_seq);
        assert!(slot < self.batch, "kv cache slot {slot} >= batch {}", self.batch);
        assert_eq!(k.len(), self.kv_dim);
        assert_eq!(v.len(), self.kv_dim);
        let o = self.off(layer, slot, pos);
        self.k[o..o + self.kv_dim].copy_from_slice(k);
        self.v[o..o + self.kv_dim].copy_from_slice(v);
    }

    /// Mark one more position valid in slot 0 (after all layers wrote it).
    pub fn advance(&mut self, pos: usize) {
        self.advance_slot(0, pos);
    }

    /// Mark one more position valid in `slot`.
    pub fn advance_slot(&mut self, slot: usize, pos: usize) {
        debug_assert!(pos >= self.lens[slot]);
        self.lens[slot] = pos + 1;
    }

    pub fn k_at(&self, layer: usize, pos: usize) -> &[f32] {
        self.k_slot_at(layer, 0, pos)
    }

    pub fn v_at(&self, layer: usize, pos: usize) -> &[f32] {
        self.v_slot_at(layer, 0, pos)
    }

    pub fn k_slot_at(&self, layer: usize, slot: usize, pos: usize) -> &[f32] {
        let o = self.off(layer, slot, pos);
        &self.k[o..o + self.kv_dim]
    }

    pub fn v_slot_at(&self, layer: usize, slot: usize, pos: usize) -> &[f32] {
        let o = self.off(layer, slot, pos);
        &self.v[o..o + self.kv_dim]
    }

    /// Bytes currently occupied by valid entries across all slots
    /// (both K and V) — eq. 3 with the batch term measured, not assumed.
    pub fn bytes_in_use(&self) -> u64 {
        self.lens
            .iter()
            .map(|len| (self.n_layers * len * self.kv_dim * 4 * 2) as u64)
            .sum()
    }

    /// Bytes currently valid in one slot (both K and V) — the per-slot
    /// eq.-3 term the serving simulator sums over *active* slots only.
    pub fn slot_bytes_in_use(&self, slot: usize) -> u64 {
        (self.n_layers * self.lens[slot] * self.kv_dim * 4 * 2) as u64
    }

    /// Bytes *read* by one decode step: attention scans every slot's
    /// cached positions in every layer (K for scores + V for mixing).
    pub fn bytes_read_per_step(&self) -> u64 {
        self.bytes_in_use()
    }

    /// Total pre-allocated capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        (self.k.len() * 4 * 2) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> LlamaConfig {
        LlamaConfig::tiny()
    }

    #[test]
    fn write_read_roundtrip() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let dim = kv.kv_dim;
        let kvec: Vec<f32> = (0..dim).map(|i| i as f32).collect();
        let vvec: Vec<f32> = (0..dim).map(|i| -(i as f32)).collect();
        kv.write(2, 0, &kvec, &vvec);
        kv.advance(0);
        assert_eq!(kv.k_at(2, 0), &kvec[..]);
        assert_eq!(kv.v_at(2, 0), &vvec[..]);
        assert_eq!(kv.len(), 1);
    }

    #[test]
    fn bytes_track_eq3_with_f32() {
        // eq 3 with data_byte=4: len · head_dim · n_layers · n_kv_heads · 4 · 2
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let z = vec![0f32; kv.kv_dim];
        for pos in 0..5 {
            for l in 0..c.n_layers {
                kv.write(l, pos, &z, &z);
            }
            kv.advance(pos);
        }
        let expect = 5 * c.head_dim() * c.n_layers * c.n_kv_heads * 4 * 2;
        assert_eq!(kv.bytes_in_use(), expect as u64);
    }

    #[test]
    fn reset_clears_len_not_capacity() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let z = vec![0f32; kv.kv_dim];
        kv.write(0, 0, &z, &z);
        kv.advance(0);
        let cap = kv.capacity_bytes();
        kv.reset();
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.capacity_bytes(), cap);
    }

    #[test]
    #[should_panic(expected = "kv cache overflow")]
    fn overflow_panics() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let z = vec![0f32; kv.kv_dim];
        kv.write(0, c.max_seq_len, &z, &z);
    }

    #[test]
    fn slots_are_independent() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 3);
        let dim = kv.kv_dim;
        for s in 0..3usize {
            let kvec: Vec<f32> = (0..dim).map(|i| (s * 1000 + i) as f32).collect();
            let vvec: Vec<f32> = (0..dim).map(|i| -((s * 1000 + i) as f32)).collect();
            kv.write_slot(1, s, 0, &kvec, &vvec);
            kv.advance_slot(s, 0);
        }
        for s in 0..3usize {
            assert_eq!(kv.k_slot_at(1, s, 0)[1], (s * 1000 + 1) as f32);
            assert_eq!(kv.v_slot_at(1, s, 0)[1], -((s * 1000 + 1) as f32));
        }
    }

    #[test]
    fn slots_advance_independently_and_sum_bytes() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        let z = vec![0f32; kv.kv_dim];
        for pos in 0..3 {
            for l in 0..c.n_layers {
                kv.write_slot(l, 0, pos, &z, &z);
            }
            kv.advance_slot(0, pos);
        }
        for l in 0..c.n_layers {
            kv.write_slot(l, 1, 0, &z, &z);
        }
        kv.advance_slot(1, 0);
        assert_eq!(kv.slot_len(0), 3);
        assert_eq!(kv.slot_len(1), 1);
        let per_pos = (c.head_dim() * c.n_layers * c.n_kv_heads * 4 * 2) as u64;
        assert_eq!(kv.bytes_in_use(), 4 * per_pos);
    }

    #[test]
    fn batched_capacity_scales() {
        let c = cfg();
        let b1 = KvCache::new(&c).capacity_bytes();
        let b4 = KvCache::new_batched(&c, 4).capacity_bytes();
        assert_eq!(b4, 4 * b1);
    }

    /// The slot-release regression (serve-loop satellite): releasing a
    /// slot must zero *its* length only; the freed slot then reports zero
    /// bytes in use while its neighbors keep their cache.
    #[test]
    fn reset_slot_zeroes_only_that_slot() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 3);
        let z = vec![0f32; kv.kv_dim];
        for s in 0..3usize {
            for pos in 0..(s + 1) {
                for l in 0..c.n_layers {
                    kv.write_slot(l, s, pos, &z, &z);
                }
                kv.advance_slot(s, pos);
            }
        }
        assert_eq!([kv.slot_len(0), kv.slot_len(1), kv.slot_len(2)], [1, 2, 3]);
        kv.reset_slot(1);
        assert_eq!([kv.slot_len(0), kv.slot_len(1), kv.slot_len(2)], [1, 0, 3]);
        assert_eq!(kv.slot_bytes_in_use(1), 0);
        let per_pos = (c.head_dim() * c.n_layers * c.n_kv_heads * 4 * 2) as u64;
        assert_eq!(kv.slot_bytes_in_use(0), per_pos);
        assert_eq!(kv.slot_bytes_in_use(2), 3 * per_pos);
        assert_eq!(kv.bytes_in_use(), 4 * per_pos);
    }

    /// The chat-reuse primitive: truncating pins the reused prefix
    /// length without touching neighbors, the truncated positions'
    /// storage stays intact (it is length, not data, that gates
    /// attention), and extending is a programming error.
    #[test]
    fn truncate_slot_pins_prefix_and_keeps_neighbors() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        let z = vec![0f32; kv.kv_dim];
        for slot in 0..2usize {
            for pos in 0..4 {
                for l in 0..c.n_layers {
                    kv.write_slot(l, slot, pos, &z, &z);
                }
                kv.advance_slot(slot, pos);
            }
        }
        kv.truncate_slot(0, 2);
        assert_eq!(kv.slot_len(0), 2);
        assert_eq!(kv.slot_len(1), 4, "neighbor untouched");
        let per_pos = (c.head_dim() * c.n_layers * c.n_kv_heads * 4 * 2) as u64;
        assert_eq!(kv.slot_bytes_in_use(0), 2 * per_pos);
        // Truncating to the current length is a no-op; to zero == reset.
        kv.truncate_slot(0, 2);
        assert_eq!(kv.slot_len(0), 2);
        kv.truncate_slot(0, 0);
        assert_eq!(kv.slot_len(0), 0);
    }

    #[test]
    #[should_panic(expected = "kv truncate cannot extend")]
    fn truncate_cannot_extend() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        let z = vec![0f32; kv.kv_dim];
        for l in 0..c.n_layers {
            kv.write_slot(l, 0, 0, &z, &z);
        }
        kv.advance_slot(0, 0);
        kv.truncate_slot(0, 2);
    }

    #[test]
    #[should_panic(expected = "kv cache slot")]
    fn truncate_out_of_range_slot_panics() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        kv.truncate_slot(2, 0);
    }

    #[test]
    #[should_panic(expected = "kv cache slot")]
    fn reset_out_of_range_slot_panics() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        kv.reset_slot(2);
    }

    #[test]
    #[should_panic(expected = "kv cache slot")]
    fn out_of_range_slot_panics() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        let z = vec![0f32; kv.kv_dim];
        kv.write_slot(0, 2, 0, &z, &z);
    }
}
