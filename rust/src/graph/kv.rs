//! Pre-allocated KV cache (paper §4.1: "KV cache storage optimization
//! creates an optimized KV cache with pre-allocated memory, updating only
//! new tokens each time instead of loading all tokens").
//!
//! Two storage layouts share one API:
//!
//! * [`KvLayout::Paged`] (the default) — a vLLM-style block allocator.
//!   K/V live in fixed-size *blocks* of [`KV_BLOCK_TOKENS`] positions
//!   (all layers of one sequence chain), drawn from a shared
//!   [`BlockPool`] with a free list and per-block refcounts. Each slot
//!   maps logical positions to its block chain through a block table;
//!   [`KvCache::fork_slot`] shares a prefix between chains by bumping
//!   refcounts, and any write into a shared block copies it first
//!   (copy-on-write), so chains stay bitwise independent.
//! * [`KvLayout::Slot`] — the original fixed `[layer][slot][pos]`
//!   layout, retained as the bitwise parity reference for the paged
//!   allocator (see the parity tests here and in the serve layer).
//!
//! Either way all storage is allocated once at engine construction and
//! the decode loop never allocates (block alloc/free is free-list
//! pointer juggling; only CoW moves data). The cache holds `batch`
//! independent sequence *slots* (paper eq. 3 is batch-aware: KV size
//! scales linearly in the batch dimension). Slot 0 keeps the original
//! single-sequence API (`write`/`advance`/`k_at`/`v_at`) so batch-1
//! callers are unchanged; the batched engine addresses slots explicitly
//! via the `*_slot` variants. Slots advance independently, so sequences
//! of different lengths can share one cache.

use crate::model::LlamaConfig;

/// Default positions per KV block in the paged layout.
pub const KV_BLOCK_TOKENS: usize = 16;

/// KV storage layout.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KvLayout {
    /// vLLM-style paged layout: fixed-size token blocks from a shared
    /// refcounted pool, per-slot block tables, copy-on-write sharing.
    Paged { block_tokens: usize },
    /// Fixed `[layer][slot][pos]` layout — the parity reference.
    Slot,
}

impl Default for KvLayout {
    fn default() -> Self {
        KvLayout::Paged { block_tokens: KV_BLOCK_TOKENS }
    }
}

/// Point-in-time pool counters for reporting (bench.json / fleet.json).
/// The cumulative counters (`cow_copies`, `prefix_forks`,
/// `shared_tokens`, `shared_bytes`, `peak_blocks_in_use`) never reset
/// over a cache's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct KvPoolStats {
    pub block_tokens: usize,
    pub blocks_total: usize,
    pub blocks_in_use: usize,
    pub peak_blocks_in_use: usize,
    /// Shared blocks privatized by a write (copy-on-write events).
    pub cow_copies: usize,
    /// `fork_slot` calls (prefix-share events).
    pub prefix_forks: usize,
    /// Positions shared by forks instead of recomputed, cumulative.
    pub shared_tokens: usize,
    /// `shared_tokens` priced in KV bytes (both K and V, f32) — the
    /// "prefix-share hit bytes" column of the fleet report.
    pub shared_bytes: u64,
}

impl KvPoolStats {
    /// Peak fraction of the pool ever in use (0 for an empty pool).
    pub fn peak_occupancy(&self) -> f64 {
        if self.blocks_total == 0 {
            0.0
        } else {
            self.peak_blocks_in_use as f64 / self.blocks_total as f64
        }
    }
}

/// The block allocator behind the paged layout: a free list plus
/// per-block refcounts, and one block-table chain per slot. The pool
/// only tracks *which* block holds what — data lives in the cache's
/// flat K/V buffers at `block * n_layers * block_tokens * kv_dim`.
#[derive(Clone, Debug)]
struct BlockPool {
    block_tokens: usize,
    blocks_total: usize,
    /// Free block ids, LIFO (pop allocates, push frees).
    free: Vec<usize>,
    /// References per block: number of slot chains mapping it.
    refcount: Vec<u32>,
    /// Per-slot chains: `tables[slot][i]` backs positions
    /// `[i*block_tokens, (i+1)*block_tokens)`.
    tables: Vec<Vec<usize>>,
    peak_blocks_in_use: usize,
    cow_copies: usize,
    prefix_forks: usize,
    shared_tokens: usize,
}

impl BlockPool {
    fn new(blocks_total: usize, block_tokens: usize, batch: usize) -> Self {
        Self {
            block_tokens,
            blocks_total,
            // Reverse so pop() hands out 0, 1, 2, … deterministically.
            free: (0..blocks_total).rev().collect(),
            refcount: vec![0; blocks_total],
            tables: vec![Vec::new(); batch],
            peak_blocks_in_use: 0,
            cow_copies: 0,
            prefix_forks: 0,
            shared_tokens: 0,
        }
    }

    fn blocks_in_use(&self) -> usize {
        self.blocks_total - self.free.len()
    }

    fn alloc(&mut self) -> Option<usize> {
        let b = self.free.pop()?;
        debug_assert_eq!(self.refcount[b], 0, "free block {b} still referenced");
        self.refcount[b] = 1;
        self.peak_blocks_in_use = self.peak_blocks_in_use.max(self.blocks_in_use());
        Some(b)
    }

    fn decref(&mut self, b: usize) {
        debug_assert!(self.refcount[b] > 0, "double free of kv block {b}");
        self.refcount[b] -= 1;
        if self.refcount[b] == 0 {
            self.free.push(b);
        }
    }

    /// Drop every block of `slot`'s chain past the first `keep_tokens`
    /// positions (the partial tail block covering `keep_tokens` stays).
    fn release_tail(&mut self, slot: usize, keep_tokens: usize) {
        let keep = keep_tokens.div_ceil(self.block_tokens);
        while self.tables[slot].len() > keep {
            let b = self.tables[slot].pop().expect("chain shorter than keep");
            self.decref(b);
        }
    }
}

/// Flat pre-allocated KV storage, f32 (data_byte = 4 in MBU eq. 3).
#[derive(Clone, Debug)]
pub struct KvCache {
    pub n_layers: usize,
    pub kv_dim: usize,
    pub max_seq: usize,
    /// Number of independent sequence slots.
    pub batch: usize,
    /// Slot layout: `[layer][slot][pos][kv_dim]`.
    /// Paged layout: `[block][layer][pos % block_tokens][kv_dim]`.
    k: Vec<f32>,
    v: Vec<f32>,
    /// Valid positions per slot.
    lens: Vec<usize>,
    /// `Some` in the paged layout, `None` in the slot layout.
    pool: Option<BlockPool>,
}

impl KvCache {
    pub fn new(config: &LlamaConfig) -> Self {
        Self::new_batched(config, 1)
    }

    /// Cache with `batch` independent sequence slots (default layout).
    pub fn new_batched(config: &LlamaConfig, batch: usize) -> Self {
        Self::new_batched_layout(config, batch, KvLayout::default())
    }

    /// Cache with `batch` slots and an explicit storage layout. The
    /// paged pool is sized `batch × ⌈max_seq / block_tokens⌉` blocks —
    /// enough for every slot at full context, so the engine itself can
    /// never exhaust it (admission control is the serve layer's job).
    pub fn new_batched_layout(config: &LlamaConfig, batch: usize, layout: KvLayout) -> Self {
        assert!(batch >= 1, "kv cache needs at least one slot");
        let kv_dim = config.n_kv_heads * config.head_dim();
        let (cap, pool) = match layout {
            KvLayout::Slot => (config.n_layers * batch * config.max_seq_len * kv_dim, None),
            KvLayout::Paged { block_tokens } => {
                assert!(block_tokens >= 1, "kv block needs at least one token");
                let blocks = batch * config.max_seq_len.div_ceil(block_tokens);
                (
                    blocks * config.n_layers * block_tokens * kv_dim,
                    Some(BlockPool::new(blocks, block_tokens, batch)),
                )
            }
        };
        Self {
            n_layers: config.n_layers,
            kv_dim,
            max_seq: config.max_seq_len,
            batch,
            k: vec![0.0; cap],
            v: vec![0.0; cap],
            lens: vec![0; batch],
            pool,
        }
    }

    /// The storage layout this cache was built with.
    pub fn layout(&self) -> KvLayout {
        match &self.pool {
            None => KvLayout::Slot,
            Some(p) => KvLayout::Paged { block_tokens: p.block_tokens },
        }
    }

    /// Positions per block (`None` in the slot layout).
    pub fn block_tokens(&self) -> Option<usize> {
        self.pool.as_ref().map(|p| p.block_tokens)
    }

    /// Slot-0 length (the single-sequence view).
    pub fn len(&self) -> usize {
        self.lens[0]
    }

    /// Valid positions in `slot`.
    pub fn slot_len(&self, slot: usize) -> usize {
        self.lens[slot]
    }

    pub fn is_empty(&self) -> bool {
        self.lens.iter().all(|l| *l == 0)
    }

    pub fn reset(&mut self) {
        for s in 0..self.batch {
            self.lens[s] = 0;
            if let Some(pool) = &mut self.pool {
                pool.release_tail(s, 0);
            }
        }
    }

    /// Release one slot: zero its valid length (and, in the paged
    /// layout, return its block chain to the pool) so a retired
    /// sequence's stale cache can never leak into a newly admitted
    /// request, while every other slot keeps decoding undisturbed.
    /// This is the claim/release primitive of the continuous-batching
    /// serve loop (DESIGN.md §5): `release` and `claim` are both a
    /// `reset_slot`.
    pub fn reset_slot(&mut self, slot: usize) {
        assert!(slot < self.batch, "kv cache slot {slot} >= batch {}", self.batch);
        self.lens[slot] = 0;
        if let Some(pool) = &mut self.pool {
            pool.release_tail(slot, 0);
        }
    }

    /// Pin one slot's valid length to exactly `len` (shrink-only): the
    /// prefix-reuse primitive of the chat-session workload (DESIGN.md
    /// §5). A follow-up turn that inherits its session's slot truncates
    /// to the prefix it is allowed to attend over, so any KV written
    /// past the handed-off prefix can never leak into the new turn.
    /// In the paged layout, whole blocks past the kept prefix go back
    /// to the free list (at most one partial tail block stays live).
    /// `reset_slot` is `truncate_slot(slot, 0)`.
    pub fn truncate_slot(&mut self, slot: usize, len: usize) {
        assert!(slot < self.batch, "kv cache slot {slot} >= batch {}", self.batch);
        assert!(
            len <= self.lens[slot],
            "kv truncate cannot extend: slot {slot} has {} valid positions, asked for {len}",
            self.lens[slot]
        );
        self.lens[slot] = len;
        if let Some(pool) = &mut self.pool {
            pool.release_tail(slot, len);
        }
    }

    /// Share `src`'s first `len` cached positions into the *empty* slot
    /// `dst` without copying: the block-table prefix is duplicated and
    /// each shared block's refcount bumped (vLLM-style prefix sharing).
    /// A later write into a shared block — by either chain — copies it
    /// first, so the chains stay bitwise independent. Paged layout only.
    pub fn fork_slot(&mut self, src: usize, dst: usize, len: usize) {
        assert!(src < self.batch, "kv cache slot {src} >= batch {}", self.batch);
        assert!(dst < self.batch, "kv cache slot {dst} >= batch {}", self.batch);
        assert!(src != dst, "kv fork needs two distinct slots (got {src} twice)");
        assert!(
            len <= self.lens[src],
            "kv fork cannot extend: slot {src} has {} valid positions, asked for {len}",
            self.lens[src]
        );
        let pool = self.pool.as_mut().expect("kv fork requires the paged layout");
        assert!(
            self.lens[dst] == 0 && pool.tables[dst].is_empty(),
            "kv fork target slot {dst} is not empty"
        );
        for i in 0..len.div_ceil(pool.block_tokens) {
            let b = pool.tables[src][i];
            pool.refcount[b] += 1;
            pool.tables[dst].push(b);
        }
        pool.prefix_forks += 1;
        pool.shared_tokens += len;
        self.lens[dst] = len;
    }

    /// Make `pos`'s block privately writable for `slot`: allocate one
    /// when the chain ends just before it, copy-on-write when shared.
    fn prepare_block_for_write(&mut self, slot: usize, pos: usize) {
        let pool = self.pool.as_mut().expect("paged layout");
        let bt = pool.block_tokens;
        let bi = pos / bt;
        assert!(
            bi <= pool.tables[slot].len(),
            "kv paged write skips unallocated blocks: slot {slot} pos {pos}"
        );
        if bi == pool.tables[slot].len() {
            let b = pool
                .alloc()
                .unwrap_or_else(|| panic!("kv block pool exhausted: slot {slot} pos {pos}"));
            pool.tables[slot].push(b);
            return;
        }
        let b = pool.tables[slot][bi];
        if pool.refcount[b] == 1 {
            return;
        }
        // Copy-on-write: privatize the shared block before mutating it.
        let nb = pool
            .alloc()
            .unwrap_or_else(|| panic!("kv block pool exhausted: slot {slot} pos {pos} (cow)"));
        pool.refcount[b] -= 1;
        pool.tables[slot][bi] = nb;
        pool.cow_copies += 1;
        let span = self.n_layers * bt * self.kv_dim;
        let (src, dst) = (b * span, nb * span);
        self.k.copy_within(src..src + span, dst);
        self.v.copy_within(src..src + span, dst);
    }

    #[inline]
    fn off(&self, layer: usize, slot: usize, pos: usize) -> usize {
        debug_assert!(slot < self.batch, "kv cache slot {slot} >= batch {}", self.batch);
        debug_assert!(pos < self.max_seq);
        match &self.pool {
            None => ((layer * self.batch + slot) * self.max_seq + pos) * self.kv_dim,
            Some(pool) => {
                let bt = pool.block_tokens;
                let block = pool.tables[slot][pos / bt];
                ((block * self.n_layers + layer) * bt + pos % bt) * self.kv_dim
            }
        }
    }

    /// Write K/V for `pos` in `layer`, slot 0. Positions must be appended
    /// in order; `advance` is called once per token after all layers wrote.
    pub fn write(&mut self, layer: usize, pos: usize, k: &[f32], v: &[f32]) {
        self.write_slot(layer, 0, pos, k, v);
    }

    /// Write K/V for `pos` in `layer` of sequence `slot`.
    pub fn write_slot(&mut self, layer: usize, slot: usize, pos: usize, k: &[f32], v: &[f32]) {
        assert!(pos < self.max_seq, "kv cache overflow: pos {pos} >= {}", self.max_seq);
        assert!(slot < self.batch, "kv cache slot {slot} >= batch {}", self.batch);
        assert_eq!(k.len(), self.kv_dim);
        assert_eq!(v.len(), self.kv_dim);
        if self.pool.is_some() {
            self.prepare_block_for_write(slot, pos);
        }
        let o = self.off(layer, slot, pos);
        self.k[o..o + self.kv_dim].copy_from_slice(k);
        self.v[o..o + self.kv_dim].copy_from_slice(v);
    }

    /// Mark one more position valid in slot 0 (after all layers wrote it).
    pub fn advance(&mut self, pos: usize) {
        self.advance_slot(0, pos);
    }

    /// Mark one more position valid in `slot`.
    pub fn advance_slot(&mut self, slot: usize, pos: usize) {
        debug_assert!(pos >= self.lens[slot]);
        self.lens[slot] = pos + 1;
    }

    pub fn k_at(&self, layer: usize, pos: usize) -> &[f32] {
        self.k_slot_at(layer, 0, pos)
    }

    pub fn v_at(&self, layer: usize, pos: usize) -> &[f32] {
        self.v_slot_at(layer, 0, pos)
    }

    pub fn k_slot_at(&self, layer: usize, slot: usize, pos: usize) -> &[f32] {
        let o = self.off(layer, slot, pos);
        &self.k[o..o + self.kv_dim]
    }

    pub fn v_slot_at(&self, layer: usize, slot: usize, pos: usize) -> &[f32] {
        let o = self.off(layer, slot, pos);
        &self.v[o..o + self.kv_dim]
    }

    /// Bytes currently occupied by valid entries across all slots
    /// (both K and V) — eq. 3 with the batch term measured, not assumed.
    /// Deliberately layout-independent (logical valid positions, not
    /// physical blocks) so MBU pricing is identical across layouts.
    pub fn bytes_in_use(&self) -> u64 {
        self.lens
            .iter()
            .map(|len| (self.n_layers * len * self.kv_dim * 4 * 2) as u64)
            .sum()
    }

    /// Bytes currently valid in one slot (both K and V) — the per-slot
    /// eq.-3 term the serving simulator sums over *active* slots only.
    pub fn slot_bytes_in_use(&self, slot: usize) -> u64 {
        (self.n_layers * self.lens[slot] * self.kv_dim * 4 * 2) as u64
    }

    /// Bytes *read* by one decode step: attention scans every slot's
    /// cached positions in every layer (K for scores + V for mixing).
    pub fn bytes_read_per_step(&self) -> u64 {
        self.bytes_in_use()
    }

    /// Total pre-allocated capacity in bytes.
    pub fn capacity_bytes(&self) -> u64 {
        (self.k.len() * 4 * 2) as u64
    }

    /// Pool counters (`None` in the slot layout).
    pub fn pool_stats(&self) -> Option<KvPoolStats> {
        let pool = self.pool.as_ref()?;
        let token_bytes = (self.n_layers * self.kv_dim * 4 * 2) as u64;
        Some(KvPoolStats {
            block_tokens: pool.block_tokens,
            blocks_total: pool.blocks_total,
            blocks_in_use: pool.blocks_in_use(),
            peak_blocks_in_use: pool.peak_blocks_in_use,
            cow_copies: pool.cow_copies,
            prefix_forks: pool.prefix_forks,
            shared_tokens: pool.shared_tokens,
            shared_bytes: pool.shared_tokens as u64 * token_bytes,
        })
    }

    /// Allocator invariant sweep (the property-test oracle): refcounts
    /// equal live references, free list + live blocks conserve the pool
    /// disjointly, nothing is leaked or double-freed, and no chain holds
    /// more than one partial block. `Ok` on the slot layout (no pool).
    pub fn pool_invariants(&self) -> Result<(), String> {
        let Some(pool) = &self.pool else { return Ok(()) };
        let bt = pool.block_tokens;
        let mut live = vec![0u32; pool.blocks_total];
        for (slot, table) in pool.tables.iter().enumerate() {
            let want = self.lens[slot].div_ceil(bt);
            if table.len() != want {
                return Err(format!(
                    "fragmentation: slot {slot} holds {} blocks for len {} (want {want})",
                    table.len(),
                    self.lens[slot]
                ));
            }
            for &b in table {
                if b >= pool.blocks_total {
                    return Err(format!("slot {slot} maps out-of-pool block {b}"));
                }
                live[b] += 1;
            }
        }
        for (b, (&l, &rc)) in live.iter().zip(&pool.refcount).enumerate() {
            if l != rc {
                return Err(format!("block {b}: refcount {rc} but {l} live references"));
            }
        }
        let mut freed = vec![false; pool.blocks_total];
        for &b in &pool.free {
            if b >= pool.blocks_total {
                return Err(format!("free list holds out-of-pool block {b}"));
            }
            if freed[b] {
                return Err(format!("block {b} double-freed (twice on the free list)"));
            }
            freed[b] = true;
            if live[b] != 0 {
                return Err(format!("block {b} on the free list but {} chains map it", live[b]));
            }
        }
        let distinct_live = live.iter().filter(|c| **c > 0).count();
        if distinct_live + pool.free.len() != pool.blocks_total {
            return Err(format!(
                "pool not conserved: {distinct_live} live + {} free != {} total (leak)",
                pool.free.len(),
                pool.blocks_total
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{check, gen};

    fn cfg() -> LlamaConfig {
        LlamaConfig::tiny()
    }

    /// Small geometry for allocator-focused tests: 2 layers, kv_dim 8.
    fn small_cfg() -> LlamaConfig {
        let mut c = LlamaConfig::tiny();
        c.n_layers = 2;
        c.d_model = 8;
        c.n_heads = 2;
        c.n_kv_heads = 2;
        c.max_seq_len = 32;
        c
    }

    /// Stamped write of one position across all layers, then advance:
    /// lane values are `stamp + layer/4` for K and the negation for V.
    fn put(kv: &mut KvCache, c: &LlamaConfig, slot: usize, pos: usize, stamp: f32) {
        for l in 0..c.n_layers {
            let val = stamp + l as f32 * 0.25;
            let kvec = vec![val; kv.kv_dim];
            let vvec = vec![-val; kv.kv_dim];
            kv.write_slot(l, slot, pos, &kvec, &vvec);
        }
        kv.advance_slot(slot, pos);
    }

    #[test]
    fn write_read_roundtrip() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let dim = kv.kv_dim;
        let kvec: Vec<f32> = (0..dim).map(|i| i as f32).collect();
        let vvec: Vec<f32> = (0..dim).map(|i| -(i as f32)).collect();
        kv.write(2, 0, &kvec, &vvec);
        kv.advance(0);
        assert_eq!(kv.k_at(2, 0), &kvec[..]);
        assert_eq!(kv.v_at(2, 0), &vvec[..]);
        assert_eq!(kv.len(), 1);
    }

    #[test]
    fn bytes_track_eq3_with_f32() {
        // eq 3 with data_byte=4: len · head_dim · n_layers · n_kv_heads · 4 · 2
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let z = vec![0f32; kv.kv_dim];
        for pos in 0..5 {
            for l in 0..c.n_layers {
                kv.write(l, pos, &z, &z);
            }
            kv.advance(pos);
        }
        let expect = 5 * c.head_dim() * c.n_layers * c.n_kv_heads * 4 * 2;
        assert_eq!(kv.bytes_in_use(), expect as u64);
    }

    #[test]
    fn reset_clears_len_not_capacity() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let z = vec![0f32; kv.kv_dim];
        kv.write(0, 0, &z, &z);
        kv.advance(0);
        let cap = kv.capacity_bytes();
        kv.reset();
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.capacity_bytes(), cap);
        assert_eq!(kv.pool_stats().unwrap().blocks_in_use, 0, "reset frees the chain");
    }

    #[test]
    #[should_panic(expected = "kv cache overflow")]
    fn overflow_panics() {
        let c = cfg();
        let mut kv = KvCache::new(&c);
        let z = vec![0f32; kv.kv_dim];
        kv.write(0, c.max_seq_len, &z, &z);
    }

    #[test]
    fn slots_are_independent() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 3);
        let dim = kv.kv_dim;
        for s in 0..3usize {
            let kvec: Vec<f32> = (0..dim).map(|i| (s * 1000 + i) as f32).collect();
            let vvec: Vec<f32> = (0..dim).map(|i| -((s * 1000 + i) as f32)).collect();
            kv.write_slot(1, s, 0, &kvec, &vvec);
            kv.advance_slot(s, 0);
        }
        for s in 0..3usize {
            assert_eq!(kv.k_slot_at(1, s, 0)[1], (s * 1000 + 1) as f32);
            assert_eq!(kv.v_slot_at(1, s, 0)[1], -((s * 1000 + 1) as f32));
        }
    }

    #[test]
    fn slots_advance_independently_and_sum_bytes() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        let z = vec![0f32; kv.kv_dim];
        for pos in 0..3 {
            for l in 0..c.n_layers {
                kv.write_slot(l, 0, pos, &z, &z);
            }
            kv.advance_slot(0, pos);
        }
        for l in 0..c.n_layers {
            kv.write_slot(l, 1, 0, &z, &z);
        }
        kv.advance_slot(1, 0);
        assert_eq!(kv.slot_len(0), 3);
        assert_eq!(kv.slot_len(1), 1);
        let per_pos = (c.head_dim() * c.n_layers * c.n_kv_heads * 4 * 2) as u64;
        assert_eq!(kv.bytes_in_use(), 4 * per_pos);
    }

    #[test]
    fn batched_capacity_scales() {
        let c = cfg();
        let b1 = KvCache::new(&c).capacity_bytes();
        let b4 = KvCache::new_batched(&c, 4).capacity_bytes();
        assert_eq!(b4, 4 * b1);
    }

    /// The slot-release regression (serve-loop satellite): releasing a
    /// slot must zero *its* length only; the freed slot then reports zero
    /// bytes in use while its neighbors keep their cache.
    #[test]
    fn reset_slot_zeroes_only_that_slot() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 3);
        let z = vec![0f32; kv.kv_dim];
        for s in 0..3usize {
            for pos in 0..(s + 1) {
                for l in 0..c.n_layers {
                    kv.write_slot(l, s, pos, &z, &z);
                }
                kv.advance_slot(s, pos);
            }
        }
        assert_eq!([kv.slot_len(0), kv.slot_len(1), kv.slot_len(2)], [1, 2, 3]);
        kv.reset_slot(1);
        assert_eq!([kv.slot_len(0), kv.slot_len(1), kv.slot_len(2)], [1, 0, 3]);
        assert_eq!(kv.slot_bytes_in_use(1), 0);
        let per_pos = (c.head_dim() * c.n_layers * c.n_kv_heads * 4 * 2) as u64;
        assert_eq!(kv.slot_bytes_in_use(0), per_pos);
        assert_eq!(kv.slot_bytes_in_use(2), 3 * per_pos);
        assert_eq!(kv.bytes_in_use(), 4 * per_pos);
    }

    /// The chat-reuse primitive: truncating pins the reused prefix
    /// length without touching neighbors, the truncated positions'
    /// storage stays intact (it is length, not data, that gates
    /// attention), and extending is a programming error.
    #[test]
    fn truncate_slot_pins_prefix_and_keeps_neighbors() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        let z = vec![0f32; kv.kv_dim];
        for slot in 0..2usize {
            for pos in 0..4 {
                for l in 0..c.n_layers {
                    kv.write_slot(l, slot, pos, &z, &z);
                }
                kv.advance_slot(slot, pos);
            }
        }
        kv.truncate_slot(0, 2);
        assert_eq!(kv.slot_len(0), 2);
        assert_eq!(kv.slot_len(1), 4, "neighbor untouched");
        let per_pos = (c.head_dim() * c.n_layers * c.n_kv_heads * 4 * 2) as u64;
        assert_eq!(kv.slot_bytes_in_use(0), 2 * per_pos);
        // Truncating to the current length is a no-op; to zero == reset.
        kv.truncate_slot(0, 2);
        assert_eq!(kv.slot_len(0), 2);
        kv.truncate_slot(0, 0);
        assert_eq!(kv.slot_len(0), 0);
    }

    #[test]
    #[should_panic(expected = "kv truncate cannot extend")]
    fn truncate_cannot_extend() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        let z = vec![0f32; kv.kv_dim];
        for l in 0..c.n_layers {
            kv.write_slot(l, 0, 0, &z, &z);
        }
        kv.advance_slot(0, 0);
        kv.truncate_slot(0, 2);
    }

    #[test]
    #[should_panic(expected = "kv cache slot")]
    fn truncate_out_of_range_slot_panics() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        kv.truncate_slot(2, 0);
    }

    #[test]
    #[should_panic(expected = "kv cache slot")]
    fn reset_out_of_range_slot_panics() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        kv.reset_slot(2);
    }

    #[test]
    #[should_panic(expected = "kv cache slot")]
    fn out_of_range_slot_panics() {
        let c = cfg();
        let mut kv = KvCache::new_batched(&c, 2);
        let z = vec![0f32; kv.kv_dim];
        kv.write_slot(0, 2, 0, &z, &z);
    }

    // ------------------------------------------------ paged allocator

    /// Both layouts must read back exactly what was written, report the
    /// same logical bytes, and (with `max_seq` a block multiple) hold
    /// the same capacity — the storage-level parity baseline.
    #[test]
    fn slot_and_paged_layouts_read_back_identically() {
        let c = small_cfg();
        let mut paged =
            KvCache::new_batched_layout(&c, 2, KvLayout::Paged { block_tokens: 4 });
        let mut slot = KvCache::new_batched_layout(&c, 2, KvLayout::Slot);
        assert_eq!(paged.layout(), KvLayout::Paged { block_tokens: 4 });
        assert_eq!(slot.layout(), KvLayout::Slot);
        for s in 0..2usize {
            for pos in 0..(7 + s) {
                put(&mut paged, &c, s, pos, (s * 100 + pos) as f32);
                put(&mut slot, &c, s, pos, (s * 100 + pos) as f32);
            }
        }
        for s in 0..2usize {
            assert_eq!(paged.slot_len(s), slot.slot_len(s));
            for l in 0..c.n_layers {
                for pos in 0..paged.slot_len(s) {
                    assert_eq!(paged.k_slot_at(l, s, pos), slot.k_slot_at(l, s, pos));
                    assert_eq!(paged.v_slot_at(l, s, pos), slot.v_slot_at(l, s, pos));
                }
            }
        }
        assert_eq!(paged.bytes_in_use(), slot.bytes_in_use());
        assert_eq!(paged.capacity_bytes(), slot.capacity_bytes());
        paged.pool_invariants().unwrap();
        assert!(slot.pool_stats().is_none());
    }

    /// Fork shares blocks without allocating; a write past the shared
    /// prefix — by either chain — copies only the affected block, and
    /// the chains stay bitwise independent afterward.
    #[test]
    fn fork_shares_blocks_and_copy_on_write_isolates_chains() {
        let c = small_cfg();
        let mut kv = KvCache::new_batched_layout(&c, 2, KvLayout::Paged { block_tokens: 4 });
        for pos in 0..6 {
            put(&mut kv, &c, 0, pos, pos as f32);
        }
        let before = kv.pool_stats().unwrap();
        assert_eq!(before.blocks_in_use, 2, "6 positions at bt=4 span 2 blocks");
        kv.fork_slot(0, 1, 6);
        kv.pool_invariants().unwrap();
        let shared = kv.pool_stats().unwrap();
        assert_eq!(shared.blocks_in_use, 2, "fork must not allocate");
        assert_eq!(shared.prefix_forks, 1);
        assert_eq!(shared.shared_tokens, 6);
        assert_eq!(
            shared.shared_bytes,
            6 * (c.n_layers * kv.kv_dim * 4 * 2) as u64
        );
        assert_eq!(kv.slot_len(1), 6);
        for l in 0..c.n_layers {
            for pos in 0..6 {
                assert_eq!(kv.k_slot_at(l, 1, pos), kv.k_slot_at(l, 0, pos).to_vec());
            }
        }
        // Fork target extends: its shared tail block is copied on write.
        put(&mut kv, &c, 1, 6, 60.0);
        kv.pool_invariants().unwrap();
        let after = kv.pool_stats().unwrap();
        assert_eq!(after.cow_copies, 1);
        assert_eq!(after.blocks_in_use, 3, "cow adds exactly one block");
        // The donor's view of the shared positions is untouched…
        for l in 0..c.n_layers {
            for pos in 0..6 {
                let want = pos as f32 + l as f32 * 0.25;
                assert_eq!(kv.k_slot_at(l, 0, pos), &vec![want; kv.kv_dim][..]);
                // …and the copy carried them over for the fork too.
                assert_eq!(kv.k_slot_at(l, 1, pos), &vec![want; kv.kv_dim][..]);
            }
            assert_eq!(kv.k_slot_at(l, 1, 6), &vec![60.0 + l as f32 * 0.25; kv.kv_dim][..]);
        }
        // The donor's tail block is private again: extending it is
        // in-place, no further copy.
        put(&mut kv, &c, 0, 6, 70.0);
        let fin = kv.pool_stats().unwrap();
        assert_eq!(fin.cow_copies, 1, "private block must not cow");
        assert_eq!(fin.blocks_in_use, 3);
        for l in 0..c.n_layers {
            assert_eq!(kv.k_slot_at(l, 0, 6), &vec![70.0 + l as f32 * 0.25; kv.kv_dim][..]);
            assert_eq!(kv.k_slot_at(l, 1, 6), &vec![60.0 + l as f32 * 0.25; kv.kv_dim][..]);
        }
        kv.pool_invariants().unwrap();
    }

    /// Truncation returns whole blocks to the free list and keeps at
    /// most one partial tail block; release returns the whole chain.
    #[test]
    fn truncate_and_reset_return_blocks_to_the_pool() {
        let c = small_cfg();
        let mut kv = KvCache::new_batched_layout(&c, 2, KvLayout::Paged { block_tokens: 4 });
        for pos in 0..10 {
            put(&mut kv, &c, 0, pos, pos as f32);
        }
        for pos in 0..4 {
            put(&mut kv, &c, 1, pos, (100 + pos) as f32);
        }
        assert_eq!(kv.pool_stats().unwrap().blocks_in_use, 4, "3 + 1 blocks");
        kv.truncate_slot(0, 5);
        kv.pool_invariants().unwrap();
        assert_eq!(kv.pool_stats().unwrap().blocks_in_use, 3, "partial tail stays");
        kv.truncate_slot(0, 4);
        assert_eq!(kv.pool_stats().unwrap().blocks_in_use, 2, "whole-block boundary");
        kv.reset_slot(0);
        kv.pool_invariants().unwrap();
        let st = kv.pool_stats().unwrap();
        assert_eq!(st.blocks_in_use, 1, "only slot 1's chain remains");
        assert_eq!(st.peak_blocks_in_use, 4, "peak is sticky");
        // Freed blocks are reused: refilling cannot grow the peak.
        for pos in 0..10 {
            put(&mut kv, &c, 0, pos, pos as f32);
        }
        assert_eq!(kv.pool_stats().unwrap().peak_blocks_in_use, 4);
        kv.pool_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "kv fork requires the paged layout")]
    fn fork_rejects_slot_layout() {
        let c = small_cfg();
        let mut kv = KvCache::new_batched_layout(&c, 2, KvLayout::Slot);
        kv.fork_slot(0, 1, 0);
    }

    #[test]
    #[should_panic(expected = "kv fork target slot")]
    fn fork_rejects_nonempty_target() {
        let c = small_cfg();
        let mut kv = KvCache::new_batched_layout(&c, 2, KvLayout::Paged { block_tokens: 4 });
        put(&mut kv, &c, 0, 0, 1.0);
        put(&mut kv, &c, 1, 0, 2.0);
        kv.fork_slot(0, 1, 1);
    }

    #[test]
    #[should_panic(expected = "kv fork cannot extend")]
    fn fork_cannot_extend_past_source() {
        let c = small_cfg();
        let mut kv = KvCache::new_batched_layout(&c, 2, KvLayout::Paged { block_tokens: 4 });
        put(&mut kv, &c, 0, 0, 1.0);
        kv.fork_slot(0, 1, 2);
    }

    /// The allocator property suite (ISSUE 6 headline satellite): random
    /// seeded op sequences — extend / fork-CoW / truncate / release —
    /// against the paged cache, with a plain value model as the oracle.
    /// After every op the allocator invariants hold (refcounts == live
    /// references, free + live conserve the pool, ≤ 1 partial block per
    /// chain) and every cached position reads back its modeled value;
    /// after all slots retire the pool is fully free (no leaks).
    #[test]
    fn prop_paged_allocator_invariants_and_cow_parity() {
        #[derive(Clone, Copy, Debug)]
        enum KvOp {
            Extend { slot: usize, tokens: usize },
            Fork { src: usize, dst: usize, frac: f32 },
            Truncate { slot: usize, frac: f32 },
            Release { slot: usize },
        }
        check("paged-kv allocator", |rng, _| {
            let mut c = small_cfg();
            c.n_layers = gen::usize_in(rng, 1, 3);
            c.max_seq_len = 8 * gen::usize_in(rng, 2, 6);
            let bt = *rng.choose(&[1usize, 3, 4, 8]);
            let batch = gen::usize_in(rng, 2, 4);
            let mut kv =
                KvCache::new_batched_layout(&c, batch, KvLayout::Paged { block_tokens: bt });
            // Oracle: the stamp written at each (slot, pos).
            let mut model: Vec<Vec<f32>> = vec![Vec::new(); batch];
            let mut stamp = 0f32;
            let ops = gen::op_sequence(rng, 60, &[5, 2, 2, 1], |rng, arm| match arm {
                0 => KvOp::Extend {
                    slot: gen::usize_in(rng, 0, batch - 1),
                    tokens: gen::usize_in(rng, 1, 2 * bt),
                },
                1 => KvOp::Fork {
                    src: gen::usize_in(rng, 0, batch - 1),
                    dst: gen::usize_in(rng, 0, batch - 1),
                    frac: rng.next_f32(),
                },
                2 => KvOp::Truncate {
                    slot: gen::usize_in(rng, 0, batch - 1),
                    frac: rng.next_f32(),
                },
                _ => KvOp::Release { slot: gen::usize_in(rng, 0, batch - 1) },
            });
            for op in ops {
                match op {
                    KvOp::Extend { slot, tokens } => {
                        for _ in 0..tokens {
                            let pos = model[slot].len();
                            if pos == c.max_seq_len {
                                break;
                            }
                            stamp += 1.0;
                            put(&mut kv, &c, slot, pos, stamp);
                            model[slot].push(stamp);
                        }
                    }
                    KvOp::Fork { src, dst, frac } => {
                        if src == dst {
                            continue;
                        }
                        kv.reset_slot(dst);
                        model[dst].clear();
                        let len = (frac * (model[src].len() + 1) as f32) as usize;
                        let len = len.min(model[src].len());
                        kv.fork_slot(src, dst, len);
                        model[dst] = model[src][..len].to_vec();
                    }
                    KvOp::Truncate { slot, frac } => {
                        let len = (frac * (model[slot].len() + 1) as f32) as usize;
                        let len = len.min(model[slot].len());
                        kv.truncate_slot(slot, len);
                        model[slot].truncate(len);
                    }
                    KvOp::Release { slot } => {
                        kv.reset_slot(slot);
                        model[slot].clear();
                    }
                }
                kv.pool_invariants().map_err(|e| format!("{op:?}: {e}"))?;
                for s in 0..batch {
                    if kv.slot_len(s) != model[s].len() {
                        return Err(format!(
                            "{op:?}: slot {s} len {} != model {}",
                            kv.slot_len(s),
                            model[s].len()
                        ));
                    }
                    for (pos, &want) in model[s].iter().enumerate() {
                        for l in 0..c.n_layers {
                            let w = want + l as f32 * 0.25;
                            if kv.k_slot_at(l, s, pos)[0] != w
                                || kv.v_slot_at(l, s, pos)[0] != -w
                            {
                                return Err(format!(
                                    "{op:?}: slot {s} layer {l} pos {pos} lost its value"
                                ));
                            }
                        }
                    }
                }
            }
            // Retire everything: a clean pool proves no block leaked.
            for s in 0..batch {
                kv.reset_slot(s);
            }
            kv.pool_invariants()?;
            let st = kv.pool_stats().unwrap();
            if st.blocks_in_use != 0 {
                return Err(format!("{} blocks leaked after all slots retired", st.blocks_in_use));
            }
            Ok(())
        });
    }
}
