//! Graph layer (paper Fig 2, middle): LLM implementation + operators +
//! KV-cache optimization, plus the generation drivers that the
//! coordinator's `run_inference` step calls — [`generate`] for one
//! sequence, [`generate_batch`] for `B` sequences sharing each weight
//! pass (the batched path behind the `--batch-sizes` sweep).

pub mod engine;
pub mod kv;
pub mod sampler;

pub use engine::{Engine, StepTraffic};
pub use kv::{KvCache, KvLayout, KvPoolStats, KV_BLOCK_TOKENS};
pub use sampler::Sampler;

use std::time::Instant;

use anyhow::Result;

/// Everything one generation run observed — the raw material for the
/// metrics engine (throughput, TTFT, TPOT, MBU traffic terms).
#[derive(Clone, Debug)]
pub struct GenStats {
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub tokens: Vec<u32>,
    /// Wall time of the prefill phase (drives TTFT).
    pub prefill_secs: f64,
    /// Wall time of each decode step.
    pub decode_secs: Vec<f64>,
    /// Bytes moved per decode step (weights + KV), from the engine ledger.
    pub decode_traffic: Vec<StepTraffic>,
    /// FLOPs per decode step.
    pub decode_flops: Vec<f64>,
}

impl GenStats {
    pub fn total_decode_secs(&self) -> f64 {
        self.decode_secs.iter().sum()
    }

    /// tokens/s over the decode phase (the paper's throughput metric).
    pub fn decode_throughput(&self) -> f64 {
        let t = self.total_decode_secs();
        if t <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / t
        }
    }

    /// Mean seconds per output token (TPOT; MBU's denominator).
    pub fn tpot_secs(&self) -> f64 {
        if self.generated_tokens == 0 {
            0.0
        } else {
            self.total_decode_secs() / self.generated_tokens as f64
        }
    }
}

/// Run prompt prefill + `max_new` decode steps with timing and traffic
/// accounting. The engine's cache is reset first.
pub fn generate(
    engine: &mut Engine,
    prompt: &[u32],
    max_new: usize,
    sampler: &mut Sampler,
) -> Result<GenStats> {
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    engine.reset();

    // elib-lint: allow(wall-clock, reason = "host-side prefill timing is this function's product, not a priced quantity")
    let t0 = Instant::now();
    let mut logits: Vec<f32> = Vec::new();
    for (i, t) in prompt.iter().enumerate() {
        logits = engine.forward(*t, i)?.to_vec();
    }
    let prefill_secs = t0.elapsed().as_secs_f64();

    let mut tokens = prompt.to_vec();
    let mut decode_secs = Vec::with_capacity(max_new);
    let mut decode_traffic = Vec::with_capacity(max_new);
    let mut decode_flops = Vec::with_capacity(max_new);
    for step in 0..max_new {
        let next = sampler.sample(&logits, &tokens);
        let pos = prompt.len() + step;
        if pos >= engine.config().max_seq_len {
            break;
        }
        // elib-lint: allow(wall-clock, reason = "host-side decode-step timing is this function's product, not a priced quantity")
        let t = Instant::now();
        logits = engine.forward(next, pos)?.to_vec();
        decode_secs.push(t.elapsed().as_secs_f64());
        decode_traffic.push(engine.step_traffic());
        decode_flops.push(engine.step_flops());
        tokens.push(next);
    }

    Ok(GenStats {
        prompt_tokens: prompt.len(),
        generated_tokens: tokens.len() - prompt.len(),
        tokens,
        prefill_secs,
        decode_secs,
        decode_traffic,
        decode_flops,
    })
}

/// What one *batched* generation run observed. Traffic entries are
/// whole-step ledgers (weights charged once per step, KV per slot), so
/// `bytes_per_token` falls as the batch amortizes the weight stream —
/// the measured counterpart of the paper's batch-aware MBU.
#[derive(Clone, Debug)]
pub struct BatchGenStats {
    pub batch: usize,
    /// Prompt length per sequence (all slots share it).
    pub prompt_tokens: usize,
    /// Tokens generated across *all* slots.
    pub generated_tokens: usize,
    pub sequences: Vec<Vec<u32>>,
    pub prefill_secs: f64,
    /// Wall time of each batched decode step.
    pub decode_secs: Vec<f64>,
    /// Bytes moved per batched step (weights once + all slots' KV).
    pub decode_traffic: Vec<StepTraffic>,
    /// FLOPs per batched step (summed over slots).
    pub decode_flops: Vec<f64>,
}

impl BatchGenStats {
    pub fn total_decode_secs(&self) -> f64 {
        self.decode_secs.iter().sum()
    }

    /// Aggregate tokens/s over the decode phase (all slots together).
    pub fn decode_throughput(&self) -> f64 {
        let t = self.total_decode_secs();
        if t <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / t
        }
    }

    /// Mean seconds per generated token (TPOT; MBU's denominator).
    pub fn tpot_secs(&self) -> f64 {
        if self.generated_tokens == 0 {
            0.0
        } else {
            self.total_decode_secs() / self.generated_tokens as f64
        }
    }

    /// Measured bytes moved per generated token, from the step ledgers.
    pub fn bytes_per_token(&self) -> u64 {
        self.decode_traffic
            .iter()
            .map(|t| t.total())
            .sum::<u64>()
            .checked_div(self.generated_tokens as u64)
            .unwrap_or(0)
    }
}

/// Run batched prefill + `max_new` batched decode steps with timing and
/// traffic accounting. All prompts must have the same length (they march
/// through the weight passes in lockstep); the engine's cache is reset
/// first. With `Sampler::Greedy` each slot's output equals an independent
/// [`generate`] run of the same prompt (stateful samplers draw in slot
/// order instead).
pub fn generate_batch(
    engine: &mut Engine,
    prompts: &[Vec<u32>],
    max_new: usize,
    sampler: &mut Sampler,
) -> Result<BatchGenStats> {
    let b = engine.batch();
    anyhow::ensure!(prompts.len() == b, "need {b} prompts, got {}", prompts.len());
    let plen = prompts[0].len();
    anyhow::ensure!(plen > 0, "empty prompt");
    anyhow::ensure!(
        prompts.iter().all(|p| p.len() == plen),
        "all prompts must share one length (got {:?})",
        prompts.iter().map(Vec::len).collect::<Vec<_>>()
    );
    engine.reset();
    let vocab = engine.config().vocab_size;

    // elib-lint: allow(wall-clock, reason = "host-side batch-prefill timing is this function's product, not a priced quantity")
    let t0 = Instant::now();
    let mut step_tokens = vec![0u32; b];
    let mut logits: Vec<f32> = Vec::new();
    for i in 0..plen {
        for (s, prompt) in prompts.iter().enumerate() {
            step_tokens[s] = prompt[i];
        }
        logits = engine.forward_batch(&step_tokens)?.to_vec();
    }
    let prefill_secs = t0.elapsed().as_secs_f64();

    let mut sequences: Vec<Vec<u32>> = prompts.to_vec();
    let mut decode_secs = Vec::with_capacity(max_new);
    let mut decode_traffic = Vec::with_capacity(max_new);
    let mut decode_flops = Vec::with_capacity(max_new);
    for step in 0..max_new {
        let pos = plen + step;
        if pos >= engine.config().max_seq_len {
            break;
        }
        for s in 0..b {
            step_tokens[s] = sampler.sample(&logits[s * vocab..(s + 1) * vocab], &sequences[s]);
        }
        // elib-lint: allow(wall-clock, reason = "host-side batch-decode timing is this function's product, not a priced quantity")
        let t = Instant::now();
        logits = engine.forward_batch(&step_tokens)?.to_vec();
        decode_secs.push(t.elapsed().as_secs_f64());
        decode_traffic.push(engine.step_traffic());
        decode_flops.push(engine.step_flops());
        for s in 0..b {
            sequences[s].push(step_tokens[s]);
        }
    }

    let generated: usize = sequences.iter().map(|s| s.len() - plen).sum();
    Ok(BatchGenStats {
        batch: b,
        prompt_tokens: plen,
        generated_tokens: generated,
        sequences,
        prefill_secs,
        decode_secs,
        decode_traffic,
        decode_flops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::BackendKind;
    use crate::model::testutil::random_model_file;
    use crate::model::ModelWeights;
    use crate::quant::QuantType;

    fn mk_engine() -> Engine {
        let mf = random_model_file(QuantType::Q8_0, 77);
        Engine::new(ModelWeights::load(&mf).unwrap(), BackendKind::Naive)
    }

    #[test]
    fn generate_produces_requested_tokens() {
        let mut e = mk_engine();
        let prompt = [1u32, 2, 3, 4];
        let stats = generate(&mut e, &prompt, 8, &mut Sampler::Greedy).unwrap();
        assert_eq!(stats.prompt_tokens, 4);
        assert_eq!(stats.generated_tokens, 8);
        assert_eq!(stats.tokens.len(), 12);
        assert_eq!(stats.decode_secs.len(), 8);
        assert!(stats.decode_throughput() > 0.0);
        assert!(stats.tpot_secs() > 0.0);
    }

    #[test]
    fn generate_is_deterministic_with_greedy() {
        let mut e1 = mk_engine();
        let mut e2 = mk_engine();
        let s1 = generate(&mut e1, &[5, 6, 7], 6, &mut Sampler::Greedy).unwrap();
        let s2 = generate(&mut e2, &[5, 6, 7], 6, &mut Sampler::Greedy).unwrap();
        assert_eq!(s1.tokens, s2.tokens);
    }

    #[test]
    fn generate_stops_at_context_limit() {
        let mut e = mk_engine();
        let max = e.config().max_seq_len;
        let prompt: Vec<u32> = (0..max as u32 - 2).map(|i| i % 256).collect();
        let stats = generate(&mut e, &prompt, 50, &mut Sampler::Greedy).unwrap();
        assert_eq!(stats.tokens.len(), max, "must clamp to max_seq_len");
    }

    #[test]
    fn traffic_recorded_per_step() {
        let mut e = mk_engine();
        let stats = generate(&mut e, &[9, 9], 4, &mut Sampler::Greedy).unwrap();
        assert_eq!(stats.decode_traffic.len(), 4);
        assert!(stats.decode_traffic[0].weight_bytes > 0);
        // KV read grows monotonically with position.
        for w in stats.decode_traffic.windows(2) {
            assert!(w[1].kv_read_bytes >= w[0].kv_read_bytes);
        }
    }

    fn mk_batched(batch: usize) -> Engine {
        let mf = random_model_file(QuantType::Q8_0, 77);
        Engine::new_batched(ModelWeights::load(&mf).unwrap(), BackendKind::Naive, batch)
    }

    #[test]
    fn generate_batch_produces_requested_tokens() {
        let mut e = mk_batched(3);
        let prompts = vec![vec![1u32, 2, 3], vec![4, 5, 6], vec![7, 8, 9]];
        let stats = generate_batch(&mut e, &prompts, 5, &mut Sampler::Greedy).unwrap();
        assert_eq!(stats.batch, 3);
        assert_eq!(stats.prompt_tokens, 3);
        assert_eq!(stats.generated_tokens, 15);
        assert_eq!(stats.decode_secs.len(), 5);
        for s in &stats.sequences {
            assert_eq!(s.len(), 8);
        }
        assert!(stats.decode_throughput() > 0.0);
        assert!(stats.bytes_per_token() > 0);
    }

    #[test]
    fn generate_batch_greedy_matches_sequential_generate() {
        let mut single = mk_engine();
        let seq = generate(&mut single, &[5, 6, 7], 6, &mut Sampler::Greedy).unwrap();
        let mut batched = mk_batched(2);
        let prompts = vec![vec![5u32, 6, 7], vec![5, 6, 7]];
        let bat = generate_batch(&mut batched, &prompts, 6, &mut Sampler::Greedy).unwrap();
        assert_eq!(bat.sequences[0], seq.tokens);
        assert_eq!(bat.sequences[1], seq.tokens);
    }

    #[test]
    fn generate_batch_rejects_ragged_prompts() {
        let mut e = mk_batched(2);
        let prompts = vec![vec![1u32, 2], vec![3u32]];
        assert!(generate_batch(&mut e, &prompts, 2, &mut Sampler::Greedy).is_err());
    }

    #[test]
    fn batched_bytes_per_token_strictly_lower() {
        // The acceptance-criterion shape: same model/backend, batch 4 moves
        // strictly fewer bytes per generated token than batch 1.
        let mut e1 = mk_batched(1);
        let prompts1 = vec![vec![3u32, 1, 4]];
        let s1 = generate_batch(&mut e1, &prompts1, 6, &mut Sampler::Greedy).unwrap();
        let mut e4 = mk_batched(4);
        let prompts4 = vec![vec![3u32, 1, 4]; 4];
        let s4 = generate_batch(&mut e4, &prompts4, 6, &mut Sampler::Greedy).unwrap();
        assert!(
            s4.bytes_per_token() < s1.bytes_per_token(),
            "batch 4 {} !< batch 1 {}",
            s4.bytes_per_token(),
            s1.bytes_per_token()
        );
    }

    #[test]
    fn generate_batch_stops_at_context_limit() {
        let mut e = mk_batched(2);
        let max = e.config().max_seq_len;
        let prompt: Vec<u32> = (0..max as u32 - 2).map(|i| i % 256).collect();
        let prompts = vec![prompt.clone(), prompt];
        let stats = generate_batch(&mut e, &prompts, 50, &mut Sampler::Greedy).unwrap();
        for s in &stats.sequences {
            assert_eq!(s.len(), max, "must clamp to max_seq_len");
        }
    }
}
