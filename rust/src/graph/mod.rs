//! Graph layer (paper Fig 2, middle): LLM implementation + operators +
//! KV-cache optimization, plus the generation driver that the
//! coordinator's `run_inference` step calls.

pub mod engine;
pub mod kv;
pub mod sampler;

pub use engine::{Engine, StepTraffic};
pub use kv::KvCache;
pub use sampler::Sampler;

use std::time::Instant;

use anyhow::Result;

/// Everything one generation run observed — the raw material for the
/// metrics engine (throughput, TTFT, TPOT, MBU traffic terms).
#[derive(Clone, Debug)]
pub struct GenStats {
    pub prompt_tokens: usize,
    pub generated_tokens: usize,
    pub tokens: Vec<u32>,
    /// Wall time of the prefill phase (drives TTFT).
    pub prefill_secs: f64,
    /// Wall time of each decode step.
    pub decode_secs: Vec<f64>,
    /// Bytes moved per decode step (weights + KV), from the engine ledger.
    pub decode_traffic: Vec<StepTraffic>,
    /// FLOPs per decode step.
    pub decode_flops: Vec<f64>,
}

impl GenStats {
    pub fn total_decode_secs(&self) -> f64 {
        self.decode_secs.iter().sum()
    }

    /// tokens/s over the decode phase (the paper's throughput metric).
    pub fn decode_throughput(&self) -> f64 {
        let t = self.total_decode_secs();
        if t <= 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / t
        }
    }

    /// Mean seconds per output token (TPOT; MBU's denominator).
    pub fn tpot_secs(&self) -> f64 {
        if self.generated_tokens == 0 {
            0.0
        } else {
            self.total_decode_secs() / self.generated_tokens as f64
        }
    }
}

/// Run prompt prefill + `max_new` decode steps with timing and traffic
/// accounting. The engine's cache is reset first.
pub fn generate(
    engine: &mut Engine,
    prompt: &[u32],
    max_new: usize,
    sampler: &mut Sampler,
) -> Result<GenStats> {
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    engine.reset();

    let t0 = Instant::now();
    let mut logits: Vec<f32> = Vec::new();
    for (i, t) in prompt.iter().enumerate() {
        logits = engine.forward(*t, i)?.to_vec();
    }
    let prefill_secs = t0.elapsed().as_secs_f64();

    let mut tokens = prompt.to_vec();
    let mut decode_secs = Vec::with_capacity(max_new);
    let mut decode_traffic = Vec::with_capacity(max_new);
    let mut decode_flops = Vec::with_capacity(max_new);
    for step in 0..max_new {
        let next = sampler.sample(&logits, &tokens);
        let pos = prompt.len() + step;
        if pos >= engine.config().max_seq_len {
            break;
        }
        let t = Instant::now();
        logits = engine.forward(next, pos)?.to_vec();
        decode_secs.push(t.elapsed().as_secs_f64());
        decode_traffic.push(engine.step_traffic());
        decode_flops.push(engine.step_flops());
        tokens.push(next);
    }

    Ok(GenStats {
        prompt_tokens: prompt.len(),
        generated_tokens: tokens.len() - prompt.len(),
        tokens,
        prefill_secs,
        decode_secs,
        decode_traffic,
        decode_flops,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::BackendKind;
    use crate::model::testutil::random_model_file;
    use crate::model::ModelWeights;
    use crate::quant::QuantType;

    fn mk_engine() -> Engine {
        let mf = random_model_file(QuantType::Q8_0, 77);
        Engine::new(ModelWeights::load(&mf).unwrap(), BackendKind::Naive)
    }

    #[test]
    fn generate_produces_requested_tokens() {
        let mut e = mk_engine();
        let prompt = [1u32, 2, 3, 4];
        let stats = generate(&mut e, &prompt, 8, &mut Sampler::Greedy).unwrap();
        assert_eq!(stats.prompt_tokens, 4);
        assert_eq!(stats.generated_tokens, 8);
        assert_eq!(stats.tokens.len(), 12);
        assert_eq!(stats.decode_secs.len(), 8);
        assert!(stats.decode_throughput() > 0.0);
        assert!(stats.tpot_secs() > 0.0);
    }

    #[test]
    fn generate_is_deterministic_with_greedy() {
        let mut e1 = mk_engine();
        let mut e2 = mk_engine();
        let s1 = generate(&mut e1, &[5, 6, 7], 6, &mut Sampler::Greedy).unwrap();
        let s2 = generate(&mut e2, &[5, 6, 7], 6, &mut Sampler::Greedy).unwrap();
        assert_eq!(s1.tokens, s2.tokens);
    }

    #[test]
    fn generate_stops_at_context_limit() {
        let mut e = mk_engine();
        let max = e.config().max_seq_len;
        let prompt: Vec<u32> = (0..max as u32 - 2).map(|i| i % 256).collect();
        let stats = generate(&mut e, &prompt, 50, &mut Sampler::Greedy).unwrap();
        assert_eq!(stats.tokens.len(), max, "must clamp to max_seq_len");
    }

    #[test]
    fn traffic_recorded_per_step() {
        let mut e = mk_engine();
        let stats = generate(&mut e, &[9, 9], 4, &mut Sampler::Greedy).unwrap();
        assert_eq!(stats.decode_traffic.len(), 4);
        assert!(stats.decode_traffic[0].weight_bytes > 0);
        // KV read grows monotonically with position.
        for w in stats.decode_traffic.windows(2) {
            assert!(w[1].kv_read_bytes >= w[0].kv_read_bytes);
        }
    }
}
