//! Token sampling strategies (greedy / temperature / top-k), seeded for
//! reproducible generation. The benchmark parameters `top_k` and
//! `repeat_last_n` mirror Algorithm 1's `benchmark_params`.

use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub enum Sampler {
    /// argmax — deterministic, used by benchmarks so runs are comparable.
    Greedy,
    /// temperature + top-k with an optional repetition penalty window.
    TopK {
        k: usize,
        temperature: f32,
        repeat_last_n: usize,
        repeat_penalty: f32,
        rng: Rng,
    },
}

impl Sampler {
    pub fn top_k(k: usize, temperature: f32, seed: u64) -> Self {
        Sampler::TopK {
            k,
            temperature,
            repeat_last_n: 64,
            repeat_penalty: 1.1,
            rng: Rng::new(seed),
        }
    }

    pub fn sample(&mut self, logits: &[f32], history: &[u32]) -> u32 {
        match self {
            Sampler::Greedy => argmax(logits),
            Sampler::TopK {
                k,
                temperature,
                repeat_last_n,
                repeat_penalty,
                rng,
            } => {
                let mut adjusted: Vec<f32> = logits.to_vec();
                // Repetition penalty over the trailing window.
                let start = history.len().saturating_sub(*repeat_last_n);
                for &t in &history[start..] {
                    let v = &mut adjusted[t as usize];
                    if *v > 0.0 {
                        *v /= *repeat_penalty;
                    } else {
                        *v *= *repeat_penalty;
                    }
                }
                let temp = temperature.max(1e-3);
                // Top-k indices by logit.
                let mut idx: Vec<usize> = (0..adjusted.len()).collect();
                let kk = (*k).clamp(1, adjusted.len());
                idx.select_nth_unstable_by(kk - 1, |a, b| {
                    adjusted[*b].partial_cmp(&adjusted[*a]).unwrap()
                });
                idx.truncate(kk);
                // Softmax over survivors.
                let max = idx.iter().map(|i| adjusted[*i]).fold(f32::NEG_INFINITY, f32::max);
                let mut probs: Vec<f32> = idx
                    .iter()
                    .map(|i| ((adjusted[*i] - max) / temp).exp())
                    .collect();
                let sum: f32 = probs.iter().sum();
                for p in &mut probs {
                    *p /= sum;
                }
                // Inverse-CDF draw.
                let r = rng.next_f32();
                let mut acc = 0f32;
                for (i, p) in idx.iter().zip(&probs) {
                    acc += p;
                    if r <= acc {
                        return *i as u32;
                    }
                }
                *idx.last().unwrap() as u32
            }
        }
    }
}

pub fn argmax(logits: &[f32]) -> u32 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, v) in logits.iter().enumerate() {
        if *v > bv {
            bv = *v;
            best = i;
        }
    }
    best as u32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1f32, 3.0, -1.0, 2.9];
        assert_eq!(Sampler::Greedy.sample(&logits, &[]), 1);
    }

    #[test]
    fn topk_only_samples_top_k() {
        let mut s = Sampler::top_k(2, 1.0, 7);
        let logits = vec![10.0f32, 9.5, -50.0, -50.0];
        for _ in 0..50 {
            let t = s.sample(&logits, &[]);
            assert!(t == 0 || t == 1, "sampled {t}");
        }
    }

    #[test]
    fn seeded_sampling_is_deterministic() {
        let logits: Vec<f32> = (0..16).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut a = Sampler::top_k(8, 0.9, 42);
        let mut b = Sampler::top_k(8, 0.9, 42);
        for _ in 0..20 {
            assert_eq!(a.sample(&logits, &[]), b.sample(&logits, &[]));
        }
    }

    #[test]
    fn repetition_penalty_reduces_repeats() {
        let mut with = Sampler::top_k(4, 0.7, 3);
        if let Sampler::TopK { repeat_penalty, .. } = &mut with {
            *repeat_penalty = 5.0; // aggressive for test signal
        }
        let logits = vec![2.0f32, 1.9, 1.8, 1.7];
        let history = vec![0u32; 32]; // token 0 heavily repeated
        let mut zero_count = 0;
        for _ in 0..100 {
            if with.sample(&logits, &history) == 0 {
                zero_count += 1;
            }
        }
        assert!(zero_count < 50, "penalty ineffective: {zero_count}/100");
    }
}
