//! Graph layer: the tiny-LLaMA forward pass over the kernel layer
//! (paper Fig 2: "the implementation of certain LLMs, the abstraction of
//! tensor library, basic algorithm operators, and the KV cache
//! optimization system").
//!
//! The decode loop is allocation-free: all scratch buffers are
//! pre-allocated at engine construction, the KV cache is pre-allocated
//! (see [`super::kv::KvCache`]), and weights are streamed through the
//! kernel layer's quantized dot products. The engine also *accounts* its
//! own memory traffic per token, which is what the MBU metric consumes.

use anyhow::Result;

use crate::kernel::{BackendKind, Dispatcher};
use crate::model::{LlamaConfig, ModelWeights};
use crate::quant::blocks::dequantize_row;
use crate::tensor;

use super::kv::KvCache;

/// Byte-traffic ledger for one forward step (feeds MBU).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTraffic {
    pub weight_bytes: u64,
    pub kv_read_bytes: u64,
    pub kv_write_bytes: u64,
}

impl StepTraffic {
    pub fn total(&self) -> u64 {
        self.weight_bytes + self.kv_read_bytes + self.kv_write_bytes
    }
}

/// The native inference engine.
pub struct Engine {
    pub weights: ModelWeights,
    pub kernels: Dispatcher,
    pub cache: KvCache,
    cfg: LlamaConfig,
    // pre-allocated scratch (decode loop never allocates)
    x: Vec<f32>,
    xn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn_out: Vec<f32>,
    proj_out: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    ffn_out: Vec<f32>,
    scores: Vec<f32>,
    logits: Vec<f32>,
    emb_row: Vec<f32>,
}

impl Engine {
    pub fn new(weights: ModelWeights, backend: BackendKind) -> Self {
        let cfg = weights.config;
        let kv_dim = cfg.n_kv_heads * cfg.head_dim();
        Self {
            cache: KvCache::new(&cfg),
            kernels: Dispatcher::new(backend),
            x: vec![0.0; cfg.d_model],
            xn: vec![0.0; cfg.d_model],
            q: vec![0.0; cfg.d_model],
            k: vec![0.0; kv_dim],
            v: vec![0.0; kv_dim],
            attn_out: vec![0.0; cfg.d_model],
            proj_out: vec![0.0; cfg.d_model],
            gate: vec![0.0; cfg.d_ff],
            up: vec![0.0; cfg.d_ff],
            ffn_out: vec![0.0; cfg.d_model],
            scores: vec![0.0; cfg.max_seq_len],
            logits: vec![0.0; cfg.vocab_size],
            emb_row: vec![0.0; cfg.d_model],
            cfg,
            weights,
        }
    }

    pub fn config(&self) -> &LlamaConfig {
        &self.cfg
    }

    pub fn reset(&mut self) {
        self.cache.reset();
    }

    /// Run one token through the model at position `pos`; returns logits.
    /// `pos` must equal the current cache length (causal order).
    pub fn forward(&mut self, token: u32, pos: usize) -> Result<&[f32]> {
        anyhow::ensure!(
            pos == self.cache.len(),
            "forward out of order: pos {pos}, cache len {}",
            self.cache.len()
        );
        anyhow::ensure!(pos < self.cfg.max_seq_len, "context overflow at pos {pos}");
        anyhow::ensure!(
            (token as usize) < self.cfg.vocab_size,
            "token {token} out of vocab"
        );
        let cfg = self.cfg;
        let hd = cfg.head_dim();
        let kv_dim = cfg.n_kv_heads * hd;
        let heads_per_kv = cfg.n_heads / cfg.n_kv_heads;

        // Embedding lookup (dequantize one row).
        dequantize_row(
            self.weights.tok_emb.qtype,
            self.weights.tok_emb.row(token as usize),
            &mut self.emb_row,
        );
        self.x.copy_from_slice(&self.emb_row);

        for l in 0..cfg.n_layers {
            // --- attention block -----------------------------------
            self.xn.copy_from_slice(&self.x);
            {
                let lw = &self.weights.layers[l];
                self.kernels.rmsnorm(&mut self.xn, &lw.attn_norm, cfg.norm_eps);
                self.kernels.qmatvec(&lw.wq, &self.xn, &mut self.q);
                self.kernels.qmatvec(&lw.wk, &self.xn, &mut self.k);
                self.kernels.qmatvec(&lw.wv, &self.xn, &mut self.v);
            }
            // RoPE on q (per head) and k (per kv head).
            for h in 0..cfg.n_heads {
                self.kernels
                    .rope(&mut self.q[h * hd..(h + 1) * hd], pos, cfg.rope_theta);
            }
            for h in 0..cfg.n_kv_heads {
                self.kernels
                    .rope(&mut self.k[h * hd..(h + 1) * hd], pos, cfg.rope_theta);
            }
            self.cache.write(l, pos, &self.k, &self.v);

            // Attention: per head over cache positions 0..=pos.
            let scale = 1.0 / (hd as f32).sqrt();
            self.attn_out.iter_mut().for_each(|v| *v = 0.0);
            for h in 0..cfg.n_heads {
                let kvh = h / heads_per_kv;
                let qh = &self.q[h * hd..(h + 1) * hd];
                let scores = &mut self.scores[..pos + 1];
                for (p, s) in scores.iter_mut().enumerate() {
                    let kp = self.cache.k_at(l, p);
                    // During this token, pos isn't advanced yet; read our
                    // own k from scratch.
                    let krow: &[f32] = if p == pos {
                        &self.k[kvh * hd..(kvh + 1) * hd]
                    } else {
                        &kp[kvh * hd..(kvh + 1) * hd]
                    };
                    *s = qh.iter().zip(krow).map(|(a, b)| a * b).sum::<f32>() * scale;
                }
                self.kernels.softmax(scores);
                let out = &mut self.attn_out[h * hd..(h + 1) * hd];
                for p in 0..=pos {
                    let w = self.scores[p];
                    if w == 0.0 {
                        continue;
                    }
                    let vrow: &[f32] = if p == pos {
                        &self.v[kvh * hd..(kvh + 1) * hd]
                    } else {
                        &self.cache.v_at(l, p)[kvh * hd..(kvh + 1) * hd]
                    };
                    for (o, vv) in out.iter_mut().zip(vrow) {
                        *o += w * vv;
                    }
                }
            }
            {
                let lw = &self.weights.layers[l];
                self.kernels.qmatvec(&lw.wo, &self.attn_out, &mut self.proj_out);
            }
            tensor::vec_add_inplace(&mut self.x, &self.proj_out);

            // --- SwiGLU MLP -----------------------------------------
            self.xn.copy_from_slice(&self.x);
            {
                let lw = &self.weights.layers[l];
                self.kernels.rmsnorm(&mut self.xn, &lw.ffn_norm, cfg.norm_eps);
                self.kernels.qmatvec(&lw.w1, &self.xn, &mut self.gate);
                self.kernels.qmatvec(&lw.w3, &self.xn, &mut self.up);
            }
            tensor::silu_inplace(&mut self.gate);
            tensor::vec_mul_inplace(&mut self.gate, &self.up);
            {
                let lw = &self.weights.layers[l];
                self.kernels.qmatvec(&lw.w2, &self.gate, &mut self.ffn_out);
            }
            tensor::vec_add_inplace(&mut self.x, &self.ffn_out);
            let _ = kv_dim;
        }
        self.cache.advance(pos);

        // Final norm + lm head.
        self.xn.copy_from_slice(&self.x);
        self.kernels
            .rmsnorm(&mut self.xn, &self.weights.out_norm.clone(), cfg.norm_eps);
        self.kernels
            .qmatvec(&self.weights.lm_head, &self.xn, &mut self.logits);
        Ok(&self.logits)
    }

    /// Byte traffic of one decode step at the *current* cache length.
    pub fn step_traffic(&self) -> StepTraffic {
        StepTraffic {
            weight_bytes: self.weights.bytes_per_token(),
            kv_read_bytes: self.cache.bytes_read_per_step(),
            kv_write_bytes: (self.cache.kv_dim * self.cache.n_layers * 4 * 2) as u64,
        }
    }

    /// FLOPs of one decode step (2·params for matmuls + attention terms).
    pub fn step_flops(&self) -> f64 {
        let c = &self.cfg;
        let d = c.d_model as f64;
        let kv_dim = (c.n_kv_heads * c.head_dim()) as f64;
        let per_layer = 2.0 * (d * d        // wq
            + d * kv_dim                    // wk
            + d * kv_dim                    // wv
            + d * d                         // wo
            + 3.0 * d * c.d_ff as f64)      // w1,w2,w3
            + 4.0 * self.cache.len().max(1) as f64 * d; // attn scores+mix
        c.n_layers as f64 * per_layer + 2.0 * d * c.vocab_size as f64
    }

    /// Sum of negative log-likelihoods of `tokens[1..]` given prefixes,
    /// plus the token count — the perplexity building block. Sequences
    /// longer than the context window are evaluated in non-overlapping
    /// windows (cache reset between them), the standard strided ppl
    /// protocol.
    pub fn sequence_nll(&mut self, tokens: &[u32]) -> Result<(f64, usize)> {
        anyhow::ensure!(tokens.len() >= 2, "need at least 2 tokens for NLL");
        let window = self.cfg.max_seq_len;
        let mut nll = 0.0;
        let mut count = 0usize;
        for chunk in tokens.chunks(window) {
            if chunk.len() < 2 {
                break;
            }
            self.reset();
            for i in 0..chunk.len() - 1 {
                let logits = self.forward(chunk[i], i)?;
                nll -= tensor::log_softmax_at(logits, chunk[i + 1] as usize);
                count += 1;
            }
        }
        Ok((nll, count))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::random_model_file;
    use crate::model::ModelWeights;
    use crate::quant::QuantType;

    fn engine(q: QuantType, backend: BackendKind) -> Engine {
        let mf = random_model_file(q, 1234);
        Engine::new(ModelWeights::load(&mf).unwrap(), backend)
    }

    #[test]
    fn forward_produces_finite_logits() {
        let mut e = engine(QuantType::F32, BackendKind::Naive);
        let logits = e.forward(42, 0).unwrap();
        assert_eq!(logits.len(), 256);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn forward_enforces_causal_order() {
        let mut e = engine(QuantType::F32, BackendKind::Naive);
        e.forward(1, 0).unwrap();
        assert!(e.forward(2, 5).is_err(), "skipping positions must fail");
    }

    #[test]
    fn context_overflow_is_an_error_not_a_crash() {
        let mut e = engine(QuantType::Q8_0, BackendKind::Naive);
        let max = e.config().max_seq_len;
        for p in 0..max {
            e.forward(7, p).unwrap();
        }
        assert!(e.forward(7, max).is_err());
    }

    #[test]
    fn deterministic_across_runs() {
        let mut e1 = engine(QuantType::Q4_0, BackendKind::Naive);
        let mut e2 = engine(QuantType::Q4_0, BackendKind::Naive);
        let a: Vec<f32> = e1.forward(5, 0).unwrap().to_vec();
        let b: Vec<f32> = e2.forward(5, 0).unwrap().to_vec();
        assert_eq!(a, b);
    }

    #[test]
    fn backends_agree_on_logits() {
        let mut naive = engine(QuantType::Q5_1, BackendKind::Naive);
        let mut par = engine(QuantType::Q5_1, BackendKind::Parallel(4));
        let toks = [10u32, 200, 33, 7];
        let mut la = vec![];
        let mut lb = vec![];
        for (i, t) in toks.iter().enumerate() {
            la = naive.forward(*t, i).unwrap().to_vec();
            lb = par.forward(*t, i).unwrap().to_vec();
        }
        let d = crate::util::stats::max_abs_diff(&la, &lb);
        assert!(d < 1e-4, "naive vs parallel logits differ by {d}");
    }

    #[test]
    fn quantization_perturbs_but_preserves_scale() {
        let mut f32e = engine(QuantType::F32, BackendKind::Naive);
        let mut q4e = engine(QuantType::Q4_0, BackendKind::Naive);
        let a: Vec<f32> = f32e.forward(9, 0).unwrap().to_vec();
        let b: Vec<f32> = q4e.forward(9, 0).unwrap().to_vec();
        let diff = crate::util::stats::max_abs_diff(&a, &b);
        assert!(diff > 0.0, "q4_0 must differ from f32");
        let scale = a.iter().fold(0f32, |m, v| m.max(v.abs())).max(1e-6);
        assert!(diff / scale < 1.0, "q4_0 logits unrecognizable: {diff} vs {scale}");
    }

    #[test]
    fn nll_is_positive_and_near_uniform_for_random_weights() {
        let mut e = engine(QuantType::F32, BackendKind::Naive);
        let toks: Vec<u32> = (0..32).map(|i| (i * 7 + 13) % 256).collect();
        let (nll, n) = e.sequence_nll(&toks).unwrap();
        assert_eq!(n, 31);
        let ppl = (nll / n as f64).exp();
        // Untrained random model ≈ uniform over 256 tokens.
        assert!((100.0..600.0).contains(&ppl), "ppl {ppl}");
    }

    #[test]
    fn traffic_grows_with_cache() {
        let mut e = engine(QuantType::Q4_0, BackendKind::Naive);
        e.forward(1, 0).unwrap();
        let t1 = e.step_traffic();
        for p in 1..10 {
            e.forward(1, p).unwrap();
        }
        let t10 = e.step_traffic();
        assert_eq!(t1.weight_bytes, t10.weight_bytes);
        assert!(t10.kv_read_bytes > t1.kv_read_bytes);
    }
}
